"""L2 model contracts: shapes, grad coverage, pallas/jnp flavor parity,
inherent sparsity of NCF embedding gradients, and trainability."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def init_params(specs, rng):
    out = []
    for s in specs:
        if s.init_std < 0:  # layer-norm gains
            out.append(jnp.ones(s.shape, jnp.float32))
        elif s.init_std == 0:
            out.append(jnp.zeros(s.shape, jnp.float32))
        else:
            out.append(jnp.asarray(rng.standard_normal(s.shape, dtype=np.float32) * s.init_std))
    return out


def test_mlp_shapes_and_grads():
    cfg = M.MlpConfig(input_dim=48, hidden=(16, 8), classes=4, batch=8)
    specs = M.mlp_specs(cfg)
    rng = np.random.default_rng(0)
    params = init_params(specs, rng)
    x = jnp.asarray(rng.standard_normal((8, 48), dtype=np.float32))
    y = jnp.asarray(rng.integers(0, 4, 8), dtype=jnp.int32)
    loss, acc, grads = M.mlp_train_step(params, x, y, cfg)
    assert loss.shape == () and 0.0 <= float(acc) <= 1.0
    assert len(grads) == len(params)
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert np.isfinite(np.asarray(g)).all()


def test_mlp_param_count_matches_resnet20_standin():
    cfg = M.MlpConfig()
    total = sum(int(np.prod(s.shape)) for s in M.mlp_specs(cfg))
    # ResNet-20 has 269,722 params (paper Table 1); stand-in within 10%
    assert abs(total - 269_722) / 269_722 < 0.10, total


def test_mlp_trains_on_separable_data():
    cfg = M.MlpConfig(input_dim=16, hidden=(16,), classes=2, batch=64)
    specs = M.mlp_specs(cfg)
    rng = np.random.default_rng(1)
    params = init_params(specs, rng)
    step = jax.jit(lambda p, x, y: M.mlp_train_step(p, x, y, cfg))
    losses = []
    for i in range(60):
        x = rng.standard_normal((64, 16), dtype=np.float32)
        y = (x[:, 0] > 0).astype(np.int32)
        loss, _, grads = step(params, jnp.asarray(x), jnp.asarray(y))
        params = [p - 0.5 * g for p, g in zip(params, grads)]
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, losses[::10]


def test_ncf_embedding_grads_inherently_sparse():
    cfg = M.NcfConfig(users=500, items=400, dim=8, hidden=(16, 8), batch=64)
    specs = M.ncf_specs(cfg)
    rng = np.random.default_rng(2)
    params = init_params(specs, rng)
    users = jnp.asarray(rng.integers(0, 500, 64), dtype=jnp.int32)
    items = jnp.asarray(rng.integers(0, 400, 64), dtype=jnp.int32)
    labels = jnp.asarray(rng.integers(0, 2, 64), dtype=jnp.float32)
    loss, hit, grads = M.ncf_train_step(params, users, items, labels, cfg)
    assert np.isfinite(float(loss))
    # user-embedding grad rows: only batch users nonzero ("inherently
    # sparse", paper §6.3 — NCF grads are ~40%+ zeros)
    ug = np.asarray(grads[0])
    nz_rows = np.unique(np.nonzero(np.abs(ug).sum(axis=1))[0])
    assert set(nz_rows).issubset(set(np.asarray(users).tolist()))
    frac_zero = (ug == 0).mean()
    assert frac_zero > 0.8, frac_zero


def test_transformer_shapes_and_loss():
    cfg = M.TransformerConfig()
    specs = M.transformer_specs(cfg)
    rng = np.random.default_rng(3)
    params = init_params(specs, rng)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)), dtype=jnp.int32)
    targets = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)), dtype=jnp.int32)
    loss, _, grads = M.transformer_train_step(params, tokens, targets, cfg)
    # random init: loss near ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0, float(loss)
    assert len(grads) == len(specs)
    for g, s in zip(grads, specs):
        assert g.shape == tuple(s.shape), s.name


def test_pallas_flavor_matches_jnp_flavor():
    # identical params/batch -> identical loss+grads across kernel flavors
    base = dict(input_dim=64, hidden=(32,), classes=8, batch=16)
    cfg_ref = M.MlpConfig(**base, use_pallas=False)
    cfg_pls = M.MlpConfig(**base, use_pallas=True)
    specs = M.mlp_specs(cfg_ref)
    rng = np.random.default_rng(4)
    params = init_params(specs, rng)
    x = jnp.asarray(rng.standard_normal((16, 64), dtype=np.float32))
    y = jnp.asarray(rng.integers(0, 8, 16), dtype=jnp.int32)
    l1, a1, g1 = M.mlp_train_step(params, x, y, cfg_ref)
    l2, a2, g2 = M.mlp_train_step(params, x, y, cfg_pls)
    assert_allclose(float(l1), float(l2), rtol=1e-5)
    for a, b in zip(g1, g2):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=1e-5)


def test_e2e_config_param_count():
    cfg = M.TransformerConfig(**M.E2E)
    total = sum(int(np.prod(s.shape)) for s in M.transformer_specs(cfg))
    assert 20_000_000 < total < 40_000_000, total
