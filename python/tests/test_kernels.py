"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes; assert_allclose is THE core correctness signal
for the kernel layer (system prompt contract).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import attention as K_attn
from compile.kernels import fitpoly as K_fitpoly
from compile.kernels import fused_linear as K_linear
from compile.kernels import qsgd as K_qsgd
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------- linear


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 96),
    n=st.integers(1, 48),
    act=st.sampled_from(["none", "relu", "gelu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    got = K_linear.fused_linear(x, w, b, act=act)
    want = ref.linear(x, w, b, act=act)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_fused_linear_tiled_path():
    # shapes that force multi-step grids in every dimension
    rng = np.random.default_rng(0)
    x, w, b = rand(rng, 256, 384), rand(rng, 384, 256), rand(rng, 256)
    got = K_linear.fused_linear(x, w, b, act="relu")
    want = ref.linear(x, w, b, act="relu")
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_vmem_footprint_under_budget():
    # default tiles must fit a 16 MiB VMEM with ample headroom
    assert K_linear.vmem_footprint_bytes() < 4 * 2**20


# -------------------------------------------------------------- attention


@settings(max_examples=15, deadline=None)
@given(
    t_blocks=st.integers(1, 4),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(t_blocks, d, seed):
    t = 16 * t_blocks
    rng = np.random.default_rng(seed)
    q, k, v = rand(rng, t, d), rand(rng, t, d), rand(rng, t, d)
    got = K_attn.attention(q, k, v, bq=16, bkv=16)
    want = ref.attention(q, k, v)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_attention_causality():
    # future tokens must not influence earlier outputs
    rng = np.random.default_rng(1)
    t, d = 32, 16
    q, k, v = rand(rng, t, d), rand(rng, t, d), rand(rng, t, d)
    base = np.asarray(K_attn.attention(q, k, v))
    k2, v2 = k.copy(), v.copy()
    k2[-1] += 100.0
    v2[-1] -= 50.0
    pert = np.asarray(K_attn.attention(q, k2, v2))
    assert_allclose(base[: t - 1], pert[: t - 1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(base[-1], pert[-1])


# ---------------------------------------------------------------- fitpoly


@settings(max_examples=10, deadline=None)
@given(
    segs=st.integers(1, 6),
    seg_len=st.sampled_from([8, 32, 64]),
    degree=st.integers(0, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_fitpoly_normal_eqs_match_ref(segs, seg_len, degree, seed):
    rng = np.random.default_rng(seed)
    y = rand(rng, segs, seg_len)
    lens = rng.integers(degree + 1, seg_len + 1, size=segs)
    mask = (np.arange(seg_len)[None, :] < lens[:, None]).astype(np.float32)
    x0 = rng.integers(0, 1000, size=segs).astype(np.float32)
    xtx_k, xty_k = K_fitpoly.fitpoly_normal_eqs(y, mask, x0, degree)
    xtx_r, xty_r = ref.fitpoly_normal_eqs(y, mask, x0, degree)
    assert_allclose(np.asarray(xtx_k), np.asarray(xtx_r), rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(xty_k), np.asarray(xty_r), rtol=1e-4, atol=1e-4)


def test_fitpoly_solve_recovers_polynomial():
    # exact quadratic data -> solved coefficients reproduce the values
    seg_len = 64
    x0 = np.array([100.0], dtype=np.float32)
    pos = x0[0] + np.arange(seg_len)
    y = (0.5 * pos**2 - 3 * pos + 2).astype(np.float32)[None, :] / 1e4
    mask = np.ones((1, seg_len), dtype=np.float32)
    coeffs = np.asarray(K_fitpoly.fitpoly_solve(y, mask, x0, degree=2))  # [1, 3]
    mid, half = pos[0] + (seg_len - 1) / 2, (seg_len - 1) / 2
    t = (pos - mid) / half
    recon = sum(coeffs[0, j] * t**j for j in range(3))
    assert_allclose(recon, y[0], rtol=1e-3, atol=1e-5)


# ------------------------------------------------------------------ qsgd


@settings(max_examples=15, deadline=None)
@given(
    nb=st.integers(1, 6),
    bucket=st.sampled_from([16, 64, 128]),
    bits=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_qsgd_kernel_matches_ref(nb, bucket, bits, seed):
    rng = np.random.default_rng(seed)
    n = nb * bucket
    values = rand(rng, n)
    randoms = rng.random(n).astype(np.float32)
    levels_k, signs_k, maxs_k = K_qsgd.qsgd_quantize(values, randoms, bucket, bits)
    maxs_ref = np.abs(values.reshape(nb, bucket)).max(axis=1)
    per_elem_max = np.repeat(maxs_ref, bucket)
    levels_r, signs_r = ref.qsgd_quantize(values, randoms, per_elem_max, bits)
    assert_allclose(np.asarray(maxs_k), maxs_ref, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(levels_k), np.asarray(levels_r))
    np.testing.assert_array_equal(np.asarray(signs_k), np.asarray(signs_r))


def test_qsgd_unbiased_reconstruction():
    # E[level/s * max * sign] = value across the random draw
    n, bucket, bits = 128, 128, 4
    rng = np.random.default_rng(3)
    values = rand(rng, n)
    s = 2**bits - 1
    acc = np.zeros(n)
    trials = 300
    for _ in range(trials):
        randoms = rng.random(n).astype(np.float32)
        levels, signs, maxs = K_qsgd.qsgd_quantize(values, randoms, bucket, bits)
        acc += np.asarray(levels) / s * maxs[0] * np.asarray(signs)
    est = acc / trials
    err = np.abs(est - values).max() / np.abs(values).max()
    assert err < 0.1, err
