"""AOT contract: HLO text is parseable-looking, manifests are complete
and consistent, and a lowered artifact executes correctly when compiled
back through XLA (python-side sanity; the rust integration test does the
same through PJRT-from-rust)."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def test_mlp_artifact_roundtrip(tmp_path):
    aot.build_mlp(str(tmp_path), name="mlp_test", input_dim=32, hidden=(16,), classes=4, batch=8)
    hlo = (tmp_path / "mlp_test.hlo.txt").read_text()
    man = json.loads((tmp_path / "mlp_test.manifest.json").read_text())
    assert hlo.startswith("HloModule"), hlo[:50]
    assert man["kind"] == "train_step"
    assert man["outputs"][0] == "loss"
    assert len(man["outputs"]) == 2 + len(man["params"])
    # inputs carry dtypes the rust side dispatches on
    assert man["inputs"][0]["dtype"] == "float32"
    assert man["inputs"][1]["dtype"] == "int32"
    # parameter count consistency
    cfg = M.MlpConfig(input_dim=32, hidden=(16,), classes=4, batch=8)
    assert [p["name"] for p in man["params"]] == [s.name for s in M.mlp_specs(cfg)]


def test_hlo_text_recompiles_and_executes(tmp_path):
    """Lower a tiny pallas-flavor model, re-parse the HLO text, execute via
    xla_client, and compare against direct jax execution."""
    from jax._src.lib import xla_client as xc

    cfg = M.MlpConfig(input_dim=16, hidden=(8,), classes=4, batch=4, use_pallas=True)
    specs = M.mlp_specs(cfg)

    def flat_fn(*args):
        params = list(args[: len(specs)])
        x, y = args[len(specs) :]
        loss, acc, grads = M.mlp_train_step(params, x, y, cfg)
        return (loss, acc, *grads)

    rng = np.random.default_rng(0)
    params = [
        (rng.standard_normal(s.shape) * max(s.init_std, 0.0)).astype(np.float32) for s in specs
    ]
    x = rng.standard_normal((4, 16)).astype(np.float32)
    y = rng.integers(0, 4, 4).astype(np.int32)

    lowered = jax.jit(flat_fn).lower(
        *[jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params],
        jax.ShapeDtypeStruct(x.shape, x.dtype),
        jax.ShapeDtypeStruct(y.shape, y.dtype),
    )
    text = aot.to_hlo_text(lowered)

    # the text must re-parse as a valid HLO module (what the rust loader
    # does via HloModuleProto::from_text_file — the id-reassigning path)
    mod = xc._xla.hlo_module_from_text(text)
    assert "f32" in mod.to_string()

    # the lowered computation must execute and match eager evaluation
    want = flat_fn(*params, x, y)
    got = lowered.compile()(*params, x, y)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=5e-4, atol=1e-5)


def test_fitpoly_and_qsgd_artifacts(tmp_path):
    aot.build_fitpoly(str(tmp_path), segs=2, seg_len=16, degree=2)
    aot.build_qsgd(str(tmp_path), n=64, bucket=32, bits=4)
    for name in ["fitpoly", "qsgd"]:
        man = json.loads((tmp_path / f"{name}.manifest.json").read_text())
        assert man["kind"] == "kernel"
        hlo = (tmp_path / f"{name}.hlo.txt").read_text()
        assert hlo.startswith("HloModule")
