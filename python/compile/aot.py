"""AOT lowering: JAX (L2) + Pallas (L1) → HLO **text** artifacts + JSON
manifests, consumed by the rust runtime (`rust/src/runtime`).

HLO text — NOT ``lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()``
— is the interchange format: jax ≥ 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the `xla`
crate) rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Run once via ``make artifacts``; never on the training path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import fitpoly as K_fitpoly
from .kernels import qsgd as K_qsgd


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _write(out_dir, name, hlo_text, manifest):
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    man_path = os.path.join(out_dir, f"{name}.manifest.json")
    with open(hlo_path, "w") as f:
        f.write(hlo_text)
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  {name}: {len(hlo_text) / 1e6:.2f} MB HLO, {len(manifest['params'])} params")


# --------------------------------------------------------------------------
# model train-step artifacts
# --------------------------------------------------------------------------


def build_model(name, cfg, specs, step_fn, inputs, out_dir):
    """Lower fn(*params, *batch) -> (loss, aux, *grads)."""
    nparams = len(specs)

    def flat_fn(*args):
        params = list(args[:nparams])
        batch = args[nparams:]
        loss, aux, grads = step_fn(params, *batch, cfg)
        return (loss, aux, *grads)

    param_specs = [_spec(s.shape) for s in specs]
    input_specs = [_spec(shape, dtype) for _, shape, dtype in inputs]
    lowered = jax.jit(flat_fn).lower(*param_specs, *input_specs)
    manifest = {
        "name": name,
        "kind": "train_step",
        "params": [s.to_json() for s in specs],
        "inputs": [
            {"name": nm, "shape": list(shape), "dtype": str(jnp.dtype(dt))}
            for nm, shape, dt in inputs
        ],
        "outputs": ["loss", "aux"] + [f"grad_{s.name}" for s in specs],
        "config": {k: (list(v) if isinstance(v, tuple) else v) for k, v in vars(cfg).items()},
    }
    _write(out_dir, name, to_hlo_text(lowered), manifest)


def build_mlp(out_dir, name="mlp", **kw):
    cfg = M.MlpConfig(**kw)
    build_model(
        name,
        cfg,
        M.mlp_specs(cfg),
        M.mlp_train_step,
        [
            ("x", (cfg.batch, cfg.input_dim), jnp.float32),
            ("y", (cfg.batch,), jnp.int32),
        ],
        out_dir,
    )


def build_ncf(out_dir, name="ncf", **kw):
    cfg = M.NcfConfig(**kw)
    build_model(
        name,
        cfg,
        M.ncf_specs(cfg),
        M.ncf_train_step,
        [
            ("users", (cfg.batch,), jnp.int32),
            ("items", (cfg.batch,), jnp.int32),
            ("labels", (cfg.batch,), jnp.float32),
        ],
        out_dir,
    )


def build_transformer(out_dir, name="transformer_small", **kw):
    cfg = M.TransformerConfig(**kw)
    build_model(
        name,
        cfg,
        M.transformer_specs(cfg),
        M.transformer_train_step,
        [
            ("tokens", (cfg.batch, cfg.seq), jnp.int32),
            ("targets", (cfg.batch, cfg.seq), jnp.int32),
        ],
        out_dir,
    )


# --------------------------------------------------------------------------
# kernel artifacts (L1 lowered standalone, pallas flavor)
# --------------------------------------------------------------------------


def build_pallas_smoke(out_dir):
    """Tiny pallas-flavored MLP train step: proves Pallas→HLO→rust-PJRT."""
    build_mlp(
        out_dir,
        name="pallas_smoke",
        input_dim=64,
        hidden=(32,),
        classes=8,
        batch=16,
        use_pallas=True,
    )


def build_fitpoly(out_dir, segs=8, seg_len=512, degree=5):
    def fn(y, mask, x0):
        return (K_fitpoly.fitpoly_solve(y, mask, x0, degree),)

    lowered = jax.jit(fn).lower(
        _spec((segs, seg_len)), _spec((segs, seg_len)), _spec((segs,))
    )
    manifest = {
        "name": "fitpoly",
        "kind": "kernel",
        "params": [],
        "inputs": [
            {"name": "y", "shape": [segs, seg_len], "dtype": "float32"},
            {"name": "mask", "shape": [segs, seg_len], "dtype": "float32"},
            {"name": "x0", "shape": [segs], "dtype": "float32"},
        ],
        "outputs": ["coeffs"],
        "config": {"segs": segs, "seg_len": seg_len, "degree": degree},
    }
    _write(out_dir, "fitpoly", to_hlo_text(lowered), manifest)


def build_qsgd(out_dir, n=4096, bucket=512, bits=7):
    def fn(values, randoms):
        return K_qsgd.qsgd_quantize(values, randoms, bucket, bits)

    lowered = jax.jit(fn).lower(_spec((n,)), _spec((n,)))
    manifest = {
        "name": "qsgd",
        "kind": "kernel",
        "params": [],
        "inputs": [
            {"name": "values", "shape": [n], "dtype": "float32"},
            {"name": "randoms", "shape": [n], "dtype": "float32"},
        ],
        "outputs": ["levels", "signs", "maxs"],
        "config": {"n": n, "bucket": bucket, "bits": bits},
    }
    _write(out_dir, "qsgd", to_hlo_text(lowered), manifest)


BUILDERS = {
    "mlp": lambda o: build_mlp(o),
    "ncf": lambda o: build_ncf(o),
    "transformer_small": lambda o: build_transformer(o),
    "transformer_e2e": lambda o: build_transformer(o, name="transformer_e2e", **M.E2E),
    "transformer_medium": lambda o: build_transformer(o, name="transformer_medium", **M.E2E_MEDIUM),
    "pallas_smoke": build_pallas_smoke,
    "fitpoly": build_fitpoly,
    "qsgd": build_qsgd,
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of artifacts to build")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    names = args.only or list(BUILDERS)
    print(f"lowering {len(names)} artifacts to {args.out_dir}:")
    for name in names:
        BUILDERS[name](args.out_dir)
    # stamp for make
    with open(os.path.join(args.out_dir, ".stamp"), "w") as f:
        f.write("\n".join(names) + "\n")


if __name__ == "__main__":
    main()
