"""L2 models (build-time JAX): the paper's benchmark families as
train-step graphs — an MLP classifier (ResNet-20/CIFAR stand-in, see
DESIGN.md §4), an NCF recommender (inherently-sparse gradients, Table 2)
and a decoder-only transformer LM (the e2e driver).

Every model exposes:
  * ``specs(cfg)``   -> [ParamSpec] (name, shape, init_std) — weights are
    initialized on the rust side from these specs; artifacts carry no data.
  * ``train_step(params, batch) -> (loss, grads)`` — pure function, lowered
    once by aot.py. Python never runs at training time.

Models call the L1 kernels through ``kernels.dispatch(use_pallas)``.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from . import kernels


@dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple
    init_std: float

    def to_json(self):
        return {"name": self.name, "shape": list(self.shape), "init_std": self.init_std}


# --------------------------------------------------------------------------
# MLP classifier (ResNet-20-on-CIFAR stand-in, ~250k params)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class MlpConfig:
    input_dim: int = 3072
    hidden: tuple = (80, 48)
    classes: int = 10
    batch: int = 128
    use_pallas: bool = False


def mlp_specs(cfg: MlpConfig):
    dims = [cfg.input_dim, *cfg.hidden, cfg.classes]
    specs = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        specs.append(ParamSpec(f"w{i}", (a, b), (2.0 / a) ** 0.5))
        specs.append(ParamSpec(f"b{i}", (b,), 0.0))
    return specs


def mlp_loss(params, x, y, cfg: MlpConfig):
    k = kernels.dispatch(cfg.use_pallas)
    h = x
    n_layers = len(cfg.hidden) + 1
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        act = "relu" if i < n_layers - 1 else "none"
        h = k.linear(h, w, b, act=act)
    logits = h
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
    acc = (logits.argmax(axis=-1) == y).mean().astype(jnp.float32)
    return nll, acc


def mlp_train_step(params, x, y, cfg: MlpConfig):
    (loss, acc), grads = jax.value_and_grad(
        lambda p: mlp_loss(p, x, y, cfg), has_aux=True
    )(params)
    return loss, acc, grads


# --------------------------------------------------------------------------
# NCF recommender (He et al. 2017) — embedding tables + MLP tower.
# Embedding gradients are inherently sparse: only the batch's rows are
# nonzero (paper §6.3 "inherently sparse model").
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class NcfConfig:
    users: int = 6000
    items: int = 4000
    dim: int = 16
    hidden: tuple = (32, 16)
    batch: int = 1024
    use_pallas: bool = False


def ncf_specs(cfg: NcfConfig):
    specs = [
        ParamSpec("user_emb", (cfg.users, cfg.dim), 0.05),
        ParamSpec("item_emb", (cfg.items, cfg.dim), 0.05),
    ]
    dims = [2 * cfg.dim, *cfg.hidden, 1]
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        specs.append(ParamSpec(f"w{i}", (a, b), (2.0 / a) ** 0.5))
        specs.append(ParamSpec(f"b{i}", (b,), 0.0))
    return specs


def ncf_loss(params, users, items, labels, cfg: NcfConfig):
    k = kernels.dispatch(cfg.use_pallas)
    ue, ie = params[0], params[1]
    u = ue[users]  # [B, D]
    v = ie[items]
    h = jnp.concatenate([u, v], axis=-1)
    n_layers = len(cfg.hidden) + 1
    for i in range(n_layers):
        w, b = params[2 + 2 * i], params[3 + 2 * i]
        act = "relu" if i < n_layers - 1 else "none"
        h = k.linear(h, w, b, act=act)
    # GMF-style interaction added to the tower logit
    logit = h[:, 0] + (u * v).sum(axis=-1)
    # binary cross-entropy with logits
    loss = jnp.mean(jnp.maximum(logit, 0.0) - logit * labels + jnp.log1p(jnp.exp(-jnp.abs(logit))))
    hit = ((logit > 0.0).astype(jnp.float32) == labels).mean()
    return loss, hit


def ncf_train_step(params, users, items, labels, cfg: NcfConfig):
    (loss, hit), grads = jax.value_and_grad(
        lambda p: ncf_loss(p, users, items, labels, cfg), has_aux=True
    )(params)
    return loss, hit, grads


# --------------------------------------------------------------------------
# Decoder-only transformer LM (the e2e driver model)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class TransformerConfig:
    vocab: int = 512
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    d_ff: int = 128
    seq: int = 32
    batch: int = 2
    use_pallas: bool = False

    @property
    def head_dim(self):
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# e2e configurations (see DESIGN.md §7). FULL is the 27M-parameter
# target; MEDIUM (~5M) is sized so a few hundred steps fit the
# single-core CI testbed — the recorded EXPERIMENTS.md run.
E2E = dict(vocab=8192, d_model=512, n_layers=6, n_heads=8, d_ff=2048, seq=128, batch=4)
E2E_MEDIUM = dict(vocab=4096, d_model=256, n_layers=4, n_heads=4, d_ff=1024, seq=64, batch=4)


def transformer_specs(cfg: TransformerConfig):
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    specs = [
        ParamSpec("tok_emb", (v, d), 0.02),
        ParamSpec("pos_emb", (cfg.seq, d), 0.02),
    ]
    for i in range(cfg.n_layers):
        p = f"l{i}_"
        specs += [
            ParamSpec(p + "ln1_g", (d,), -1.0),  # init_std<0 => init to 1.0
            ParamSpec(p + "ln1_b", (d,), 0.0),
            ParamSpec(p + "wqkv", (d, 3 * d), (2.0 / d) ** 0.5),
            ParamSpec(p + "bqkv", (3 * d,), 0.0),
            ParamSpec(p + "wo", (d, d), (2.0 / d) ** 0.5 / (2 * cfg.n_layers) ** 0.5),
            ParamSpec(p + "bo", (d,), 0.0),
            ParamSpec(p + "ln2_g", (d,), -1.0),
            ParamSpec(p + "ln2_b", (d,), 0.0),
            ParamSpec(p + "wff1", (d, f), (2.0 / d) ** 0.5),
            ParamSpec(p + "bff1", (f,), 0.0),
            ParamSpec(p + "wff2", (f, d), (2.0 / f) ** 0.5 / (2 * cfg.n_layers) ** 0.5),
            ParamSpec(p + "bff2", (d,), 0.0),
        ]
    specs += [
        ParamSpec("lnf_g", (d,), -1.0),
        ParamSpec("lnf_b", (d,), 0.0),
        ParamSpec("head", (d, v), (1.0 / d) ** 0.5),
    ]
    return specs


def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def transformer_loss(params, tokens, targets, cfg: TransformerConfig):
    k = kernels.dispatch(cfg.use_pallas)
    it = iter(params)
    tok_emb = next(it)
    pos_emb = next(it)
    b, t = tokens.shape
    h = tok_emb[tokens] + pos_emb[None, :t, :]
    # per (batch, head) attention over [T, hd] via double vmap
    attn_bh = jax.vmap(jax.vmap(k.attention))
    for _ in range(cfg.n_layers):
        ln1_g, ln1_b = next(it), next(it)
        wqkv, bqkv = next(it), next(it)
        wo, bo = next(it), next(it)
        ln2_g, ln2_b = next(it), next(it)
        wff1, bff1 = next(it), next(it)
        wff2, bff2 = next(it), next(it)

        x = _layer_norm(h, ln1_g, ln1_b)
        qkv = k.linear(x.reshape(b * t, -1), wqkv, bqkv).reshape(b, t, 3 * cfg.d_model)
        q, kk, v = jnp.split(qkv, 3, axis=-1)
        hd = cfg.head_dim
        # [B, H, T, hd]
        split = lambda z: z.reshape(b, t, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        o = attn_bh(split(q), split(kk), split(v))  # [B, H, T, hd]
        o = o.transpose(0, 2, 1, 3).reshape(b * t, cfg.d_model)
        h = h + k.linear(o, wo, bo).reshape(b, t, -1)

        x = _layer_norm(h, ln2_g, ln2_b)
        y1 = k.linear(x.reshape(b * t, -1), wff1, bff1, act="gelu")
        h = h + k.linear(y1, wff2, bff2).reshape(b, t, -1)

    lnf_g, lnf_b = next(it), next(it)
    head = next(it)
    x = _layer_norm(h, lnf_g, lnf_b)
    logits = x.reshape(b * t, -1) @ head  # [B*T, V]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets.reshape(-1)[:, None], axis=-1).mean()
    return nll


def transformer_train_step(params, tokens, targets, cfg: TransformerConfig):
    loss, grads = jax.value_and_grad(lambda p: transformer_loss(p, tokens, targets, cfg))(
        params
    )
    # expose a dummy aux slot so all models share (loss, aux, grads) layout
    return loss, loss, grads
