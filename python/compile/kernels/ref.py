"""Pure-jnp oracles for every Pallas kernel (L1 correctness contract).

pytest checks kernel-vs-ref with `assert_allclose`; the L2 models call the
same functions through `kernels.dispatch`, so the oracle *is* the math the
training artifacts ship with (the Pallas flavor is numerics-identical, see
DESIGN.md §7).
"""

import jax.numpy as jnp


def linear(x, w, b, act="none"):
    """Fused linear layer: act(x @ w + b)."""
    y = x @ w + b
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "gelu":
        # tanh approximation (matches the pallas kernel)
        y = 0.5 * y * (1.0 + jnp.tanh(0.7978845608028654 * (y + 0.044715 * y**3)))
    elif act != "none":
        raise ValueError(f"unknown activation {act}")
    return y


def attention(q, k, v, scale=None):
    """Single-head scaled dot-product attention with causal mask.

    q, k, v: [T, D]. Returns [T, D].
    """
    t, d = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    logits = (q @ k.T) * scale
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    logits = jnp.where(causal, logits, -1e30)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return p @ v


def fitpoly_normal_eqs(y, mask, x0, degree):
    """Per-segment Vandermonde normal equations for Fit-Poly (paper §5).

    y:    [S, L] padded segment values
    mask: [S, L] 1.0 where valid
    x0:   [S]    absolute start position of each segment
    Returns (xtx [S, m, m], xty [S, m]) with m = degree+1, over the
    rescaled domain t = (x - mid)/half per segment (matching
    rust/src/linalg/polyfit.rs).
    """
    s, l = y.shape
    m = degree + 1
    lens = mask.sum(axis=1)  # [S]
    x1 = x0 + jnp.maximum(lens - 1.0, 0.0)
    mid = (x0 + x1) / 2.0
    half = jnp.maximum((x1 - x0) / 2.0, 1.0)
    pos = x0[:, None] + jnp.arange(l, dtype=y.dtype)[None, :]  # [S, L]
    t = (pos - mid[:, None]) / half[:, None]
    # powers [S, L, m]
    powers = t[:, :, None] ** jnp.arange(m, dtype=y.dtype)[None, None, :]
    powers = powers * mask[:, :, None]
    xtx = jnp.einsum("sla,slb->sab", powers, powers)
    xty = jnp.einsum("sla,sl->sa", powers, y * mask)
    return xtx, xty


def qsgd_quantize(values, randoms, max_per_bucket, bits):
    """QSGD stochastic quantization levels (paper §3 plug-in; matches
    rust/src/compress/value/qsgd.rs given the same uniform randoms).

    values:  [N] f32
    randoms: [N] f32 in [0,1)
    max_per_bucket: [N] the bucket's max |v| broadcast per element
    Returns (levels [N] int32, signs [N] int32 in {-1, 1}).
    """
    s = float(2**bits - 1)
    scaled = jnp.where(
        max_per_bucket > 0.0, jnp.abs(values) / max_per_bucket * s, 0.0
    )
    levels = jnp.floor(scaled + randoms).astype(jnp.int32)
    levels = jnp.minimum(levels, jnp.int32(s))
    signs = jnp.where(values < 0.0, -1, 1).astype(jnp.int32)
    return levels, signs
