"""L1 Pallas kernel: blocked causal attention with online softmax.

The GPU flash-attention insight (tile KV, keep running max/denominator)
maps to TPU as: grid = (T/bq, T/bkv) with the KV axis innermost; the
running statistics (m, l) and the output accumulator live in the output
refs across KV steps — VMEM-resident, no HBM round-trips. Causality skips
nothing structurally (whole blocks are masked via the logits), keeping
the schedule static as Mosaic requires.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 64
DEFAULT_BKV = 64


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, nkv, scale, bq, bkv):
    qi = pl.program_id(0)
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...]
    k = k_ref[...]
    logits = (q @ k.T) * scale  # [bq, bkv]
    # causal mask in absolute coordinates
    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], k.shape[0]), 0)
    cols = kj * bkv + jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], k.shape[0]), 1)
    logits = jnp.where(rows >= cols, logits, -1e30)

    m_prev = m_ref[...]  # [bq, 1]
    l_prev = l_ref[...]
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)  # [bq, bkv]
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    o_ref[...] = o_ref[...] * alpha + p @ v_ref[...]
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kj == nkv - 1)
    def _finalize():
        o_ref[...] = o_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def _pick_tile(dim, pref):
    t = min(pref, dim)
    while dim % t != 0:
        t -= 1
    return t


def _pallas_attention(q, k, v, bq=None, bkv=None):
    """Raw kernel invocation (no AD)."""
    t, d = q.shape
    assert k.shape == (t, d) and v.shape == (t, d)
    bq = bq or _pick_tile(t, DEFAULT_BQ)
    bkv = bkv or _pick_tile(t, DEFAULT_BKV)
    scale = 1.0 / float(d) ** 0.5
    out, _m, _l = pl.pallas_call(
        partial(_kernel, nkv=t // bkv, scale=scale, bq=bq, bkv=bkv),
        grid=(t // bq, t // bkv),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bkv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bkv, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), q.dtype),
            jax.ShapeDtypeStruct((t, 1), q.dtype),
            jax.ShapeDtypeStruct((t, 1), q.dtype),
        ],
        interpret=True,
    )(q, k, v)
    return out


# The online-softmax grid kernel carries running statistics across grid
# steps and is not AD-traceable; define the VJP explicitly. Forward runs
# the Pallas kernel; backward uses the standard attention gradient
# (materialized probabilities — fine at build time; a Pallas backward
# kernel is the flash-attention-2 extension documented in DESIGN.md).
@jax.custom_vjp
def _attention_vjp(q, k, v):
    return _pallas_attention(q, k, v)


def _attn_fwd(q, k, v):
    return _pallas_attention(q, k, v), (q, k, v)


def _attn_bwd(res, do):
    q, k, v = res
    t, d = q.shape
    scale = 1.0 / float(d) ** 0.5
    logits = (q @ k.T) * scale
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    logits = jnp.where(causal, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)  # [T, T]
    dv = p.T @ do
    dp = do @ v.T
    # softmax backward: dlogits = p * (dp - rowsum(dp * p))
    dl = p * (dp - (dp * p).sum(axis=-1, keepdims=True))
    dl = jnp.where(causal, dl, 0.0)
    dq = (dl @ k) * scale
    dk = (dl.T @ q) * scale
    return dq, dk, dv


_attention_vjp.defvjp(_attn_fwd, _attn_bwd)


def attention(q, k, v, bq=None, bkv=None):
    """Causal attention, single head (differentiable). [T, D] -> [T, D]."""
    if bq is not None or bkv is not None:
        return _pallas_attention(q, k, v, bq=bq, bkv=bkv)
    return _attention_vjp(q, k, v)
