"""L1 Pallas kernel: QSGD stochastic quantization.

One grid step = one bucket: the bucket's values, the pre-drawn uniform
randoms and the scalar max live in VMEM; the quantization is a pure VPU
(elementwise) computation. Randomness comes in as an input so the kernel
is deterministic and replayable against the rust codec.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(v_ref, r_ref, levels_ref, signs_ref, maxs_ref, *, bits, bucket):
    v = v_ref[...].reshape(bucket)
    r = r_ref[...].reshape(bucket)
    s = float(2**bits - 1)
    mx = jnp.max(jnp.abs(v))
    scaled = jnp.where(mx > 0.0, jnp.abs(v) / mx * s, 0.0)
    levels = jnp.minimum(jnp.floor(scaled + r), s).astype(jnp.int32)
    signs = jnp.where(v < 0.0, -1, 1).astype(jnp.int32)
    levels_ref[...] = levels.reshape(1, bucket)
    signs_ref[...] = signs.reshape(1, bucket)
    maxs_ref[...] = mx.reshape(1, 1)


def qsgd_quantize(values, randoms, bucket, bits):
    """values, randoms: [N] with N divisible by bucket.

    Returns (levels [N] i32, signs [N] i32, maxs [N/bucket] f32).
    """
    n = values.shape[0]
    assert n % bucket == 0, "pad to a bucket multiple before calling"
    nb = n // bucket
    v2 = values.reshape(nb, bucket)
    r2 = randoms.reshape(nb, bucket)
    levels, signs, maxs = pl.pallas_call(
        partial(_kernel, bits=bits, bucket=bucket),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, bucket), lambda i: (i, 0)),
            pl.BlockSpec((1, bucket), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bucket), lambda i: (i, 0)),
            pl.BlockSpec((1, bucket), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bucket), jnp.int32),
            jax.ShapeDtypeStruct((nb, bucket), jnp.int32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=True,
    )(v2, r2)
    return levels.reshape(n), signs.reshape(n), maxs.reshape(nb)
