"""L1 kernels: Pallas implementations + pure-jnp oracles.

`dispatch(use_pallas)` returns the kernel namespace the L2 models build
against. Training artifacts are lowered with the jnp flavor (identical
math, XLA-fusible); the Pallas flavor backs the smoke artifact and the
kernel parity tests — interpret=True is a correctness vehicle on CPU, not
a performance one (DESIGN.md §Hardware-Adaptation).
"""

from . import attention as _attention
from . import fused_linear as _fused_linear
from . import ref


class _PallasKernels:
    linear = staticmethod(_fused_linear.fused_linear)
    attention = staticmethod(_attention.attention)


class _RefKernels:
    linear = staticmethod(ref.linear)
    attention = staticmethod(ref.attention)


def dispatch(use_pallas: bool):
    return _PallasKernels if use_pallas else _RefKernels
