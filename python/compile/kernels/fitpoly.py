"""L1 Pallas kernel: batched Fit-Poly normal equations (paper §5,
"Our GPU implementation uses Least-Square fitting, which can be trivially
expressed with tensor operations").

Each grid step processes one segment: builds the rescaled Vandermonde
powers in VMEM and contracts XᵀX [m×m] and Xᵀy [m] on the MXU. The tiny
(≤6×6) Cholesky solve stays outside the kernel (jnp.linalg.solve in the
surrounding jitted function) — solving 6×6 systems on the MXU wastes the
systolic array.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(y_ref, mask_ref, x0_ref, xtx_ref, xty_ref, *, degree, seg_len):
    m = degree + 1
    y = y_ref[...].reshape(seg_len)  # [L]
    mask = mask_ref[...].reshape(seg_len)
    x0 = x0_ref[0, 0]
    length = mask.sum()
    x1 = x0 + jnp.maximum(length - 1.0, 0.0)
    mid = (x0 + x1) / 2.0
    half = jnp.maximum((x1 - x0) / 2.0, 1.0)
    pos = x0 + jax.lax.iota(y.dtype, seg_len)
    t = (pos - mid) / half
    powers = t[:, None] ** jax.lax.iota(y.dtype, m)[None, :]  # [L, m]
    powers = powers * mask[:, None]
    xtx_ref[...] = (powers.T @ powers).reshape(1, m, m)
    xty_ref[...] = (powers.T @ (y * mask)).reshape(1, m)


def fitpoly_normal_eqs(y, mask, x0, degree):
    """Batched normal equations. y, mask: [S, L]; x0: [S].

    Returns (xtx [S, m, m], xty [S, m]).
    """
    s, l = y.shape
    m = degree + 1
    return pl.pallas_call(
        partial(_kernel, degree=degree, seg_len=l),
        grid=(s,),
        in_specs=[
            pl.BlockSpec((1, l), lambda i: (i, 0)),
            pl.BlockSpec((1, l), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, m, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, m, m), y.dtype),
            jax.ShapeDtypeStruct((s, m), y.dtype),
        ],
        interpret=True,
    )(y, mask, x0.reshape(-1, 1))


def _chol_solve_batched(a, b):
    """Batched SPD solve via fully-unrolled Cholesky (m <= 9).

    jnp.linalg.solve lowers to a typed-FFI LAPACK custom call that the
    xla_extension 0.5.1 runtime behind the rust loader rejects
    (API_VERSION_TYPED_FFI); an unrolled Cholesky lowers to plain HLO.
    a: [S, m, m], b: [S, m] -> x: [S, m].
    """
    m = a.shape[-1]
    l = [[None] * m for _ in range(m)]
    for i in range(m):
        for j in range(i + 1):
            s = a[:, i, j]
            for k in range(j):
                s = s - l[i][k] * l[j][k]
            if i == j:
                l[i][i] = jnp.sqrt(jnp.maximum(s, 1e-20))
            else:
                l[i][j] = s / l[j][j]
    # forward solve L y = b
    y = [None] * m
    for i in range(m):
        s = b[:, i]
        for k in range(i):
            s = s - l[i][k] * y[k]
        y[i] = s / l[i][i]
    # back solve L^T x = y
    x = [None] * m
    for i in reversed(range(m)):
        s = y[i]
        for k in range(i + 1, m):
            s = s - l[k][i] * x[k]
        x[i] = s / l[i][i]
    return jnp.stack(x, axis=-1)


def fitpoly_solve(y, mask, x0, degree):
    """Full Fit-Poly batch: kernel-built normal equations + unrolled
    Cholesky solve (plain-HLO friendly).

    Returns coefficients [S, degree+1] (low order first, rescaled domain).
    """
    xtx, xty = fitpoly_normal_eqs(y, mask, x0, degree)
    m = degree + 1
    # ridge for rank-deficient (short/padded) segments
    eye = jnp.eye(m, dtype=y.dtype) * 1e-6
    return _chol_solve_batched(xtx + eye[None], xty)
