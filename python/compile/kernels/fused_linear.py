"""L1 Pallas kernel: fused tiled matmul + bias + activation.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid is
(M/bm, N/bn, K/bk); each step holds an (bm×bk) x-tile, (bk×bn) w-tile and
the (bm×bn) f32 accumulator in VMEM and contracts on the MXU. The K axis
is the innermost grid dimension so the output tile is revisited
(accumulated) across K steps — the Pallas analogue of the CUDA
threadblock-K loop. `interpret=True` is mandatory on the CPU PJRT plugin
(Mosaic custom-calls are TPU-only); numerics are identical.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: multiples of the 128x128 MXU tile on real TPU; kept small
# enough that x/w/out tiles fit VMEM (see vmem_footprint_bytes below).
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _kernel(x_ref, w_ref, b_ref, o_ref, *, nk, act):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...] @ w_ref[...]

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        y = o_ref[...] + b_ref[...]
        if act == "relu":
            y = jnp.maximum(y, 0.0)
        elif act == "gelu":
            y = 0.5 * y * (1.0 + jnp.tanh(0.7978845608028654 * (y + 0.044715 * y**3)))
        o_ref[...] = y


def _pick_tile(dim, pref):
    """Largest divisor of `dim` that is <= pref (keeps the grid exact)."""
    t = min(pref, dim)
    while dim % t != 0:
        t -= 1
    return t


def _pallas_linear(x, w, b, act):
    """Raw kernel invocation (no AD)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,), (x.shape, w.shape, b.shape)
    bm = _pick_tile(m, DEFAULT_BM)
    bn = _pick_tile(n, DEFAULT_BN)
    bk = _pick_tile(k, DEFAULT_BK)
    nk = k // bk
    return pl.pallas_call(
        partial(_kernel, nk=nk, act=act),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b.reshape(1, -1))


def _act_grad(pre, act):
    if act == "none":
        return jnp.ones_like(pre)
    if act == "relu":
        return (pre > 0.0).astype(pre.dtype)
    if act == "gelu":
        # d/dy of the tanh-approximated gelu
        c = 0.7978845608028654
        inner = c * (pre + 0.044715 * pre**3)
        th = jnp.tanh(inner)
        return 0.5 * (1.0 + th) + 0.5 * pre * (1.0 - th**2) * c * (1.0 + 3 * 0.044715 * pre**2)
    raise ValueError(act)


# The accumulating grid kernel is not AD-traceable; provide the VJP
# explicitly (as production flash/matmul kernels do). The backward pass
# reuses the same Pallas kernel for its two transposed matmuls, so both
# directions run on the L1 kernel.
@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _linear_vjp(act, x, w, b):
    return _pallas_linear(x, w, b, act)


def _linear_fwd(act, x, w, b):
    return _pallas_linear(x, w, b, act), (x, w, b)


def _linear_bwd(act, res, dy):
    x, w, b = res
    # rematerialize the pre-activation through the kernel (act="none")
    if act == "none":
        dpre = dy
    else:
        pre = _pallas_linear(x, w, b, "none")
        dpre = dy * _act_grad(pre, act)
    zero_n = jnp.zeros((w.shape[0],), x.dtype)
    zero_m = jnp.zeros((w.shape[1],), x.dtype)
    dx = _pallas_linear(dpre, w.T, zero_n, "none")
    dw = _pallas_linear(x.T, dpre, zero_m, "none")
    db = dpre.sum(axis=0)
    return dx, dw, db


_linear_vjp.defvjp(_linear_fwd, _linear_bwd)


def fused_linear(x, w, b, act="none"):
    """act(x @ w + b) as a Pallas kernel (differentiable). x: [M, K]."""
    return _linear_vjp(act, x, w, b)


def vmem_footprint_bytes(bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK, dtype_bytes=4):
    """Per-step VMEM residency estimate for the §Perf roofline notes."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn + bn)
