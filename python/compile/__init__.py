"""Build-time compile package: L2 models, L1 kernels, AOT lowering.

Never imported at runtime — the rust binary only consumes artifacts/.
"""
