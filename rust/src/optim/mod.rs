//! Optimizers (rust is the parameter server of record; artifacts only
//! compute gradients). SGD, SGD-momentum (the paper's CNN benchmarks)
//! and Adam (NCF, Table 1).

use crate::tensor::Tensor;

pub trait Optimizer: Send {
    /// Apply one update step: `params[i] -= step(grads[i])`.
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]);

    fn name(&self) -> &'static str;

    fn set_lr(&mut self, lr: f32);
}

/// Plain SGD.
pub struct Sgd {
    pub lr: f32,
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        for (p, g) in params.iter_mut().zip(grads) {
            for (w, &dg) in p.data_mut().iter_mut().zip(g.data()) {
                *w -= self.lr * dg;
            }
        }
    }

    fn name(&self) -> &'static str {
        "sgd"
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

/// SGD with (heavy-ball) momentum — "SGD-M" in paper Table 1.
pub struct Momentum {
    pub lr: f32,
    pub beta: f32,
    velocity: Vec<Vec<f32>>,
}

impl Momentum {
    pub fn new(lr: f32, beta: f32) -> Self {
        Self { lr, beta, velocity: Vec::new() }
    }
}

impl Optimizer for Momentum {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        if self.velocity.is_empty() {
            self.velocity = params.iter().map(|p| vec![0.0; p.numel()]).collect();
        }
        for ((p, g), v) in params.iter_mut().zip(grads).zip(&mut self.velocity) {
            for ((w, &dg), vel) in p.data_mut().iter_mut().zip(g.data()).zip(v.iter_mut()) {
                *vel = self.beta * *vel + dg;
                *w -= self.lr * *vel;
            }
        }
    }

    fn name(&self) -> &'static str {
        "momentum"
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

/// Adam (Kingma & Ba), defaults β₁=0.9 β₂=0.999 ε=1e-8.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Self { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: Vec::new(), v: Vec::new() }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        if self.m.is_empty() {
            self.m = params.iter().map(|p| vec![0.0; p.numel()]).collect();
            self.v = params.iter().map(|p| vec![0.0; p.numel()]).collect();
        }
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for (((p, g), m), v) in params.iter_mut().zip(grads).zip(&mut self.m).zip(&mut self.v) {
            for (((w, &dg), mi), vi) in
                p.data_mut().iter_mut().zip(g.data()).zip(m.iter_mut()).zip(v.iter_mut())
            {
                *mi = self.beta1 * *mi + (1.0 - self.beta1) * dg;
                *vi = self.beta2 * *vi + (1.0 - self.beta2) * dg * dg;
                let mhat = *mi / bc1;
                let vhat = *vi / bc2;
                *w -= self.lr * mhat / (vhat.sqrt() + self.eps);
            }
        }
    }

    fn name(&self) -> &'static str {
        "adam"
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

/// Factory used by the config system.
pub fn by_name(name: &str, lr: f32) -> Option<Box<dyn Optimizer>> {
    match name {
        "sgd" => Some(Box::new(Sgd { lr })),
        "momentum" | "sgdm" | "sgd-m" => Some(Box::new(Momentum::new(lr, 0.9))),
        "adam" => Some(Box::new(Adam::new(lr))),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quadratic_descends(opt: &mut dyn Optimizer) -> f32 {
        // minimize f(w) = ||w - 3||^2 from w=0
        let mut params = vec![Tensor::from_vec(vec![0.0f32; 4])];
        for _ in 0..200 {
            let grads = vec![Tensor::from_vec(
                params[0].data().iter().map(|&w| 2.0 * (w - 3.0)).collect(),
            )];
            opt.step(&mut params, &grads);
        }
        params[0].data().iter().map(|&w| (w - 3.0).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn all_optimizers_converge_on_quadratic() {
        assert!(quadratic_descends(&mut Sgd { lr: 0.1 }) < 1e-3);
        assert!(quadratic_descends(&mut Momentum::new(0.05, 0.9)) < 1e-3);
        assert!(quadratic_descends(&mut Adam::new(0.3)) < 1e-2);
    }

    #[test]
    fn momentum_accelerates_vs_sgd() {
        // same lr: momentum reaches closer in fewer steps
        let run = |opt: &mut dyn Optimizer, steps: usize| {
            let mut params = vec![Tensor::from_vec(vec![0.0f32])];
            for _ in 0..steps {
                let grads =
                    vec![Tensor::from_vec(vec![2.0 * (params[0].data()[0] - 3.0)])];
                opt.step(&mut params, &grads);
            }
            (params[0].data()[0] - 3.0).abs()
        };
        let sgd = run(&mut Sgd { lr: 0.01 }, 50);
        let mom = run(&mut Momentum::new(0.01, 0.9), 50);
        assert!(mom < sgd, "momentum {mom} vs sgd {sgd}");
    }

    #[test]
    fn factory() {
        for n in ["sgd", "momentum", "adam"] {
            assert!(by_name(n, 0.1).is_some());
        }
        assert!(by_name("nope", 0.1).is_none());
    }
}
