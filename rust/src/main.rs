//! DeepReduce leader entrypoint.
//!
//! Subcommands:
//!   train        — run distributed training with a DeepReduce instantiation
//!   serve        — run the multi-tenant reduction service with synthetic tenants
//!   smoke        — load the pallas smoke artifact through PJRT and execute it
//!   codecs       — quick codec volume table on a synthetic sparse gradient
//!   list-codecs  — print the codec registry (names, params, chainability)
//!   info         — list artifacts and their manifests
//!   help         — print the full flag reference (`cli::usage`)

use deepreduce::cli::Args;
use deepreduce::collective::Topology;
use deepreduce::compress::{
    index_by_name, value_by_name, CodecRegistry, CodecSet, CompressSpec, DeepReduce,
};
use deepreduce::coordinator::{CompressionSpec, ModelKind, TrainConfig, Trainer};
use deepreduce::runtime;
use deepreduce::service::{JobRequest, ProfileStore, ReductionService, ServiceConfig};
use deepreduce::simnet::Link;
use deepreduce::sparsify::{Sparsifier, TopK};
use deepreduce::util::benchkit::Table;
use deepreduce::util::prng::Rng;
use deepreduce::util::testkit::gradient_like;
use std::path::PathBuf;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprint!("{}", deepreduce::cli::usage());
        std::process::exit(2);
    }
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    // reject unrecognized flags up front: a typo like --toplogy must not
    // silently fall back to defaults
    if let Err(e) = args.check_known(deepreduce::cli::KNOWN_FLAGS) {
        eprintln!("argument error: {e}");
        std::process::exit(2);
    }
    let result = match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "smoke" => cmd_smoke(),
        "codecs" => cmd_codecs(&args),
        // both spellings: subcommand (documented) and bare flag
        "list-codecs" | "--list-codecs" => cmd_list_codecs(),
        "info" => cmd_info(),
        "help" => {
            print!("{}", deepreduce::cli::usage());
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other}");
            eprint!("{}", deepreduce::cli::usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let model_name = args.get_or("model", "mlp");
    let model = ModelKind::parse(&model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model_name}"))?;
    let artifact = args.get_or(
        "artifact",
        match model {
            ModelKind::Mlp => "mlp",
            ModelKind::Ncf => "ncf",
            ModelKind::Transformer => "transformer_small",
        },
    );
    let mut cfg = TrainConfig::new(model, &artifact);
    cfg.workers = args.get_usize("workers", 4)?;
    cfg.steps = args.get_usize("steps", 100)?;
    cfg.lr = args.get_f64("lr", cfg.lr as f64)? as f32;
    cfg.optimizer = args.get_or("optimizer", &cfg.optimizer);
    cfg.seed = args.get_usize("seed", 42)? as u64;
    cfg.log_every = args.get_usize("log-every", 10)?;
    let index = args.get_or("index", "");
    let value = args.get_or("value", "");
    // any scenario knob runs on the virtual-time fabric
    let scenario_flags =
        ["straggler", "compute-jitter", "link-jitter", "node-mbps", "link-flap", "crash"]
            .iter()
            .any(|&f| args.get(f).is_some());
    // --schedule / --topology / --fabric / --trace / a scenario knob
    // alone activates the compression pipeline (raw/raw) so none of
    // these flags is ever silently ignored
    if !index.is_empty()
        || !value.is_empty()
        || args.get("schedule").is_some()
        || args.get("topology").is_some()
        || args.get("fabric").is_some()
        || args.get("trace").is_some()
        || scenario_flags
    {
        let idx = if index.is_empty() { "raw".to_string() } else { index };
        let val = if value.is_empty() { "raw".to_string() } else { value };
        // the CLI is a thin parser into the typed spec: full chain
        // syntax (`rle+deflate`, `bloom_p2(fpr=0.01)+zstd`) parses
        // here; the legacy --fpr / --value-param flags shim onto the
        // head stages' declared legacy parameter keys
        let mut compress = CompressSpec::parse(&idx, &val)
            .map_err(|e| anyhow::anyhow!("--index/--value: {e}"))?;
        let registry = CodecRegistry::global();
        registry.apply_legacy_param(
            CodecSet::Index,
            &mut compress.index,
            args.get_f64("fpr", f64::NAN)?,
        );
        registry.apply_legacy_param(
            CodecSet::Value,
            &mut compress.value,
            args.get_f64("value-param", f64::NAN)?,
        );
        // fail early with the registry's diagnostics (unknown codec,
        // undeclared parameter, out-of-range value) instead of deep in
        // the trainer build
        registry
            .build_index(&compress.index, 0)
            .map_err(|e| anyhow::anyhow!("--index: {e}"))?;
        registry
            .build_value(&compress.value, 0)
            .map_err(|e| anyhow::anyhow!("--value: {e}"))?;
        let mut spec = CompressionSpec::with_spec(args.get_f64("ratio", 0.01)?, compress);
        if args.get_or("sparsifier", "topk") == "identity" {
            spec.sparsifier = "identity".into();
            spec.ratio = 1.0;
        }
        spec.sparsifier = args.get_or("sparsifier", &spec.sparsifier);
        // EF follows --no-ef for every sparsifier, identity included
        // (matches the pre-redesign CLI, which overwrote the identity
        // constructor's EF default the same way)
        spec.error_feedback = !args.flag("no-ef");
        // sparse allreduce schedule: gather_all (default) | recursive_double
        // | ring_rescatter | ring_rescatter_exact | chunked_rescatter
        // | hierarchical
        spec.schedule = args.get_or("schedule", &spec.schedule);
        // two-level node × rank grid: --topology NxR meters intra vs
        // inter bytes for any schedule, and (when --schedule is not
        // given) switches to the hierarchical schedule that exploits it
        spec.topology = args.get_or("topology", &spec.topology);
        if !spec.topology.is_empty() && args.get("schedule").is_none() {
            spec.schedule = "hierarchical".into();
        }
        spec.inner_schedule = args.get_or("inner-schedule", &spec.inner_schedule);
        // chunked_rescatter chunk count (rounded up to a multiple of
        // the world size; 0 = auto, one chunk per rank)
        spec.chunks = args.get_usize("chunks", spec.chunks)?;
        spec.intra_mbps = args.get_f64("intra-mbps", spec.intra_mbps)?;
        spec.inter_mbps = args.get_f64("inter-mbps", spec.inter_mbps)?;
        // virtual-time fabric + scenario knobs: any scenario flag
        // implies --fabric virtual when --fabric is not given
        spec.fabric = args.get_or("fabric", &spec.fabric);
        if scenario_flags && args.get("fabric").is_none() {
            // --crash needs elastic membership, which only the fleet
            // event loop provides; other knobs default to virtual
            spec.fabric =
                if args.get("crash").is_some() { "fleet".into() } else { "virtual".into() };
        }
        spec.straggler = args.get_or("straggler", &spec.straggler);
        spec.compute_jitter = args.get_f64("compute-jitter", spec.compute_jitter)?;
        spec.link_jitter = args.get_f64("link-jitter", spec.link_jitter)?;
        spec.node_mbps = args.get_or("node-mbps", &spec.node_mbps);
        spec.link_flap = args.get_or("link-flap", &spec.link_flap);
        spec.crash = args.get_or("crash", &spec.crash);
        spec.autotune_cost = args.get_or("autotune-cost", &spec.autotune_cost);
        // gradient pipeline: --bucket-bytes caps fused buckets (0 = one
        // bucket per tensor); --autotune [on|off] picks codecs per bucket
        // by the calibrated cost model (DESIGN.md §6)
        spec.bucket_bytes = args.get_usize("bucket-bytes", 0)?;
        // modelled link for autotune comm costs + pipeline step-time
        // metrics (Mbps; paper default 100)
        spec.pipeline_link_mbps = args.get_f64("pipeline-link-mbps", spec.pipeline_link_mbps)?;
        spec.autotune = match args.get("autotune") {
            Some("on") | Some("true") | Some("1") => true,
            Some("off") | Some("false") | Some("0") => false,
            Some(other) => anyhow::bail!("--autotune expects on|off, got {other}"),
            None => args.flag("autotune"),
        };
        // structured tracing (DESIGN.md §11); validated here so a typo
        // fails before the trainer builds
        spec.trace = args.get_or("trace", &spec.trace);
        deepreduce::obs::TraceLevel::parse(&spec.trace).map_err(|e| anyhow::anyhow!("--trace: {e}"))?;
        cfg.compression = Some(spec);
    }
    anyhow::ensure!(
        !args.flag("trace-summary") || cfg.compression.as_ref().is_some_and(|s| s.trace != "off"),
        "--trace-summary requires --trace step|sampled|full"
    );
    anyhow::ensure!(
        !args.flag("health-summary")
            || cfg.compression.as_ref().is_some_and(|s| s.trace == "sampled"),
        "--health-summary requires --trace sampled"
    );
    let mut trainer = Trainer::new(cfg)?;
    let report = trainer.run()?;
    println!("{}", report.to_json().to_string());
    eprintln!(
        "final loss {:.4}  aux {:.4}  relative volume {:.4}",
        report.final_loss(),
        report.final_aux(10),
        report.relative_volume()
    );
    let (intra, inter) = report.total_link_bytes();
    if inter > 0 {
        eprintln!("fabric link classes: intra-node {intra} B  inter-node {inter} B");
    }
    // measured virtual-time numbers are the primary timing output when
    // the run used the event fabric (`--fabric virtual`)
    if report.total_measured_s() > 0.0 {
        eprintln!(
            "virtual fabric: measured step time {:.4}s total  mean rank idle {:.4}s total",
            report.total_measured_s(),
            report.total_rank_idle_s()
        );
    }
    if let Some(last) = report.steps.last() {
        if last.bucket_count > 0 {
            let (serial, overlap) = report.pipeline_times_s();
            eprintln!(
                "pipeline: {} buckets/worker  codecs [{}]  modelled step time {:.4}s serial -> {:.4}s overlapped",
                last.bucket_count,
                report.distinct_autotune_choices().join(", "),
                serial,
                overlap
            );
        }
    }
    // trace artifact + optional terminal breakdown (--trace step|full;
    // at sampled the trace holds only the exemplar ranks' timelines)
    if let Some(trace) = trainer.take_trace() {
        if args.flag("trace-summary") {
            eprint!("{}", trace.summary());
        }
        let path = trace.write()?;
        eprintln!("trace written to {}", path.display());
    }
    // fleet health artifact (--trace sampled): percentile series, flagged
    // ranks with attributed causes, exemplar-trace pointer
    if let Some(health) = trainer.take_health() {
        if args.flag("health-summary") {
            eprint!("{}", health.summary());
        }
        let path = health.write()?;
        eprintln!("health written to {}", path.display());
    }
    Ok(())
}

/// Run the multi-tenant reduction service with a synthetic tenant mix:
/// `--dense-tenants` high-density jobs next to `--tenants` sparse ones,
/// interleaved for `--rounds` fair-share rounds on one shared fabric.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let topo_s = args.get_or("topology", "4x4");
    let topo = Topology::parse(&topo_s)
        .ok_or_else(|| anyhow::anyhow!("--topology expects NxR, got {topo_s}"))?;
    let sparse_tenants = args.get_usize("tenants", 3)?;
    let dense_tenants = args.get_usize("dense-tenants", 1)?;
    let ranks_per_job = args.get_usize("ranks-per-job", topo.ranks_per_node)?;
    let rounds = args.get_usize("rounds", 10)?;
    let dim = args.get_usize("dim", 65_536)?;
    let ratio = args.get_f64("ratio", 0.01)?;
    let intra = Link::mbps(args.get_f64("intra-mbps", 10_000.0)?);
    let inter = Link::mbps(args.get_f64("inter-mbps", 100.0)?);
    let seed = args.get_usize("seed", 42)? as u64;
    let autotune = match args.get("autotune") {
        Some("on") | Some("true") | Some("1") => true,
        Some("off") | Some("false") | Some("0") => false,
        Some(other) => anyhow::bail!("--autotune expects on|off, got {other}"),
        None => args.flag("autotune"),
    };
    let profile_dir =
        args.get("profile-dir").map(PathBuf::from).unwrap_or_else(ProfileStore::repo_root);
    let mut service = ReductionService::new(
        ServiceConfig::new(topo, intra, inter).with_profiles(profile_dir.clone()),
    );
    eprintln!(
        "reduction service on {} ({} ranks)  frame budget [intra {:.0} B, inter {:.0} B]  profiles in {}",
        topo.label(),
        topo.world(),
        service.config().frame_budget[0],
        service.config().frame_budget[1],
        profile_dir.display()
    );
    let mut ids = Vec::new();
    for i in 0..dense_tenants + sparse_tenants {
        let (name, density) = if i < dense_tenants {
            (format!("dense{i}"), 0.5)
        } else {
            (format!("sparse{}", i - dense_tenants), ratio)
        };
        let req = JobRequest {
            autotune,
            seed: seed ^ i as u64,
            ..JobRequest::synthetic(&name, ranks_per_job, dim, density)
        };
        // a rejected tenant is reported, not fatal: the daemon keeps
        // serving whoever fit (admission is the backpressure mechanism)
        match service.submit(req) {
            Ok(id) => {
                let job = service.job(id).expect("submit registered the job");
                let start = if !autotune {
                    "static codecs"
                } else if job.setup.warm_start {
                    "warm start"
                } else {
                    "cold calibration"
                };
                eprintln!("admitted {name} as {id} on ranks {:?} ({start})", job.placement);
                ids.push(id);
            }
            Err(e) => eprintln!("rejected {name}: {e}"),
        }
    }
    anyhow::ensure!(!ids.is_empty(), "no tenant was admitted");
    for _ in 0..rounds {
        service.run_round()?;
    }
    let mut table = Table::new(
        &format!("{rounds} fair-share rounds over {} tenants on {}", ids.len(), topo.label()),
        &[
            "job",
            "name",
            "steps",
            "step s",
            "intra B",
            "inter B",
            "setup s",
            "first step s",
            "start",
        ],
    );
    let mut aggregate = 0.0;
    for id in &ids {
        let job = service.job(*id).expect("admitted job stays queryable");
        aggregate += job.steps as f64 / job.virtual_s.max(f64::EPSILON);
        table.row(&[
            job.id.to_string(),
            job.name.clone(),
            job.steps.to_string(),
            format!("{:.4}", job.step_time_s()),
            job.bytes[0].to_string(),
            job.bytes[1].to_string(),
            format!("{:.4}", job.setup.total_s()),
            format!("{:.4}", job.first_step_s.unwrap_or(f64::NAN)),
            if !autotune {
                "static"
            } else if job.setup.warm_start {
                "warm"
            } else {
                "cold"
            }
            .to_string(),
        ]);
    }
    table.print();
    eprintln!("aggregate throughput {aggregate:.2} steps/virtual-s");
    for id in ids {
        if let Some(path) = service.finish(id)? {
            eprintln!("profile written to {}", path.display());
        }
    }
    Ok(())
}

fn cmd_smoke() -> anyhow::Result<()> {
    anyhow::ensure!(
        runtime::artifact_available("pallas_smoke"),
        "run `make artifacts` first"
    );
    let art = runtime::Artifact::load_default("pallas_smoke")?;
    let params = art.init_params(1);
    let batch_cfg = art.manifest.config_usize("batch").unwrap_or(16);
    let input_dim = art.manifest.config_usize("input_dim").unwrap_or(64);
    let classes = art.manifest.config_usize("classes").unwrap_or(8);
    let mut data = deepreduce::data::SynthImages::new(input_dim, classes, batch_cfg, 7);
    let out = art.train_step(&params, &data.next_batch())?;
    anyhow::ensure!(out.loss.is_finite(), "non-finite loss");
    println!(
        "pallas smoke OK: loss={:.4} acc={:.4} grads={} tensors",
        out.loss,
        out.aux,
        out.grads.len()
    );
    Ok(())
}

fn cmd_codecs(args: &Args) -> anyhow::Result<()> {
    let d = args.get_usize("dim", 36_864)?;
    let ratio = args.get_f64("ratio", 0.01)?;
    let mut rng = Rng::new(7);
    let g = gradient_like(&mut rng, d);
    let mut topk = TopK::new(ratio);
    let sp = topk.sparsify(&g);
    let mut table = Table::new(
        &format!("codec volumes, d={d}, top-{}%", ratio * 100.0),
        &["instantiation", "index B", "value B", "reorder B", "total B", "vs kv"],
    );
    let combos = [
        ("raw", "raw"),
        ("bitmap", "raw"),
        ("rle", "raw"),
        ("huffman", "raw"),
        ("delta_varint", "raw"),
        ("bloom_p0", "raw"),
        ("bloom_p2", "raw"),
        ("raw", "deflate"),
        ("raw", "qsgd"),
        ("raw", "fitpoly"),
        ("raw", "fitdexp"),
        ("bloom_p2", "fitpoly"),
        // composed chains (DESIGN.md §10): second stage re-compresses
        // the first stage's byte stream
        ("rle+deflate", "raw"),
        ("delta_varint+deflate", "raw"),
    ];
    for (i, v) in combos {
        let dr = DeepReduce::new(
            index_by_name(i, 0.001, 1).unwrap(),
            value_by_name(v, f64::NAN, 1).unwrap(),
        );
        let b = dr.volume(&sp, Some(&g));
        table.row(&[
            dr.name(),
            b.index_bytes.to_string(),
            b.value_bytes.to_string(),
            b.reorder_bytes.to_string(),
            b.total().to_string(),
            format!("{:.3}", b.total() as f64 / sp.kv_wire_bytes() as f64),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_list_codecs() -> anyhow::Result<()> {
    let registry = CodecRegistry::global();
    let mut table = Table::new(
        "codec registry — chain syntax: <index>[+stage]... e.g. rle+deflate, bloom_p2(fpr=0.01)+zstd",
        &["name", "set", "params (key:type=default)", "lossless", "chainable"],
    );
    for row in registry.rows() {
        table.row(&[
            row.name,
            row.set.to_string(),
            row.params,
            if row.lossless { "yes" } else { "no" }.to_string(),
            if row.chainable { "yes" } else { "leads only" }.to_string(),
        ]);
    }
    table.print();
    println!("chainable = may appear after '+'; every index/value codec may lead a chain.");
    println!("lossy codecs may appear only as the leading stage.");
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    let dir = runtime::artifacts_dir();
    anyhow::ensure!(dir.exists(), "artifacts dir {dir:?} missing; run `make artifacts`");
    let mut table = Table::new("artifacts", &["name", "kind", "params", "total", "inputs"]);
    let mut entries: Vec<_> = std::fs::read_dir(&dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("json"))
        .collect();
    entries.sort();
    for p in entries {
        let m = runtime::Manifest::parse(&std::fs::read_to_string(&p)?)?;
        table.row(&[
            m.name.clone(),
            m.kind.clone(),
            m.params.len().to_string(),
            m.total_params().to_string(),
            m.inputs.iter().map(|i| i.name.as_str()).collect::<Vec<_>>().join(","),
        ]);
    }
    table.print();
    Ok(())
}
