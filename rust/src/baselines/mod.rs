//! State-of-the-art baselines the paper compares against (§6.3, §7):
//! SketchML (Jiang et al., SIGMOD'18), SKCompress (Jiang et al., VLDB J.
//! '20) and 3LC (Lim et al., SysML'19).
//!
//! Per the paper, SketchML/SKCompress "can be viewed as special cases of
//! DeepReduce": we implement their value stage as a [`ValueCodec`]
//! (quantile-bucket quantization ± Huffman) and their index stage as an
//! [`IndexCodec`] (delta + varint ± Huffman), then compose them through
//! the same framework. 3LC is a dense-tensor compressor and keeps its
//! own interface.

mod sketch;
mod threelc;

pub use sketch::{DeltaHuffmanIndex, QuantileBucketValue};
pub use threelc::ThreeLC;

use crate::compress::DeepReduce;

/// SketchML: quantile-bucket values (no Huffman), delta+varint keys.
pub fn sketchml(buckets: usize) -> DeepReduce {
    DeepReduce::new(
        Box::new(crate::compress::index::DeltaVarint),
        Box::new(QuantileBucketValue::new(buckets, false)),
    )
}

/// SKCompress: SketchML + Huffman on bucket ids and on delta-key bytes.
pub fn skcompress(buckets: usize) -> DeepReduce {
    DeepReduce::new(
        Box::new(DeltaHuffmanIndex),
        Box::new(QuantileBucketValue::new(buckets, true)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsify::{Sparsifier, TopK};
    use crate::util::prng::Rng;
    use crate::util::stats::rel_l2_err;
    use crate::util::testkit::gradient_like;

    #[test]
    fn sketchml_and_skcompress_roundtrip_with_bounded_error() {
        let mut rng = Rng::new(400);
        let g = gradient_like(&mut rng, 20_000);
        let mut topk = TopK::new(0.01);
        let sp = topk.sparsify(&g);
        for (name, dr) in [("sketchml", sketchml(64)), ("skcompress", skcompress(64))] {
            let c = dr.encode(&sp, Some(&g));
            let back = dr.decode(&c).unwrap();
            assert_eq!(back.indices(), sp.indices(), "{name}: support must be lossless");
            let err = rel_l2_err(sp.values(), back.values());
            assert!(err < 0.1, "{name}: rel err {err}");
        }
    }

    #[test]
    fn skcompress_smaller_than_sketchml() {
        // large enough that the two 256-byte Huffman tables amortize
        let mut rng = Rng::new(401);
        let g = gradient_like(&mut rng, 400_000);
        let mut topk = TopK::new(0.02);
        let sp = topk.sparsify(&g);
        let a = sketchml(64).encode(&sp, Some(&g)).wire_bytes();
        let b = skcompress(64).encode(&sp, Some(&g)).wire_bytes();
        assert!(b < a, "skcompress {b} vs sketchml {a}");
        // both far below raw kv
        assert!(b < sp.kv_wire_bytes() / 2);
    }
}
