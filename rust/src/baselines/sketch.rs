//! SketchML / SKCompress building blocks.
//!
//! * [`QuantileBucketValue`] — the SketchML value stage: a non-uniform
//!   quantile sketch maps each value to one of `q` buckets; the wire
//!   carries the bucket centroids and per-value bucket ids (bit-packed,
//!   or Huffman-coded for the SKCompress variant). Per the paper (§6.3)
//!   we omit the grouped MinMaxSketch and positive/negative separation,
//!   "as they have only minor effects".
//! * [`DeltaHuffmanIndex`] — the SKCompress index stage: delta encoding
//!   to varint bytes, then Huffman over those bytes (table on the wire).

use crate::compress::{IndexCodec, IndexEncoding, ValueCodec, ValueEncoding};
use crate::util::bitio::{BitReader, BitWriter};
use crate::util::huffman::{byte_freqs, Huffman};
use crate::util::varint;

/// SketchML quantile-bucket value quantization.
pub struct QuantileBucketValue {
    pub buckets: usize,
    pub huffman: bool,
}

impl QuantileBucketValue {
    pub fn new(buckets: usize, huffman: bool) -> Self {
        assert!((2..=256).contains(&buckets), "buckets in 2..=256");
        Self { buckets, huffman }
    }

    fn bits(&self) -> u32 {
        usize::BITS - (self.buckets - 1).leading_zeros()
    }
}

impl ValueCodec for QuantileBucketValue {
    fn name(&self) -> &str {
        if self.huffman {
            "sketch_huff"
        } else {
            "sketch"
        }
    }

    fn encode(&self, values: &[f32]) -> ValueEncoding {
        let n = values.len();
        let q = self.buckets.min(n.max(1));
        // exact quantile boundaries on a sorted copy (the paper's
        // streaming quantile sketch approximates these)
        let mut sorted: Vec<f32> = values.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let mut bounds = Vec::with_capacity(q + 1);
        for i in 0..=q {
            let pos = (i * n.saturating_sub(1)) / q.max(1);
            bounds.push(sorted.get(pos).copied().unwrap_or(0.0));
        }
        // bucket ids + centroids
        let mut ids = Vec::with_capacity(n);
        let mut sums = vec![0.0f64; q];
        let mut counts = vec![0u64; q];
        for &v in values {
            // rightmost bucket whose lower bound <= v
            let mut b = match bounds[1..q].binary_search_by(|p| p.partial_cmp(&v).unwrap()) {
                Ok(i) => i + 1,
                Err(i) => i,
            };
            if b >= q {
                b = q - 1;
            }
            ids.push(b as u8);
            sums[b] += v as f64;
            counts[b] += 1;
        }
        let centroids: Vec<f32> = (0..q)
            .map(|b| if counts[b] > 0 { (sums[b] / counts[b] as f64) as f32 } else { 0.0 })
            .collect();

        let mut bytes = Vec::new();
        varint::write_u64(&mut bytes, q as u64);
        for &c in &centroids {
            bytes.extend_from_slice(&c.to_le_bytes());
        }
        if self.huffman {
            let freqs = byte_freqs(&ids);
            let h = Huffman::from_freqs(&freqs).expect("n>0 ensured by caller paths");
            bytes.extend_from_slice(&h.table_bytes());
            bytes.extend_from_slice(&h.encode(&ids));
        } else {
            let bits = self.bits();
            let mut w = BitWriter::with_capacity(n * bits as usize / 8 + 8);
            for &id in &ids {
                w.write_bits(id as u64, bits);
            }
            bytes.extend_from_slice(&w.finish());
        }
        ValueEncoding { bytes, perm: None }
    }

    fn decode(&self, bytes: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        if n == 0 {
            return Ok(Vec::new());
        }
        let mut pos = 0usize;
        let q = varint::read_u64(bytes, &mut pos)? as usize;
        anyhow::ensure!(q >= 1 && q <= 256, "bad bucket count {q}");
        anyhow::ensure!(pos + q * 4 <= bytes.len(), "centroids truncated");
        let mut centroids = Vec::with_capacity(q);
        for _ in 0..q {
            centroids.push(f32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()));
            pos += 4;
        }
        let ids: Vec<u8> = if self.huffman {
            anyhow::ensure!(pos + 256 <= bytes.len(), "huffman table truncated");
            let mut lens = [0u8; 256];
            lens.copy_from_slice(&bytes[pos..pos + 256]);
            pos += 256;
            let h = Huffman::from_lens(lens).map_err(|e| anyhow::anyhow!("{e}"))?;
            h.decode(&bytes[pos..], n).map_err(|e| anyhow::anyhow!("{e}"))?
        } else {
            let bits = self.bits();
            let mut r = BitReader::new(&bytes[pos..]);
            (0..n).map(|_| r.read_bits(bits).map(|v| v as u8)).collect::<Result<_, _>>()?
        };
        ids.iter()
            .map(|&id| {
                anyhow::ensure!((id as usize) < q, "bucket id out of range");
                Ok(centroids[id as usize])
            })
            .collect()
    }
}

/// SKCompress index stage: deltas → varint bytes → Huffman.
pub struct DeltaHuffmanIndex;

impl IndexCodec for DeltaHuffmanIndex {
    fn name(&self) -> &str {
        "delta_huffman"
    }

    fn encode(&self, _d: usize, support: &[u32]) -> IndexEncoding {
        // delta + varint byte stream
        let mut raw = Vec::with_capacity(support.len() * 2);
        let mut prev = 0u64;
        for (k, &i) in support.iter().enumerate() {
            let delta = if k == 0 { i as u64 } else { i as u64 - prev };
            varint::write_u64(&mut raw, delta);
            prev = i as u64;
        }
        let mut bytes = Vec::new();
        varint::write_u64(&mut bytes, support.len() as u64);
        varint::write_u64(&mut bytes, raw.len() as u64);
        if raw.is_empty() {
            return IndexEncoding { bytes, effective: support.to_vec() };
        }
        let h = Huffman::from_freqs(&byte_freqs(&raw)).expect("nonempty");
        bytes.extend_from_slice(&h.table_bytes());
        bytes.extend_from_slice(&h.encode(&raw));
        IndexEncoding { bytes, effective: support.to_vec() }
    }

    fn decode(&self, d: usize, bytes: &[u8]) -> anyhow::Result<Vec<u32>> {
        let mut pos = 0usize;
        let n = varint::read_u64(bytes, &mut pos)? as usize;
        let raw_len = varint::read_u64(bytes, &mut pos)? as usize;
        if raw_len == 0 {
            anyhow::ensure!(n == 0, "nonzero count with empty payload");
            return Ok(Vec::new());
        }
        anyhow::ensure!(pos + 256 <= bytes.len(), "huffman table truncated");
        let mut lens = [0u8; 256];
        lens.copy_from_slice(&bytes[pos..pos + 256]);
        pos += 256;
        let h = Huffman::from_lens(lens).map_err(|e| anyhow::anyhow!("{e}"))?;
        let raw = h.decode(&bytes[pos..], raw_len).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut out = Vec::with_capacity(n);
        let mut rpos = 0usize;
        let mut acc = 0u64;
        for k in 0..n {
            let delta = varint::read_u64(&raw, &mut rpos)?;
            acc = if k == 0 { delta } else { acc + delta };
            anyhow::ensure!((acc as usize) < d, "index out of range");
            out.push(acc as u32);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{IndexCodec, ValueCodec};
    use crate::util::prng::Rng;
    use crate::util::stats::rel_l2_err;
    use crate::util::testkit::{forall, gradient_like, sorted_support};

    #[test]
    fn quantile_buckets_roundtrip_error_drops_with_buckets() {
        let mut rng = Rng::new(500);
        let values = gradient_like(&mut rng, 5000);
        let mut errs = Vec::new();
        for q in [8usize, 64, 256] {
            let codec = QuantileBucketValue::new(q, false);
            let enc = codec.encode(&values);
            let out = codec.decode(&enc.bytes, values.len()).unwrap();
            errs.push(rel_l2_err(&values, &out));
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
        assert!(errs[2] < 0.2, "{errs:?}");
    }

    #[test]
    fn huffman_variant_matches_plain_decode() {
        let mut rng = Rng::new(501);
        let values = gradient_like(&mut rng, 3000);
        let plain = QuantileBucketValue::new(64, false);
        let huff = QuantileBucketValue::new(64, true);
        let a = plain.decode(&plain.encode(&values).bytes, values.len()).unwrap();
        let b = huff.decode(&huff.encode(&values).bytes, values.len()).unwrap();
        assert_eq!(a, b, "same buckets -> same decode");
    }

    #[test]
    fn delta_huffman_roundtrip() {
        forall(
            "delta-huffman",
            30,
            5000,
            |rng, size| {
                let d = 1 + rng.below(size as u64) as usize;
                let r = rng.below(d as u64 + 1) as usize;
                (d, sorted_support(rng, d, r))
            },
            |(d, support)| {
                let enc = DeltaHuffmanIndex.encode(*d, support);
                let dec = DeltaHuffmanIndex.decode(*d, &enc.bytes).map_err(|e| e.to_string())?;
                if dec == *support {
                    Ok(())
                } else {
                    Err("mismatch".into())
                }
            },
        );
    }

    #[test]
    fn bucket_ids_bitpacked_volume() {
        // 64 buckets -> 6 bits/value + 64*4 centroid bytes
        let values = vec![0.5f32; 10_000];
        let codec = QuantileBucketValue::new(64, false);
        let enc = codec.encode(&values);
        let expected = 1 + 64 * 4 + (10_000usize * 6).div_ceil(8);
        assert!(enc.bytes.len() <= expected + 8, "{} vs {expected}", enc.bytes.len());
    }
}
