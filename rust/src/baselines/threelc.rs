//! 3LC (Lim, Andersen, Kaminsky — SysML'19): a dense-tensor traffic
//! compressor combining
//!  1. **3-value quantization with a sparsity multiplier s**: with
//!     M = max|g|, each element is quantized to round(v/(s·M)) clamped
//!     to {−1,0,1} and dequantized as v̂ = trit·s·M. Larger s widens the
//!     zero bin ⇒ more zeros (sparsity) and more error, compensated by
//!     error feedback upstream.
//!  2. **Quartic (base-3⁵) encoding**: 5 trits per byte (3⁵ = 243 ≤ 256).
//!  3. **Zero-run encoding (ZRE)**: runs of the all-zero byte (121) are
//!     folded into the spare byte values 243–255 (run lengths 2–14).
//!
//! 3LC is applied to the *dense* gradient (it is a stand-alone method in
//! the paper's Fig 9 comparison), so it has its own dense interface.

use crate::util::varint;

pub struct ThreeLC {
    /// sparsity multiplier s ∈ [1, 2); the paper's Fig 9 uses s = 1
    pub s: f32,
}

impl ThreeLC {
    pub fn new(s: f32) -> Self {
        assert!((1.0..2.0).contains(&s), "3LC sparsity multiplier in [1,2)");
        Self { s }
    }

    pub fn name(&self) -> &'static str {
        "3lc"
    }

    /// Quantize + encode a dense gradient.
    pub fn encode(&self, grad: &[f32]) -> Vec<u8> {
        let m = grad.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let scale = if m > 0.0 { 1.0 / (self.s * m) } else { 0.0 };
        // trits in {0,1,2} = value+1
        let mut out = Vec::with_capacity(grad.len() / 4 + 16);
        varint::write_u64(&mut out, grad.len() as u64);
        out.extend_from_slice(&m.to_le_bytes());
        let mut bytes = Vec::with_capacity(grad.len() / 5 + 1);
        for chunk in grad.chunks(5) {
            let mut b = 0u16;
            for (k, &v) in chunk.iter().enumerate() {
                let t = (v * scale).round().clamp(-1.0, 1.0) as i8 + 1;
                b += (t as u16) * POW3[k];
            }
            debug_assert!(b < 243);
            bytes.push(b as u8);
        }
        // zero-run encoding over the quartic bytes
        let zero_byte = 121u8; // trits (1,1,1,1,1)
        let mut i = 0usize;
        while i < bytes.len() {
            let b = bytes[i];
            if b == zero_byte {
                let mut run = 1usize;
                while i + run < bytes.len() && bytes[i + run] == zero_byte && run < 14 {
                    run += 1;
                }
                if run >= 2 {
                    out.push(241 + run as u8); // 243..=255 for runs 2..=14
                } else {
                    out.push(zero_byte);
                }
                i += run;
            } else {
                out.push(b);
                i += 1;
            }
        }
        out
    }

    /// Decode to the dense gradient approximation.
    pub fn decode(&self, bytes: &[u8]) -> anyhow::Result<Vec<f32>> {
        let mut pos = 0usize;
        let d = varint::read_u64(bytes, &mut pos)? as usize;
        anyhow::ensure!(pos + 4 <= bytes.len(), "3lc header truncated");
        let m = f32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
        pos += 4;
        let step = self.s * m;
        let zero_byte = 121u8;
        let mut quartic = Vec::with_capacity(d / 5 + 1);
        for &b in &bytes[pos..] {
            if b >= 243 {
                let run = (b - 241) as usize;
                quartic.extend(std::iter::repeat_n(zero_byte, run));
            } else {
                quartic.push(b);
            }
        }
        anyhow::ensure!(quartic.len() == d.div_ceil(5), "3lc payload length mismatch");
        let mut out = Vec::with_capacity(d);
        'outer: for &b in &quartic {
            let mut v = b as u16;
            for _ in 0..5 {
                let t = (v % 3) as i32 - 1;
                out.push(t as f32 * step);
                v /= 3;
                if out.len() == d {
                    break 'outer;
                }
            }
        }
        anyhow::ensure!(out.len() == d, "3lc decoded length mismatch");
        Ok(out)
    }
}

const POW3: [u16; 5] = [1, 3, 9, 27, 81];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_quantized_values() {
        let mut rng = Rng::new(600);
        let g: Vec<f32> = (0..10_007).map(|_| rng.next_gaussian() as f32 * 0.1).collect();
        let c = ThreeLC::new(1.0);
        let enc = c.encode(&g);
        let dec = c.decode(&enc).unwrap();
        assert_eq!(dec.len(), g.len());
        let m = g.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for (&orig, &back) in g.iter().zip(&dec) {
            // quantization to {-sM, 0, sM} with s=1: error <= M/2
            assert!((orig - back).abs() <= m / 2.0 + 1e-6);
            // decoded values are exactly one of the three levels
            assert!(back == 0.0 || (back.abs() - m).abs() < 1e-6);
        }
    }

    #[test]
    fn compresses_sparse_gradients_hard() {
        // gradient with many small values -> mostly zero trits -> ZRE wins
        let mut rng = Rng::new(601);
        let g: Vec<f32> = (0..50_000)
            .map(|_| {
                if rng.next_f64() < 0.02 {
                    rng.next_gaussian() as f32
                } else {
                    rng.next_gaussian() as f32 * 0.001
                }
            })
            .collect();
        let c = ThreeLC::new(1.0);
        let enc = c.encode(&g);
        // paper: 3LC reaches ~39x on such tensors; we assert > 20x
        assert!(enc.len() * 20 < g.len() * 4, "3lc size {} vs raw {}", enc.len(), g.len() * 4);
        let dec = c.decode(&enc).unwrap();
        assert_eq!(dec.len(), g.len());
    }

    #[test]
    fn higher_s_more_zeros() {
        let mut rng = Rng::new(602);
        let g: Vec<f32> = (0..5000).map(|_| rng.next_gaussian() as f32).collect();
        let z1 = ThreeLC::new(1.0).decode(&ThreeLC::new(1.0).encode(&g)).unwrap();
        let z2 = ThreeLC::new(1.9).decode(&ThreeLC::new(1.9).encode(&g)).unwrap();
        let n1 = z1.iter().filter(|&&v| v == 0.0).count();
        let n2 = z2.iter().filter(|&&v| v == 0.0).count();
        assert!(n2 > n1, "s=1.9 zeros {n2} vs s=1.0 zeros {n1}");
    }

    #[test]
    fn all_zero_input() {
        let g = vec![0.0f32; 1000];
        let c = ThreeLC::new(1.0);
        let dec = c.decode(&c.encode(&g)).unwrap();
        assert_eq!(dec, g);
    }

    #[test]
    fn length_not_multiple_of_five() {
        for d in [1usize, 4, 5, 6, 9, 11] {
            let g: Vec<f32> = (0..d).map(|i| i as f32 - 2.0).collect();
            let c = ThreeLC::new(1.0);
            let dec = c.decode(&c.encode(&g)).unwrap();
            assert_eq!(dec.len(), d);
        }
    }
}
