//! Synthetic datasets with the statistics the experiments need
//! (DESIGN.md §4 substitution table): cluster images for classification,
//! Zipf implicit-feedback interactions for NCF, and a Markov token
//! corpus for the LM. All deterministic in the seed, shardable by
//! worker rank.

use crate::runtime::artifact::BatchInput;
use crate::util::prng::Rng;

/// Classification batches: K Gaussian clusters in input space, one per
/// class (learnable but not trivial: cluster spread ~ separation).
pub struct SynthImages {
    dim: usize,
    classes: usize,
    batch: usize,
    means: Vec<Vec<f32>>,
    rng: Rng,
    noise: f32,
}

impl SynthImages {
    pub fn new(dim: usize, classes: usize, batch: usize, seed: u64) -> Self {
        // class means drawn once from the SAME seed on every worker,
        // worker rank only perturbs the sampling stream
        let mut meta = Rng::new(seed ^ 0xDA7A_0001);
        // separation scaled by 1/sqrt(dim) so the Bayes accuracy is
        // meaningfully below 1 — otherwise every compressor looks equal
        // and the Fig 6/7 comparisons degenerate
        let scale = 3.0 / (dim as f32).sqrt();
        let means = (0..classes)
            .map(|_| (0..dim).map(|_| meta.next_gaussian() as f32 * scale).collect())
            .collect();
        Self { dim, classes, batch, means, rng: Rng::new(seed), noise: 1.0 }
    }

    pub fn shard(dim: usize, classes: usize, batch: usize, seed: u64, rank: usize) -> Self {
        let mut s = Self::new(dim, classes, batch, seed);
        s.rng = Rng::new(seed.wrapping_add(0x9E37 * (rank as u64 + 1)));
        s
    }

    /// Next batch as artifact inputs [x, y].
    pub fn next_batch(&mut self) -> Vec<BatchInput> {
        let mut x = Vec::with_capacity(self.batch * self.dim);
        let mut y = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let c = self.rng.below(self.classes as u64) as usize;
            y.push(c as i32);
            for j in 0..self.dim {
                x.push(self.means[c][j] + self.rng.next_gaussian() as f32 * self.noise);
            }
        }
        vec![BatchInput::F32(x), BatchInput::I32(y)]
    }
}

/// NCF implicit feedback: Zipf-popular users/items; label from a latent
/// dot-product model (so the task is learnable) + negative sampling.
pub struct SynthNcf {
    users: usize,
    items: usize,
    batch: usize,
    user_lat: Vec<Vec<f32>>,
    item_lat: Vec<Vec<f32>>,
    rng: Rng,
}

impl SynthNcf {
    pub fn new(users: usize, items: usize, batch: usize, seed: u64) -> Self {
        let dim = 4;
        let mut meta = Rng::new(seed ^ 0xDA7A_0002);
        let user_lat =
            (0..users).map(|_| (0..dim).map(|_| meta.next_gaussian() as f32).collect()).collect();
        let item_lat =
            (0..items).map(|_| (0..dim).map(|_| meta.next_gaussian() as f32).collect()).collect();
        Self { users, items, batch, user_lat, item_lat, rng: Rng::new(seed) }
    }

    pub fn shard(users: usize, items: usize, batch: usize, seed: u64, rank: usize) -> Self {
        let mut s = Self::new(users, items, batch, seed);
        s.rng = Rng::new(seed.wrapping_add(0x9E37 * (rank as u64 + 1)));
        s
    }

    fn zipf(&mut self, n: usize) -> usize {
        // log-uniform draw over [0, n): Zipf-like popularity skew (low
        // ids are much more frequent), which is what drives the paper's
        // inherent embedding-gradient sparsity pattern
        let u = self.rng.next_f64();
        let h = (n as f64).ln();
        (((h * u).exp() - 1.0).min(n as f64 - 1.0)) as usize
    }

    pub fn next_batch(&mut self) -> Vec<BatchInput> {
        let mut users = Vec::with_capacity(self.batch);
        let mut items = Vec::with_capacity(self.batch);
        let mut labels = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let u = self.zipf(self.users);
            let i = self.zipf(self.items);
            let dot: f32 =
                self.user_lat[u].iter().zip(&self.item_lat[i]).map(|(a, b)| a * b).sum();
            let p = 1.0 / (1.0 + (-dot as f64).exp());
            labels.push((self.rng.next_f64() < p) as i32 as f32);
            users.push(u as i32);
            items.push(i as i32);
        }
        vec![BatchInput::I32(users), BatchInput::I32(items), BatchInput::F32(labels)]
    }
}

/// Markov-chain token corpus: each token's successor distribution is
/// concentrated on few tokens, so an LM can reduce loss well below
/// ln(vocab).
pub struct TinyCorpus {
    vocab: usize,
    seq: usize,
    batch: usize,
    /// per-token: 4 likely successors
    succ: Vec<[u32; 4]>,
    rng: Rng,
    state: u32,
}

impl TinyCorpus {
    pub fn new(vocab: usize, seq: usize, batch: usize, seed: u64) -> Self {
        let mut meta = Rng::new(seed ^ 0xDA7A_0003);
        let succ = (0..vocab)
            .map(|_| {
                [
                    meta.below(vocab as u64) as u32,
                    meta.below(vocab as u64) as u32,
                    meta.below(vocab as u64) as u32,
                    meta.below(vocab as u64) as u32,
                ]
            })
            .collect();
        Self { vocab, seq, batch, succ, rng: Rng::new(seed), state: 0 }
    }

    pub fn shard(vocab: usize, seq: usize, batch: usize, seed: u64, rank: usize) -> Self {
        let mut s = Self::new(vocab, seq, batch, seed);
        s.rng = Rng::new(seed.wrapping_add(0x9E37 * (rank as u64 + 1)));
        s.state = s.rng.below(vocab as u64) as u32;
        s
    }

    fn next_token(&mut self) -> u32 {
        // 90%: one of the 4 designated successors; 10%: uniform
        let t = if self.rng.next_f64() < 0.9 {
            self.succ[self.state as usize][self.rng.below(4) as usize]
        } else {
            self.rng.below(self.vocab as u64) as u32
        };
        self.state = t;
        t
    }

    /// Next batch as artifact inputs [tokens, targets] (targets are the
    /// next-token shift).
    pub fn next_batch(&mut self) -> Vec<BatchInput> {
        let n = self.batch * self.seq;
        let mut tokens = Vec::with_capacity(n);
        let mut targets = Vec::with_capacity(n);
        for _ in 0..self.batch {
            let mut prev = self.next_token() as i32;
            for _ in 0..self.seq {
                let next = self.next_token() as i32;
                tokens.push(prev);
                targets.push(next);
                prev = next;
            }
        }
        vec![BatchInput::I32(tokens), BatchInput::I32(targets)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::BatchInput;

    #[test]
    fn images_learnable_structure() {
        let mut d = SynthImages::new(16, 4, 256, 7);
        let batch = d.next_batch();
        let (BatchInput::F32(x), BatchInput::I32(y)) = (&batch[0], &batch[1]) else {
            panic!("wrong input kinds")
        };
        assert_eq!(x.len(), 256 * 16);
        assert_eq!(y.len(), 256);
        assert!(y.iter().all(|&c| (0..4).contains(&c)));
        // same-class samples are closer to their mean than to others
        // (statistically): check intra vs inter distance
        let mean_of = |c: i32| -> Vec<f32> {
            let rows: Vec<&[f32]> = y
                .iter()
                .enumerate()
                .filter(|(_, &yc)| yc == c)
                .map(|(i, _)| &x[i * 16..(i + 1) * 16])
                .collect();
            let mut m = vec![0.0f32; 16];
            for r in &rows {
                for (a, &b) in m.iter_mut().zip(*r) {
                    *a += b;
                }
            }
            m.iter_mut().for_each(|v| *v /= rows.len().max(1) as f32);
            m
        };
        let m0 = mean_of(0);
        let m1 = mean_of(1);
        let dist: f32 = m0.iter().zip(&m1).map(|(a, b)| (a - b).powi(2)).sum();
        assert!(dist > 0.05, "class means collapsed: {dist}");
    }

    #[test]
    fn shards_differ_but_share_structure() {
        let mut a = SynthImages::shard(8, 2, 32, 5, 0);
        let mut b = SynthImages::shard(8, 2, 32, 5, 1);
        assert_eq!(a.means, b.means);
        let ba = a.next_batch();
        let bb = b.next_batch();
        let (BatchInput::F32(xa), BatchInput::F32(xb)) = (&ba[0], &bb[0]) else { panic!() };
        assert_ne!(xa, xb);
    }

    #[test]
    fn ncf_labels_correlate_with_latent() {
        let mut d = SynthNcf::new(100, 80, 2000, 11);
        let batch = d.next_batch();
        let (BatchInput::I32(us), BatchInput::I32(is_), BatchInput::F32(ls)) =
            (&batch[0], &batch[1], &batch[2])
        else {
            panic!()
        };
        // positives should have higher latent dot on average
        let mut pos = 0.0f64;
        let mut npos = 0;
        let mut neg = 0.0f64;
        let mut nneg = 0;
        for k in 0..us.len() {
            let dot: f32 = d.user_lat[us[k] as usize]
                .iter()
                .zip(&d.item_lat[is_[k] as usize])
                .map(|(a, b)| a * b)
                .sum();
            if ls[k] > 0.5 {
                pos += dot as f64;
                npos += 1;
            } else {
                neg += dot as f64;
                nneg += 1;
            }
        }
        assert!(npos > 100 && nneg > 100);
        assert!(pos / npos as f64 > neg / nneg as f64 + 0.2);
    }

    #[test]
    fn corpus_is_predictable() {
        let mut d = TinyCorpus::new(64, 32, 4, 13);
        let batch = d.next_batch();
        let (BatchInput::I32(toks), BatchInput::I32(tgts)) = (&batch[0], &batch[1]) else {
            panic!()
        };
        assert_eq!(toks.len(), 128);
        assert_eq!(tgts.len(), 128);
        // shifted relationship within each row
        for b in 0..4 {
            for t in 0..31 {
                assert_eq!(toks[b * 32 + t + 1], tgts[b * 32 + t]);
            }
        }
        // successor concentration: most transitions use the 4 designated
        let mut hits = 0;
        let mut total = 0;
        for b in 0..4 {
            for t in 0..31 {
                let cur = toks[b * 32 + t] as usize;
                let nxt = tgts[b * 32 + t] as u32;
                total += 1;
                if d.succ[cur].contains(&nxt) {
                    hits += 1;
                }
            }
        }
        assert!(hits * 10 >= total * 7, "{hits}/{total}");
    }
}
