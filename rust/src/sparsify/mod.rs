//! Gradient sparsifiers (δ-compressors) and error-feedback memory.
//!
//! DeepReduce sits *behind* a sparsifier: the input to the framework is
//! either an explicitly sparsified gradient (Top-r / Random-r, as in
//! GRACE) or an inherently sparse one (identity). Per paper §2, both
//! Top-r and Random-r are δ-compressors with δ = r/d.

mod memory;
mod randomk;
mod threshold;
mod topk;

pub use memory::ErrorFeedback;
pub use randomk::RandomK;
pub use threshold::Threshold;
pub use topk::{top_r_indices, TopK};

use crate::tensor::SparseTensor;
use crate::util::prng::Rng;

/// A sparsifier maps a dense gradient to a sparse one.
pub trait Sparsifier: Send {
    /// Select the support and produce the sparse gradient.
    fn sparsify(&mut self, grad: &[f32]) -> SparseTensor;

    /// Human-readable name for reports.
    fn name(&self) -> &'static str;
}

/// Identity "sparsifier" for inherently sparse gradients: keeps exactly
/// the nonzero elements (paper: NCF gradients are ~40% zeros).
#[derive(Clone, Debug, Default)]
pub struct Identity;

impl Sparsifier for Identity {
    fn sparsify(&mut self, grad: &[f32]) -> SparseTensor {
        SparseTensor::from_dense(grad)
    }

    fn name(&self) -> &'static str {
        "identity"
    }
}

/// Build a sparsifier by name (config system entry point).
/// `ratio` is r/d for topk/randomk, the absolute threshold for threshold.
pub fn by_name(name: &str, ratio: f64, seed: u64) -> Option<Box<dyn Sparsifier>> {
    match name {
        "topk" | "top-r" | "topr" => Some(Box::new(TopK::new(ratio))),
        "randomk" | "rand-r" | "randr" => Some(Box::new(RandomK::new(ratio, Rng::new(seed)))),
        "threshold" => Some(Box::new(Threshold::new(ratio as f32))),
        "identity" | "none" => Some(Box::new(Identity)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_keeps_nonzeros() {
        let g = vec![0.0f32, 1.0, 0.0, -2.0];
        let s = Identity.sparsify(&g);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.to_dense().data(), g.as_slice());
    }

    #[test]
    fn factory() {
        assert!(by_name("topk", 0.01, 0).is_some());
        assert!(by_name("randomk", 0.01, 0).is_some());
        assert!(by_name("threshold", 0.5, 0).is_some());
        assert!(by_name("identity", 0.0, 0).is_some());
        assert!(by_name("nope", 0.0, 0).is_none());
    }
}
