//! Error-feedback / memory compensation (Stich et al. 2018; Karimireddy
//! et al. 2019). The paper enables memory compensation for all methods in
//! §6.3: the residual `g - C(g)` is accumulated locally and added to the
//! next step's gradient before compression.

use crate::tensor::SparseTensor;

/// Per-tensor residual memory.
#[derive(Clone, Debug)]
pub struct ErrorFeedback {
    residual: Vec<f32>,
    /// residual decay (1.0 = classic EF)
    pub beta: f32,
}

impl ErrorFeedback {
    pub fn new(dim: usize) -> Self {
        Self { residual: vec![0.0; dim], beta: 1.0 }
    }

    pub fn dim(&self) -> usize {
        self.residual.len()
    }

    /// `corrected = grad + beta * residual` (into a fresh buffer).
    pub fn apply(&self, grad: &[f32]) -> Vec<f32> {
        assert_eq!(grad.len(), self.residual.len());
        grad.iter().zip(&self.residual).map(|(&g, &m)| g + self.beta * m).collect()
    }

    /// After compressing `corrected` into `kept`, store the residual
    /// `corrected - kept`.
    pub fn update(&mut self, corrected: &[f32], kept: &SparseTensor) {
        assert_eq!(corrected.len(), self.residual.len());
        assert_eq!(kept.dense_len(), self.residual.len());
        self.residual.copy_from_slice(corrected);
        for (&i, &v) in kept.indices().iter().zip(kept.values()) {
            self.residual[i as usize] -= v;
        }
    }

    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    pub fn reset(&mut self) {
        self.residual.iter_mut().for_each(|x| *x = 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsify::{Sparsifier, TopK};
    use crate::util::prng::Rng;
    use crate::util::stats::l2_sq;

    #[test]
    fn residual_tracks_uncompressed_mass() {
        let mut ef = ErrorFeedback::new(4);
        let g = vec![1.0f32, 10.0, 0.5, -3.0];
        let corrected = ef.apply(&g);
        assert_eq!(corrected, g); // empty memory
        let kept = SparseTensor::new(4, vec![1, 3], vec![10.0, -3.0]);
        ef.update(&corrected, &kept);
        assert_eq!(ef.residual(), &[1.0, 0.0, 0.5, 0.0]);
        // next round: residual folded in
        let g2 = vec![0.0f32; 4];
        let c2 = ef.apply(&g2);
        assert_eq!(c2, &[1.0, 0.0, 0.5, 0.0]);
    }

    #[test]
    fn ef_preserves_total_signal_over_time() {
        // With EF + Top-r, the sum of transmitted values converges to the
        // sum of gradients (no mass is permanently lost).
        let mut rng = Rng::new(50);
        let d = 200;
        let mut ef = ErrorFeedback::new(d);
        let mut topk = TopK::new(0.05);
        let mut sent_sum = vec![0.0f64; d];
        let mut grad_sum = vec![0.0f64; d];
        for _ in 0..400 {
            let g: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32 * 0.1).collect();
            for (a, &b) in grad_sum.iter_mut().zip(&g) {
                *a += b as f64;
            }
            let corrected = ef.apply(&g);
            let kept = topk.sparsify(&corrected);
            ef.update(&corrected, &kept);
            for (&i, &v) in kept.indices().iter().zip(kept.values()) {
                sent_sum[i as usize] += v as f64;
            }
        }
        // residual bounds the difference
        let diff: f64 = grad_sum
            .iter()
            .zip(&sent_sum)
            .map(|(&a, &b)| (a - b).powi(2))
            .sum::<f64>()
            .sqrt();
        let res_norm = l2_sq(ef.residual()).sqrt();
        assert!(
            (diff - res_norm).abs() < 1e-3 * (1.0 + res_norm),
            "diff {diff} vs residual norm {res_norm}"
        );
    }

    #[test]
    fn beta_scales_memory() {
        let mut ef = ErrorFeedback::new(2);
        ef.beta = 0.5;
        let kept = SparseTensor::new(2, vec![], vec![]);
        ef.update(&[2.0, 4.0], &kept);
        assert_eq!(ef.apply(&[0.0, 0.0]), &[1.0, 2.0]);
    }
}
