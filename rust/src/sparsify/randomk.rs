//! Random-r sparsifier (Stich et al. 2018): keep r uniformly random
//! coordinates. Unbiased when rescaled by d/r; the paper uses the plain
//! (biased) variant inside GRACE, which we mirror, with optional
//! rescaling for the unbiased form.

use super::Sparsifier;
use crate::tensor::SparseTensor;
use crate::util::prng::Rng;

#[derive(Clone, Debug)]
pub struct RandomK {
    ratio: f64,
    rng: Rng,
    /// rescale kept values by d/r to make the compressor unbiased
    pub unbiased: bool,
}

impl RandomK {
    pub fn new(ratio: f64, rng: Rng) -> Self {
        assert!(ratio > 0.0 && ratio <= 1.0);
        Self { ratio, rng, unbiased: false }
    }

    pub fn r_for(&self, d: usize) -> usize {
        ((d as f64 * self.ratio).round() as usize).clamp(1, d)
    }
}

impl Sparsifier for RandomK {
    fn sparsify(&mut self, grad: &[f32]) -> SparseTensor {
        let d = grad.len();
        let r = self.r_for(d);
        let mut idx = self.rng.sample_indices(d, r);
        idx.sort_unstable();
        let mut sp = SparseTensor::gather(grad, &idx);
        if self.unbiased {
            let scale = d as f32 / r as f32;
            for v in sp.values_mut() {
                *v *= scale;
            }
        }
        sp
    }

    fn name(&self) -> &'static str {
        "randomk"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::l2_sq;
    use crate::util::testkit::gradient_like;

    #[test]
    fn selects_r_distinct_sorted() {
        let mut s = RandomK::new(0.2, Rng::new(1));
        let g = vec![1.0f32; 1000];
        let sp = s.sparsify(&g);
        assert_eq!(sp.nnz(), 200);
        assert!(sp.indices().windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn expected_error_matches_remark1() {
        // E||g - Randr(g)||^2 = (1 - r/d)||g||^2 over the sampling
        let mut rng = Rng::new(31);
        let g = gradient_like(&mut rng, 400);
        let norm = l2_sq(&g);
        let trials = 300;
        let mut acc = 0.0;
        let mut s = RandomK::new(0.25, Rng::new(99));
        for _ in 0..trials {
            let sp = s.sparsify(&g);
            let dense = sp.to_dense();
            acc += g
                .iter()
                .zip(dense.data())
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>();
        }
        let mean_err = acc / trials as f64;
        let expected = (1.0 - 0.25) * norm;
        assert!(
            (mean_err - expected).abs() / expected < 0.1,
            "mean {mean_err} vs expected {expected}"
        );
    }

    #[test]
    fn unbiased_rescaling() {
        let mut s = RandomK::new(0.5, Rng::new(2));
        s.unbiased = true;
        let g = vec![1.0f32; 10];
        let sp = s.sparsify(&g);
        for &v in sp.values() {
            assert_eq!(v, 2.0);
        }
    }
}
