//! Hard-threshold sparsifier (Strom 2015; Dryden et al. 2016 use an
//! adaptive variant): keep elements with `|g[i]| >= τ`. Output sparsity is
//! data-dependent, which exercises the variable-r paths of the codecs.

use super::Sparsifier;
use crate::tensor::SparseTensor;

#[derive(Clone, Debug)]
pub struct Threshold {
    tau: f32,
    /// if set, adapt τ each call to target this fraction of elements
    /// (simple multiplicative control, Dryden-style)
    pub target_ratio: Option<f64>,
}

impl Threshold {
    pub fn new(tau: f32) -> Self {
        assert!(tau >= 0.0);
        Self { tau, target_ratio: None }
    }

    pub fn adaptive(tau0: f32, target_ratio: f64) -> Self {
        assert!((0.0..=1.0).contains(&target_ratio));
        Self { tau: tau0, target_ratio: Some(target_ratio) }
    }

    pub fn tau(&self) -> f32 {
        self.tau
    }
}

impl Sparsifier for Threshold {
    fn sparsify(&mut self, grad: &[f32]) -> SparseTensor {
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for (i, &x) in grad.iter().enumerate() {
            if x.abs() >= self.tau && x != 0.0 {
                indices.push(i as u32);
                values.push(x);
            }
        }
        if let Some(target) = self.target_ratio {
            // proportional control toward the target keep-fraction
            let got = indices.len() as f64 / grad.len().max(1) as f64;
            if got > 0.0 {
                let adj = (got / target).clamp(0.5, 2.0) as f32;
                self.tau = (self.tau * adj.sqrt()).max(1e-12);
            } else {
                self.tau *= 0.5;
            }
        }
        SparseTensor::new(grad.len(), indices, values)
    }

    fn name(&self) -> &'static str {
        "threshold"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn keeps_only_above_tau() {
        let g = vec![0.1f32, -0.5, 0.04, 2.0, 0.0];
        let mut s = Threshold::new(0.1);
        let sp = s.sparsify(&g);
        assert_eq!(sp.indices(), &[0, 1, 3]);
    }

    #[test]
    fn adaptive_converges_to_target() {
        let mut rng = Rng::new(40);
        let mut s = Threshold::adaptive(1.0, 0.1);
        let mut last_ratio = 0.0;
        for _ in 0..60 {
            let g: Vec<f32> = (0..2000).map(|_| rng.next_gaussian() as f32).collect();
            let sp = s.sparsify(&g);
            last_ratio = sp.nnz() as f64 / g.len() as f64;
        }
        assert!((last_ratio - 0.1).abs() < 0.05, "ratio {last_ratio}");
    }

    #[test]
    fn zero_elements_never_kept() {
        let g = vec![0.0f32; 100];
        let mut s = Threshold::new(0.0);
        assert_eq!(s.sparsify(&g).nnz(), 0);
    }
}
