//! Top-r sparsifier: keep the r highest-magnitude elements (Aji &
//! Heafield 2017; Alistarh et al. 2018). δ-compressor with the smallest
//! error among r-sparsifiers (paper Remark 1).
//!
//! Selection is O(d) expected via quickselect on |g| rather than a full
//! sort — this is the L3 hot path for every training step.

use super::Sparsifier;
use crate::tensor::SparseTensor;

#[derive(Clone, Debug)]
pub struct TopK {
    /// fraction r/d in (0, 1]
    ratio: f64,
}

impl TopK {
    pub fn new(ratio: f64) -> Self {
        assert!(ratio > 0.0 && ratio <= 1.0, "top-k ratio must be in (0,1]: {ratio}");
        Self { ratio }
    }

    /// Number of kept elements for a gradient of dimensionality d
    /// (at least 1, as in GRACE).
    pub fn r_for(&self, d: usize) -> usize {
        ((d as f64 * self.ratio).round() as usize).clamp(1, d)
    }
}

impl Sparsifier for TopK {
    fn sparsify(&mut self, grad: &[f32]) -> SparseTensor {
        let d = grad.len();
        let r = self.r_for(d);
        let idx = top_r_indices(grad, r);
        SparseTensor::gather(grad, &idx)
    }

    fn name(&self) -> &'static str {
        "topk"
    }
}

/// Indices of the r largest |values|, returned sorted ascending.
/// Ties at the threshold magnitude are broken by lower index (so the
/// result is deterministic and exactly r elements).
pub fn top_r_indices(grad: &[f32], r: usize) -> Vec<u32> {
    let d = grad.len();
    assert!(r <= d);
    if r == d {
        return (0..d as u32).collect();
    }
    if r == 0 {
        return Vec::new();
    }
    // quickselect over an index permutation on key |grad[i]| descending
    let mut idx: Vec<u32> = (0..d as u32).collect();
    let key = |i: u32| {
        let v = grad[i as usize].abs();
        // NaN-safe total order: NaN sorts lowest
        if v.is_nan() {
            f32::NEG_INFINITY
        } else {
            v
        }
    };
    // partition so the first r entries have the largest keys
    let mut lo = 0usize;
    let mut hi = d;
    let mut rng = crate::util::prng::SplitMix64::new(0x7091_D00D ^ d as u64);
    while hi - lo > 1 {
        // median-of-3-ish random pivot
        let p = lo + (rng.next_u64() as usize) % (hi - lo);
        let pivot = key(idx[p]);
        // three-way partition (descending): [> pivot | == pivot | < pivot]
        let mut i = lo;
        let mut j = lo;
        let mut k = hi;
        while j < k {
            let kj = key(idx[j]);
            if kj > pivot {
                idx.swap(i, j);
                i += 1;
                j += 1;
            } else if kj < pivot {
                k -= 1;
                idx.swap(j, k);
            } else {
                j += 1;
            }
        }
        if r <= i {
            hi = i;
        } else if r >= j {
            lo = j;
        } else {
            // boundary falls inside the == pivot band: tie-break by index.
            // sort the band ascending by index and cut at r.
            idx[i..j].sort_unstable();
            break;
        }
    }
    let mut out = idx[..r].to_vec();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::testkit::{forall, gradient_like};

    fn top_r_reference(grad: &[f32], r: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..grad.len() as u32).collect();
        idx.sort_by(|&a, &b| {
            let ka = grad[a as usize].abs();
            let kb = grad[b as usize].abs();
            kb.partial_cmp(&ka).unwrap().then(a.cmp(&b))
        });
        let mut out = idx[..r].to_vec();
        out.sort_unstable();
        out
    }

    #[test]
    fn matches_reference_on_random() {
        forall(
            "topk-vs-sort",
            60,
            2000,
            |rng, size| {
                let n = 1 + rng.below(size as u64) as usize;
                let r = 1 + rng.below(n as u64) as usize;
                (gradient_like(rng, n), r)
            },
            |(g, r)| {
                let fast = top_r_indices(g, *r);
                let slow = top_r_reference(g, *r);
                // selected magnitudes must match even if tie indices differ
                let mag = |ix: &[u32]| {
                    let mut m: Vec<f32> = ix.iter().map(|&i| g[i as usize].abs()).collect();
                    m.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    m
                };
                if mag(&fast) == mag(&slow) && fast.len() == *r {
                    Ok(())
                } else {
                    Err(format!("fast {fast:?} != slow {slow:?}"))
                }
            },
        );
    }

    #[test]
    fn exact_on_distinct_values() {
        let g = vec![0.1f32, -5.0, 0.3, 2.0, -0.2];
        assert_eq!(top_r_indices(&g, 2), vec![1, 3]);
        assert_eq!(top_r_indices(&g, 5), vec![0, 1, 2, 3, 4]);
        assert_eq!(top_r_indices(&g, 0), Vec::<u32>::new());
    }

    #[test]
    fn ties_resolved_deterministically() {
        let g = vec![1.0f32; 10];
        let a = top_r_indices(&g, 3);
        let b = top_r_indices(&g, 3);
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn delta_compressor_bound() {
        // Remark 1: E||g - Topr(g)||^2 <= (1 - r/d)||g||^2
        let mut rng = Rng::new(30);
        for _ in 0..20 {
            let g = gradient_like(&mut rng, 500);
            let mut s = TopK::new(0.1);
            let sp = s.sparsify(&g);
            let dense = sp.to_dense();
            let err: f64 = g
                .iter()
                .zip(dense.data())
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum();
            let bound = (1.0 - 0.1) * crate::util::stats::l2_sq(&g);
            assert!(err <= bound + 1e-6, "err {err} > bound {bound}");
        }
    }

    #[test]
    fn r_for_clamps() {
        let t = TopK::new(0.01);
        assert_eq!(t.r_for(10), 1); // rounds to 0 -> clamped to 1
        assert_eq!(t.r_for(36864), 369);
    }
}
