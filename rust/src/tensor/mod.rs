//! Tensor representations: dense, sparse (decoupled keys/values — the
//! core DeepReduce decomposition), and bitmap supports.

mod bitmap;
mod dense;
mod sparse;

pub use bitmap::Bitmap;
pub use dense::Tensor;
pub use sparse::SparseTensor;
