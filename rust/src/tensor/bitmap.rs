//! Bit-string support representation: `B[i] = 1` iff gradient element `i`
//! is nonzero (paper §3, Figure 1c). This is the second of DeepReduce's
//! two equivalent index representations and the input format for RLE.

/// Fixed-length bitmap over a gradient of dimensionality `d`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bitmap {
    words: Vec<u64>,
    len: usize,
}

impl Bitmap {
    pub fn zeros(len: usize) -> Self {
        Self { words: vec![0u64; len.div_ceil(64)], len }
    }

    /// Build from a sorted (or unsorted) index list over domain `[0, len)`.
    pub fn from_indices(len: usize, indices: &[u32]) -> Self {
        let mut b = Self::zeros(len);
        for &i in indices {
            b.set(i as usize);
        }
        b
    }

    /// Build from the nonzero positions of a dense slice.
    pub fn from_dense(data: &[f32]) -> Self {
        let mut b = Self::zeros(data.len());
        for (i, &x) in data.iter().enumerate() {
            if x != 0.0 {
                b.set(i);
            }
        }
        b
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    #[inline]
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] &= !(1u64 << (i % 64));
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Extract the sorted index list (inverse of `from_indices`).
    pub fn to_indices(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.count_ones());
        for (wi, &w) in self.words.iter().enumerate() {
            let mut bits = w;
            while bits != 0 {
                let b = bits.trailing_zeros();
                out.push((wi * 64 + b as usize) as u32);
                bits &= bits - 1;
            }
        }
        out
    }

    /// Iterate runs of identical bits as `(bit, run_len)` — the RLE input.
    pub fn runs(&self) -> RunIter<'_> {
        RunIter { bm: self, pos: 0 }
    }

    /// Raw words (for serialization).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Rebuild from raw words + length.
    pub fn from_words(words: Vec<u64>, len: usize) -> Self {
        assert_eq!(words.len(), len.div_ceil(64));
        // mask tail garbage so equality and counts are well-defined
        let mut b = Self { words, len };
        if len % 64 != 0 {
            if let Some(last) = b.words.last_mut() {
                *last &= (1u64 << (len % 64)) - 1;
            }
        }
        b
    }
}

pub struct RunIter<'a> {
    bm: &'a Bitmap,
    pos: usize,
}

impl Iterator for RunIter<'_> {
    /// (bit value, run length)
    type Item = (bool, usize);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.bm.len {
            return None;
        }
        let bit = self.bm.get(self.pos);
        let start = self.pos;
        // word-at-a-time scan for the next flip
        let mut i = self.pos + 1;
        while i < self.bm.len {
            if i % 64 == 0 {
                // whole-word skip when uniform
                let w = self.bm.words[i / 64];
                let uniform = if bit { u64::MAX } else { 0 };
                if w == uniform && i + 64 <= self.bm.len {
                    i += 64;
                    continue;
                }
            }
            if self.bm.get(i) != bit {
                break;
            }
            i += 1;
        }
        self.pos = i;
        Some((bit, i - start))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn set_get_count() {
        let mut b = Bitmap::zeros(130);
        assert_eq!(b.count_ones(), 0);
        b.set(0);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(64) && b.get(129));
        assert!(!b.get(1));
        assert_eq!(b.count_ones(), 3);
        b.clear(64);
        assert_eq!(b.count_ones(), 2);
    }

    #[test]
    fn indices_roundtrip() {
        let idx = vec![0u32, 5, 63, 64, 65, 127, 128];
        let b = Bitmap::from_indices(200, &idx);
        assert_eq!(b.to_indices(), idx);
    }

    #[test]
    fn from_dense_matches() {
        let data = [0.0f32, 1.0, 0.0, -2.0, 0.0];
        let b = Bitmap::from_dense(&data);
        assert_eq!(b.to_indices(), vec![1, 3]);
    }

    #[test]
    fn runs_cover_and_alternate() {
        let mut rng = Rng::new(20);
        for _ in 0..20 {
            let n = 1 + rng.below(500) as usize;
            let mut b = Bitmap::zeros(n);
            for i in 0..n {
                if rng.next_f64() < 0.3 {
                    b.set(i);
                }
            }
            let runs: Vec<(bool, usize)> = b.runs().collect();
            let total: usize = runs.iter().map(|&(_, l)| l).sum();
            assert_eq!(total, n);
            for w in runs.windows(2) {
                assert_ne!(w[0].0, w[1].0, "adjacent runs must alternate");
            }
            // reconstruct
            let mut pos = 0;
            let mut b2 = Bitmap::zeros(n);
            for (bit, l) in runs {
                if bit {
                    for i in pos..pos + l {
                        b2.set(i);
                    }
                }
                pos += l;
            }
            assert_eq!(b, b2);
        }
    }

    #[test]
    fn long_uniform_runs_fast_path() {
        let mut b = Bitmap::zeros(10_000);
        for i in 3000..7000 {
            b.set(i);
        }
        let runs: Vec<(bool, usize)> = b.runs().collect();
        assert_eq!(runs, vec![(false, 3000), (true, 4000), (false, 3000)]);
    }

    #[test]
    fn from_words_masks_tail() {
        let b = Bitmap::from_words(vec![u64::MAX], 10);
        assert_eq!(b.count_ones(), 10);
    }
}
