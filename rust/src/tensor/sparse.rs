//! Sparse tensor in decoupled index/value form — the central DeepReduce
//! data structure (paper §3): the support set `S` (sorted u32 indices)
//! and the value array `V` with `V[i] = g[S[i]]`, plus the dense
//! dimensionality `d` needed for reconstruction.

use super::{Bitmap, Tensor};

/// `r`-sparse view of a gradient of dimensionality `d`.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseTensor {
    /// dense dimensionality d
    dense_len: usize,
    /// sorted, unique indices (the support set S)
    indices: Vec<u32>,
    /// values aligned with `indices`
    values: Vec<f32>,
}

impl SparseTensor {
    /// Construct from parallel arrays. Indices must be sorted and unique.
    pub fn new(dense_len: usize, indices: Vec<u32>, values: Vec<f32>) -> Self {
        assert_eq!(indices.len(), values.len());
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]), "indices must be sorted+unique");
        debug_assert!(indices.last().is_none_or(|&i| (i as usize) < dense_len));
        Self { dense_len, indices, values }
    }

    /// Extract all nonzero elements of a dense slice.
    pub fn from_dense(data: &[f32]) -> Self {
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for (i, &x) in data.iter().enumerate() {
            if x != 0.0 {
                indices.push(i as u32);
                values.push(x);
            }
        }
        Self { dense_len: data.len(), indices, values }
    }

    /// Gather `g[S[i]]` for a given support over a dense gradient.
    pub fn gather(data: &[f32], support: &[u32]) -> Self {
        let values = support.iter().map(|&i| data[i as usize]).collect();
        Self { dense_len: data.len(), indices: support.to_vec(), values }
    }

    pub fn dense_len(&self) -> usize {
        self.dense_len
    }

    /// Number of stored (nonzero) elements, r.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    pub fn values(&self) -> &[f32] {
        &self.values
    }

    pub fn values_mut(&mut self) -> &mut [f32] {
        &mut self.values
    }

    pub fn into_parts(self) -> (usize, Vec<u32>, Vec<f32>) {
        (self.dense_len, self.indices, self.values)
    }

    /// Scatter back to a dense vector (zeros elsewhere).
    pub fn to_dense(&self) -> Tensor {
        let mut data = vec![0.0f32; self.dense_len];
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            data[i as usize] = v;
        }
        Tensor::from_vec(data)
    }

    /// Scatter-add into an existing dense buffer (aggregation path).
    pub fn add_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.dense_len);
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            out[i as usize] += v;
        }
    }

    /// The bitmap representation of the support set.
    pub fn support_bitmap(&self) -> Bitmap {
        Bitmap::from_indices(self.dense_len, &self.indices)
    }

    /// Wire size of the naive `<key,value>` representation in bytes
    /// (32-bit keys + 32-bit values) — the paper's Figure 1b baseline.
    pub fn kv_wire_bytes(&self) -> usize {
        self.nnz() * 8
    }

    /// Squared l2 norm.
    pub fn l2_sq(&self) -> f64 {
        crate::util::stats::l2_sq(&self.values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip() {
        let data = vec![0.0f32, 1.5, 0.0, 0.0, -2.0, 0.25];
        let s = SparseTensor::from_dense(&data);
        assert_eq!(s.nnz(), 3);
        assert_eq!(s.indices(), &[1, 4, 5]);
        assert_eq!(s.values(), &[1.5, -2.0, 0.25]);
        assert_eq!(s.to_dense().data(), data.as_slice());
    }

    #[test]
    fn gather_uses_support_order() {
        let data = vec![10.0f32, 20.0, 30.0, 40.0];
        let s = SparseTensor::gather(&data, &[1, 3]);
        assert_eq!(s.values(), &[20.0, 40.0]);
        assert_eq!(s.dense_len(), 4);
    }

    #[test]
    fn add_into_accumulates() {
        let s = SparseTensor::new(4, vec![0, 2], vec![1.0, 2.0]);
        let mut acc = vec![1.0f32; 4];
        s.add_into(&mut acc);
        assert_eq!(acc, vec![2.0, 1.0, 3.0, 1.0]);
    }

    #[test]
    fn support_bitmap_matches() {
        let s = SparseTensor::new(10, vec![0, 7, 9], vec![1.0, 2.0, 3.0]);
        let b = s.support_bitmap();
        assert_eq!(b.to_indices(), s.indices());
    }

    #[test]
    fn figure1_example_sizes() {
        // Paper Fig 1: d=8, r=4 -> dense 256 bits, kv 256 bits
        let s = SparseTensor::new(8, vec![1, 3, 5, 6], vec![4.6, 5.8, 7.0, 7.6]);
        assert_eq!(s.kv_wire_bytes() * 8, 256);
        assert_eq!(s.dense_len() * 32, 256);
    }
}
