//! Dense f32 tensor: a shape plus a flat buffer.
//!
//! Gradients cross the compression framework *flattened* (the paper
//! operates on rank-1 views of each layer's gradient); shape is carried
//! for the runtime boundary where literals need their original form.

use std::fmt;

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        let numel: usize = shape.iter().product();
        assert_eq!(numel, data.len(), "shape {shape:?} != data len {}", data.len());
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let numel = shape.iter().product();
        Self { shape, data: vec![0.0; numel] }
    }

    pub fn from_vec(data: Vec<f32>) -> Self {
        Self { shape: vec![data.len()], data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshaped(mut self, shape: Vec<usize>) -> Self {
        let numel: usize = shape.iter().product();
        assert_eq!(numel, self.data.len());
        self.shape = shape;
        self
    }

    /// Squared l2 norm in f64.
    pub fn l2_sq(&self) -> f64 {
        crate::util::stats::l2_sq(&self.data)
    }

    /// Count of exactly-zero elements (for inherent-sparsity stats).
    pub fn zero_count(&self) -> usize {
        self.data.iter().filter(|&&x| x == 0.0).count()
    }

    /// `self += other`
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// `self *= s`
    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 0.0]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.zero_count(), 1);
        assert!((t.l2_sq() - 55.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn arithmetic() {
        let mut a = Tensor::from_vec(vec![1.0, 2.0]);
        let b = Tensor::from_vec(vec![3.0, 4.0]);
        a.add_assign(&b);
        assert_eq!(a.data(), &[4.0, 6.0]);
        a.scale(0.5);
        assert_eq!(a.data(), &[2.0, 3.0]);
    }

    #[test]
    fn reshape() {
        let t = Tensor::from_vec(vec![0.0; 6]).reshaped(vec![3, 2]);
        assert_eq!(t.shape(), &[3, 2]);
    }
}
