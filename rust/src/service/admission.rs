//! Admission control: validate a job request and reserve its share of
//! fabric capacity before any rank is claimed.
//!
//! A job is admitted only when (1) enough fabric ranks are free for its
//! placement and (2) the sum of all running jobs' single-step byte
//! estimates — plus this job's — still fits the per-round frame budget
//! on every link class its placement touches. (2) is what lets the
//! scheduler's progress floor (`crate::service::scheduler`) guarantee
//! one step per tenant per round without ever overrunning a frame.

use super::scheduler::LinkClass;
use crate::collective::{Schedule, SparseConfig, Topology};
use crate::compress::CompressSpec;

/// Everything a tenant declares when it asks the service for capacity.
#[derive(Clone, Debug)]
pub struct JobRequest {
    /// Unique among running jobs; prefixes the job's artifacts.
    pub name: String,
    /// Profile-key component (`PROFILE_<model>_…`): which model family
    /// the autotune calibration describes.
    pub model: String,
    /// Fabric ranks the job reduces over.
    pub ranks: usize,
    /// Fair-share weight (> 0): relative claim on each round's surplus
    /// after every tenant's floor step.
    pub weight: f64,
    /// Gradient domain per step (fused bucket length).
    pub dim: usize,
    /// Expected gradient density in (0, 1] — drives the admission byte
    /// estimate and the autotuner's codec pick.
    pub density: f64,
    /// Collective schedule. `Hierarchical` is only admitted for jobs
    /// spanning the whole fabric (leader roles pin every rank).
    pub schedule: Schedule,
    /// `ChunkedRescatter` chunk count (0 = auto).
    pub chunks: usize,
    /// Index/value codec pipelines (lossy stages fall back to raw on
    /// the wire, as in the trainer).
    pub compress: CompressSpec,
    /// Autotune at admission: calibrate (or warm-load) a
    /// `CodecPolicy`, pick the codec pair and schedule for the job's
    /// density, and persist the profile at finish.
    pub autotune: bool,
    pub seed: u64,
    /// Full sparse-collective tuning override (the trainer-client path
    /// threads its `SparseConfig` through verbatim). `None` = service
    /// defaults with [`JobRequest::chunks`].
    pub sparse: Option<SparseConfig>,
}

impl JobRequest {
    /// A synthetic-gradient tenant with service defaults: weight 1,
    /// chunked-rescatter, raw codecs, no autotune.
    pub fn synthetic(name: &str, ranks: usize, dim: usize, density: f64) -> Self {
        Self {
            name: name.to_string(),
            model: name.to_string(),
            ranks,
            weight: 1.0,
            dim,
            density,
            schedule: Schedule::ChunkedRescatter,
            chunks: 0,
            compress: CompressSpec::raw(),
            autotune: false,
            seed: 0xD0_5E11,
            sparse: None,
        }
    }

    /// Entries a step's sparsified gradient keeps.
    pub fn nnz(&self) -> usize {
        ((self.dim as f64 * self.density).round() as usize).clamp(1, self.dim.max(1))
    }

    /// Admission byte estimate for one step: every member ships its
    /// container (~32 B header + 8 B per entry) once and receives the
    /// aggregate once. Deliberately a coarse upper proxy — scheduling
    /// charges the *metered* bytes, this number only sizes the
    /// reservation.
    pub fn est_step_bytes(&self) -> f64 {
        2.0 * self.ranks as f64 * (32.0 + 8.0 * self.nnz() as f64)
    }
}

/// Why a request was turned away. Structured so callers (CLI, tests)
/// can react per cause instead of string-matching.
#[derive(Debug)]
pub enum AdmissionError {
    /// The request itself is invalid (zero ranks, non-positive weight,
    /// density outside (0, 1], hierarchical on a partial placement…).
    BadRequest(String),
    /// A running job already uses this name.
    DuplicateName(String),
    /// Not enough free fabric ranks.
    NoCapacity { need: usize, free: usize },
    /// The per-round byte budget on one link class cannot absorb this
    /// job's floor step on top of the running tenants'.
    BudgetExceeded { class: LinkClass, need_bytes: f64, free_bytes: f64 },
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::BadRequest(m) => write!(f, "bad job request: {m}"),
            AdmissionError::DuplicateName(n) => {
                write!(f, "job name {n:?} is already running")
            }
            AdmissionError::NoCapacity { need, free } => {
                write!(f, "placement needs {need} ranks but only {free} are free")
            }
            AdmissionError::BudgetExceeded { class, need_bytes, free_bytes } => write!(
                f,
                "{} frame budget cannot absorb the job's floor step \
                 ({need_bytes:.0} B needed, {free_bytes:.0} B free)",
                class.name()
            ),
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Validate `req` against a previewed `placement` and the scheduler's
/// current load, returning the per-class single-step byte estimate the
/// scheduler should reserve. Does not mutate anything — the caller
/// commits placement + share only on `Ok`.
pub fn admit(
    req: &JobRequest,
    topo: Topology,
    placement: &[usize],
    load: [f64; 2],
    frame_budget: [f64; 2],
) -> Result<[f64; 2], AdmissionError> {
    if req.name.is_empty() {
        return Err(AdmissionError::BadRequest("empty job name".into()));
    }
    if req.ranks == 0 {
        return Err(AdmissionError::BadRequest("ranks must be >= 1".into()));
    }
    if !(req.weight.is_finite() && req.weight > 0.0) {
        return Err(AdmissionError::BadRequest(format!(
            "weight must be a positive finite number, got {}",
            req.weight
        )));
    }
    if req.dim == 0 {
        return Err(AdmissionError::BadRequest("dim must be >= 1".into()));
    }
    if !(req.density.is_finite() && req.density > 0.0 && req.density <= 1.0) {
        return Err(AdmissionError::BadRequest(format!(
            "density must be in (0, 1], got {}",
            req.density
        )));
    }
    if req.schedule == Schedule::Hierarchical && req.ranks != topo.world() {
        return Err(AdmissionError::BadRequest(
            "hierarchical jobs must span the whole fabric \
             (leader roles pin every rank of the grid)"
                .into(),
        ));
    }
    debug_assert_eq!(placement.len(), req.ranks);
    // which classes the placement exercises: members on one node never
    // cross the inter boundary; a multi-node span is charged on both
    let crosses = spans_nodes(topo, placement);
    let total = req.est_step_bytes();
    let est = [total, if crosses { total } else { 0.0 }];
    for class in LinkClass::ALL {
        let c = class.idx();
        if est[c] > 0.0 && load[c] + est[c] > frame_budget[c] {
            return Err(AdmissionError::BudgetExceeded {
                class,
                need_bytes: est[c],
                free_bytes: (frame_budget[c] - load[c]).max(0.0),
            });
        }
    }
    Ok(est)
}

/// Whether a placement spans more than one node of the grid.
pub fn spans_nodes(topo: Topology, placement: &[usize]) -> bool {
    match placement.split_first() {
        Some((&first, rest)) => {
            let n0 = topo.node_of(first);
            rest.iter().any(|&r| topo.node_of(r) != n0)
        }
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates_request_fields() {
        let topo = Topology::new(2, 4);
        let ok = JobRequest::synthetic("a", 2, 4096, 0.01);
        let placement = [0usize, 1];
        assert!(admit(&ok, topo, &placement, [0.0; 2], [1e9; 2]).is_ok());
        for (patch, what) in [
            (Box::new(|r: &mut JobRequest| r.name.clear()) as Box<dyn Fn(&mut JobRequest)>, "name"),
            (Box::new(|r: &mut JobRequest| r.weight = 0.0), "weight"),
            (Box::new(|r: &mut JobRequest| r.weight = f64::NAN), "nan weight"),
            (Box::new(|r: &mut JobRequest| r.dim = 0), "dim"),
            (Box::new(|r: &mut JobRequest| r.density = 0.0), "density 0"),
            (Box::new(|r: &mut JobRequest| r.density = 1.5), "density 1.5"),
            (Box::new(|r: &mut JobRequest| r.schedule = Schedule::Hierarchical), "partial hier"),
        ] {
            let mut bad = ok.clone();
            patch(&mut bad);
            assert!(
                matches!(
                    admit(&bad, topo, &placement, [0.0; 2], [1e9; 2]),
                    Err(AdmissionError::BadRequest(_))
                ),
                "{what} should be rejected"
            );
        }
    }

    #[test]
    fn single_node_placements_skip_the_inter_budget() {
        let topo = Topology::new(2, 4);
        let req = JobRequest::synthetic("a", 4, 4096, 0.01);
        // inter budget is exhausted, but ranks 0-3 sit on node 0
        let est = admit(&req, topo, &[0, 1, 2, 3], [0.0, 0.0], [1e9, 0.0]).unwrap();
        assert!(est[0] > 0.0);
        assert_eq!(est[1], 0.0);
        // a node-spanning placement needs the inter budget too
        let err = admit(&req, topo, &[2, 3, 4, 5], [0.0, 0.0], [1e9, 0.0]);
        assert!(
            matches!(err, Err(AdmissionError::BudgetExceeded { class: LinkClass::Inter, .. })),
            "{err:?}"
        );
    }

    #[test]
    fn budget_accounts_for_running_load() {
        let topo = Topology::flat(8);
        let req = JobRequest::synthetic("a", 2, 4096, 0.5);
        let est = req.est_step_bytes();
        let placement = [0usize, 1];
        assert!(admit(&req, topo, &placement, [0.0; 2], [est * 2.0, est * 2.0]).is_ok());
        let full = admit(&req, topo, &placement, [est * 1.5, 0.0], [est * 2.0, est * 2.0]);
        assert!(matches!(
            full,
            Err(AdmissionError::BudgetExceeded { class: LinkClass::Intra, .. })
        ));
    }

    #[test]
    fn estimate_scales_with_density_and_ranks() {
        let sparse = JobRequest::synthetic("s", 4, 1 << 16, 0.001);
        let dense = JobRequest::synthetic("d", 4, 1 << 16, 0.9);
        assert!(dense.est_step_bytes() > 100.0 * sparse.est_step_bytes());
        let wide = JobRequest::synthetic("w", 8, 1 << 16, 0.001);
        assert!(wide.est_step_bytes() > 1.9 * sparse.est_step_bytes());
    }
}
