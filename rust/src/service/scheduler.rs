//! Weighted deficit fair-share scheduler: per-round byte budgets on
//! each link class, with a progress floor.
//!
//! Classic weighted deficit round robin (DRR) divides a frame's byte
//! budget among tenants in proportion to weight and serves a tenant
//! while its deficit counter covers the next quantum. Two adaptations
//! for the reduction service:
//!
//! 1. **Two link classes.** A step consumes intra- and inter-node
//!    budget simultaneously, so each tenant keeps one deficit counter
//!    *per class* and a step is affordable only when every class it
//!    touches is covered.
//! 2. **A progress floor.** Admission guarantees that the sum of all
//!    admitted jobs' single-step estimates fits the frame budget
//!    (`crate::service::admission`), so every round serves every
//!    tenant at least once before any deficit-funded extra steps. This
//!    is what makes starvation structurally impossible: a dense tenant
//!    can consume the whole *surplus*, never a sparse tenant's floor
//!    step.
//!
//! Deficits are charged with **actual metered bytes** (the provisional
//! estimate is reconciled in [`FairShare::charge`]), so a tenant that
//! underestimates its traffic repays the overdraft from later rounds'
//! credits. Both credit and overdraft are clamped to one frame plus one
//! step burst, which bounds any tenant's unfairness window to a
//! constant number of frames — the standard DRR latency bound.

use super::registry::JobId;
use std::collections::BTreeMap;

/// The two metered link classes of the shared fabric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkClass {
    Intra,
    Inter,
}

impl LinkClass {
    pub const ALL: [LinkClass; 2] = [LinkClass::Intra, LinkClass::Inter];

    pub fn idx(self) -> usize {
        match self {
            LinkClass::Intra => 0,
            LinkClass::Inter => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LinkClass::Intra => "intra",
            LinkClass::Inter => "inter",
        }
    }
}

struct Tenant {
    weight: f64,
    /// Banked byte credit per link class (can run negative after an
    /// underestimated step, down to the clamp).
    deficit: [f64; 2],
    /// Estimated bytes one step costs this tenant per class.
    est_step: [f64; 2],
}

/// The per-round scheduling state. One instance per
/// [`crate::service::ReductionService`].
pub struct FairShare {
    frame_budget: [f64; 2],
    tenants: BTreeMap<u32, Tenant>,
}

impl FairShare {
    /// `frame_budget` is the bytes one scheduling round may put on each
    /// link class. `f64::INFINITY` disables metering on a class (the
    /// single-tenant trainer path).
    pub fn new(frame_budget: [f64; 2]) -> Self {
        Self { frame_budget, tenants: BTreeMap::new() }
    }

    pub fn frame_budget(&self) -> [f64; 2] {
        self.frame_budget
    }

    /// Sum of admitted tenants' single-step estimates per class — the
    /// load admission compares against the frame budget.
    pub fn load(&self) -> [f64; 2] {
        let mut l = [0.0; 2];
        for t in self.tenants.values() {
            l[0] += t.est_step[0];
            l[1] += t.est_step[1];
        }
        l
    }

    pub fn tenant_count(&self) -> usize {
        self.tenants.len()
    }

    /// Current banked credit of one tenant (tests and reports).
    pub fn deficit(&self, id: JobId) -> Option<[f64; 2]> {
        self.tenants.get(&id.0).map(|t| t.deficit)
    }

    /// Register an admitted tenant. `est_step` is its per-class
    /// single-step byte estimate (from admission).
    pub fn admit(&mut self, id: JobId, weight: f64, est_step: [f64; 2]) {
        let tenant =
            Tenant { weight: weight.max(f64::MIN_POSITIVE), deficit: [0.0; 2], est_step };
        self.tenants.insert(id.0, tenant);
    }

    pub fn remove(&mut self, id: JobId) {
        self.tenants.remove(&id.0);
    }

    /// Plan one scheduling round: credit every tenant its weighted
    /// share of the frame budget, then return the service order — one
    /// floor step per tenant (ascending id), followed by extra steps
    /// granted to the largest banked surplus while deficits cover them.
    /// Deterministic: ties break toward the lower id.
    pub fn next_round(&mut self) -> Vec<JobId> {
        if self.tenants.is_empty() {
            return Vec::new();
        }
        let total_w: f64 = self.tenants.values().map(|t| t.weight).sum();
        let budget = self.frame_budget;
        let ids: Vec<u32> = self.tenants.keys().copied().collect();
        for id in &ids {
            let share = self.tenants[id].weight / total_w;
            let t = self.tenants.get_mut(id).unwrap();
            for c in 0..2 {
                if budget[c].is_finite() {
                    t.deficit[c] += share * budget[c];
                }
            }
        }
        for id in &ids {
            let t = self.tenants.get_mut(id).unwrap();
            Self::clamp_static(budget, t);
        }
        // progress floor: one step each, provisionally charged at the
        // estimate ([`FairShare::charge`] reconciles to actual bytes)
        let quota = self.round_quota();
        let mut spent = [0.0; 2];
        let mut order: Vec<JobId> = Vec::new();
        for id in &ids {
            let t = self.tenants.get_mut(id).unwrap();
            for c in 0..2 {
                t.deficit[c] -= t.est_step[c];
                spent[c] += t.est_step[c];
            }
            order.push(JobId(*id));
        }
        // surplus service: highest normalized surplus first, while the
        // tenant can afford a full step in every class it uses AND the
        // round's scheduled estimates stay inside [`Self::round_quota`]
        // (banked refunds from over-estimated steps must not let one
        // round flood the fabric). The cap bounds the round even under
        // a zero-cost estimate.
        let cap = ids.len() * 8;
        while order.len() < cap {
            let mut best: Option<(f64, u32)> = None;
            for (&id, t) in &self.tenants {
                let affordable = (0..2).all(|c| {
                    t.est_step[c] <= 0.0
                        || (t.deficit[c] >= t.est_step[c] && spent[c] + t.est_step[c] <= quota[c])
                });
                if !affordable {
                    continue;
                }
                let surplus = (0..2)
                    .filter(|&c| t.est_step[c] > 0.0)
                    .map(|c| t.deficit[c] / t.est_step[c])
                    .fold(f64::INFINITY, f64::min);
                if best.is_none_or(|(s, _)| surplus > s) {
                    best = Some((surplus, id));
                }
            }
            let Some((_, id)) = best else { break };
            let t = self.tenants.get_mut(&id).unwrap();
            for c in 0..2 {
                t.deficit[c] -= t.est_step[c];
                spent[c] += t.est_step[c];
            }
            order.push(JobId(id));
        }
        order
    }

    fn clamp_static(budget: [f64; 2], t: &mut Tenant) {
        for c in 0..2 {
            if !budget[c].is_finite() {
                continue;
            }
            let cap = budget[c] + t.est_step[c];
            t.deficit[c] = t.deficit[c].clamp(-cap, cap);
        }
    }

    /// Reconcile one executed step: replace the provisional estimate
    /// charged in [`FairShare::next_round`] with the actually metered
    /// bytes. Overdraft is clamped to one frame + one burst.
    pub fn charge(&mut self, id: JobId, actual: [f64; 2]) {
        let budget = self.frame_budget;
        if let Some(t) = self.tenants.get_mut(&id.0) {
            for c in 0..2 {
                t.deficit[c] += t.est_step[c] - actual[c];
            }
            Self::clamp_static(budget, t);
        }
    }

    /// The hard per-round byte ceiling the round order respects on each
    /// class: the frame budget plus one single-step burst per tenant
    /// (the standard DRR slack — a tenant's last affordable step may
    /// straddle the budget edge). Property tests assert scheduled
    /// estimates against this.
    pub fn round_quota(&self) -> [f64; 2] {
        let mut q = self.frame_budget;
        for t in self.tenants.values() {
            q[0] += t.est_step[0];
            q[1] += t.est_step[1];
        }
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est(i: f64, x: f64) -> [f64; 2] {
        [i, x]
    }

    #[test]
    fn every_tenant_gets_a_floor_step() {
        let mut fs = FairShare::new([1000.0, 1000.0]);
        fs.admit(JobId(0), 100.0, est(900.0, 0.0)); // dense bully
        fs.admit(JobId(1), 1.0, est(50.0, 0.0));
        fs.admit(JobId(2), 1.0, est(50.0, 0.0));
        for _ in 0..20 {
            let order = fs.next_round();
            for id in [0, 1, 2] {
                assert!(
                    order.contains(&JobId(id)),
                    "tenant {id} starved in round order {order:?}"
                );
            }
            for id in &order {
                // reconcile with actuals equal to the estimate
                let actual = if id.0 == 0 { est(900.0, 0.0) } else { est(50.0, 0.0) };
                fs.charge(*id, actual);
            }
        }
    }

    #[test]
    fn weights_steer_the_surplus() {
        let mut fs = FairShare::new([10_000.0, 0.0]);
        fs.admit(JobId(0), 9.0, est(1000.0, 0.0));
        fs.admit(JobId(1), 1.0, est(1000.0, 0.0));
        let mut steps = [0usize; 2];
        for _ in 0..50 {
            for id in fs.next_round() {
                steps[id.0 as usize] += 1;
                fs.charge(id, est(1000.0, 0.0));
            }
        }
        assert!(steps[1] >= 50, "floor guarantees one step per round: {steps:?}");
        assert!(
            steps[0] > 3 * steps[1],
            "a 9x weight should win most surplus steps: {steps:?}"
        );
    }

    #[test]
    fn round_estimates_respect_the_quota() {
        let mut fs = FairShare::new([5000.0, 2000.0]);
        let ests = [est(1200.0, 400.0), est(800.0, 100.0), est(3000.0, 1500.0)];
        for (i, e) in ests.iter().enumerate() {
            fs.admit(JobId(i as u32), 1.0 + i as f64, *e);
        }
        let quota = fs.round_quota();
        // reconcile at the estimate, then at half of it: tenants that
        // keep under-running their estimate bank refunds, and the quota
        // must hold structurally even once everyone is flush
        for scale in [1.0, 0.5] {
            for _ in 0..30 {
                let order = fs.next_round();
                let mut used = [0.0; 2];
                for id in &order {
                    let e = ests[id.0 as usize];
                    used[0] += e[0];
                    used[1] += e[1];
                    fs.charge(*id, [e[0] * scale, e[1] * scale]);
                }
                for c in 0..2 {
                    assert!(
                        used[c] <= quota[c] + 1e-6,
                        "class {c} at scale {scale}: {used:?} exceeds quota {quota:?} \
                         (order {order:?})"
                    );
                }
            }
        }
    }

    #[test]
    fn infinite_budget_disables_metering() {
        let mut fs = FairShare::new([f64::INFINITY, f64::INFINITY]);
        fs.admit(JobId(0), 1.0, est(1e9, 1e9));
        let order = fs.next_round();
        assert!(!order.is_empty());
        fs.charge(JobId(0), est(5e9, 5e9));
        let d = fs.deficit(JobId(0)).unwrap();
        assert!(d[0].is_finite() && d[1].is_finite(), "no NaN/Inf poisoning: {d:?}");
    }

    #[test]
    fn removal_frees_the_share() {
        let mut fs = FairShare::new([1000.0, 1000.0]);
        fs.admit(JobId(0), 1.0, est(400.0, 0.0));
        fs.admit(JobId(1), 1.0, est(400.0, 0.0));
        assert_eq!(fs.load(), [800.0, 0.0]);
        fs.remove(JobId(0));
        assert_eq!(fs.load(), [400.0, 0.0]);
        assert_eq!(fs.tenant_count(), 1);
        assert!(fs.deficit(JobId(0)).is_none());
    }
}
