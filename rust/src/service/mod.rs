//! Multi-tenant reduction service: one shared fleet fabric, many
//! concurrent training jobs.
//!
//! DeepReduce frames compressed sparse communication as *system
//! support* — transparent to the job, orthogonal to the sparsifier —
//! which in production means a long-running service rather than a
//! per-process pool. This module promotes the trainer's private
//! `CollectivePool`/`FleetPool` into that service:
//!
//! - [`registry`] — job identity, disjoint rank placement, per-job
//!   accounting (steps, virtual seconds, metered bytes per link class).
//! - [`admission`] — request validation plus capacity/byte-budget
//!   checks; a job is only admitted when every running tenant can still
//!   take its guaranteed floor step per round.
//! - [`scheduler`] — weighted deficit fair-share over the two link
//!   classes, with a progress floor so a dense tenant can outspend but
//!   never starve a sparse one.
//! - [`profiles`] — versioned `PROFILE_<model>_<topology>_<link>.json`
//!   artifacts persisting [`crate::pipeline::CodecPolicy`] calibration,
//!   so a returning job warm-starts without the calibration sweep.
//! - [`api`] — the [`ReductionService`] itself: submit / step /
//!   run_round / finish over one shared `fleetsim` event loop.
//!
//! The `serve` CLI subcommand (`crate::cli`) drives an in-process
//! instance with synthetic tenants; `coordinator::Trainer`'s fleet mode
//! is a single-tenant client of the same API.

pub mod admission;
pub mod api;
pub mod profiles;
pub mod registry;
pub mod scheduler;

pub use admission::{admit, spans_nodes, AdmissionError, JobRequest};
pub use api::{ReductionService, ServiceConfig, StepReport};
pub use profiles::{Profile, ProfileError, ProfileKey, ProfileStore, PROFILE_SCHEMA_VERSION};
pub use registry::{JobEntry, JobId, JobRegistry, JobState, SetupStats};
pub use scheduler::{FairShare, LinkClass};
