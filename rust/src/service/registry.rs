//! Job registry: identity, rank placement, and per-job accounting for
//! every tenant admitted to the shared reduction fabric.
//!
//! The registry owns the free-rank pool. Placements are disjoint
//! ascending rank sets handed out lowest-first, so two running jobs
//! never share a fabric port — the property the `mixed_tenant_scaling`
//! bench's isolation gate rests on (a member's event-loop state machine
//! only touches its own ports; see `crate::fleetsim`).

use std::collections::{BTreeMap, BTreeSet};

/// Opaque handle for one admitted job.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(pub u32);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job{}", self.0)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Running,
    Finished,
}

/// Startup-cost breakdown: what the job paid before its first step.
/// `calibration_s` is the wall-clock autotune sweep (cold start);
/// `profile_load_s` is the wall-clock `PROFILE_*.json` load + import
/// (warm start). At most one of the two is non-zero.
#[derive(Clone, Copy, Debug, Default)]
pub struct SetupStats {
    pub warm_start: bool,
    pub calibration_s: f64,
    pub profile_load_s: f64,
}

impl SetupStats {
    /// Total setup seconds charged ahead of the first step.
    pub fn total_s(&self) -> f64 {
        self.calibration_s + self.profile_load_s
    }
}

/// Accounting row for one job.
pub struct JobEntry {
    pub id: JobId,
    pub name: String,
    /// Ascending, disjoint fabric ranks this job reduces over.
    pub placement: Vec<usize>,
    pub weight: f64,
    pub state: JobState,
    pub steps: u64,
    /// Metered fabric traffic attributed to this job, `[intra, inter]`.
    pub bytes: [u64; 2],
    /// Accumulated virtual step seconds (sum of per-step critical
    /// paths over the job's members).
    pub virtual_s: f64,
    /// Setup seconds plus the first step's virtual seconds — the
    /// cold-vs-warm number the bench gates on. `None` until step 1.
    pub first_step_s: Option<f64>,
    pub setup: SetupStats,
}

impl JobEntry {
    /// Mean virtual seconds per completed step (NaN before step 1).
    pub fn step_time_s(&self) -> f64 {
        if self.steps == 0 {
            f64::NAN
        } else {
            self.virtual_s / self.steps as f64
        }
    }

    /// Total metered bytes across both link classes.
    pub fn total_bytes(&self) -> u64 {
        self.bytes[0] + self.bytes[1]
    }
}

/// Identity + placement + accounting for every job the service has
/// seen. Finished jobs stay queryable (their ranks return to the pool).
pub struct JobRegistry {
    world: usize,
    next: u32,
    free: BTreeSet<usize>,
    jobs: BTreeMap<u32, JobEntry>,
}

impl JobRegistry {
    pub fn new(world: usize) -> Self {
        Self { world, next: 0, free: (0..world).collect(), jobs: BTreeMap::new() }
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn free_ranks(&self) -> usize {
        self.free.len()
    }

    /// The placement `ranks` ranks would get right now (lowest free
    /// ranks, ascending) without claiming them — admission previews the
    /// placement to classify its link usage before committing.
    pub fn peek_placement(&self, ranks: usize) -> Option<Vec<usize>> {
        if ranks == 0 || ranks > self.free.len() {
            return None;
        }
        Some(self.free.iter().copied().take(ranks).collect())
    }

    /// Whether a *running* job already uses `name` (finished jobs free
    /// their name for reuse along with their ranks).
    pub fn name_in_use(&self, name: &str) -> bool {
        self.jobs
            .values()
            .any(|j| j.state == JobState::Running && j.name == name)
    }

    /// Claim `placement` (must come from [`JobRegistry::peek_placement`])
    /// and register the job.
    pub fn register(
        &mut self,
        name: &str,
        placement: Vec<usize>,
        weight: f64,
        setup: SetupStats,
    ) -> JobId {
        debug_assert!(placement.iter().all(|r| self.free.contains(r)));
        for r in &placement {
            self.free.remove(r);
        }
        let id = JobId(self.next);
        self.next += 1;
        self.jobs.insert(
            id.0,
            JobEntry {
                id,
                name: name.to_string(),
                placement,
                weight,
                state: JobState::Running,
                steps: 0,
                bytes: [0, 0],
                virtual_s: 0.0,
                first_step_s: None,
                setup,
            },
        );
        id
    }

    /// Release the job's ranks and mark it finished. Returns false when
    /// the id is unknown or already finished.
    pub fn finish(&mut self, id: JobId) -> bool {
        match self.jobs.get_mut(&id.0) {
            Some(j) if j.state == JobState::Running => {
                j.state = JobState::Finished;
                for &r in &j.placement {
                    self.free.insert(r);
                }
                true
            }
            _ => false,
        }
    }

    pub fn get(&self, id: JobId) -> Option<&JobEntry> {
        self.jobs.get(&id.0)
    }

    pub fn get_mut(&mut self, id: JobId) -> Option<&mut JobEntry> {
        self.jobs.get_mut(&id.0)
    }

    /// Every job ever registered, ascending by id.
    pub fn jobs(&self) -> impl Iterator<Item = &JobEntry> {
        self.jobs.values()
    }

    /// Running jobs only, ascending by id.
    pub fn running(&self) -> impl Iterator<Item = &JobEntry> {
        self.jobs.values().filter(|j| j.state == JobState::Running)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placements_are_disjoint_and_recycled() {
        let mut reg = JobRegistry::new(8);
        let p1 = reg.peek_placement(3).unwrap();
        let a = reg.register("a", p1.clone(), 1.0, SetupStats::default());
        assert_eq!(p1, vec![0, 1, 2]);
        let p2 = reg.peek_placement(3).unwrap();
        assert_eq!(p2, vec![3, 4, 5]);
        let b = reg.register("b", p2, 1.0, SetupStats::default());
        assert_eq!(reg.free_ranks(), 2);
        assert!(reg.peek_placement(3).is_none(), "only 2 ranks left");
        assert!(reg.name_in_use("a") && reg.name_in_use("b"));
        assert!(reg.finish(a));
        assert!(!reg.finish(a), "double finish is a no-op");
        assert_eq!(reg.free_ranks(), 5);
        assert!(!reg.name_in_use("a"), "finished jobs free their name");
        // the freed low ranks are handed out again, ascending
        assert_eq!(reg.peek_placement(4).unwrap(), vec![0, 1, 2, 6]);
        assert_eq!(reg.running().count(), 1);
        assert_eq!(reg.get(b).unwrap().name, "b");
    }
}
