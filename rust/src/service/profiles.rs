//! Persistent autotune profiles: versioned `PROFILE_*.json` artifacts
//! that let a returning job skip its startup calibration sweep.
//!
//! A profile captures what [`crate::pipeline::CodecPolicy`] learned
//! about one (model, topology, link) combination — the codec throughput
//! curves over the calibration density ladder plus the schedule/chunk
//! pick — keyed so a job resubmitted on the same fabric shape warm-starts
//! with the persisted choices. The load path is schema-guarded the same
//! way the wire containers are: any truncation or field-level damage
//! yields a structured [`ProfileError`], never a panic and never a
//! silently-wrong policy (`CodecPolicy::import_json` revalidates every
//! number before the profile is accepted).

use crate::pipeline::CodecPolicy;
use crate::simnet::Link;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Version stamp of the `PROFILE_*.json` schema. Bump on any breaking
/// layout change; loaders reject other versions with
/// [`ProfileError::Schema`] so a stale profile re-calibrates instead of
/// mis-parsing.
pub const PROFILE_SCHEMA_VERSION: u32 = 1;

const PROFILE_KIND: &str = "deepreduce_profile";

/// Lowercase the name and map anything outside `[a-z0-9]` to `-` so the
/// key components survive as a filename.
fn slug(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        let ch = ch.to_ascii_lowercase();
        if ch.is_ascii_alphanumeric() {
            out.push(ch);
        } else if !out.ends_with('-') {
            out.push('-');
        }
    }
    let trimmed = out.trim_matches('-').to_string();
    if trimmed.is_empty() { "unnamed".to_string() } else { trimmed }
}

/// What a calibration is keyed by: the profile is only reusable for the
/// same model family on the same fabric shape and link speed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProfileKey {
    pub model: String,
    /// Topology label the job's placement spans, e.g. `2x4`.
    pub topology: String,
    /// Link-speed slug of the class the policy was calibrated for,
    /// e.g. `100mbps`.
    pub link: String,
}

impl ProfileKey {
    pub fn new(model: &str, topology: &str, link: Link) -> Self {
        Self {
            model: slug(model),
            topology: slug(topology),
            link: Self::link_slug(link),
        }
    }

    /// `100mbps`-style slug from the link's bandwidth (fractional
    /// megabit rates spell the point as `p`: 2.5 Mbps → `2p5mbps`).
    pub fn link_slug(link: Link) -> String {
        let mb = link.bandwidth_bps * 8.0 / 1e6;
        if !mb.is_finite() {
            return "ideal".to_string();
        }
        let s = if mb.fract() == 0.0 && mb < 9e15 {
            format!("{}", mb as u64)
        } else {
            format!("{mb}").replace('.', "p")
        };
        format!("{s}mbps")
    }

    /// The artifact filename this key maps to.
    pub fn file_name(&self) -> String {
        format!("PROFILE_{}_{}_{}.json", self.model, self.topology, self.link)
    }
}

/// One persisted calibration: the policy's learned curves plus the
/// schedule pick made for the job's density.
pub struct Profile {
    pub key: ProfileKey,
    /// `CodecPolicy::export_json` payload (link/worker-independent).
    pub policy: Json,
    /// `(schedule_name, chunks)` pick, when the producer made one.
    pub schedule: Option<(String, usize)>,
}

impl Profile {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("schema_version".to_string(), Json::Num(PROFILE_SCHEMA_VERSION as f64));
        m.insert("kind".to_string(), Json::Str(PROFILE_KIND.to_string()));
        m.insert("model".to_string(), Json::Str(self.key.model.clone()));
        m.insert("topology".to_string(), Json::Str(self.key.topology.clone()));
        m.insert("link".to_string(), Json::Str(self.key.link.clone()));
        m.insert("policy".to_string(), self.policy.clone());
        let sched = match &self.schedule {
            Some((name, chunks)) => {
                let mut s = BTreeMap::new();
                s.insert("schedule".to_string(), Json::Str(name.clone()));
                s.insert("chunks".to_string(), Json::Num(*chunks as f64));
                Json::Obj(s)
            }
            None => Json::Null,
        };
        m.insert("schedule".to_string(), sched);
        Json::Obj(m)
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        self.to_json().to_string().into_bytes()
    }

    /// Schema-guarded load. Every failure mode — truncation, non-UTF-8,
    /// malformed JSON, version skew, wrong artifact kind, damaged policy
    /// numbers — maps to a structured [`ProfileError`].
    pub fn from_bytes(bytes: &[u8]) -> Result<Profile, ProfileError> {
        let text = std::str::from_utf8(bytes).map_err(|_| ProfileError::Utf8)?;
        let v = Json::parse(text).map_err(|e| ProfileError::Malformed {
            detail: format!("json parse: {e:?}"),
        })?;
        let version = v
            .get("schema_version")
            .and_then(Json::as_f64)
            .filter(|x| x.fract() == 0.0 && *x >= 0.0)
            .map(|x| x as u64);
        if version != Some(PROFILE_SCHEMA_VERSION as u64) {
            return Err(ProfileError::Schema { found: version, expect: PROFILE_SCHEMA_VERSION });
        }
        let kind = v.get("kind").and_then(Json::as_str).unwrap_or_default();
        if kind != PROFILE_KIND {
            return Err(ProfileError::WrongKind { found: kind.to_string() });
        }
        let field = |name: &str| -> Result<String, ProfileError> {
            v.get(name)
                .and_then(Json::as_str)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .ok_or_else(|| ProfileError::Malformed {
                    detail: format!("missing or empty string field {name:?}"),
                })
        };
        let key = ProfileKey {
            model: field("model")?,
            topology: field("topology")?,
            link: field("link")?,
        };
        let policy = v
            .get("policy")
            .cloned()
            .ok_or_else(|| ProfileError::Malformed {
                detail: "missing policy object".to_string(),
            })?;
        // revalidate the full policy payload at load time (with a
        // throwaway binding) so corruption is caught here, not at the
        // first choose() call
        CodecPolicy::import_json(&policy, Link::mbps(100.0), 2)
            .map_err(|e| ProfileError::Malformed { detail: format!("policy: {e}") })?;
        let schedule = match v.get("schedule") {
            None | Some(Json::Null) => None,
            Some(s) => {
                let name = s
                    .get("schedule")
                    .and_then(Json::as_str)
                    .ok_or_else(|| ProfileError::Malformed {
                        detail: "schedule entry without a schedule name".to_string(),
                    })?;
                if crate::collective::Schedule::parse(name).is_none() {
                    return Err(ProfileError::Malformed {
                        detail: format!("unknown schedule {name:?}"),
                    });
                }
                let chunks = s.get("chunks").and_then(Json::as_usize).ok_or_else(|| {
                    ProfileError::Malformed { detail: "schedule entry without chunks".to_string() }
                })?;
                Some((name.to_string(), chunks))
            }
        };
        Ok(Profile { key, policy, schedule })
    }

    /// Rebind the persisted policy to a live link + worker count.
    pub fn policy(&self, link: Link, workers: usize) -> Result<CodecPolicy, ProfileError> {
        CodecPolicy::import_json(&self.policy, link, workers)
            .map_err(|e| ProfileError::Malformed { detail: format!("policy: {e}") })
    }
}

/// Why a profile failed to load. Structured (not a string) so the
/// service can distinguish "no profile yet" from "damaged artifact" and
/// the hardening tests can assert the exact cause.
#[derive(Debug)]
pub enum ProfileError {
    Io(std::io::Error),
    /// The file is not valid UTF-8 (binary damage).
    Utf8,
    /// Parsed, but the payload is structurally wrong; `detail` names the
    /// first offending field.
    Malformed { detail: String },
    /// Version skew: written by a different schema revision.
    Schema { found: Option<u64>, expect: u32 },
    /// A JSON artifact of some other kind was handed to the loader.
    WrongKind { found: String },
}

impl std::fmt::Display for ProfileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProfileError::Io(e) => write!(f, "profile io: {e}"),
            ProfileError::Utf8 => write!(f, "profile is not valid UTF-8"),
            ProfileError::Malformed { detail } => write!(f, "malformed profile: {detail}"),
            ProfileError::Schema { found, expect } => match found {
                Some(v) => write!(f, "profile schema version {v} (this build expects {expect})"),
                None => write!(f, "profile has no schema_version (this build expects {expect})"),
            },
            ProfileError::WrongKind { found } => {
                write!(f, "not a profile artifact (kind {found:?})")
            }
        }
    }
}

impl std::error::Error for ProfileError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ProfileError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ProfileError {
    fn from(e: std::io::Error) -> Self {
        ProfileError::Io(e)
    }
}

/// Directory-backed profile store. Missing files are a normal cold
/// start (`Ok(None)`); present-but-damaged files are an error the
/// caller surfaces before falling back to calibration.
pub struct ProfileStore {
    dir: PathBuf,
}

impl ProfileStore {
    pub fn new<P: Into<PathBuf>>(dir: P) -> Self {
        Self { dir: dir.into() }
    }

    /// The repo root, where the other `BENCH_`/`TRACE_`/`HEALTH_`
    /// artifacts live — the default profile directory for the CLI.
    pub fn repo_root() -> PathBuf {
        let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        manifest.parent().map(Path::to_path_buf).unwrap_or(manifest)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn path(&self, key: &ProfileKey) -> PathBuf {
        self.dir.join(key.file_name())
    }

    pub fn save(&self, profile: &Profile) -> Result<PathBuf, ProfileError> {
        std::fs::create_dir_all(&self.dir)?;
        let path = self.path(&profile.key);
        std::fs::write(&path, profile.to_bytes())?;
        Ok(path)
    }

    /// `Ok(None)` when no profile exists for the key (cold start);
    /// `Err` when one exists but fails validation.
    pub fn load(&self, key: &ProfileKey) -> Result<Option<Profile>, ProfileError> {
        let bytes = match std::fs::read(self.path(key)) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e.into()),
        };
        Profile::from_bytes(&bytes).map(Some)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{default_candidates, CodecPolicy};

    fn sample_profile() -> Profile {
        let (idx, val) = default_candidates(false);
        let policy = CodecPolicy::calibrate_bytes_only(&idx, &val, 7, Link::mbps(100.0), 4);
        Profile {
            key: ProfileKey::new("ResNet-50", "2x4", Link::mbps(100.0)),
            policy: policy.export_json(),
            schedule: Some(("chunked_rescatter".to_string(), 4)),
        }
    }

    #[test]
    fn keys_slug_into_stable_filenames() {
        let key = ProfileKey::new("ResNet-50 (v1.5)", "2x4", Link::mbps(100.0));
        assert_eq!(key.file_name(), "PROFILE_resnet-50-v1-5_2x4_100mbps.json");
        assert_eq!(ProfileKey::link_slug(Link::mbps(2.5)), "2p5mbps");
        assert_eq!(ProfileKey::link_slug(Link::ideal()), "ideal");
        let key2 = ProfileKey::new("", "", Link::gbps(1.0));
        assert_eq!(key2.file_name(), "PROFILE_unnamed_unnamed_1000mbps.json");
    }

    #[test]
    fn store_round_trips_through_disk() {
        let dir = std::env::temp_dir().join(format!("profiles-{}", std::process::id()));
        let store = ProfileStore::new(&dir);
        let profile = sample_profile();
        assert!(store.load(&profile.key).unwrap().is_none(), "cold store is empty");
        let path = store.save(&profile).unwrap();
        assert!(path.ends_with(profile.key.file_name()));
        let back = store.load(&profile.key).unwrap().expect("saved profile loads");
        assert_eq!(back.key, profile.key);
        assert_eq!(back.schedule, profile.schedule);
        assert_eq!(back.to_bytes(), profile.to_bytes(), "byte-stable round trip");
        back.policy(Link::mbps(10.0), 8).expect("policy rebinds to a new link");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn version_skew_and_wrong_kind_are_structured() {
        let profile = sample_profile();
        let text = String::from_utf8(profile.to_bytes()).unwrap();
        let skew = text.replace("\"schema_version\":1", "\"schema_version\":99");
        assert!(matches!(
            Profile::from_bytes(skew.as_bytes()),
            Err(ProfileError::Schema { found: Some(99), expect: 1 })
        ));
        let other = text.replace(PROFILE_KIND, "deepreduce_health");
        assert!(matches!(
            Profile::from_bytes(other.as_bytes()),
            Err(ProfileError::WrongKind { .. })
        ));
        assert!(matches!(Profile::from_bytes(&[0xFF, 0xFE]), Err(ProfileError::Utf8)));
    }
}
