//! The in-process service API: admit jobs, interleave their collective
//! steps on one shared fleet fabric, meter and reconcile fair-share.
//!
//! A [`ReductionService`] owns exactly one [`FleetFabric`] event loop.
//! Jobs are placed on disjoint ascending rank sets, so concurrent
//! tenants never contend for a fabric port — a job's collective runs
//! `allreduce_members` over its own placement and leaves every other
//! rank's clock, idle meter, and in-flight state untouched. Interleaving
//! is therefore pure scheduling: [`ReductionService::run_round`] asks
//! the deficit scheduler for the round's service order and executes one
//! collective step per grant, charging each tenant the bytes it actually
//! metered.
//!
//! Warm start: when a profile store is configured and a matching
//! `PROFILE_*.json` exists, `submit` rebinds the persisted
//! [`CodecPolicy`] instead of running the calibration sweep, and
//! [`ReductionService::finish`] persists a fresh calibration for the
//! next cold submit.

use super::admission::{admit, spans_nodes, AdmissionError, JobRequest};
use super::profiles::{Profile, ProfileKey, ProfileStore};
use super::registry::{JobEntry, JobId, JobRegistry, JobState, SetupStats};
use super::scheduler::FairShare;
use crate::collective::sparse::SegmentCodec;
use crate::collective::{Schedule, SparseConfig, Topology};
use crate::compress::CompressSpec;
use crate::fleetsim::FleetFabric;
use crate::pipeline::{default_candidates, CodecPolicy};
use crate::simnet::Link;
use crate::tensor::SparseTensor;
use crate::util::prng::Rng;
use crate::util::testkit::{gradient_like, sorted_support};
use crate::vfabric::Scenario;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

/// Everything the daemon is configured with at startup.
#[derive(Clone)]
pub struct ServiceConfig {
    pub topology: Topology,
    pub intra: Link,
    pub inter: Link,
    /// Bytes one scheduling round may put on each link class,
    /// `[intra, inter]`. `f64::INFINITY` disables metering on a class.
    pub frame_budget: [f64; 2],
    pub scenario: Scenario,
    /// Where `PROFILE_*.json` artifacts live; `None` disables
    /// persistence (every autotuned job cold-starts).
    pub profile_dir: Option<PathBuf>,
    /// Virtual compute seconds each member spends per step before the
    /// exchange (the service-driven synthetic-gradient path).
    pub compute_s: f64,
}

impl ServiceConfig {
    /// Default frame budget: one virtual second of aggregate class
    /// bandwidth (every rank's port busy for the whole frame).
    pub fn new(topology: Topology, intra: Link, inter: Link) -> Self {
        let world = topology.world() as f64;
        Self {
            topology,
            intra,
            inter,
            frame_budget: [intra.bandwidth_bps * world, inter.bandwidth_bps * world],
            scenario: Scenario::none(0),
            profile_dir: None,
            compute_s: 0.0,
        }
    }

    /// Disable byte metering entirely — the single-tenant trainer path,
    /// where fairness is moot and the budget must never throttle.
    pub fn unmetered(mut self) -> Self {
        self.frame_budget = [f64::INFINITY, f64::INFINITY];
        self
    }

    pub fn with_frame_budget(mut self, frame_budget: [f64; 2]) -> Self {
        self.frame_budget = frame_budget;
        self
    }

    pub fn with_scenario(mut self, scenario: Scenario) -> Self {
        self.scenario = scenario;
        self
    }

    pub fn with_profiles<P: Into<PathBuf>>(mut self, dir: P) -> Self {
        self.profile_dir = Some(dir.into());
        self
    }

    pub fn with_compute_s(mut self, compute_s: f64) -> Self {
        self.compute_s = compute_s;
        self
    }
}

/// What one executed step cost, for callers that stream progress.
#[derive(Clone, Copy, Debug)]
pub struct StepReport {
    pub job: JobId,
    /// The job's step count after this step (1-based).
    pub step: u64,
    /// Virtual seconds the step took (critical path over members).
    pub virt_s: f64,
    pub start_s: f64,
    pub end_s: f64,
    /// Metered fabric bytes this step, `[intra, inter]`.
    pub bytes: [u64; 2],
}

/// Per-job execution state the registry's accounting row doesn't carry.
struct JobRuntime {
    sched: Schedule,
    sparse: SparseConfig,
    codec: SegmentCodec,
    /// Present on autotuned jobs; exported to the profile at finish.
    policy: Option<CodecPolicy>,
    key: ProfileKey,
    rng: Rng,
    dim: usize,
    nnz: usize,
}

/// The long-running multi-tenant reduction daemon (in-process form).
pub struct ReductionService {
    fabric: FleetFabric,
    cfg: ServiceConfig,
    registry: JobRegistry,
    shares: FairShare,
    store: Option<ProfileStore>,
    rt: BTreeMap<u32, JobRuntime>,
}

impl ReductionService {
    pub fn new(cfg: ServiceConfig) -> Self {
        let fabric =
            FleetFabric::new(cfg.topology, cfg.intra, cfg.inter, cfg.scenario.clone());
        let registry = JobRegistry::new(cfg.topology.world());
        let shares = FairShare::new(cfg.frame_budget);
        let store = cfg.profile_dir.clone().map(ProfileStore::new);
        Self { fabric, cfg, registry, shares, store, rt: BTreeMap::new() }
    }

    /// Admit a job: validate, place, reserve fair-share, and resolve its
    /// codec + schedule (calibrating or warm-loading when autotuned).
    pub fn submit(&mut self, req: JobRequest) -> Result<JobId, AdmissionError> {
        if self.registry.name_in_use(&req.name) {
            return Err(AdmissionError::DuplicateName(req.name.clone()));
        }
        let placement = self.registry.peek_placement(req.ranks).ok_or(
            AdmissionError::NoCapacity { need: req.ranks, free: self.registry.free_ranks() },
        )?;
        let est = admit(
            &req,
            self.cfg.topology,
            &placement,
            self.shares.load(),
            self.shares.frame_budget(),
        )?;
        // the link class the exchange is bound by, for calibration keys
        let job_link = if spans_nodes(self.cfg.topology, &placement) {
            self.cfg.inter
        } else {
            self.cfg.intra
        };
        let span = format!("{}-{}r", self.cfg.topology.label(), req.ranks);
        let key = ProfileKey::new(&req.model, &span, job_link);
        let dim = req.dim;
        let nnz = req.nnz();
        let mut setup = SetupStats::default();
        let (sched, chunks, compress, policy) = if req.autotune {
            let warm = self.store.as_ref().and_then(|s| s.load(&key).ok().flatten());
            let (policy, warm_sched) = match warm {
                Some(profile) => {
                    let t0 = Instant::now();
                    match profile.policy(job_link, req.ranks) {
                        Ok(p) => {
                            setup.warm_start = true;
                            setup.profile_load_s = t0.elapsed().as_secs_f64();
                            (p, profile.schedule.clone())
                        }
                        // a profile that validated at load but fails to
                        // rebind falls back to a cold calibration
                        Err(_) => {
                            (Self::cold_calibrate(&mut setup, req.seed, job_link, req.ranks), None)
                        }
                    }
                }
                None => (Self::cold_calibrate(&mut setup, req.seed, job_link, req.ranks), None),
            };
            let choice = policy.choose(dim, nnz);
            let compress = CompressSpec::parse(&choice.index, &choice.value)
                .map_err(|e| AdmissionError::BadRequest(format!("autotuned codec: {e}")))?;
            let (sched, chunks) = match warm_sched {
                Some((name, chunks)) => {
                    let sched = Schedule::parse(&name).ok_or_else(|| {
                        AdmissionError::BadRequest(format!("profile schedule {name:?}"))
                    })?;
                    (sched, chunks)
                }
                None => policy.choose_schedule(dim, nnz, req.ranks, job_link),
            };
            // the lossy ring drops collisions; the service owes exact sums
            let sched =
                if sched == Schedule::RingRescatter { Schedule::RingRescatterExact } else { sched };
            (sched, chunks, compress, Some(policy))
        } else {
            (req.schedule, req.chunks, req.compress.clone(), None)
        };
        let sparse = req.sparse.clone().unwrap_or_else(|| SparseConfig {
            chunks,
            topology: (sched == Schedule::Hierarchical).then_some(self.cfg.topology),
            ..SparseConfig::default()
        });
        let codec = SegmentCodec::lossless_or_raw(&compress, req.seed, sparse.dense_switch);
        let id = self.registry.register(&req.name, placement, req.weight, setup);
        self.shares.admit(id, req.weight, est);
        self.rt.insert(
            id.0,
            JobRuntime {
                sched,
                sparse,
                codec,
                policy,
                key,
                rng: Rng::new(req.seed ^ 0x5E41_71CE ^ id.0 as u64),
                dim,
                nnz,
            },
        );
        Ok(id)
    }

    fn cold_calibrate(
        setup: &mut SetupStats,
        seed: u64,
        link: Link,
        workers: usize,
    ) -> CodecPolicy {
        let t0 = Instant::now();
        let (idx, val) = default_candidates(false);
        let policy = CodecPolicy::calibrate(&idx, &val, seed, link, workers);
        setup.warm_start = false;
        setup.calibration_s = t0.elapsed().as_secs_f64();
        policy
    }

    /// Run one collective for a job over `members` (an ascending subset
    /// of its placement; elastic callers pass the alive subset). Meters
    /// the fabric before/after — the event loop is single-threaded, so
    /// the byte delta is exactly this collective's traffic — and charges
    /// the job's fair share with it.
    pub fn collective(
        &mut self,
        id: JobId,
        members: &[usize],
        inputs: Vec<SparseTensor>,
    ) -> anyhow::Result<Vec<SparseTensor>> {
        let rt = self.rt.get(&id.0).ok_or_else(|| anyhow::anyhow!("unknown job {id}"))?;
        let entry = self.registry.get(id).expect("runtime implies registry entry");
        anyhow::ensure!(entry.state == JobState::Running, "{id} is finished");
        for m in members {
            anyhow::ensure!(
                entry.placement.binary_search(m).is_ok(),
                "rank {m} is not in {id}'s placement {:?}",
                entry.placement
            );
        }
        let before = [self.fabric.intra_bytes(), self.fabric.inter_bytes()];
        let out =
            self.fabric.allreduce_members(members, rt.sched, &rt.sparse, &rt.codec, inputs)?;
        let delta = [
            self.fabric.intra_bytes() - before[0],
            self.fabric.inter_bytes() - before[1],
        ];
        let entry = self.registry.get_mut(id).expect("checked above");
        entry.bytes[0] += delta[0];
        entry.bytes[1] += delta[1];
        self.shares.charge(id, [delta[0] as f64, delta[1] as f64]);
        Ok(out)
    }

    /// Execute one full step of a service-driven job: barrier its
    /// members, spend the configured compute, exchange one synthetic
    /// gradient at the job's density, and account the step.
    pub fn step_job(&mut self, id: JobId) -> anyhow::Result<StepReport> {
        let rt = self.rt.get_mut(&id.0).ok_or_else(|| anyhow::anyhow!("unknown job {id}"))?;
        let entry = self.registry.get(id).expect("runtime implies registry entry");
        anyhow::ensure!(entry.state == JobState::Running, "{id} is finished");
        let members = entry.placement.clone();
        let (dim, nnz) = (rt.dim, rt.nnz);
        let inputs: Vec<SparseTensor> = members
            .iter()
            .map(|_| {
                let idx = sorted_support(&mut rt.rng, dim, nnz);
                let vals = gradient_like(&mut rt.rng, idx.len());
                SparseTensor::new(dim, idx, vals)
            })
            .collect();
        let start_s =
            members.iter().map(|&m| self.fabric.clock_s(m)).fold(0.0, f64::max);
        for &m in &members {
            self.fabric.sync_to(m, start_s);
            self.fabric.elapse(m, self.cfg.compute_s);
        }
        let bytes_before = self.registry.get(id).expect("checked").bytes;
        self.collective(id, &members, inputs)?;
        let end_s = members.iter().map(|&m| self.fabric.clock_s(m)).fold(0.0, f64::max);
        let entry = self.registry.get_mut(id).expect("checked");
        let virt_s = end_s - start_s;
        entry.steps += 1;
        entry.virtual_s += virt_s;
        if entry.first_step_s.is_none() {
            entry.first_step_s = Some(entry.setup.total_s() + virt_s);
        }
        Ok(StepReport {
            job: id,
            step: entry.steps,
            virt_s,
            start_s,
            end_s,
            bytes: [entry.bytes[0] - bytes_before[0], entry.bytes[1] - bytes_before[1]],
        })
    }

    /// Account one externally-driven step (the trainer-client path,
    /// where the caller ran [`ReductionService::collective`] itself and
    /// knows the step's virtual duration).
    pub fn note_step(&mut self, id: JobId, virt_s: f64) {
        if let Some(entry) = self.registry.get_mut(id) {
            entry.steps += 1;
            entry.virtual_s += virt_s;
            if entry.first_step_s.is_none() {
                entry.first_step_s = Some(entry.setup.total_s() + virt_s);
            }
        }
    }

    /// One fair-share scheduling round: every running tenant's floor
    /// step plus the deficit-funded surplus, in the scheduler's order.
    pub fn run_round(&mut self) -> anyhow::Result<Vec<StepReport>> {
        let order = self.shares.next_round();
        let mut reports = Vec::with_capacity(order.len());
        for id in order {
            if self.registry.get(id).map(|j| j.state) != Some(JobState::Running) {
                continue;
            }
            reports.push(self.step_job(id)?);
        }
        Ok(reports)
    }

    /// Retire a job: persist its calibration (when autotuned and a
    /// store is configured), release its ranks and its fair share.
    /// Returns the profile path when one was written.
    pub fn finish(&mut self, id: JobId) -> anyhow::Result<Option<PathBuf>> {
        let persisted = match (self.rt.get(&id.0), &self.store) {
            (Some(rt), Some(store)) => match &rt.policy {
                Some(policy) => {
                    let profile = Profile {
                        key: rt.key.clone(),
                        policy: policy.export_json(),
                        schedule: Some((rt.sched.name().to_string(), rt.sparse.chunks)),
                    };
                    Some(store.save(&profile).map_err(anyhow::Error::from)?)
                }
                None => None,
            },
            _ => None,
        };
        self.rt.remove(&id.0);
        self.shares.remove(id);
        self.registry.finish(id);
        Ok(persisted)
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    pub fn world(&self) -> usize {
        self.registry.world()
    }

    pub fn free_ranks(&self) -> usize {
        self.registry.free_ranks()
    }

    pub fn job(&self, id: JobId) -> Option<&JobEntry> {
        self.registry.get(id)
    }

    /// Every job the service has seen, ascending by id.
    pub fn jobs(&self) -> impl Iterator<Item = &JobEntry> {
        self.registry.jobs()
    }

    pub fn shares(&self) -> &FairShare {
        &self.shares
    }

    /// A member rank's virtual clock (trainer-client plumbing).
    pub fn clock_s(&self, rank: usize) -> f64 {
        self.fabric.clock_s(rank)
    }

    /// A member rank's accumulated recv-wait idle seconds.
    pub fn idle_s(&self, rank: usize) -> f64 {
        self.fabric.idle_s(rank)
    }

    /// Barrier plumbing for external drivers: advance `rank` to at
    /// least `t` without counting the gap as idle.
    pub fn sync_member(&mut self, rank: usize, t: f64) {
        self.fabric.sync_to(rank, t);
    }

    /// Local-work plumbing for external drivers: spend `dt` seconds of
    /// compute on `rank`.
    pub fn elapse_member(&mut self, rank: usize, dt: f64) {
        self.fabric.elapse(rank, dt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn svc(nodes: usize, rpn: usize) -> ReductionService {
        ReductionService::new(ServiceConfig::new(
            Topology::new(nodes, rpn),
            Link::mbps(1000.0),
            Link::mbps(100.0),
        ))
    }

    #[test]
    fn submit_places_steps_and_finishes() {
        let mut s = svc(2, 4);
        let a = s.submit(JobRequest::synthetic("a", 4, 1 << 12, 0.01)).unwrap();
        let b = s.submit(JobRequest::synthetic("b", 4, 1 << 12, 0.01)).unwrap();
        assert!(matches!(
            s.submit(JobRequest::synthetic("a", 1, 1 << 12, 0.01)),
            Err(AdmissionError::DuplicateName(_))
        ));
        assert!(matches!(
            s.submit(JobRequest::synthetic("c", 4, 1 << 12, 0.01)),
            Err(AdmissionError::NoCapacity { need: 4, free: 0 })
        ));
        let ra = s.step_job(a).unwrap();
        let rb = s.step_job(b).unwrap();
        assert!(ra.virt_s > 0.0 && rb.virt_s > 0.0);
        assert!(ra.bytes[0] > 0, "node-local job meters intra bytes");
        assert_eq!(ra.bytes[1], 0, "node-local job never crosses the inter link");
        assert_eq!(s.job(a).unwrap().steps, 1);
        s.finish(a).unwrap();
        assert_eq!(s.free_ranks(), 4);
        assert!(s.step_job(a).is_err(), "finished jobs cannot step");
        let c = s.submit(JobRequest::synthetic("c", 4, 1 << 12, 0.01)).unwrap();
        assert_eq!(s.job(c).unwrap().placement, vec![0, 1, 2, 3]);
    }

    #[test]
    fn rounds_interleave_all_tenants() {
        let mut s = svc(4, 2);
        let ids: Vec<JobId> = (0..4)
            .map(|i| {
                s.submit(JobRequest::synthetic(&format!("t{i}"), 2, 1 << 12, 0.02)).unwrap()
            })
            .collect();
        let reports = s.run_round().unwrap();
        for id in &ids {
            assert!(
                reports.iter().any(|r| r.job == *id),
                "{id} missed the round: {reports:?}"
            );
        }
        for id in &ids {
            assert!(s.job(*id).unwrap().steps >= 1);
        }
    }

    #[test]
    fn disjoint_tenants_do_not_move_each_others_clocks() {
        let mut s = svc(2, 4);
        let a = s.submit(JobRequest::synthetic("a", 4, 1 << 12, 0.05)).unwrap();
        let _b = s.submit(JobRequest::synthetic("b", 4, 1 << 12, 0.05)).unwrap();
        let b_clock: Vec<f64> = (4..8).map(|r| s.clock_s(r)).collect();
        s.step_job(a).unwrap();
        for (i, r) in (4..8).enumerate() {
            assert_eq!(s.clock_s(r), b_clock[i], "rank {r} moved during a's step");
        }
    }
}
