//! Fleet-scale event-loop runner (DESIGN.md §13): the threaded virtual
//! fabric re-hosted on one thread.
//!
//! The threaded vfabric runs one OS thread per rank, which caps every
//! experiment at laptop-core counts. But the fabric's virtual-time model
//! is a Kahn process network: `send` never blocks, `recv` blocks on one
//! *specific* source, and all timing state is rank-local (clock, idle,
//! per-class port frees) plus the `(depart, busy)` stamps riding on each
//! message. A process network's outcome depends only on each process's
//! program order — never on the interleaving — so the same collectives
//! can run cooperatively on a single thread and produce **bit-identical**
//! byte meters and virtual clocks (`tests/fleetsim_equivalence.rs` pins
//! this against the threaded runner).
//!
//! Each rank's collective step is reified as a resumable state machine
//! (`RankTask`, built in the private `kernels` module): `poll` runs the rank's program
//! until it completes or a `try_recv` misses, at which point the rank
//! *parks* on the awaited source. The runner keeps a ready queue seeded
//! in rank order; delivering a message to a rank parked on its sender
//! re-queues the receiver. Tie-breaking is deterministic: FIFO rank
//! order by default, with LIFO and seeded-shuffle [`ReadyPolicy`]s that
//! the determinism suite uses to prove results are queue-order-free (the
//! process-network argument made executable).
//!
//! Occupancy math is shared with the threaded fabric —
//! `vfabric::transfer_busy` / `resolve_link` — so the exact
//! f64 operation order is common by construction. Jitter draws come from
//! the same per-rank streams (`seed ^ mix64(rank)`), one draw per send
//! in program order.
//!
//! Scale: a 10k-rank `chunked_rescatter` step is ~10⁸ message events.
//! Two things keep that cheap: payloads are `Rc`-shared (a broadcast is
//! one buffer, n−1 pointer bumps), and the all-to-all histogram phase
//! uses a *barrage* fast path on uniform-class rosters (no jitter, no
//! flaps, no stragglers, >64 ranks): the sender books its egress port
//! once for all n−1 identical copies and receivers reconstruct their
//! copy's departure as `d0 + (j−1)·busy` instead of materializing n²
//! queued messages. The closed form differs from sequential accumulation
//! only in f64 rounding (~1 ulp), and the fast path is size-gated far
//! above every bit-exactness test point.
//!
//! Observability at this scale goes through `--trace sampled`
//! (DESIGN.md §14): the per-message `Send`/`Recv`/`RecvWait` spans the
//! runner records are folded into streaming per-step histograms at the
//! collector chokepoint instead of being retained, so a 10k-rank traced
//! step stays O(ranks) in memory while full span traces survive only
//! for the exemplar ranks.

pub(crate) mod kernels;

use crate::collective::sparse::SegmentCodec;
use crate::collective::{Schedule, SparseConfig, Topology};
use crate::obs;
use crate::simnet::Link;
use crate::tensor::SparseTensor;
use crate::util::prng::{mix64, Rng};
use crate::vfabric::{self, Scenario, INTRA};
use std::collections::{HashMap, VecDeque};
use std::rc::Rc;

/// Rosters at or below this size never use the barrage fast path, so
/// every differential test point (n ≤ 8, and well beyond) exercises the
/// sequential per-message path that is bit-identical to the threaded
/// fabric.
const BARRAGE_MIN: usize = 64;

/// Deterministic hasher for runner-internal maps. Keys are small
/// integers (peer ranks), so one `mix64` round beats SipHash — and
/// unlike `std::collections::hash_map::RandomState` it is identical on
/// every platform and run, which the determinism suite relies on.
#[derive(Clone, Copy, Default)]
pub(crate) struct FleetHash(u64);

impl std::hash::Hasher for FleetHash {
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = mix64(self.0 ^ u64::from(b));
        }
    }
    fn write_usize(&mut self, i: usize) {
        self.0 = mix64(self.0 ^ i as u64);
    }
    fn write_u64(&mut self, i: u64) {
        self.0 = mix64(self.0 ^ i);
    }
}

#[derive(Clone, Copy, Default)]
pub(crate) struct FleetBuildHash;

impl std::hash::BuildHasher for FleetBuildHash {
    type Hasher = FleetHash;
    fn build_hasher(&self) -> FleetHash {
        FleetHash(0x9E37_79B9_7F4A_7C15)
    }
}

/// One in-flight transfer with its virtual-time stamps — the fleet twin
/// of the threaded fabric's channel message, with the payload behind an
/// `Rc` so broadcasts share one buffer.
pub(crate) struct Msg {
    depart: f64,
    busy: f64,
    payload: Rc<Vec<u8>>,
}

/// Per-rank queued messages, keyed by source (per-pair FIFO order, same
/// as the threaded fabric's per-pair channels).
type Inbox = HashMap<usize, VecDeque<Msg>, FleetBuildHash>;

/// Persistent per-rank virtual-time state: the exact fields a threaded
/// [`crate::vfabric::VirtualEndpoint`] keeps, surviving across
/// collectives so multi-step runs accumulate clocks the same way.
struct RankState {
    clock: f64,
    idle: f64,
    egress_free: [f64; 2],
    ingress_free: [f64; 2],
    rng: Rng,
}

/// Single-threaded byte meters (same accounting as the fabrics).
#[derive(Default)]
struct Meters {
    bytes: u64,
    intra: u64,
    inter: u64,
}

impl Meters {
    fn add(&mut self, class: usize, len: u64) {
        self.bytes += len;
        if class == INTRA {
            self.intra += len;
        } else {
            self.inter += len;
        }
    }
}

/// How the runner breaks ties among simultaneously-ready ranks. Every
/// policy yields bit-identical results, meters, and clocks — the
/// determinism tests run all three to prove it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadyPolicy {
    /// First-ready-first-polled, seeded in rank order (the default).
    Fifo,
    /// Newest-ready-first.
    Lifo,
    /// Seeded pseudo-random pops from the ready set.
    Shuffle(u64),
}

/// What a parked rank is waiting for.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Waiting {
    /// `try_recv(src)` missed: woken by the next message (or barrage
    /// announcement) from that global rank.
    Msg(usize),
    /// Waiting on a shared-scratch publication (chunked bounds).
    Shared,
}

/// A registered uniform broadcast: one egress booking covers all n−1
/// copies; receiver `j` (ring order) reconstructs its copy's departure
/// as `d0 + (j−1)·busy`.
struct Barrage {
    payload: Rc<Vec<u8>>,
    d0: f64,
    busy: f64,
}

/// Per-collective cross-rank scratch. The chunked schedule's balanced
/// bounds are a pure function of the summed histogram, identical on
/// every rank — so roster position 0 computes them once and publishes
/// here instead of every rank decoding n−1 histograms (O(n²·bins) work
/// at fleet scale). Keyed by `(first member, roster len)` so a
/// hierarchical inner chunked run gets its own slot.
#[derive(Default)]
struct SharedScratch {
    bounds: HashMap<(usize, usize), Rc<Vec<usize>>, FleetBuildHash>,
}

/// A sub-communicator view: `members` are global ranks (ascending, or
/// node order for leader groups), `me` is this rank's index in it. The
/// fleet twin of [`crate::collective::SubEndpoint`] re-ranking.
#[derive(Clone)]
pub(crate) struct Roster {
    pub members: Rc<Vec<usize>>,
    pub me: usize,
}

impl Roster {
    pub fn n(&self) -> usize {
        self.members.len()
    }
    pub fn global(&self, j: usize) -> usize {
        self.members[j]
    }
    /// Shared-scratch key: unique among concurrently-active rosters
    /// (an inner leader group always has fewer members than its world).
    pub fn key(&self) -> (usize, usize) {
        (self.members[0], self.members.len())
    }
}

/// Result of polling a rank's state machine.
pub(crate) enum TaskPoll {
    /// Parked — the context records what it waits on.
    Pending,
    /// The rank's collective completed with this result.
    Done(SparseTensor),
}

/// A rank's collective step as a resumable state machine: `poll` runs
/// the rank's program order until completion or a missed `try_recv`.
/// Contract: a `Pending` return must follow a missed receive (or an
/// explicit [`FleetCtx::park_shared`]) in the same poll — the runner
/// treats an unparked `Pending` as a kernel bug.
pub(crate) trait RankTask {
    fn poll(&mut self, ctx: &mut FleetCtx) -> anyhow::Result<TaskPoll>;
}

/// The execution context handed to a rank for one poll: its own
/// virtual-time state plus the runner's routing surfaces. Send/receive
/// semantics mirror [`crate::vfabric::VirtualEndpoint`] operation for
/// operation (same meters, same obs counters, spans via
/// [`obs::virtual_span`] with the explicit rank — never the thread-local
/// vclock, which would corrupt under rank multiplexing).
pub(crate) struct FleetCtx<'a> {
    /// this rank's global id
    pub me: usize,
    topo: Topology,
    intra: Link,
    inter: Link,
    scenario: &'a Scenario,
    state: &'a mut RankState,
    inbox: &'a mut Inbox,
    outbox: &'a mut Vec<(usize, Msg)>,
    barrage: &'a mut Vec<Option<Barrage>>,
    shared: &'a mut SharedScratch,
    meters: &'a mut Meters,
    missed: Option<usize>,
    missed_shared: bool,
    announced: bool,
    published: bool,
}

impl FleetCtx<'_> {
    pub fn send(&mut self, dst: usize, payload: Vec<u8>) {
        self.send_rc(dst, Rc::new(payload));
    }

    /// Non-blocking virtual send: books the egress port, stamps the
    /// delivery window, meters the bytes — the exact operation order of
    /// the threaded `VirtualEndpoint::send`.
    pub fn send_rc(&mut self, dst: usize, payload: Rc<Vec<u8>>) {
        assert_ne!(dst, self.me, "self-send not allowed");
        let len = payload.len() as u64;
        let (alpha, beta, class) =
            vfabric::resolve_link(self.topo, self.me, dst, self.intra, self.inter, self.scenario);
        self.meters.add(class, len);
        let busy = vfabric::transfer_busy(
            alpha,
            beta,
            class,
            payload.len(),
            self.state.clock,
            self.topo.node_of(self.me),
            self.topo.node_of(dst),
            self.scenario,
            &mut self.state.rng,
        );
        let depart = self.state.clock.max(self.state.egress_free[class]);
        self.state.egress_free[class] = depart + busy;
        obs::virtual_span(
            obs::SpanKind::Send,
            obs::Lane::egress(class),
            self.me,
            depart,
            depart + busy,
            len,
        );
        obs::count(if class == INTRA { "vfabric.intra_bytes" } else { "vfabric.inter_bytes" }, len);
        obs::observe("vfabric.egress_backlog_s", depart - self.state.clock);
        self.outbox.push((dst, Msg { depart, busy, payload }));
    }

    /// Non-blocking receive from `src`: on a hit, books the ingress port
    /// and advances this rank's clock exactly like the threaded `recv`;
    /// on a miss, records the awaited source so the runner parks us.
    pub fn try_recv(&mut self, src: usize) -> Option<Rc<Vec<u8>>> {
        assert_ne!(src, self.me);
        match self.inbox.get_mut(&src).and_then(|q| q.pop_front()) {
            Some(msg) => Some(self.deliver(src, msg)),
            None => {
                self.missed = Some(src);
                None
            }
        }
    }

    /// Ingress booking shared by inbox and barrage deliveries.
    fn deliver(&mut self, src: usize, msg: Msg) -> Rc<Vec<u8>> {
        let (_, _, class) =
            vfabric::resolve_link(self.topo, self.me, src, self.intra, self.inter, self.scenario);
        let before = self.state.clock;
        let delivery = self.state.ingress_free[class].max(msg.depart) + msg.busy;
        self.state.ingress_free[class] = delivery;
        if delivery > before {
            self.state.idle += delivery - before;
            self.state.clock = delivery;
        }
        let len = msg.payload.len() as u64;
        obs::virtual_span(
            obs::SpanKind::RecvWait,
            obs::Lane::Cpu,
            self.me,
            before,
            self.state.clock,
            len,
        );
        obs::virtual_span(
            obs::SpanKind::Recv,
            obs::Lane::ingress(class),
            self.me,
            delivery - msg.busy,
            delivery,
            len,
        );
        msg.payload
    }

    /// Whether the uniform-copy broadcast fast path is valid for this
    /// roster: every copy must get identical `(α, β, class)` and draw
    /// nothing from the jitter stream, and the roster must be big enough
    /// that n² message materialization is worth avoiding.
    pub fn barrage_ok(&self, roster: &Roster) -> bool {
        if roster.n() <= BARRAGE_MIN {
            return false;
        }
        let s = self.scenario;
        if s.link_jitter > 0.0
            || !s.link_flaps.is_empty()
            || !s.stragglers.is_empty()
            || !s.node_mbps.is_empty()
        {
            return false;
        }
        // uniform link class: all members on one node (all intra) or one
        // member per node (all inter). Members are node-sorted, so a
        // pairwise-adjacent check covers the whole roster.
        let nodes: Vec<usize> = roster.members.iter().map(|&g| self.topo.node_of(g)).collect();
        nodes.windows(2).all(|w| w[0] == w[1]) || nodes.windows(2).all(|w| w[0] < w[1])
    }

    /// Register this rank's copy of `payload` toward every other roster
    /// member in ring order: one egress booking for all n−1 copies.
    /// Callers must have checked [`FleetCtx::barrage_ok`].
    pub fn barrage_send_all(&mut self, roster: &Roster, payload: Rc<Vec<u8>>) {
        let k = roster.n();
        debug_assert!(k > BARRAGE_MIN);
        let peer = roster.global((roster.me + 1) % k);
        let (alpha, beta, class) =
            vfabric::resolve_link(self.topo, self.me, peer, self.intra, self.inter, self.scenario);
        let len = payload.len() as u64;
        let copies = (k - 1) as u64;
        self.meters.add(class, len * copies);
        // gated: no flap, no jitter — occupancy is the bare α + b/β
        let busy = alpha + payload.len() as f64 / beta;
        let d0 = self.state.clock.max(self.state.egress_free[class]);
        self.state.egress_free[class] = d0 + copies as f64 * busy;
        obs::virtual_span(
            obs::SpanKind::Send,
            obs::Lane::egress(class),
            self.me,
            d0,
            d0 + copies as f64 * busy,
            len * copies,
        );
        obs::count(
            if class == INTRA { "vfabric.intra_bytes" } else { "vfabric.inter_bytes" },
            len * copies,
        );
        obs::observe("vfabric.egress_backlog_s", d0 - self.state.clock);
        self.barrage[self.me] = Some(Barrage { payload, d0, busy });
        self.announced = true;
    }

    /// Receive the barrage copy from `src`, where `j ∈ 1..n` is this
    /// rank's position in the sender's ring send order. Parks until the
    /// sender has announced.
    pub fn barrage_recv(&mut self, src: usize, j: usize) -> Option<Rc<Vec<u8>>> {
        debug_assert!(j >= 1);
        let msg = match &self.barrage[src] {
            Some(b) => Msg {
                depart: b.d0 + (j - 1) as f64 * b.busy,
                busy: b.busy,
                payload: Rc::clone(&b.payload),
            },
            None => {
                self.missed = Some(src);
                return None;
            }
        };
        Some(self.deliver(src, msg))
    }

    /// Look up a published chunked-bounds result for this roster.
    pub fn shared_bounds(&self, key: (usize, usize)) -> Option<Rc<Vec<usize>>> {
        self.shared.bounds.get(&key).cloned()
    }

    /// Publish the chunked bounds for this roster, waking every rank
    /// parked on a shared publication.
    pub fn publish_bounds(&mut self, key: (usize, usize), bounds: Vec<usize>) {
        self.shared.bounds.insert(key, Rc::new(bounds));
        self.published = true;
    }

    /// Park until the next shared publication (re-check on wake:
    /// publications for other rosters wake spuriously).
    pub fn park_shared(&mut self) {
        self.missed_shared = true;
    }
}

/// The fleet fabric: persistent per-rank virtual-time state plus byte
/// meters, executing whole collectives single-threadedly via
/// [`FleetFabric::allreduce`]. Mirrors the accessor surface of
/// [`crate::vfabric::VirtualNetwork`], with `elapse`/`sync_to` taking
/// the rank explicitly (there are no per-rank endpoint objects).
pub struct FleetFabric {
    topo: Topology,
    intra: Link,
    inter: Link,
    scenario: Scenario,
    policy: ReadyPolicy,
    states: Vec<RankState>,
    meters: Meters,
}

impl FleetFabric {
    /// Build the fabric over `topo` with per-class link parameters and a
    /// [`Scenario`] — the same constructor shape (and the same per-rank
    /// jitter stream seeding) as the threaded `VirtualNetwork`.
    pub fn new(topo: Topology, intra: Link, inter: Link, scenario: Scenario) -> Self {
        let n = topo.world();
        assert!(n >= 1);
        let states = (0..n)
            .map(|rank| RankState {
                clock: 0.0,
                idle: 0.0,
                egress_free: [0.0; 2],
                ingress_free: [0.0; 2],
                rng: Rng::new(scenario.seed ^ mix64(rank as u64)),
            })
            .collect();
        Self {
            topo,
            intra,
            inter,
            scenario,
            policy: ReadyPolicy::Fifo,
            states,
            meters: Meters::default(),
        }
    }

    /// Flat single-node fabric with one link everywhere and no scenario.
    pub fn flat(n: usize, link: Link) -> Self {
        Self::new(Topology::flat(n), link, link, Scenario::none(0))
    }

    /// Override the ready-queue tie-breaking policy (builder style).
    pub fn with_policy(mut self, policy: ReadyPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn n(&self) -> usize {
        self.topo.world()
    }

    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// This rank's virtual clock, seconds.
    pub fn clock_s(&self, rank: usize) -> f64 {
        self.states[rank].clock
    }

    /// The fabric-wide virtual time: the maximum rank clock.
    pub fn max_clock_s(&self) -> f64 {
        self.states.iter().map(|s| s.clock).fold(0.0, f64::max)
    }

    /// Accumulated recv-wait idle time of `rank`, seconds.
    pub fn idle_s(&self, rank: usize) -> f64 {
        self.states[rank].idle
    }

    /// Total recv-wait idle time across all ranks, seconds.
    pub fn total_idle_s(&self) -> f64 {
        self.states.iter().map(|s| s.idle).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.meters.bytes
    }

    pub fn intra_bytes(&self) -> u64 {
        self.meters.intra
    }

    pub fn inter_bytes(&self) -> u64 {
        self.meters.inter
    }

    pub fn reset_bytes(&mut self) {
        self.meters = Meters::default();
    }

    /// Local work: advance `rank`'s clock by `dt` seconds.
    pub fn elapse(&mut self, rank: usize, dt: f64) {
        if dt > 0.0 {
            self.states[rank].clock += dt;
        }
    }

    /// Barrier alignment: advance `rank`'s clock to at least `t` without
    /// counting the gap as idle.
    pub fn sync_to(&mut self, rank: usize, t: f64) {
        let s = &mut self.states[rank];
        if t > s.clock {
            s.clock = t;
        }
    }

    /// Run one sparse allreduce over the whole world. `inputs[r]` is
    /// rank r's contribution; returns every rank's result.
    pub fn allreduce(
        &mut self,
        sched: Schedule,
        cfg: &SparseConfig,
        codec: &SegmentCodec,
        inputs: Vec<SparseTensor>,
    ) -> anyhow::Result<Vec<SparseTensor>> {
        let members: Vec<usize> = (0..self.topo.world()).collect();
        self.allreduce_members(&members, sched, cfg, codec, inputs)
    }

    /// Run one sparse allreduce over a subset of ranks (elastic
    /// membership: crashed ranks simply sit out — see
    /// [`Scenario::alive_members`]). `members` must be ascending global
    /// ranks; `inputs[j]` belongs to `members[j]`, and results come back
    /// in the same order. Non-member rank state is untouched.
    pub fn allreduce_members(
        &mut self,
        members: &[usize],
        sched: Schedule,
        cfg: &SparseConfig,
        codec: &SegmentCodec,
        inputs: Vec<SparseTensor>,
    ) -> anyhow::Result<Vec<SparseTensor>> {
        anyhow::ensure!(!members.is_empty(), "fleet collective needs at least one member");
        anyhow::ensure!(
            inputs.len() == members.len(),
            "{} inputs for {} members",
            inputs.len(),
            members.len()
        );
        anyhow::ensure!(
            members.windows(2).all(|w| w[0] < w[1]),
            "fleet members must be ascending and unique"
        );
        let shared_members = Rc::new(members.to_vec());
        let tasks: Vec<Box<dyn RankTask>> = inputs
            .into_iter()
            .enumerate()
            .map(|(j, input)| {
                let roster = Roster { members: Rc::clone(&shared_members), me: j };
                kernels::build(sched, cfg, codec, roster, input)
            })
            .collect();
        self.run(members, tasks)
    }

    /// The event loop: poll ready ranks, route their sends, wake parked
    /// receivers, until every task completes (or nothing can progress —
    /// a schedule bug, reported with who-waits-on-whom diagnostics).
    fn run(
        &mut self,
        participants: &[usize],
        mut tasks: Vec<Box<dyn RankTask>>,
    ) -> anyhow::Result<Vec<SparseTensor>> {
        let world = self.topo.world();
        let k = participants.len();
        let policy = self.policy;
        let mut part_of: Vec<Option<u32>> = vec![None; world];
        for (j, &g) in participants.iter().enumerate() {
            anyhow::ensure!(g < world, "fleet member {g} outside world {world}");
            part_of[g] = Some(j as u32);
        }
        let mut inboxes: Vec<Inbox> = (0..k).map(|_| Inbox::default()).collect();
        let mut parked: Vec<Option<Waiting>> = (0..k).map(|_| None).collect();
        let mut queue: VecDeque<usize> = (0..k).collect();
        let mut in_queue = vec![true; k];
        let mut barrage: Vec<Option<Barrage>> = (0..world).map(|_| None).collect();
        let mut shared = SharedScratch::default();
        let mut outbox: Vec<(usize, Msg)> = Vec::new();
        let mut results: Vec<Option<SparseTensor>> = (0..k).map(|_| None).collect();
        let mut remaining = k;
        let mut pol_rng = match policy {
            ReadyPolicy::Shuffle(seed) => Some(Rng::new(seed)),
            _ => None,
        };

        let FleetFabric { topo, intra, inter, scenario, states, meters, .. } = self;
        let (topo, intra, inter) = (*topo, *intra, *inter);
        let scenario: &Scenario = scenario;

        while remaining > 0 {
            let Some(pi) = pop_ready(&mut queue, policy, &mut pol_rng) else {
                let stuck: Vec<String> = parked
                    .iter()
                    .enumerate()
                    .filter_map(|(j, w)| {
                        w.map(|w| match w {
                            Waiting::Msg(src) => {
                                format!("rank {} awaits rank {src}", participants[j])
                            }
                            Waiting::Shared => {
                                format!("rank {} awaits shared bounds", participants[j])
                            }
                        })
                    })
                    .collect();
                anyhow::bail!(
                    "fleetsim deadlock with {remaining} unfinished rank(s): [{}]",
                    stuck.join(", ")
                );
            };
            in_queue[pi] = false;
            let g = participants[pi];
            let mut ctx = FleetCtx {
                me: g,
                topo,
                intra,
                inter,
                scenario,
                state: &mut states[g],
                inbox: &mut inboxes[pi],
                outbox: &mut outbox,
                barrage: &mut barrage,
                shared: &mut shared,
                meters: &mut *meters,
                missed: None,
                missed_shared: false,
                announced: false,
                published: false,
            };
            let polled = tasks[pi].poll(&mut ctx);
            let (missed, missed_shared) = (ctx.missed, ctx.missed_shared);
            let (announced, published) = (ctx.announced, ctx.published);
            drop(ctx);
            match polled {
                Err(e) => return Err(e.context(format!("fleet rank {g} sparse allreduce failed"))),
                Ok(TaskPoll::Done(t)) => {
                    results[pi] = Some(t);
                    remaining -= 1;
                }
                Ok(TaskPoll::Pending) => {
                    if missed_shared {
                        parked[pi] = Some(Waiting::Shared);
                    } else if let Some(src) = missed {
                        parked[pi] = Some(Waiting::Msg(src));
                    } else {
                        anyhow::bail!("fleetsim rank {g}: Pending poll without a parked wait");
                    }
                }
            }
            // route this poll's sends; wake receivers parked on us
            for (dst, msg) in outbox.drain(..) {
                let Some(dpi) = part_of[dst] else {
                    anyhow::bail!("fleet rank {g} sent to rank {dst}, not in this collective");
                };
                let dpi = dpi as usize;
                inboxes[dpi].entry(g).or_default().push_back(msg);
                if parked[dpi] == Some(Waiting::Msg(g)) {
                    parked[dpi] = None;
                    if !in_queue[dpi] {
                        queue.push_back(dpi);
                        in_queue[dpi] = true;
                    }
                }
            }
            if announced {
                // a barrage is "a message to everyone": wake all ranks
                // parked on this sender
                for (dpi, w) in parked.iter_mut().enumerate() {
                    if *w == Some(Waiting::Msg(g)) {
                        *w = None;
                        if !in_queue[dpi] {
                            queue.push_back(dpi);
                            in_queue[dpi] = true;
                        }
                    }
                }
            }
            if published {
                for (dpi, w) in parked.iter_mut().enumerate() {
                    if *w == Some(Waiting::Shared) {
                        *w = None;
                        if !in_queue[dpi] {
                            queue.push_back(dpi);
                            in_queue[dpi] = true;
                        }
                    }
                }
            }
        }
        Ok(results.into_iter().map(|r| r.expect("completed rank result")).collect())
    }
}

fn pop_ready(
    queue: &mut VecDeque<usize>,
    policy: ReadyPolicy,
    rng: &mut Option<Rng>,
) -> Option<usize> {
    match policy {
        ReadyPolicy::Fifo => queue.pop_front(),
        ReadyPolicy::Lifo => queue.pop_back(),
        ReadyPolicy::Shuffle(_) => {
            if queue.is_empty() {
                return None;
            }
            let i = rng.as_mut().expect("shuffle rng").below(queue.len() as u64) as usize;
            queue.swap(i, queue.len() - 1);
            queue.pop_back()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::sparse::SegmentCodec;

    fn link(alpha: f64, bps: f64) -> Link {
        Link { bandwidth_bps: bps, latency_s: alpha }
    }

    fn inputs(n: usize, d: usize) -> Vec<SparseTensor> {
        (0..n)
            .map(|r| {
                SparseTensor::new(d, vec![r as u32, (r + n) as u32], vec![1.0, (r + 1) as f32])
            })
            .collect()
    }

    fn correct_sum(n: usize, d: usize) -> Vec<f32> {
        let mut want = vec![0.0f32; d];
        for t in inputs(n, d) {
            for (&i, &v) in t.indices().iter().zip(t.values()) {
                want[i as usize] += v;
            }
        }
        want
    }

    #[test]
    fn every_schedule_sums_exactly_across_policies() {
        let d = 64;
        for policy in [ReadyPolicy::Fifo, ReadyPolicy::Lifo, ReadyPolicy::Shuffle(7)] {
            for sched in Schedule::all() {
                for n in [1usize, 2, 4, 7] {
                    let topo =
                        if n % 2 == 0 { Topology::new(2, n / 2) } else { Topology::flat(n) };
                    let mut fab =
                        FleetFabric::new(topo, link(1e-6, 1e9), link(1e-5, 1e8), Scenario::none(3))
                            .with_policy(policy);
                    let cfg = SparseConfig {
                        topology: Some(topo),
                        resparsify: false,
                        ..SparseConfig::default()
                    };
                    let codec = SegmentCodec::raw(cfg.dense_switch);
                    let outs = fab
                        .allreduce(sched, &cfg, &codec, inputs(n, d))
                        .unwrap_or_else(|e| panic!("{} n={n}: {e:?}", sched.name()));
                    let want = correct_sum(n, d);
                    for (r, out) in outs.iter().enumerate() {
                        assert_eq!(
                            out.to_dense().data(),
                            want.as_slice(),
                            "{} n={n} rank {r} policy {policy:?}",
                            sched.name()
                        );
                    }
                    if n > 1 {
                        assert!(fab.total_bytes() > 0);
                        assert!(fab.max_clock_s() > 0.0);
                    }
                }
            }
        }
    }

    #[test]
    fn members_subset_excludes_crashed_ranks() {
        let n = 6;
        let d = 32;
        let mut fab = FleetFabric::flat(n, link(0.0, 1e6));
        let members = vec![0usize, 1, 3, 4, 5]; // rank 2 crashed
        let ins: Vec<SparseTensor> =
            members.iter().map(|&r| SparseTensor::new(d, vec![r as u32], vec![1.0])).collect();
        let cfg = SparseConfig::default();
        let codec = SegmentCodec::raw(cfg.dense_switch);
        let outs = fab
            .allreduce_members(&members, Schedule::GatherAll, &cfg, &codec, ins)
            .unwrap();
        for out in &outs {
            assert_eq!(out.indices(), &[0, 1, 3, 4, 5]);
        }
        // the crashed rank never moved
        assert_eq!(fab.clock_s(2), 0.0);
        assert!(fab.clock_s(0) > 0.0);
    }

    #[test]
    fn deadlock_reports_who_waits_on_whom() {
        struct StuckTask;
        impl RankTask for StuckTask {
            fn poll(&mut self, ctx: &mut FleetCtx) -> anyhow::Result<TaskPoll> {
                // wait on a message nobody sends
                let src = (ctx.me + 1) % 2;
                match ctx.try_recv(src) {
                    Some(_) => unreachable!(),
                    None => Ok(TaskPoll::Pending),
                }
            }
        }
        let mut fab = FleetFabric::flat(2, Link::ideal());
        let tasks: Vec<Box<dyn RankTask>> = vec![Box::new(StuckTask), Box::new(StuckTask)];
        let err = fab.run(&[0, 1], tasks).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("deadlock"), "{msg}");
        assert!(msg.contains("rank 0 awaits rank 1"), "{msg}");
    }

    #[test]
    fn clocks_and_meters_persist_across_collectives() {
        let d = 32;
        let n = 4;
        let mut fab = FleetFabric::flat(n, link(0.0, 100.0));
        let cfg = SparseConfig::default();
        let codec = SegmentCodec::raw(cfg.dense_switch);
        fab.allreduce(Schedule::GatherAll, &cfg, &codec, inputs(n, d)).unwrap();
        let c1 = fab.max_clock_s();
        let b1 = fab.total_bytes();
        assert!(c1 > 0.0 && b1 > 0);
        fab.allreduce(Schedule::GatherAll, &cfg, &codec, inputs(n, d)).unwrap();
        // second step starts where the first left off
        assert!(fab.max_clock_s() > c1);
        assert_eq!(fab.total_bytes(), 2 * b1);
        fab.reset_bytes();
        assert_eq!(fab.total_bytes(), 0);
        // elapse / sync_to move individual rank clocks
        let c = fab.clock_s(0);
        fab.elapse(0, 1.5);
        assert!((fab.clock_s(0) - (c + 1.5)).abs() < 1e-12);
        fab.sync_to(1, 100.0);
        assert_eq!(fab.clock_s(1), 100.0);
        fab.sync_to(1, 1.0); // never moves backwards
        assert_eq!(fab.clock_s(1), 100.0);
    }
}
