//! Rank state machines for the fleet runner: each schedule's per-rank
//! program order from `crate::collective::sparse`, reified as a
//! resumable [`RankTask`].
//!
//! **Lockstep invariant:** every kernel here must perform the *exact*
//! send/recv/merge sequence of its threaded twin — same peers, same
//! payload bytes, same merge order, same jitter-stream draws — because
//! `tests/fleetsim_equivalence.rs` pins the two fabrics bit-identical
//! on byte meters and virtual clocks. Anyone changing a schedule in
//! `collective/sparse/` must mirror the change here (the differential
//! test catches a miss at every n ≤ 8 point).
//!
//! The threaded kernels also open wall-clock `Round` RAII spans; those
//! are intentionally omitted here — wall time is meaningless when one
//! OS thread multiplexes every rank, and the differential tests compare
//! virtual-stamped spans only. Byte meters, virtual clocks, payload
//! bytes, and `sched.*` counters are all mirrored exactly.
//!
//! Structure: sends never park (the fabric's channels are unbounded),
//! so each kernel is an enum-state machine whose states sit exactly at
//! the receive points; `poll` runs forward until a `try_recv` misses.
//! Two fleet-only adaptations preserve results while cutting the
//! O(n²)-rank costs of the all-to-all histogram phase:
//!
//! - the gather sub-machine streams arrivals instead of buffering all
//!   n−1 blobs (and switches to the barrage fast path on large uniform
//!   rosters — see `fleetsim` module docs);
//! - the chunked schedule computes its balanced bounds once, at roster
//!   position 0, and publishes them through the runner's shared
//!   scratch. The summed histogram is order-independent (`u64`
//!   saturating adds of partial sums that cannot saturate), so the
//!   shared bounds are byte-identical to every rank's own computation.

use super::{FleetCtx, RankTask, Roster, TaskPoll};
use crate::collective::sparse::{merge, prev_power_of_two, SegmentCodec};
use crate::collective::{Schedule, SparseConfig, Topology};
use crate::tensor::SparseTensor;
use crate::util::varint;
use std::rc::Rc;

/// Build the state machine for `roster.me`'s side of one collective —
/// the fleet twin of `Schedule::build_with` (same dispatch, same
/// hierarchical-inner fallback, codecs duplicated per rank).
pub(crate) fn build(
    sched: Schedule,
    cfg: &SparseConfig,
    codec: &SegmentCodec,
    roster: Roster,
    input: SparseTensor,
) -> Box<dyn RankTask> {
    match sched {
        Schedule::GatherAll => Box::new(GatherAllTask::new(codec.duplicate(), roster, input)),
        Schedule::RecursiveDouble => {
            Box::new(RecursiveDoubleTask::new(codec.duplicate(), roster, input))
        }
        Schedule::RingRescatter => {
            Box::new(RingTask::new(codec.duplicate(), cfg.resparsify, roster, input))
        }
        Schedule::RingRescatterExact => {
            Box::new(RingTask::new(codec.duplicate(), false, roster, input))
        }
        Schedule::ChunkedRescatter => {
            Box::new(ChunkedTask::new(codec.duplicate(), cfg.chunks, roster, input))
        }
        Schedule::Hierarchical => Box::new(HierTask::new(codec.duplicate(), *cfg, roster, input)),
    }
}

fn empty(d: usize) -> SparseTensor {
    SparseTensor::new(d, Vec::new(), Vec::new())
}

// ---------------------------------------------------------------- gather

/// Streaming twin of `collective::all_gather_peers`: same ring send
/// order (`me+1, me+2, …`) and reverse-ring receive order
/// (`me−1, me−2, …`), yielding arrivals one at a time so callers decide
/// whether to keep them.
enum AgpEvent {
    /// blob from roster-local `peer` arrived
    Got(usize, Rc<Vec<u8>>),
    Pending,
    Finished,
}

struct AllGatherPeers {
    roster: Roster,
    blob: Option<Rc<Vec<u8>>>,
    barrage: bool,
    sent: bool,
    /// next receive index, 1..n
    j: usize,
}

impl AllGatherPeers {
    fn new(roster: Roster, blob: Vec<u8>) -> Self {
        Self { roster, blob: Some(Rc::new(blob)), barrage: false, sent: false, j: 1 }
    }

    fn step(&mut self, ctx: &mut FleetCtx) -> AgpEvent {
        let n = self.roster.n();
        let me = self.roster.me;
        if !self.sent {
            self.sent = true;
            let blob = self.blob.take().expect("gather blob");
            self.barrage = ctx.barrage_ok(&self.roster);
            if self.barrage {
                ctx.barrage_send_all(&self.roster, blob);
            } else {
                for j in 1..n {
                    ctx.send_rc(self.roster.global((me + j) % n), Rc::clone(&blob));
                }
            }
        }
        if self.j >= n {
            return AgpEvent::Finished;
        }
        let peer = (me + n - self.j) % n;
        let src = self.roster.global(peer);
        let got = if self.barrage {
            // my position in src's ring send order
            ctx.barrage_recv(src, (me + n - peer) % n)
        } else {
            ctx.try_recv(src)
        };
        match got {
            None => AgpEvent::Pending,
            Some(raw) => {
                self.j += 1;
                AgpEvent::Got(peer, raw)
            }
        }
    }
}

pub(crate) struct GatherAllTask {
    codec: SegmentCodec,
    roster: Roster,
    d: usize,
    acc: Option<SparseTensor>,
    agp: Option<AllGatherPeers>,
    blobs: Vec<Option<Rc<Vec<u8>>>>,
}

impl GatherAllTask {
    pub(crate) fn new(codec: SegmentCodec, roster: Roster, input: SparseTensor) -> Self {
        let n = roster.n();
        let d = input.dense_len();
        let agp = if n > 1 {
            Some(AllGatherPeers::new(roster.clone(), codec.encode(&input, 0, d)))
        } else {
            None
        };
        Self { codec, roster, d, acc: Some(input), agp, blobs: (0..n).map(|_| None).collect() }
    }
}

impl RankTask for GatherAllTask {
    fn poll(&mut self, ctx: &mut FleetCtx) -> anyhow::Result<TaskPoll> {
        let n = self.roster.n();
        if n == 1 {
            return Ok(TaskPoll::Done(self.acc.take().expect("input")));
        }
        let agp = self.agp.as_mut().expect("gather sub-machine");
        loop {
            match agp.step(ctx) {
                AgpEvent::Pending => return Ok(TaskPoll::Pending),
                AgpEvent::Got(peer, raw) => self.blobs[peer] = Some(raw),
                AgpEvent::Finished => break,
            }
        }
        // merge in ascending peer order — the threaded kernel's order,
        // so f32 sums are bit-identical
        let mut acc = self.acc.take().expect("input");
        for peer in 0..n {
            if peer == self.roster.me {
                continue;
            }
            let raw = self.blobs[peer].take().expect("gathered blob");
            acc = merge::merge_sum(&acc, &self.codec.decode(self.d, &raw)?);
        }
        crate::obs::count("sched.gather_all_steps", 1);
        Ok(TaskPoll::Done(acc))
    }
}

// ------------------------------------------------------ recursive double

enum RdState {
    Start,
    /// folded-out extra (me ≥ p): sent, awaiting the result back
    FoldBack,
    /// fold target (me < extras): awaiting the extra's contribution
    FoldIn,
    /// doubling round: sent to `me ^ stride`, awaiting the partner
    Stride(usize),
}

pub(crate) struct RecursiveDoubleTask {
    codec: SegmentCodec,
    roster: Roster,
    d: usize,
    p: usize,
    extras: usize,
    acc: Option<SparseTensor>,
    state: RdState,
}

impl RecursiveDoubleTask {
    pub(crate) fn new(codec: SegmentCodec, roster: Roster, input: SparseTensor) -> Self {
        let n = roster.n();
        let p = prev_power_of_two(n);
        Self {
            codec,
            roster,
            d: input.dense_len(),
            p,
            extras: n - p,
            acc: Some(input),
            state: RdState::Start,
        }
    }

    fn enter_stride(&mut self, ctx: &mut FleetCtx, stride: usize) {
        let partner = self.roster.global(self.roster.me ^ stride);
        let blob = self.codec.encode(self.acc.as_ref().expect("acc"), 0, self.d);
        ctx.send(partner, blob);
        self.state = RdState::Stride(stride);
    }
}

impl RankTask for RecursiveDoubleTask {
    fn poll(&mut self, ctx: &mut FleetCtx) -> anyhow::Result<TaskPoll> {
        let me = self.roster.me;
        loop {
            match self.state {
                RdState::Start => {
                    if self.roster.n() == 1 {
                        return Ok(TaskPoll::Done(self.acc.take().expect("input")));
                    }
                    if me >= self.p {
                        let partner = self.roster.global(me - self.p);
                        let blob = self.codec.encode(self.acc.as_ref().expect("acc"), 0, self.d);
                        ctx.send(partner, blob);
                        self.state = RdState::FoldBack;
                    } else if me < self.extras {
                        self.state = RdState::FoldIn;
                    } else {
                        self.enter_stride(ctx, 1);
                    }
                }
                RdState::FoldBack => {
                    let partner = self.roster.global(me - self.p);
                    match ctx.try_recv(partner) {
                        None => return Ok(TaskPoll::Pending),
                        Some(raw) => return Ok(TaskPoll::Done(self.codec.decode(self.d, &raw)?)),
                    }
                }
                RdState::FoldIn => {
                    let src = self.roster.global(self.p + me);
                    match ctx.try_recv(src) {
                        None => return Ok(TaskPoll::Pending),
                        Some(raw) => {
                            let folded = self.codec.decode(self.d, &raw)?;
                            let acc = self.acc.take().expect("acc");
                            self.acc = Some(merge::merge_sum(&acc, &folded));
                            self.enter_stride(ctx, 1);
                        }
                    }
                }
                RdState::Stride(stride) => {
                    let partner = self.roster.global(me ^ stride);
                    match ctx.try_recv(partner) {
                        None => return Ok(TaskPoll::Pending),
                        Some(raw) => {
                            let theirs = self.codec.decode(self.d, &raw)?;
                            let acc = self.acc.take().expect("acc");
                            self.acc = Some(merge::merge_sum(&acc, &theirs));
                            let next = stride << 1;
                            if next < self.p {
                                self.enter_stride(ctx, next);
                            } else {
                                if me < self.extras {
                                    let blob = self
                                        .codec
                                        .encode(self.acc.as_ref().expect("acc"), 0, self.d);
                                    ctx.send(self.roster.global(self.p + me), blob);
                                }
                                return Ok(TaskPoll::Done(self.acc.take().expect("acc")));
                            }
                        }
                    }
                }
            }
        }
    }
}

// -------------------------------------------------------- ring rescatter

enum RingState {
    Start,
    /// reduce-scatter round `s`: sent, awaiting the previous rank
    RsRecv(usize),
    /// allgather round `s`: sent, awaiting the previous rank
    AgRecv(usize),
}

pub(crate) struct RingTask {
    codec: SegmentCodec,
    resparsify: bool,
    roster: Roster,
    d: usize,
    bounds: Vec<usize>,
    segs: Vec<SparseTensor>,
    k_max: u64,
    state: RingState,
    input: Option<SparseTensor>,
}

impl RingTask {
    pub(crate) fn new(
        codec: SegmentCodec,
        resparsify: bool,
        roster: Roster,
        input: SparseTensor,
    ) -> Self {
        let n = roster.n();
        let d = input.dense_len();
        let k_max = input.nnz() as u64;
        let (bounds, segs, input) = if n > 1 {
            let bounds = merge::chunk_bounds(d, n);
            let segs = merge::split_ranges(&input, &bounds);
            (bounds, segs, None)
        } else {
            (Vec::new(), Vec::new(), Some(input))
        };
        Self { codec, resparsify, roster, d, bounds, segs, k_max, state: RingState::Start, input }
    }

    fn send_rs(&mut self, ctx: &mut FleetCtx, s: usize) {
        let n = self.roster.n();
        let me = self.roster.me;
        let cs = (me + n - s) % n;
        let mut msg = Vec::new();
        varint::write_u64(&mut msg, self.k_max);
        msg.extend_from_slice(&self.codec.encode(
            &self.segs[cs],
            self.bounds[cs],
            self.bounds[cs + 1],
        ));
        ctx.send(self.roster.global((me + 1) % n), msg);
    }

    fn send_ag(&mut self, ctx: &mut FleetCtx, s: usize) {
        let n = self.roster.n();
        let me = self.roster.me;
        let cs = (me + 1 + n - s) % n;
        let blob = self.codec.encode(&self.segs[cs], self.bounds[cs], self.bounds[cs + 1]);
        ctx.send(self.roster.global((me + 1) % n), blob);
    }
}

impl RankTask for RingTask {
    fn poll(&mut self, ctx: &mut FleetCtx) -> anyhow::Result<TaskPoll> {
        let n = self.roster.n();
        let me = self.roster.me;
        let prev = if n > 1 { self.roster.global((me + n - 1) % n) } else { 0 };
        loop {
            match self.state {
                RingState::Start => {
                    if n == 1 {
                        return Ok(TaskPoll::Done(self.input.take().expect("input")));
                    }
                    self.send_rs(ctx, 0);
                    self.state = RingState::RsRecv(0);
                }
                RingState::RsRecv(s) => {
                    let Some(raw) = ctx.try_recv(prev) else {
                        return Ok(TaskPoll::Pending);
                    };
                    let mut pos = 0usize;
                    self.k_max = self.k_max.max(varint::read_u64(&raw, &mut pos)?);
                    let incoming = self.codec.decode(self.d, &raw[pos..])?;
                    let cr = (me + n - s - 1) % n;
                    self.segs[cr] = merge::merge_sum(&self.segs[cr], &incoming);
                    if s + 1 < n - 1 {
                        self.send_rs(ctx, s + 1);
                        self.state = RingState::RsRecv(s + 1);
                    } else {
                        let own = (me + 1) % n;
                        if self.resparsify {
                            self.segs[own] = merge::top_r_sparse(
                                &self.segs[own],
                                (self.k_max as usize).div_ceil(n),
                            );
                        }
                        self.send_ag(ctx, 0);
                        self.state = RingState::AgRecv(0);
                    }
                }
                RingState::AgRecv(s) => {
                    let Some(raw) = ctx.try_recv(prev) else {
                        return Ok(TaskPoll::Pending);
                    };
                    let cr = (me + n - s) % n;
                    self.segs[cr] = self.codec.decode(self.d, &raw)?;
                    if s + 1 < n - 1 {
                        self.send_ag(ctx, s + 1);
                        self.state = RingState::AgRecv(s + 1);
                    } else {
                        let mut idx = Vec::with_capacity(self.segs.iter().map(|t| t.nnz()).sum());
                        let mut val = Vec::with_capacity(idx.capacity());
                        for seg in self.segs.drain(..) {
                            let (_, i, v) = seg.into_parts();
                            idx.extend(i);
                            val.extend(v);
                        }
                        return Ok(TaskPoll::Done(SparseTensor::new(self.d, idx, val)));
                    }
                }
            }
        }
    }
}

// ------------------------------------------------------ chunked rescatter

enum ChState {
    /// histogram allgather in flight
    Hist,
    /// non-zero roster positions: awaiting the published bounds
    WaitBounds,
    /// phase-1 offset `s`, frame `j`: sent, awaiting the source's frame
    PxRecv(usize, usize),
    /// phase-2 round `s`, frame `j`: sent, awaiting the previous rank
    AgRecv(usize, usize),
}

pub(crate) struct ChunkedTask {
    codec: SegmentCodec,
    roster: Roster,
    d: usize,
    m: usize,
    p: usize,
    state: ChState,
    agp: Option<AllGatherPeers>,
    /// roster position 0 accumulates the summed histogram here
    total: Option<Vec<u64>>,
    bounds: Option<Rc<Vec<usize>>>,
    input: Option<SparseTensor>,
    segs: Vec<SparseTensor>,
    acc: Vec<SparseTensor>,
    groups: Vec<Vec<SparseTensor>>,
    send_group: Vec<SparseTensor>,
    recvd: Vec<SparseTensor>,
}

impl ChunkedTask {
    pub(crate) fn new(
        codec: SegmentCodec,
        chunks: usize,
        roster: Roster,
        input: SparseTensor,
    ) -> Self {
        let n = roster.n();
        let d = input.dense_len();
        let m = crate::collective::sparse::ChunkedRescatter::sub_chunks(chunks, n);
        let p = m * n;
        let (agp, total) = if n > 1 {
            let bins = merge::balance_bins(d, p);
            let counts = merge::bin_counts(&input, bins);
            let mut blob = Vec::with_capacity(bins * 2);
            for &c in &counts {
                varint::write_u64(&mut blob, c);
            }
            let total = if roster.me == 0 { Some(counts) } else { None };
            (Some(AllGatherPeers::new(roster.clone(), blob)), total)
        } else {
            (None, None)
        };
        Self {
            codec,
            roster,
            d,
            m,
            p,
            state: ChState::Hist,
            agp,
            total,
            bounds: None,
            input: Some(input),
            segs: Vec::new(),
            acc: Vec::new(),
            groups: Vec::new(),
            send_group: Vec::new(),
            recvd: Vec::new(),
        }
    }

    /// Bounds are in: split my contribution and seed the accumulator
    /// with my own group's slices, then open phase-1 offset 1.
    fn start_phase1(&mut self, ctx: &mut FleetCtx) {
        let bounds = self.bounds.as_ref().expect("bounds");
        let input = self.input.take().expect("input");
        self.segs = merge::split_ranges(&input, bounds);
        let me = self.roster.me;
        self.acc = (0..self.m)
            .map(|j| std::mem::replace(&mut self.segs[me * self.m + j], empty(self.d)))
            .collect();
        self.px_send(ctx, 1, 0);
        self.state = ChState::PxRecv(1, 0);
    }

    fn px_send(&mut self, ctx: &mut FleetCtx, s: usize, j: usize) {
        let n = self.roster.n();
        let dst = (self.roster.me + s) % n;
        let c = dst * self.m + j;
        let bounds = self.bounds.as_ref().expect("bounds");
        let blob = self.codec.encode(&self.segs[c], bounds[c], bounds[c + 1]);
        ctx.send(self.roster.global(dst), blob);
    }

    /// Open phase-2 round `s`: take the outgoing group, ship its first
    /// frame.
    fn ag_enter(&mut self, ctx: &mut FleetCtx, s: usize) {
        let n = self.roster.n();
        let gs = (self.roster.me + n - s) % n;
        self.send_group = std::mem::take(&mut self.groups[gs]);
        self.recvd = Vec::with_capacity(self.m);
        self.ag_send(ctx, s, 0);
        self.state = ChState::AgRecv(s, 0);
    }

    fn ag_send(&mut self, ctx: &mut FleetCtx, s: usize, j: usize) {
        let n = self.roster.n();
        let me = self.roster.me;
        let gs = (me + n - s) % n;
        let c = gs * self.m + j;
        let bounds = self.bounds.as_ref().expect("bounds");
        let blob = self.codec.encode(&self.send_group[j], bounds[c], bounds[c + 1]);
        ctx.send(self.roster.global((me + 1) % n), blob);
    }
}

impl RankTask for ChunkedTask {
    fn poll(&mut self, ctx: &mut FleetCtx) -> anyhow::Result<TaskPoll> {
        let n = self.roster.n();
        let me = self.roster.me;
        loop {
            match self.state {
                ChState::Hist => {
                    if n == 1 {
                        return Ok(TaskPoll::Done(self.input.take().expect("input")));
                    }
                    let agp = self.agp.as_mut().expect("hist gather");
                    loop {
                        match agp.step(ctx) {
                            AgpEvent::Pending => return Ok(TaskPoll::Pending),
                            AgpEvent::Got(peer, raw) => {
                                // only position 0 folds histograms in; the
                                // sum is arrival-order independent, so its
                                // bounds equal what any rank would compute
                                if let Some(total) = self.total.as_mut() {
                                    let mut pos = 0usize;
                                    for t in total.iter_mut() {
                                        *t = t.saturating_add(varint::read_u64(&raw, &mut pos)?);
                                    }
                                    if pos != raw.len() {
                                        anyhow::bail!(
                                            "rank {peer} histogram has {} trailing byte(s)",
                                            raw.len() - pos
                                        );
                                    }
                                }
                            }
                            AgpEvent::Finished => break,
                        }
                    }
                    self.agp = None;
                    if let Some(total) = self.total.take() {
                        let bounds = merge::balanced_bounds(&total, self.d, self.p);
                        ctx.publish_bounds(self.roster.key(), bounds);
                        self.bounds = ctx.shared_bounds(self.roster.key());
                        self.start_phase1(ctx);
                    } else {
                        self.state = ChState::WaitBounds;
                    }
                }
                ChState::WaitBounds => match ctx.shared_bounds(self.roster.key()) {
                    None => {
                        ctx.park_shared();
                        return Ok(TaskPoll::Pending);
                    }
                    Some(b) => {
                        self.bounds = Some(b);
                        self.start_phase1(ctx);
                    }
                },
                ChState::PxRecv(s, j) => {
                    let src = self.roster.global((me + n - s) % n);
                    let Some(raw) = ctx.try_recv(src) else {
                        return Ok(TaskPoll::Pending);
                    };
                    let incoming = self.codec.decode(self.d, &raw)?;
                    self.acc[j] = merge::merge_sum(&self.acc[j], &incoming);
                    if j + 1 < self.m {
                        self.px_send(ctx, s, j + 1);
                        self.state = ChState::PxRecv(s, j + 1);
                    } else if s + 1 < n {
                        self.px_send(ctx, s + 1, 0);
                        self.state = ChState::PxRecv(s + 1, 0);
                    } else {
                        self.segs = Vec::new();
                        self.groups = (0..n).map(|_| Vec::new()).collect();
                        self.groups[me] = std::mem::take(&mut self.acc);
                        self.ag_enter(ctx, 0);
                    }
                }
                ChState::AgRecv(s, j) => {
                    let prev = self.roster.global((me + n - 1) % n);
                    let Some(raw) = ctx.try_recv(prev) else {
                        return Ok(TaskPoll::Pending);
                    };
                    self.recvd.push(self.codec.decode(self.d, &raw)?);
                    if j + 1 < self.m {
                        self.ag_send(ctx, s, j + 1);
                        self.state = ChState::AgRecv(s, j + 1);
                    } else {
                        let gs = (me + n - s) % n;
                        let gr = (me + n - s - 1) % n;
                        self.groups[gs] = std::mem::take(&mut self.send_group);
                        self.groups[gr] = std::mem::take(&mut self.recvd);
                        if s + 1 < n - 1 {
                            self.ag_enter(ctx, s + 1);
                        } else {
                            let mut idx = Vec::new();
                            let mut val = Vec::new();
                            for g in self.groups.drain(..) {
                                for sub in g {
                                    let (_, i, v) = sub.into_parts();
                                    idx.extend(i);
                                    val.extend(v);
                                }
                            }
                            return Ok(TaskPoll::Done(SparseTensor::new(self.d, idx, val)));
                        }
                    }
                }
            }
        }
    }
}

// ----------------------------------------------------------- hierarchical

enum HState {
    Start,
    /// member: contributed, awaiting the broadcast back
    MemberRecv,
    /// leader: draining member contributions in rank order
    LeadGather,
    /// leader: inner schedule running among the leaders
    Inner,
}

pub(crate) struct HierTask {
    codec: SegmentCodec,
    cfg: SparseConfig,
    roster: Roster,
    d: usize,
    /// resolved at Start (cfg.topology or flat world)
    topo: Topology,
    acc: Option<SparseTensor>,
    inner: Option<Box<dyn RankTask>>,
    /// next roster-local member rank to gather from
    gather_next: usize,
    state: HState,
}

impl HierTask {
    pub(crate) fn new(codec: SegmentCodec, cfg: SparseConfig, roster: Roster, input: SparseTensor) -> Self {
        let d = input.dense_len();
        Self {
            codec,
            cfg,
            roster,
            d,
            topo: Topology::flat(1),
            acc: Some(input),
            inner: None,
            gather_next: 0,
            state: HState::Start,
        }
    }

    /// Phase 3, leader side: encode once, ship the shared buffer to
    /// every member.
    fn bcast(&mut self, ctx: &mut FleetCtx) {
        if self.topo.ranks_per_node > 1 {
            let me = self.roster.me;
            let node = self.topo.node_of(me);
            let blob = Rc::new(self.codec.encode(self.acc.as_ref().expect("acc"), 0, self.d));
            for m in self.topo.members(node) {
                if m != me {
                    ctx.send_rc(self.roster.global(m), Rc::clone(&blob));
                }
            }
        }
    }
}

impl RankTask for HierTask {
    fn poll(&mut self, ctx: &mut FleetCtx) -> anyhow::Result<TaskPoll> {
        let n = self.roster.n();
        let me = self.roster.me;
        loop {
            match self.state {
                HState::Start => {
                    if n == 1 {
                        return Ok(TaskPoll::Done(self.acc.take().expect("input")));
                    }
                    let topo = self.cfg.topology.unwrap_or_else(|| Topology::flat(n));
                    anyhow::ensure!(
                        topo.world() == n,
                        "topology {} expects {} ranks, world is {n}",
                        topo.label(),
                        topo.world()
                    );
                    self.topo = topo;
                    let node = topo.node_of(me);
                    let leader = topo.leader_of(node);
                    if me != leader {
                        let blob = self.codec.encode(self.acc.as_ref().expect("acc"), 0, self.d);
                        ctx.send(self.roster.global(leader), blob);
                        self.state = HState::MemberRecv;
                    } else {
                        self.gather_next = topo.members(node).start;
                        self.state = HState::LeadGather;
                    }
                }
                HState::MemberRecv => {
                    let leader = self.topo.leader_of(self.topo.node_of(me));
                    match ctx.try_recv(self.roster.global(leader)) {
                        None => return Ok(TaskPoll::Pending),
                        Some(raw) => return Ok(TaskPoll::Done(self.codec.decode(self.d, &raw)?)),
                    }
                }
                HState::LeadGather => {
                    let node = self.topo.node_of(me);
                    let members = self.topo.members(node);
                    while self.gather_next < members.end {
                        let m = self.gather_next;
                        if m == me {
                            self.gather_next += 1;
                            continue;
                        }
                        let Some(raw) = ctx.try_recv(self.roster.global(m)) else {
                            return Ok(TaskPoll::Pending);
                        };
                        let theirs = self.codec.decode(self.d, &raw)?;
                        let acc = self.acc.take().expect("acc");
                        self.acc = Some(merge::merge_sum(&acc, &theirs));
                        self.gather_next += 1;
                    }
                    if self.topo.nodes > 1 {
                        // the leader group is flat by construction; guard
                        // against a recursive inner pick (same fallback
                        // as Schedule::build_with)
                        let inner_sched = if self.cfg.inner == Schedule::Hierarchical {
                            Schedule::GatherAll
                        } else {
                            self.cfg.inner
                        };
                        let inner_members: Vec<usize> =
                            self.topo.leaders().iter().map(|&l| self.roster.global(l)).collect();
                        let inner_roster =
                            Roster { members: Rc::new(inner_members), me: node };
                        let input = self.acc.take().expect("acc");
                        self.inner = Some(build(
                            inner_sched,
                            &self.cfg,
                            &self.codec,
                            inner_roster,
                            input,
                        ));
                        self.state = HState::Inner;
                    } else {
                        self.bcast(ctx);
                        return Ok(TaskPoll::Done(self.acc.take().expect("acc")));
                    }
                }
                HState::Inner => {
                    match self.inner.as_mut().expect("inner task").poll(ctx)? {
                        TaskPoll::Pending => return Ok(TaskPoll::Pending),
                        TaskPoll::Done(t) => {
                            self.inner = None;
                            self.acc = Some(t);
                            self.bcast(ctx);
                            return Ok(TaskPoll::Done(self.acc.take().expect("acc")));
                        }
                    }
                }
            }
        }
    }
}
