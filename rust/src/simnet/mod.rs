//! Analytic network-time model (the testbed substitution for the paper's
//! 100 Mbps–10 Gbps link sweep in Fig 11).
//!
//! The paper varies bandwidth and reports per-iteration wall time broken
//! into forward/backward compute, encode/decode, and communication. The
//! first two are *measured* on this testbed; communication time is
//! *modelled* from exact wire byte counts with the standard α–β model:
//! `T = steps·α + bytes_on_busiest_link/β`.

/// A link configuration.
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// bandwidth, bytes/second
    pub bandwidth_bps: f64,
    /// per-message latency, seconds (α)
    pub latency_s: f64,
}

impl Link {
    pub fn mbps(mb: f64) -> Self {
        Self { bandwidth_bps: mb * 1e6 / 8.0, latency_s: 50e-6 }
    }

    pub fn gbps(gb: f64) -> Self {
        Self { bandwidth_bps: gb * 1e9 / 8.0, latency_s: 25e-6 }
    }
}

/// Time for a ring allreduce of a dense payload of `bytes` across `n`
/// workers: 2(n−1) steps, each moving `bytes/n` per link.
pub fn allreduce_time(bytes: u64, n: usize, link: Link) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let steps = 2 * (n - 1);
    steps as f64 * link.latency_s
        + (2.0 * (n as f64 - 1.0) / n as f64) * bytes as f64 / link.bandwidth_bps
}

/// Time for an allgather where each worker contributes `blob_bytes`:
/// every worker receives (n−1) blobs; with full-duplex links and a ring
/// schedule this is (n−1) steps of `blob_bytes` each.
pub fn allgather_time(blob_bytes: u64, n: usize, link: Link) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    (n - 1) as f64 * (link.latency_s + blob_bytes as f64 / link.bandwidth_bps)
}

/// Parameter-server exchange: server ingests n−1 blobs and broadcasts the
/// aggregate; the server link is the bottleneck.
pub fn ps_time(up_bytes: u64, down_bytes: u64, n: usize, link: Link) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    2.0 * link.latency_s
        + ((n - 1) as f64 * up_bytes as f64 + (n - 1) as f64 * down_bytes as f64)
            / link.bandwidth_bps
}

/// One Fig-11 style iteration breakdown (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct IterBreakdown {
    pub compute_s: f64,
    pub codec_s: f64,
    pub comm_s: f64,
}

impl IterBreakdown {
    pub fn total(&self) -> f64 {
        self.compute_s + self.codec_s + self.comm_s
    }

    /// Speedup of this breakdown relative to a baseline.
    pub fn speedup_vs(&self, baseline: &IterBreakdown) -> f64 {
        baseline.total() / self.total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_scaling() {
        // 10x the bandwidth -> ~10x less comm time (latency negligible at MB sizes)
        let b = 10_000_000u64;
        let slow = allgather_time(b, 4, Link::mbps(100.0));
        let fast = allgather_time(b, 4, Link::gbps(1.0));
        assert!((slow / fast - 10.0).abs() < 0.5, "ratio {}", slow / fast);
    }

    #[test]
    fn allreduce_asymptotics() {
        // ring allreduce per-worker traffic is bandwidth-optimal: ~2x
        // payload regardless of n (for large n)
        let link = Link::gbps(10.0);
        let t4 = allreduce_time(1 << 24, 4, link);
        let t16 = allreduce_time(1 << 24, 16, link);
        assert!(t16 < t4 * 1.5, "t16 {t16} vs t4 {t4}");
    }

    #[test]
    fn compression_crossover_shape() {
        // Fig 11's qualitative claim: compression helps at low bandwidth,
        // not when links are fast relative to codec cost.
        let n = 4;
        let dense = 127_000_000u64; // NCF-sized fp32 gradient
        let sparse_blob = dense / 20; // top-10% + container overhead
        let codec_cost = 0.8; // seconds of encode+decode (measured elsewhere)
        for (link, expect_win) in [(Link::mbps(100.0), true), (Link::gbps(10.0), false)] {
            let baseline = IterBreakdown {
                compute_s: 1.0,
                codec_s: 0.0,
                comm_s: allreduce_time(dense, n, link),
            };
            let dr = IterBreakdown {
                compute_s: 1.0,
                codec_s: codec_cost,
                comm_s: allgather_time(sparse_blob, n, link),
            };
            assert_eq!(dr.total() < baseline.total(), expect_win, "link {link:?}");
        }
    }

    #[test]
    fn single_worker_zero_comm() {
        assert_eq!(allreduce_time(1 << 20, 1, Link::gbps(1.0)), 0.0);
        assert_eq!(allgather_time(1 << 20, 1, Link::gbps(1.0)), 0.0);
        assert_eq!(ps_time(1, 1, 1, Link::gbps(1.0)), 0.0);
    }
}
