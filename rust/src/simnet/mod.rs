//! Analytic network-time model (the testbed substitution for the paper's
//! 100 Mbps–10 Gbps link sweep in Fig 11).
//!
//! The paper varies bandwidth and reports per-iteration wall time broken
//! into forward/backward compute, encode/decode, and communication. The
//! first two are *measured* on this testbed; communication time is
//! *modelled* from exact wire byte counts with the standard α–β model:
//! `T = steps·α + bytes_on_busiest_link/β`.

/// A link configuration.
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// bandwidth, bytes/second
    pub bandwidth_bps: f64,
    /// per-message latency, seconds (α)
    pub latency_s: f64,
}

impl Link {
    pub fn mbps(mb: f64) -> Self {
        Self { bandwidth_bps: mb * 1e6 / 8.0, latency_s: 50e-6 }
    }

    pub fn gbps(gb: f64) -> Self {
        Self { bandwidth_bps: gb * 1e9 / 8.0, latency_s: 25e-6 }
    }

    /// The ideal link: zero latency, infinite bandwidth. Every transfer
    /// takes zero virtual time — on the virtual-time fabric
    /// (`crate::vfabric`) this reduces it to the instant fabric, which
    /// the differential tests in `tests/vfabric.rs` exploit.
    pub fn ideal() -> Self {
        Self { bandwidth_bps: f64::INFINITY, latency_s: 0.0 }
    }
}

/// Time for a ring allreduce of a dense payload of `bytes` across `n`
/// workers: 2(n−1) steps, each moving `bytes/n` per link.
pub fn allreduce_time(bytes: u64, n: usize, link: Link) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let steps = 2 * (n - 1);
    steps as f64 * link.latency_s
        + (2.0 * (n as f64 - 1.0) / n as f64) * bytes as f64 / link.bandwidth_bps
}

/// Time for an allgather where each worker contributes `blob_bytes`:
/// every worker receives (n−1) blobs; with full-duplex links and a ring
/// schedule this is (n−1) steps of `blob_bytes` each.
pub fn allgather_time(blob_bytes: u64, n: usize, link: Link) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    (n - 1) as f64 * (link.latency_s + blob_bytes as f64 / link.bandwidth_bps)
}

/// Parameter-server exchange: server ingests n−1 blobs and broadcasts the
/// aggregate; the server link is the bottleneck.
pub fn ps_time(up_bytes: u64, down_bytes: u64, n: usize, link: Link) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    2.0 * link.latency_s
        + ((n - 1) as f64 * up_bytes as f64 + (n - 1) as f64 * down_bytes as f64)
            / link.bandwidth_bps
}

// ---------------------------------------------------------------------
// Per-schedule cost models for the sparse allreduce subsystem
// (collective::sparse). Each model mirrors its schedule's wire format
// byte-for-byte under a uniform-load assumption and is cross-checked
// against Network::total_bytes() in the tests below (DESIGN.md §5).
// ---------------------------------------------------------------------

/// Byte costs of the sparse segment wire format
/// (`collective::sparse::SegmentCodec`).
#[derive(Clone, Copy, Debug)]
pub struct SegWire {
    /// tag + range + section-length headers per message
    pub header_bytes: u64,
    /// bytes per sparse entry (index + value)
    pub sparse_entry_bytes: u64,
    /// bytes per element of a dense segment
    pub dense_elem_bytes: u64,
    /// density at which segments ship dense (must match the codec)
    pub dense_switch: f64,
}

impl SegWire {
    /// The default raw/raw segment codec: 4-byte index + 4-byte value per
    /// sparse entry, 4-byte dense elements, ~12 bytes of varint headers.
    pub fn raw(dense_switch: f64) -> Self {
        Self { header_bytes: 12, sparse_entry_bytes: 8, dense_elem_bytes: 4, dense_switch }
    }

    /// Wire size of one segment carrying `entries` over a range of
    /// `range_elems` elements, using the same density probe as the
    /// segment encoder (`collective::sparse::merge::density`).
    pub fn segment_bytes(&self, entries: u64, range_elems: u64) -> u64 {
        let dense = range_elems > 0
            && crate::collective::sparse::merge::density(entries as usize, range_elems as usize)
                >= self.dense_switch;
        if dense {
            self.header_bytes + range_elems * self.dense_elem_bytes
        } else {
            self.header_bytes + entries * self.sparse_entry_bytes
        }
    }
}

fn floor_pow2(n: usize) -> u64 {
    crate::collective::sparse::prev_power_of_two(n) as u64
}

/// Total fabric bytes of the GatherAll schedule: every rank ships its
/// whole-tensor segment (`nnz` entries over domain `d`) to n−1 peers.
pub fn gather_all_bytes(nnz: u64, d: u64, n: usize, w: SegWire) -> u64 {
    if n <= 1 {
        return 0;
    }
    n as u64 * (n as u64 - 1) * w.segment_bytes(nnz.min(d), d)
}

/// Per-worker α–β time of GatherAll: n−1 blob transfers on a ring.
pub fn gather_all_time(nnz: u64, d: u64, n: usize, link: Link, w: SegWire) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let blob = w.segment_bytes(nnz.min(d), d) as f64;
    (n - 1) as f64 * (link.latency_s + blob / link.bandwidth_bps)
}

/// Total fabric bytes of RecursiveDouble under the disjoint-support
/// worst case (union sizes add exactly until the dense cap). Exact for
/// power-of-two `n` with strided supports; an upper bound otherwise.
pub fn recursive_double_bytes(nnz: u64, d: u64, n: usize, w: SegWire) -> u64 {
    if n <= 1 {
        return 0;
    }
    let p = floor_pow2(n);
    let extras = n as u64 - p;
    let union_all = (n as u64 * nnz).min(d);
    // fold-in: extras ship their own tensor, later receive the result
    let mut total = extras * (w.segment_bytes(nnz.min(d), d) + w.segment_bytes(union_all, d));
    // doubling rounds: at stride 2^t every participant holds ~2^t loads
    let load = n as u64 * nnz / p;
    let mut stride = 1u64;
    while stride < p {
        total += p * w.segment_bytes((stride * load).min(d), d);
        stride <<= 1;
    }
    total
}

/// Per-worker α–β time of RecursiveDouble: ⌈log₂ n⌉ exchange rounds
/// (payload doubling each round, dense-capped), plus the fold for
/// non-power-of-two worlds.
pub fn recursive_double_time(nnz: u64, d: u64, n: usize, link: Link, w: SegWire) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let p = floor_pow2(n);
    let extras = n as u64 - p;
    let load = n as u64 * nnz / p;
    let mut t = 0.0;
    if extras > 0 {
        let union_all = (n as u64 * nnz).min(d);
        t += 2.0 * link.latency_s
            + (w.segment_bytes(nnz.min(d), d) + w.segment_bytes(union_all, d)) as f64
                / link.bandwidth_bps;
    }
    let mut stride = 1u64;
    while stride < p {
        t += link.latency_s
            + w.segment_bytes((stride * load).min(d), d) as f64 / link.bandwidth_bps;
        stride <<= 1;
    }
    t
}

/// Total fabric bytes of RingRescatter under uniform load: a sparse
/// reduce-scatter whose forwarded chunk accumulates one rank's worth of
/// entries per hop (dense-capped), then a ring allgather of the owned
/// chunks (re-sparsified to ⌈nnz/n⌉ when `resparsify`).
pub fn ring_rescatter_bytes(nnz: u64, d: u64, n: usize, w: SegWire, resparsify: bool) -> u64 {
    if n <= 1 {
        return 0;
    }
    let nn = n as u64;
    let chunk = d / nn;
    let per_chunk = nnz / nn;
    let mut per_rank = 0u64;
    for s in 1..nn {
        per_rank += w.segment_bytes((s * per_chunk).min(chunk), chunk);
    }
    let owned = if resparsify {
        nnz.div_ceil(nn).min(chunk)
    } else {
        (nn * per_chunk).min(chunk)
    };
    per_rank += (nn - 1) * w.segment_bytes(owned, chunk);
    nn * per_rank
}

/// Per-worker α–β time of RingRescatter: 2(n−1) pipelined ring steps.
pub fn ring_rescatter_time(
    nnz: u64,
    d: u64,
    n: usize,
    link: Link,
    w: SegWire,
    resparsify: bool,
) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nn = n as u64;
    let chunk = d / nn;
    let per_chunk = nnz / nn;
    let mut t = 0.0;
    for s in 1..nn {
        t += link.latency_s
            + w.segment_bytes((s * per_chunk).min(chunk), chunk) as f64 / link.bandwidth_bps;
    }
    let owned = if resparsify {
        nnz.div_ceil(nn).min(chunk)
    } else {
        (nn * per_chunk).min(chunk)
    };
    t += (nn - 1) as f64
        * (link.latency_s + w.segment_bytes(owned, chunk) as f64 / link.bandwidth_bps);
    t
}

/// Total fabric bytes of ChunkedRescatter under uniform load: the
/// varint histogram allgather (every rank ships `balance_bins` counts of
/// ~`nnz/bins` entries each to n−1 peers), the pairwise direct-exchange
/// reduce-scatter (`m` sub-chunk frames of `nnz/p` entries per peer,
/// p = m·n), and the ring allgather of the merged groups (`m` frames of
/// up to `n·nnz/p` entries per step). `chunks = 0` models the auto
/// split (one chunk per rank), mirroring `ChunkedRescatter::sub_chunks`.
pub fn chunked_rescatter_bytes(nnz: u64, d: u64, n: usize, chunks: usize, w: SegWire) -> u64 {
    if n <= 1 {
        return 0;
    }
    let nn = n as u64;
    let m = crate::collective::sparse::ChunkedRescatter::sub_chunks(chunks, n) as u64;
    let p = m * nn;
    let bins = crate::collective::sparse::merge::balance_bins(d as usize, p as usize) as u64;
    let hist_blob = bins * crate::util::varint::encoded_len(nnz / bins) as u64;
    let sub_w = d / p;
    let sub_k = (nnz / p).min(sub_w);
    let merged = (nn * (nnz / p)).min(sub_w);
    nn * (nn - 1)
        * (hist_blob
            + m * (w.segment_bytes(sub_k, sub_w) + w.segment_bytes(merged, sub_w)))
}

/// Per-worker α–β time of ChunkedRescatter: n−1 histogram transfers,
/// then (n−1)·m pairwise reduce-scatter frames and (n−1)·m allgather
/// frames. Every frame pays α, so larger chunk counts trade latency for
/// finer streaming overlap — the knob the autotuner sweeps.
pub fn chunked_rescatter_time(
    nnz: u64,
    d: u64,
    n: usize,
    chunks: usize,
    link: Link,
    w: SegWire,
) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nn = n as u64;
    let m = crate::collective::sparse::ChunkedRescatter::sub_chunks(chunks, n) as u64;
    let p = m * nn;
    let bins = crate::collective::sparse::merge::balance_bins(d as usize, p as usize) as u64;
    let hist_blob = bins * crate::util::varint::encoded_len(nnz / bins) as u64;
    let sub_w = d / p;
    let sub_k = (nnz / p).min(sub_w);
    let merged = (nn * (nnz / p)).min(sub_w);
    (n - 1) as f64
        * ((link.latency_s + hist_blob as f64 / link.bandwidth_bps)
            + m as f64
                * (link.latency_s + w.segment_bytes(sub_k, sub_w) as f64 / link.bandwidth_bps)
            + m as f64
                * (link.latency_s + w.segment_bytes(merged, sub_w) as f64 / link.bandwidth_bps))
}

// ---------------------------------------------------------------------
// Two-level (node × rank) models for the hierarchical schedule
// (collective::sparse::Hierarchical, DESIGN.md §8). Real clusters have
// two link classes; the fabric meters them separately
// (Network::{intra,inter}_bytes) and these models mirror that split.
// ---------------------------------------------------------------------

use crate::collective::{Schedule, Topology};

/// Total fabric bytes of one *flat* schedule under the uniform
/// disjoint-support load (the dispatch table the hierarchical model
/// reuses for its inter-node hop). A hierarchical `inner` falls back to
/// GatherAll, mirroring `Schedule::build_with`.
pub fn flat_schedule_bytes(
    sched: Schedule,
    nnz: u64,
    d: u64,
    n: usize,
    w: SegWire,
    resparsify: bool,
) -> u64 {
    match sched {
        Schedule::GatherAll | Schedule::Hierarchical => gather_all_bytes(nnz, d, n, w),
        Schedule::RecursiveDouble => recursive_double_bytes(nnz, d, n, w),
        Schedule::RingRescatter => ring_rescatter_bytes(nnz, d, n, w, resparsify),
        Schedule::RingRescatterExact => ring_rescatter_bytes(nnz, d, n, w, false),
        Schedule::ChunkedRescatter => chunked_rescatter_bytes(nnz, d, n, 0, w),
    }
}

/// Per-worker α–β time of one flat schedule (same dispatch as
/// [`flat_schedule_bytes`]).
pub fn flat_schedule_time(
    sched: Schedule,
    nnz: u64,
    d: u64,
    n: usize,
    link: Link,
    w: SegWire,
    resparsify: bool,
) -> f64 {
    match sched {
        Schedule::GatherAll | Schedule::Hierarchical => gather_all_time(nnz, d, n, link, w),
        Schedule::RecursiveDouble => recursive_double_time(nnz, d, n, link, w),
        Schedule::RingRescatter => ring_rescatter_time(nnz, d, n, link, w, resparsify),
        Schedule::RingRescatterExact => ring_rescatter_time(nnz, d, n, link, w, false),
        Schedule::ChunkedRescatter => chunked_rescatter_time(nnz, d, n, 0, link, w),
    }
}

/// Entry count of the global result the hierarchical schedule
/// broadcasts in phase 3, under the disjoint-support worst case: the
/// full union for exact inner schedules, the re-sparsified chunk budget
/// for the lossy ring.
fn hierarchical_final_nnz(
    nnz: u64,
    d: u64,
    topo: Topology,
    inner: Schedule,
    resparsify: bool,
) -> u64 {
    let nodes = topo.nodes as u64;
    let node_nnz = (topo.ranks_per_node as u64 * nnz).min(d);
    if inner == Schedule::RingRescatter && resparsify && topo.nodes > 1 {
        let chunk = d / nodes;
        (nodes * node_nnz.div_ceil(nodes).min(chunk)).min(d)
    } else {
        (nodes * node_nnz).min(d)
    }
}

/// Byte totals of the hierarchical schedule as `(intra, inter)`, under
/// uniform disjoint supports of `nnz` entries per rank over domain `d`:
///
/// - intra: every non-leader ships its segment to the node leader
///   (phase 1), then receives the global result back (phase 3);
/// - inter: the node leaders run `inner` on node sums of
///   `min(R·nnz, d)` entries (phase 2).
///
/// Cross-checked against the fabric's per-class meters within 2% in
/// `tests::hierarchical_byte_model_matches_wire`.
pub fn hierarchical_bytes(
    nnz: u64,
    d: u64,
    topo: Topology,
    w: SegWire,
    inner: Schedule,
    resparsify: bool,
) -> (u64, u64) {
    if topo.world() <= 1 {
        return (0, 0);
    }
    let members = topo.ranks_per_node as u64 - 1; // non-leaders per node
    let node_nnz = (topo.ranks_per_node as u64 * nnz).min(d);
    let fin = hierarchical_final_nnz(nnz, d, topo, inner, resparsify);
    let intra = topo.nodes as u64
        * members
        * (w.segment_bytes(nnz.min(d), d) + w.segment_bytes(fin, d));
    let inter = if topo.nodes > 1 {
        flat_schedule_bytes(inner, node_nnz, d, topo.nodes, w, resparsify)
    } else {
        0
    };
    (intra, inter)
}

/// Per-worker α–β time of the hierarchical schedule with separate link
/// parameters per class: the leader ingests its `R−1` members serially
/// on the intra link, runs the inner schedule across the inter link,
/// then broadcasts the result back over the intra link.
#[allow(clippy::too_many_arguments)]
pub fn hierarchical_time(
    nnz: u64,
    d: u64,
    topo: Topology,
    intra: Link,
    inter: Link,
    w: SegWire,
    inner: Schedule,
    resparsify: bool,
) -> f64 {
    if topo.world() <= 1 {
        return 0.0;
    }
    let members = (topo.ranks_per_node - 1) as f64;
    let node_nnz = (topo.ranks_per_node as u64 * nnz).min(d);
    let fin = hierarchical_final_nnz(nnz, d, topo, inner, resparsify);
    let mut t = members
        * (intra.latency_s + w.segment_bytes(nnz.min(d), d) as f64 / intra.bandwidth_bps);
    if topo.nodes > 1 {
        t += flat_schedule_time(inner, node_nnz, d, topo.nodes, inter, w, resparsify);
    }
    t += members * (intra.latency_s + w.segment_bytes(fin, d) as f64 / intra.bandwidth_bps);
    t
}

// ---------------------------------------------------------------------
// Step-time accounting for the bucketed gradient pipeline
// (`crate::pipeline`, DESIGN.md §6). A step is a sequence of buckets,
// each contributing an encode stage (measured) and a communication
// stage (α–β modelled from the bucket's wire bytes).
// ---------------------------------------------------------------------

/// Unoverlapped step time: every bucket encodes, then ships, strictly in
/// sequence — the per-tensor baseline the paper's evaluation implies.
pub fn serial_step_time(stages: &[(f64, f64)]) -> f64 {
    stages.iter().map(|&(e, c)| e + c).sum()
}

/// Overlapped step time: bucket *i+1* encodes while bucket *i* is in
/// flight on the fabric. Encoding is serial on the worker core; bucket
/// i's transfer starts once both its encode and transfer i−1 finish.
/// Always ≤ [`serial_step_time`]; the gap is the overlap win. This is
/// the standard pipeline lower bound (encoder may run arbitrarily far
/// ahead); a bounded hand-off executor like
/// `pipeline::double_buffered` can lag it slightly on strongly
/// encode-skewed bucket mixes.
pub fn pipelined_step_time(stages: &[(f64, f64)]) -> f64 {
    let mut enc_done = 0.0f64;
    let mut comm_done = 0.0f64;
    for &(e, c) in stages {
        enc_done += e;
        comm_done = enc_done.max(comm_done) + c;
    }
    comm_done
}

/// One Fig-11 style iteration breakdown (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct IterBreakdown {
    pub compute_s: f64,
    pub codec_s: f64,
    pub comm_s: f64,
}

impl IterBreakdown {
    pub fn total(&self) -> f64 {
        self.compute_s + self.codec_s + self.comm_s
    }

    /// Speedup of this breakdown relative to a baseline.
    pub fn speedup_vs(&self, baseline: &IterBreakdown) -> f64 {
        baseline.total() / self.total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_scaling() {
        // 10x the bandwidth -> ~10x less comm time (latency negligible at MB sizes)
        let b = 10_000_000u64;
        let slow = allgather_time(b, 4, Link::mbps(100.0));
        let fast = allgather_time(b, 4, Link::gbps(1.0));
        assert!((slow / fast - 10.0).abs() < 0.5, "ratio {}", slow / fast);
    }

    #[test]
    fn allreduce_asymptotics() {
        // ring allreduce per-worker traffic is bandwidth-optimal: ~2x
        // payload regardless of n (for large n)
        let link = Link::gbps(10.0);
        let t4 = allreduce_time(1 << 24, 4, link);
        let t16 = allreduce_time(1 << 24, 16, link);
        assert!(t16 < t4 * 1.5, "t16 {t16} vs t4 {t4}");
    }

    #[test]
    fn compression_crossover_shape() {
        // Fig 11's qualitative claim: compression helps at low bandwidth,
        // not when links are fast relative to codec cost.
        let n = 4;
        let dense = 127_000_000u64; // NCF-sized fp32 gradient
        let sparse_blob = dense / 20; // top-10% + container overhead
        let codec_cost = 0.8; // seconds of encode+decode (measured elsewhere)
        for (link, expect_win) in [(Link::mbps(100.0), true), (Link::gbps(10.0), false)] {
            let baseline = IterBreakdown {
                compute_s: 1.0,
                codec_s: 0.0,
                comm_s: allreduce_time(dense, n, link),
            };
            let dr = IterBreakdown {
                compute_s: 1.0,
                codec_s: codec_cost,
                comm_s: allgather_time(sparse_blob, n, link),
            };
            assert_eq!(dr.total() < baseline.total(), expect_win, "link {link:?}");
        }
    }

    #[test]
    fn single_worker_zero_comm() {
        assert_eq!(allreduce_time(1 << 20, 1, Link::gbps(1.0)), 0.0);
        assert_eq!(allgather_time(1 << 20, 1, Link::gbps(1.0)), 0.0);
        assert_eq!(ps_time(1, 1, 1, Link::gbps(1.0)), 0.0);
        let w = SegWire::raw(0.5);
        assert_eq!(gather_all_bytes(100, 1000, 1, w), 0);
        assert_eq!(recursive_double_bytes(100, 1000, 1, w), 0);
        assert_eq!(ring_rescatter_bytes(100, 1000, 1, w, true), 0);
        assert_eq!(gather_all_time(100, 1000, 1, Link::gbps(1.0), w), 0.0);
        assert_eq!(recursive_double_time(100, 1000, 1, Link::gbps(1.0), w), 0.0);
        assert_eq!(ring_rescatter_time(100, 1000, 1, Link::gbps(1.0), w, true), 0.0);
        assert_eq!(chunked_rescatter_bytes(100, 1000, 1, 0, w), 0);
        assert_eq!(chunked_rescatter_time(100, 1000, 1, 0, Link::gbps(1.0), w), 0.0);
        let solo = Topology::flat(1);
        assert_eq!(hierarchical_bytes(100, 1000, solo, w, Schedule::GatherAll, true), (0, 0));
        assert_eq!(
            hierarchical_time(
                100,
                1000,
                solo,
                Link::gbps(1.0),
                Link::mbps(100.0),
                w,
                Schedule::GatherAll,
                true
            ),
            0.0
        );
    }

    /// Build n disjoint, evenly-strided supports of k entries over [0, d)
    /// — the uniform-load worst case the byte models assume exactly.
    fn strided_inputs(n: usize, d: usize, k: usize) -> Vec<crate::tensor::SparseTensor> {
        let m = d / k; // stride between a rank's entries
        assert!(m % n == 0 || m / n >= 1, "construction needs d >= k*n");
        (0..n)
            .map(|r| {
                let off = r * m / n;
                let idx: Vec<u32> = (0..k).map(|j| (j * m + off) as u32).collect();
                let val: Vec<f32> =
                    (0..k).map(|j| 0.5 + ((r * k + j) % 97) as f32 / 100.0).collect();
                crate::tensor::SparseTensor::new(d, idx, val)
            })
            .collect()
    }

    /// Each analytic byte model must agree with the exact fabric byte
    /// count of its schedule within 2% (mirrors the dense ring check in
    /// collective::tests).
    #[test]
    fn schedule_byte_models_match_wire() {
        use crate::collective::sparse::{Schedule, SparseConfig};
        use crate::collective::Network;
        use std::thread;

        let d = 8192usize;
        let k = 1024usize;
        let w = SegWire::raw(0.5);
        for n in [4usize, 8] {
            let inputs = strided_inputs(n, d, k);
            let cases = [
                (Schedule::GatherAll, gather_all_bytes(k as u64, d as u64, n, w)),
                (Schedule::RecursiveDouble, recursive_double_bytes(k as u64, d as u64, n, w)),
                (
                    Schedule::RingRescatter,
                    ring_rescatter_bytes(k as u64, d as u64, n, w, true),
                ),
                (
                    Schedule::RingRescatterExact,
                    ring_rescatter_bytes(k as u64, d as u64, n, w, false),
                ),
            ];
            for (sched, model) in cases {
                let net = Network::new(n);
                let handles: Vec<_> = net
                    .endpoints()
                    .into_iter()
                    .zip(inputs.clone())
                    .map(|(ep, t)| {
                        thread::spawn(move || {
                            sched.build(SparseConfig::default()).allreduce(&ep, t).unwrap()
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().unwrap();
                }
                let wire = net.total_bytes() as f64;
                let predicted = model as f64;
                assert!(
                    (wire - predicted).abs() / predicted < 0.02,
                    "{sched:?} n={n}: wire {wire} vs model {predicted}"
                );
            }
        }
    }

    /// The chunked model must track the fabric within 2% across world
    /// sizes, densities and chunk counts (histogram exchange included).
    #[test]
    fn chunked_byte_model_matches_wire() {
        use crate::collective::sparse::{Schedule, SparseConfig};
        use crate::collective::Network;
        use std::thread;

        let d = 8192usize;
        let w = SegWire::raw(0.5);
        for n in [4usize, 8] {
            for k in [512usize, 1024] {
                for chunks in [0usize, 2 * n] {
                    let inputs = strided_inputs(n, d, k);
                    let net = Network::new(n);
                    let cfg = SparseConfig { chunks, ..SparseConfig::default() };
                    let handles: Vec<_> = net
                        .endpoints()
                        .into_iter()
                        .zip(inputs)
                        .map(|(ep, t)| {
                            thread::spawn(move || {
                                Schedule::ChunkedRescatter
                                    .build(cfg)
                                    .allreduce(&ep, t)
                                    .unwrap()
                            })
                        })
                        .collect();
                    for h in handles {
                        h.join().unwrap();
                    }
                    let wire = net.total_bytes() as f64;
                    let model =
                        chunked_rescatter_bytes(k as u64, d as u64, n, chunks, w) as f64;
                    assert!(
                        (wire - model).abs() / model < 0.02,
                        "n={n} k={k} chunks={chunks}: wire {wire} vs model {model}"
                    );
                }
            }
        }
    }

    /// The hierarchical model's per-class byte split must agree with
    /// the fabric's intra/inter meters within 2%, across node shapes
    /// and inner schedules (same strided worst-case construction as
    /// `schedule_byte_models_match_wire`).
    #[test]
    fn hierarchical_byte_model_matches_wire() {
        use crate::collective::sparse::SparseConfig;
        use crate::collective::Network;
        use std::thread;

        let d = 8192usize;
        let k = 512usize;
        let w = SegWire::raw(0.5);
        for (nodes, rpn) in [(2usize, 4usize), (4, 2), (2, 2)] {
            let topo = Topology::new(nodes, rpn);
            let inputs = strided_inputs(topo.world(), d, k);
            for inner in [
                Schedule::GatherAll,
                Schedule::RecursiveDouble,
                Schedule::RingRescatter,
                Schedule::RingRescatterExact,
            ] {
                let cfg = SparseConfig { topology: Some(topo), inner, ..SparseConfig::default() };
                let net = Network::with_topology(topo);
                let handles: Vec<_> = net
                    .endpoints()
                    .into_iter()
                    .zip(inputs.clone())
                    .map(|(ep, t)| {
                        thread::spawn(move || {
                            Schedule::Hierarchical.build(cfg).allreduce(&ep, t).unwrap()
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().unwrap();
                }
                let (mi, mx) = hierarchical_bytes(k as u64, d as u64, topo, w, inner, true);
                for (wire, model, class) in [
                    (net.intra_bytes() as f64, mi as f64, "intra"),
                    (net.inter_bytes() as f64, mx as f64, "inter"),
                ] {
                    assert!(
                        (wire - model).abs() / model < 0.02,
                        "{}x{} inner {inner:?} {class}: wire {wire} vs model {model}",
                        topo.nodes,
                        topo.ranks_per_node,
                    );
                }
            }
        }
    }

    /// The two-class time model orders as expected: slower inter links
    /// hurt, and for a fixed world the hierarchical schedule's modelled
    /// inter traffic shrinks as ranks concentrate onto fewer nodes.
    #[test]
    fn hierarchical_models_rank_as_expected() {
        let w = SegWire::raw(0.5);
        let d = 100_000u64;
        let k = d / 100;
        let fast = Link::gbps(10.0);
        let slow = Link::mbps(100.0);
        let topo = Topology::new(2, 8);
        let t_fast = hierarchical_time(k, d, topo, fast, fast, w, Schedule::GatherAll, true);
        let t_slow = hierarchical_time(k, d, topo, fast, slow, w, Schedule::GatherAll, true);
        assert!(t_slow > t_fast, "slow inter link must dominate: {t_slow} vs {t_fast}");
        // 2×8 crosses the slow boundary with 2 node sums; flat GatherAll
        // on the same 16 ranks would cross with up to 16·15 blobs — the
        // hierarchical inter bytes must be far below the flat total
        let (_, inter) = hierarchical_bytes(k, d, topo, w, Schedule::GatherAll, true);
        let flat_total = gather_all_bytes(k, d, 16, w);
        assert!(inter * 4 < flat_total, "inter {inter} vs flat {flat_total}");
    }

    #[test]
    fn pipelined_time_never_exceeds_serial() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(0x91BE);
        for _ in 0..200 {
            let n = 1 + rng.below(12) as usize;
            let stages: Vec<(f64, f64)> =
                (0..n).map(|_| (rng.next_f64() * 0.01, rng.next_f64() * 0.01)).collect();
            let s = serial_step_time(&stages);
            let p = pipelined_step_time(&stages);
            assert!(p <= s + 1e-12, "pipelined {p} > serial {s}");
            // lower bound: total comm plus the first encode
            let comm: f64 = stages.iter().map(|&(_, c)| c).sum();
            assert!(p + 1e-12 >= comm + stages[0].0, "pipelined {p} below lower bound");
        }
        assert_eq!(serial_step_time(&[]), 0.0);
        assert_eq!(pipelined_step_time(&[]), 0.0);
    }

    #[test]
    fn pipelined_time_hides_encode_under_comm() {
        // comm-bound: every encode after the first hides completely
        let stages = [(1.0, 10.0), (1.0, 10.0), (1.0, 10.0)];
        assert_eq!(serial_step_time(&stages), 33.0);
        assert_eq!(pipelined_step_time(&stages), 31.0);
        // encode-bound: comm hides instead, total = encodes + last comm
        let stages = [(10.0, 1.0), (10.0, 1.0), (10.0, 1.0)];
        assert_eq!(pipelined_step_time(&stages), 31.0);
        // single bucket: nothing to overlap
        assert_eq!(pipelined_step_time(&[(2.0, 3.0)]), 5.0);
    }

    #[test]
    fn schedule_models_rank_as_expected() {
        // at 10% density and n >= 8, re-sparsifying ring rescatter moves
        // fewer bytes than GatherAll; recursive doubling at most matches
        let w = SegWire::raw(0.5);
        let d = 100_000u64;
        let k = d / 10;
        for n in [8usize, 16, 32] {
            let ga = gather_all_bytes(k, d, n, w);
            let rr = ring_rescatter_bytes(k, d, n, w, true);
            let rd = recursive_double_bytes(k, d, n, w);
            assert!(rr < ga, "n={n}: ring {rr} vs gather {ga}");
            assert!(rd <= ga + ga / 10, "n={n}: rd {rd} vs gather {ga}");
        }
        // time model follows bytes at MB scales where latency is negligible
        let link = Link::mbps(100.0);
        let t_ga = gather_all_time(k, d, 8, link, w);
        let t_rr = ring_rescatter_time(k, d, 8, link, w, true);
        assert!(t_rr < t_ga);
    }
}
