//! A loaded artifact: HLO text compiled on the PJRT CPU client, plus its
//! manifest. Mirrors /opt/xla-example/load_hlo (text → proto → compile →
//! execute; the text parser reassigns instruction ids, which is why text
//! is the interchange format — see DESIGN.md §2).

use super::manifest::Manifest;
use crate::tensor::Tensor;
use crate::util::prng::Rng;
use std::path::Path;

/// Batch input for one execution: either f32 or i32 payloads matching the
/// manifest's input specs in order.
#[derive(Clone, Debug)]
pub enum BatchInput {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Outputs of a train-step execution.
#[derive(Clone, Debug)]
pub struct StepOutput {
    pub loss: f32,
    /// model-specific auxiliary metric (accuracy / hit-rate / loss again)
    pub aux: f32,
    pub grads: Vec<Tensor>,
}

pub struct Artifact {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Load `<dir>/<name>.hlo.txt` + manifest and compile it.
    pub fn load(dir: &Path, name: &str) -> anyhow::Result<Self> {
        let man_text = std::fs::read_to_string(dir.join(format!("{name}.manifest.json")))?;
        let manifest = Manifest::parse(&man_text)?;
        let client = xla::PjRtClient::cpu()?;
        let hlo_path = dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Self { manifest, client, exe })
    }

    /// Load from the default artifacts directory.
    pub fn load_default(name: &str) -> anyhow::Result<Self> {
        Self::load(&super::artifacts_dir(), name)
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Initialize parameters from the manifest specs (deterministic).
    pub fn init_params(&self, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        self.manifest
            .params
            .iter()
            .map(|spec| {
                let n = spec.numel();
                let data: Vec<f32> = if spec.init_std < 0.0 {
                    vec![1.0; n] // layer-norm gains
                } else if spec.init_std == 0.0 {
                    vec![0.0; n]
                } else {
                    (0..n)
                        .map(|_| (rng.next_gaussian() * spec.init_std) as f32)
                        .collect()
                };
                Tensor::new(spec.shape.clone(), data)
            })
            .collect()
    }

    fn literal_f32(&self, shape: &[usize], data: &[f32]) -> anyhow::Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&s| s as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims)?)
    }

    fn literal_i32(&self, shape: &[usize], data: &[i32]) -> anyhow::Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&s| s as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims)?)
    }

    /// Execute a `train_step` artifact: params in manifest order plus the
    /// batch inputs. Returns loss, aux and per-parameter gradients.
    pub fn train_step(&self, params: &[Tensor], batch: &[BatchInput]) -> anyhow::Result<StepOutput> {
        anyhow::ensure!(self.manifest.kind == "train_step", "not a train_step artifact");
        anyhow::ensure!(params.len() == self.manifest.params.len(), "param arity mismatch");
        anyhow::ensure!(batch.len() == self.manifest.inputs.len(), "input arity mismatch");
        let mut literals = Vec::with_capacity(params.len() + batch.len());
        for (t, spec) in params.iter().zip(&self.manifest.params) {
            anyhow::ensure!(t.shape() == spec.shape.as_slice(), "param {} shape", spec.name);
            literals.push(self.literal_f32(t.shape(), t.data())?);
        }
        for (b, spec) in batch.iter().zip(&self.manifest.inputs) {
            match (b, spec.dtype.as_str()) {
                (BatchInput::F32(v), "float32") => {
                    anyhow::ensure!(v.len() == spec.numel(), "input {} size", spec.name);
                    literals.push(self.literal_f32(&spec.shape, v)?);
                }
                (BatchInput::I32(v), "int32") => {
                    anyhow::ensure!(v.len() == spec.numel(), "input {} size", spec.name);
                    literals.push(self.literal_i32(&spec.shape, v)?);
                }
                (got, want) => {
                    anyhow::bail!("input {}: dtype {want} vs provided {got:?}", spec.name)
                }
            }
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        anyhow::ensure!(
            outs.len() == 2 + self.manifest.params.len(),
            "output arity: got {}, want {}",
            outs.len(),
            2 + self.manifest.params.len()
        );
        let loss = outs[0].to_vec::<f32>()?[0];
        let aux = outs[1].to_vec::<f32>()?[0];
        let grads = outs[2..]
            .iter()
            .zip(&self.manifest.params)
            .map(|(l, spec)| -> anyhow::Result<Tensor> {
                Ok(Tensor::new(spec.shape.clone(), l.to_vec::<f32>()?))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(StepOutput { loss, aux, grads })
    }

    /// Execute a `kernel` artifact with raw f32 inputs; returns the raw
    /// f32/i32 outputs as flat f32 tensors (i32 outputs are converted).
    pub fn run_kernel(&self, inputs: &[BatchInput]) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(self.manifest.kind == "kernel", "not a kernel artifact");
        let mut literals = Vec::with_capacity(inputs.len());
        for (b, spec) in inputs.iter().zip(&self.manifest.inputs) {
            match b {
                BatchInput::F32(v) => literals.push(self.literal_f32(&spec.shape, v)?),
                BatchInput::I32(v) => literals.push(self.literal_i32(&spec.shape, v)?),
            }
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        outs.iter()
            .map(|l| -> anyhow::Result<Vec<f32>> {
                match l.ty()? {
                    xla::ElementType::F32 => Ok(l.to_vec::<f32>()?),
                    xla::ElementType::S32 => {
                        Ok(l.to_vec::<i32>()?.into_iter().map(|v| v as f32).collect())
                    }
                    other => anyhow::bail!("unsupported kernel output type {other:?}"),
                }
            })
            .collect()
    }
}
