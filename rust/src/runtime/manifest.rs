//! Artifact manifests: the JSON contract written by `python/compile/aot.py`.

use crate::util::json::Json;
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// >0: normal(0, std); ==0: zeros; <0: ones (layer-norm gains)
    pub init_std: f64,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl InputSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub kind: String,
    pub params: Vec<ParamSpec>,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
    pub config: BTreeMap<String, Json>,
}

impl Manifest {
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let j = Json::parse(text)?;
        let get_str = |k: &str| -> anyhow::Result<String> {
            Ok(j.get(k)
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow::anyhow!("manifest missing string '{k}'"))?
                .to_string())
        };
        let shape_of = |v: &Json| -> anyhow::Result<Vec<usize>> {
            v.as_arr()
                .ok_or_else(|| anyhow::anyhow!("shape not an array"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| anyhow::anyhow!("bad shape entry")))
                .collect()
        };
        let params = j
            .get("params")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing params"))?
            .iter()
            .map(|p| -> anyhow::Result<ParamSpec> {
                Ok(ParamSpec {
                    name: p
                        .get("name")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow::anyhow!("param missing name"))?
                        .to_string(),
                    shape: shape_of(p.get("shape").ok_or_else(|| anyhow::anyhow!("no shape"))?)?,
                    init_std: p
                        .get("init_std")
                        .and_then(|v| v.as_f64())
                        .ok_or_else(|| anyhow::anyhow!("param missing init_std"))?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let inputs = j
            .get("inputs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing inputs"))?
            .iter()
            .map(|p| -> anyhow::Result<InputSpec> {
                Ok(InputSpec {
                    name: p
                        .get("name")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow::anyhow!("input missing name"))?
                        .to_string(),
                    shape: shape_of(p.get("shape").ok_or_else(|| anyhow::anyhow!("no shape"))?)?,
                    dtype: p
                        .get("dtype")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow::anyhow!("input missing dtype"))?
                        .to_string(),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let outputs = j
            .get("outputs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing outputs"))?
            .iter()
            .map(|v| {
                v.as_str()
                    .map(String::from)
                    .ok_or_else(|| anyhow::anyhow!("bad output name"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let config = match j.get("config") {
            Some(Json::Obj(m)) => m.clone(),
            _ => BTreeMap::new(),
        };
        Ok(Self { name: get_str("name")?, kind: get_str("kind")?, params, inputs, outputs, config })
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    pub fn config_usize(&self, key: &str) -> Option<usize> {
        self.config.get(key).and_then(|v| v.as_usize())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "mlp", "kind": "train_step",
      "params": [
        {"name": "w0", "shape": [4, 2], "init_std": 0.5},
        {"name": "b0", "shape": [2], "init_std": 0.0}
      ],
      "inputs": [
        {"name": "x", "shape": [8, 4], "dtype": "float32"},
        {"name": "y", "shape": [8], "dtype": "int32"}
      ],
      "outputs": ["loss", "aux", "grad_w0", "grad_b0"],
      "config": {"batch": 8, "use_pallas": false}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "mlp");
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].numel(), 8);
        assert_eq!(m.total_params(), 10);
        assert_eq!(m.inputs[1].dtype, "int32");
        assert_eq!(m.outputs.len(), 4);
        assert_eq!(m.config_usize("batch"), Some(8));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn parses_real_artifacts_when_present() {
        let dir = crate::runtime::artifacts_dir();
        if !dir.exists() {
            return; // make artifacts not run yet
        }
        for entry in std::fs::read_dir(&dir).unwrap() {
            let p = entry.unwrap().path();
            if p.extension().and_then(|e| e.to_str()) == Some("json") {
                let text = std::fs::read_to_string(&p).unwrap();
                let m = Manifest::parse(&text).unwrap_or_else(|e| panic!("{p:?}: {e}"));
                if m.kind == "train_step" {
                    assert_eq!(m.outputs.len(), 2 + m.params.len(), "{}", m.name);
                }
            }
        }
    }
}
