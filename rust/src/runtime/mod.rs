//! Runtime boundary: load AOT artifacts (HLO text + JSON manifest) and
//! execute them on the PJRT CPU client from the L3 hot path.
//!
//! Python runs only at `make artifacts` time; this module is the entire
//! training-time interface to the compiled models.

pub mod artifact;
mod manifest;

pub use artifact::{Artifact, BatchInput, StepOutput};
pub use manifest::{InputSpec, Manifest, ParamSpec};

/// Default artifacts directory relative to the repo root, overridable via
/// `DEEPREDUCE_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("DEEPREDUCE_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

/// True when the named artifact pair is present (tests skip politely when
/// `make artifacts` has not run).
pub fn artifact_available(name: &str) -> bool {
    let dir = artifacts_dir();
    dir.join(format!("{name}.hlo.txt")).exists()
        && dir.join(format!("{name}.manifest.json")).exists()
}
