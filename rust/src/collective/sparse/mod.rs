//! Topology-aware sparse allreduce (DESIGN.md §5).
//!
//! DeepReduce itself is topology-oblivious (paper §3): the evaluation
//! ships every rank's compressed blob to every peer (Horovod allgather),
//! which is O(n·k) per worker. SparCML (Renggli et al.) and Ok-Topk
//! (Li et al.) show that *schedule-aware* sparse collectives do much
//! better. This subsystem provides a [`SparseAllreduce`] trait with three
//! schedules:
//!
//! - [`GatherAll`] — the baseline behaviour, refactored in: allgather of
//!   whole-tensor segments, local index-union sum.
//! - [`RecursiveDouble`] — SparCML-style split allgather over ⌈log₂ n⌉
//!   rounds, merging payloads by index union at each hop, with a switch
//!   to dense representation once union density crosses a threshold.
//! - [`RingRescatter`] — Ok-Topk-style sparse reduce-scatter over chunk
//!   ranges, optional re-sparsification of the owned chunk back to
//!   ~k/n entries, then a ring allgather of the reduced chunks.
//!
//! All schedules speak the same segment wire format ([`SegmentCodec`]),
//! which composes with the existing DeepReduce index/value codecs, and
//! run over the byte-counted in-process fabric ([`super::Network`]), so
//! every claim about traffic is checked against exact wire bytes (see
//! `crate::simnet` for the matching α–β cost models).

mod gather_all;
pub mod merge;
mod recursive_double;
mod ring_rescatter;
mod wire;

pub use gather_all::GatherAll;
pub use recursive_double::RecursiveDouble;
pub use ring_rescatter::RingRescatter;
pub use wire::SegmentCodec;

use super::Endpoint;
use crate::tensor::SparseTensor;

/// Largest power of two ≤ n (n ≥ 1). Shared by the recursive-doubling
/// schedule and its simnet cost model so the two cannot drift.
pub fn prev_power_of_two(n: usize) -> usize {
    1 << (usize::BITS - 1 - n.leading_zeros())
}

/// Tuning shared by the schedules.
#[derive(Clone, Copy, Debug)]
pub struct SparseConfig {
    /// Union density in [0, 1] at which a wire segment switches to dense
    /// representation. With raw 8-byte sparse entries vs 4-byte dense
    /// elements the break-even point is 0.5.
    pub dense_switch: f64,
    /// Re-sparsify owned chunks back to ⌈k/n⌉ entries before the
    /// allgather phase (RingRescatter only; the Ok-Topk trade: bounded
    /// traffic for a top-k style approximation of the sum).
    pub resparsify: bool,
}

impl Default for SparseConfig {
    fn default() -> Self {
        Self { dense_switch: 0.5, resparsify: true }
    }
}

/// A sparse allreduce schedule: every rank contributes one
/// [`SparseTensor`] over the same dense domain and receives the global
/// element-wise sum (exact, unless the schedule re-sparsifies).
pub trait SparseAllreduce: Send + Sync {
    fn name(&self) -> &'static str;

    /// Whether the result is the exact sum (no re-sparsification loss).
    fn exact(&self) -> bool {
        true
    }

    fn allreduce(&self, ep: &Endpoint, input: SparseTensor) -> anyhow::Result<SparseTensor>;
}

/// Schedule selector — the config/CLI surface of the subsystem.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    GatherAll,
    RecursiveDouble,
    /// Ok-Topk style (re-sparsifies unless `SparseConfig.resparsify` is off).
    RingRescatter,
    /// RingRescatter with re-sparsification forced off (exact sum).
    RingRescatterExact,
}

impl Schedule {
    pub fn parse(name: &str) -> Option<Schedule> {
        Some(match name {
            "gather_all" | "gatherall" | "allgather" => Schedule::GatherAll,
            "recursive_double" | "recursive_doubling" | "rd" => Schedule::RecursiveDouble,
            "ring_rescatter" | "ring" | "ok_topk" => Schedule::RingRescatter,
            "ring_rescatter_exact" | "ring_exact" => Schedule::RingRescatterExact,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Schedule::GatherAll => "gather_all",
            Schedule::RecursiveDouble => "recursive_double",
            Schedule::RingRescatter => "ring_rescatter",
            Schedule::RingRescatterExact => "ring_rescatter_exact",
        }
    }

    pub fn all() -> [Schedule; 4] {
        [
            Schedule::GatherAll,
            Schedule::RecursiveDouble,
            Schedule::RingRescatter,
            Schedule::RingRescatterExact,
        ]
    }

    pub fn build(&self, cfg: SparseConfig) -> Box<dyn SparseAllreduce> {
        self.build_with(cfg, SegmentCodec::raw(cfg.dense_switch))
    }

    /// Build with a custom segment codec (compose DeepReduce index/value
    /// codecs into the schedule's wire format).
    pub fn build_with(&self, cfg: SparseConfig, codec: SegmentCodec) -> Box<dyn SparseAllreduce> {
        match self {
            Schedule::GatherAll => Box::new(GatherAll::with_codec(codec)),
            Schedule::RecursiveDouble => Box::new(RecursiveDouble::with_codec(codec)),
            Schedule::RingRescatter => Box::new(RingRescatter::with_codec(codec, cfg.resparsify)),
            Schedule::RingRescatterExact => Box::new(RingRescatter::with_codec(codec, false)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_parse_roundtrips() {
        for s in Schedule::all() {
            assert_eq!(Schedule::parse(s.name()), Some(s));
        }
        assert_eq!(Schedule::parse("rd"), Some(Schedule::RecursiveDouble));
        assert!(Schedule::parse("nope").is_none());
    }

    #[test]
    fn build_reports_exactness() {
        let cfg = SparseConfig::default();
        assert!(Schedule::GatherAll.build(cfg).exact());
        assert!(Schedule::RecursiveDouble.build(cfg).exact());
        assert!(!Schedule::RingRescatter.build(cfg).exact());
        assert!(Schedule::RingRescatterExact.build(cfg).exact());
    }
}
