//! Topology-aware sparse allreduce (DESIGN.md §5 and §8).
//!
//! DeepReduce itself is topology-oblivious (paper §3): the evaluation
//! ships every rank's compressed blob to every peer (Horovod allgather),
//! which is O(n·k) per worker. SparCML (Renggli et al.) and Ok-Topk
//! (Li et al.) show that *schedule-aware* sparse collectives do much
//! better. This subsystem provides a [`SparseAllreduce`] trait with six
//! schedules:
//!
//! - [`GatherAll`] — the baseline behaviour, refactored in: allgather of
//!   whole-tensor segments, local index-union sum.
//! - [`RecursiveDouble`] — SparCML-style split allgather over ⌈log₂ n⌉
//!   rounds, merging payloads by index union at each hop, with a switch
//!   to dense representation once union density crosses a threshold.
//! - [`RingRescatter`] — Ok-Topk-style sparse reduce-scatter over chunk
//!   ranges, optional re-sparsification of the owned chunk back to
//!   ~k/n entries, then a ring allgather of the reduced chunks (the
//!   exact variant is [`Schedule::RingRescatterExact`]).
//! - [`ChunkedRescatter`] — histogram-balanced chunk partition, pairwise
//!   direct-exchange reduce-scatter (no accumulated forwarding through
//!   stragglers), ring allgather of the merged chunks, with intra-step
//!   encode/ship streaming per sub-chunk. Exact.
//! - [`Hierarchical`] — leader-based two-level schedule over a
//!   node × rank [`Topology`]: intra-node reduce to a per-node leader,
//!   any of the flat schedules among the leaders across the slow
//!   inter-node links, then intra-node broadcast (DESIGN.md §8).
//!
//! All schedules speak the same segment wire format ([`SegmentCodec`]),
//! which composes with the existing DeepReduce index/value codecs, and
//! run over the byte-counted in-process fabric ([`super::Network`]), so
//! every claim about traffic is checked against exact wire bytes (see
//! `crate::simnet` for the matching α–β cost models).
//!
//! # Example
//!
//! Summing two ranks' sparse gradients over the in-process fabric:
//!
//! ```
//! use deepreduce::collective::{Network, Schedule, SparseConfig};
//! use deepreduce::tensor::SparseTensor;
//!
//! let net = Network::new(2);
//! let handles: Vec<_> = net
//!     .endpoints()
//!     .into_iter()
//!     .enumerate()
//!     .map(|(rank, ep)| {
//!         std::thread::spawn(move || {
//!             // rank 0 holds {0: 1.0, 2: 1.0}, rank 1 holds {2: 1.0, 4: 1.0}
//!             let support = if rank == 0 { vec![0u32, 2] } else { vec![2, 4] };
//!             let mine = SparseTensor::new(6, support, vec![1.0; 2]);
//!             let sched = Schedule::GatherAll.build(SparseConfig::default());
//!             sched.allreduce(&ep, mine).unwrap()
//!         })
//!     })
//!     .collect();
//! for h in handles {
//!     let sum = h.join().unwrap();
//!     assert_eq!(sum.indices(), &[0, 2, 4]);
//!     assert_eq!(sum.values(), &[1.0, 2.0, 1.0]);
//! }
//! // every byte that crossed the fabric was metered
//! assert!(net.total_bytes() > 0);
//! ```

mod chunked;
mod gather_all;
mod hierarchical;
pub mod merge;
mod recursive_double;
mod ring_rescatter;
mod wire;

pub use chunked::ChunkedRescatter;
pub use gather_all::GatherAll;
pub use hierarchical::Hierarchical;
pub use recursive_double::RecursiveDouble;
pub use ring_rescatter::RingRescatter;
pub use wire::{SegmentCodec, SegmentError};

use super::{Comm, Topology};
use crate::tensor::SparseTensor;

/// Largest power of two ≤ n (n ≥ 1). Shared by the recursive-doubling
/// schedule and its simnet cost model so the two cannot drift.
pub fn prev_power_of_two(n: usize) -> usize {
    1 << (usize::BITS - 1 - n.leading_zeros())
}

/// Tuning shared by the schedules.
#[derive(Clone, Copy, Debug)]
pub struct SparseConfig {
    /// Union density in [0, 1] at which a wire segment switches to dense
    /// representation. With raw 8-byte sparse entries vs 4-byte dense
    /// elements the break-even point is 0.5.
    pub dense_switch: f64,
    /// Re-sparsify owned chunks back to ⌈k/n⌉ entries before the
    /// allgather phase (RingRescatter only; the Ok-Topk trade: bounded
    /// traffic for a top-k style approximation of the sum).
    pub resparsify: bool,
    /// Node × rank grid the [`Hierarchical`] schedule reduces over.
    /// `None` = single node (pure leader reduce + broadcast). Flat
    /// schedules ignore it — but the fabric still meters intra/inter
    /// bytes against it when built via `Network::with_topology`.
    pub topology: Option<Topology>,
    /// Inter-node schedule the leaders run inside [`Hierarchical`]
    /// (must be flat; a hierarchical inner falls back to GatherAll).
    pub inner: Schedule,
    /// Total chunk count for [`ChunkedRescatter`], rounded up to a
    /// multiple of the world size. `0` = auto (one chunk per rank).
    pub chunks: usize,
}

impl Default for SparseConfig {
    fn default() -> Self {
        Self {
            dense_switch: 0.5,
            resparsify: true,
            topology: None,
            inner: Schedule::GatherAll,
            chunks: 0,
        }
    }
}

/// A sparse allreduce schedule: every rank contributes one
/// [`SparseTensor`] over the same dense domain and receives the global
/// element-wise sum (exact, unless the schedule re-sparsifies).
///
/// Schedules are written against [`Comm`] rather than a concrete
/// endpoint, so the same implementations run on the whole world or
/// re-ranked inside a sub-communicator (`super::SubEndpoint`) — which
/// is exactly how [`Hierarchical`] reuses them for its inter-node hop.
pub trait SparseAllreduce: Send + Sync {
    fn name(&self) -> &'static str;

    /// Whether the result is the exact sum (no re-sparsification loss).
    fn exact(&self) -> bool {
        true
    }

    fn allreduce(&self, ep: &dyn Comm, input: SparseTensor) -> anyhow::Result<SparseTensor>;
}

/// Schedule selector — the config/CLI surface of the subsystem.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    GatherAll,
    RecursiveDouble,
    /// Ok-Topk style (re-sparsifies unless `SparseConfig.resparsify` is off).
    RingRescatter,
    /// RingRescatter with re-sparsification forced off (exact sum).
    RingRescatterExact,
    /// Histogram-balanced chunked reduce-scatter + allgather with
    /// intra-step streaming (exact; chunk count from
    /// `SparseConfig.chunks`, 0 = one per rank).
    ChunkedRescatter,
    /// Two-level leader schedule over `SparseConfig.topology`, running
    /// `SparseConfig.inner` among the node leaders.
    Hierarchical,
}

impl Schedule {
    pub fn parse(name: &str) -> Option<Schedule> {
        Some(match name {
            "gather_all" | "gatherall" | "allgather" => Schedule::GatherAll,
            "recursive_double" | "recursive_doubling" | "rd" => Schedule::RecursiveDouble,
            "ring_rescatter" | "ring" | "ok_topk" => Schedule::RingRescatter,
            "ring_rescatter_exact" | "ring_exact" => Schedule::RingRescatterExact,
            "chunked_rescatter" | "chunked" => Schedule::ChunkedRescatter,
            "hierarchical" | "hier" | "two_level" => Schedule::Hierarchical,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Schedule::GatherAll => "gather_all",
            Schedule::RecursiveDouble => "recursive_double",
            Schedule::RingRescatter => "ring_rescatter",
            Schedule::RingRescatterExact => "ring_rescatter_exact",
            Schedule::ChunkedRescatter => "chunked_rescatter",
            Schedule::Hierarchical => "hierarchical",
        }
    }

    pub fn all() -> [Schedule; 6] {
        [
            Schedule::GatherAll,
            Schedule::RecursiveDouble,
            Schedule::RingRescatter,
            Schedule::RingRescatterExact,
            Schedule::ChunkedRescatter,
            Schedule::Hierarchical,
        ]
    }

    /// The flat schedules (everything but [`Schedule::Hierarchical`]) —
    /// the valid inner schedules of the hierarchical one, and the
    /// baselines its benches compare against.
    pub fn flat() -> [Schedule; 5] {
        [
            Schedule::GatherAll,
            Schedule::RecursiveDouble,
            Schedule::RingRescatter,
            Schedule::RingRescatterExact,
            Schedule::ChunkedRescatter,
        ]
    }

    pub fn build(&self, cfg: SparseConfig) -> Box<dyn SparseAllreduce> {
        self.build_with(cfg, SegmentCodec::raw(cfg.dense_switch))
    }

    /// Build with a custom segment codec (compose DeepReduce index/value
    /// codecs into the schedule's wire format).
    pub fn build_with(&self, cfg: SparseConfig, codec: SegmentCodec) -> Box<dyn SparseAllreduce> {
        match self {
            Schedule::GatherAll => Box::new(GatherAll::with_codec(codec)),
            Schedule::RecursiveDouble => Box::new(RecursiveDouble::with_codec(codec)),
            Schedule::RingRescatter => Box::new(RingRescatter::with_codec(codec, cfg.resparsify)),
            Schedule::RingRescatterExact => Box::new(RingRescatter::with_codec(codec, false)),
            Schedule::ChunkedRescatter => {
                Box::new(ChunkedRescatter::with_codec(codec, cfg.chunks))
            }
            Schedule::Hierarchical => {
                // the leader group is flat by construction; guard against
                // a recursive inner pick
                let inner_sched = if cfg.inner == Schedule::Hierarchical {
                    Schedule::GatherAll
                } else {
                    cfg.inner
                };
                let inner = inner_sched.build_with(cfg, codec.duplicate());
                Box::new(Hierarchical::with_codec(codec, cfg.topology, inner))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_parse_roundtrips() {
        for s in Schedule::all() {
            assert_eq!(Schedule::parse(s.name()), Some(s));
        }
        assert_eq!(Schedule::parse("rd"), Some(Schedule::RecursiveDouble));
        assert_eq!(Schedule::parse("hier"), Some(Schedule::Hierarchical));
        assert!(Schedule::parse("nope").is_none());
    }

    #[test]
    fn build_reports_exactness() {
        let cfg = SparseConfig::default();
        assert!(Schedule::GatherAll.build(cfg).exact());
        assert!(Schedule::RecursiveDouble.build(cfg).exact());
        assert!(!Schedule::RingRescatter.build(cfg).exact());
        assert!(Schedule::RingRescatterExact.build(cfg).exact());
        assert!(Schedule::ChunkedRescatter.build(cfg).exact());
        // hierarchical exactness follows the inner schedule
        assert!(Schedule::Hierarchical.build(cfg).exact());
        let lossy = SparseConfig { inner: Schedule::RingRescatter, ..cfg };
        assert!(!Schedule::Hierarchical.build(lossy).exact());
    }

    #[test]
    fn hierarchical_inner_recursion_falls_back_flat() {
        let cfg = SparseConfig { inner: Schedule::Hierarchical, ..SparseConfig::default() };
        // must not recurse; the fallback inner (GatherAll) is exact
        assert!(Schedule::Hierarchical.build(cfg).exact());
    }

    #[test]
    fn flat_excludes_hierarchical() {
        assert!(!Schedule::flat().contains(&Schedule::Hierarchical));
        assert_eq!(Schedule::flat().len() + 1, Schedule::all().len());
    }
}
