//! Sparse merge/sum kernels the schedules are built from: index-union
//! coalescing, chunk-range split, density probe and magnitude-based
//! re-sparsification. All operate on sorted-support [`SparseTensor`]s.

use crate::tensor::SparseTensor;

/// Index-union merge: the result's support is `S_a ∪ S_b` and values at
/// shared indices are summed. O(nnz_a + nnz_b).
pub fn merge_sum(a: &SparseTensor, b: &SparseTensor) -> SparseTensor {
    let mut sp = crate::obs::span(crate::obs::SpanKind::Merge);
    assert_eq!(a.dense_len(), b.dense_len(), "merge over mismatched domains");
    let (ai, av) = (a.indices(), a.values());
    let (bi, bv) = (b.indices(), b.values());
    let mut idx = Vec::with_capacity(ai.len() + bi.len());
    let mut val = Vec::with_capacity(ai.len() + bi.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < ai.len() && j < bi.len() {
        use std::cmp::Ordering::*;
        match ai[i].cmp(&bi[j]) {
            Less => {
                idx.push(ai[i]);
                val.push(av[i]);
                i += 1;
            }
            Greater => {
                idx.push(bi[j]);
                val.push(bv[j]);
                j += 1;
            }
            Equal => {
                idx.push(ai[i]);
                val.push(av[i] + bv[j]);
                i += 1;
                j += 1;
            }
        }
    }
    idx.extend_from_slice(&ai[i..]);
    val.extend_from_slice(&av[i..]);
    idx.extend_from_slice(&bi[j..]);
    val.extend_from_slice(&bv[j..]);
    if sp.live() {
        sp.set_bytes(idx.len() as u64 * 8);
        crate::obs::observe("merge.out_nnz", idx.len() as f64);
        crate::obs::count("merge.calls", 1);
    }
    SparseTensor::new(a.dense_len(), idx, val)
}

/// Support density nnz/domain (0.0 for an empty domain) — THE probe
/// that drives the dense-representation switch, shared by the segment
/// encoder and the simnet byte models so the rule cannot drift.
pub fn density(nnz: usize, domain: usize) -> f64 {
    if domain == 0 {
        0.0
    } else {
        nnz as f64 / domain as f64
    }
}

/// The dense-ring chunk boundaries: chunk `c` covers
/// `[bounds[c], bounds[c+1])`; same partition as `all_reduce_ring`.
pub fn chunk_bounds(d: usize, n: usize) -> Vec<usize> {
    (0..=n).map(|c| c * d / n).collect()
}

/// Entries of `t` with index in `[lo, hi)`. Indices stay absolute and the
/// result keeps the full dense domain, so segments merge/concat cleanly.
pub fn slice_range(t: &SparseTensor, lo: usize, hi: usize) -> SparseTensor {
    let idx = t.indices();
    let a = idx.partition_point(|&i| (i as usize) < lo);
    let b = idx.partition_point(|&i| (i as usize) < hi);
    SparseTensor::new(t.dense_len(), idx[a..b].to_vec(), t.values()[a..b].to_vec())
}

/// Split into one segment per chunk range (`bounds` as from
/// [`chunk_bounds`]). Single pass over the support.
pub fn split_ranges(t: &SparseTensor, bounds: &[usize]) -> Vec<SparseTensor> {
    let n = bounds.len().saturating_sub(1);
    let mut out = Vec::with_capacity(n);
    for c in 0..n {
        out.push(slice_range(t, bounds[c], bounds[c + 1]));
    }
    out
}

/// Bin resolution the chunked schedule balances at: a few bins per
/// chunk, capped by the domain. Deterministic in `(d, chunks)` only, so
/// every rank derives the identical binning without coordination — and
/// the simnet byte model can reproduce the histogram-exchange volume
/// exactly.
pub fn balance_bins(d: usize, chunks: usize) -> usize {
    (4 * chunks.max(1)).min(d).max(1)
}

/// Per-bin entry counts of `t` over `bins` equal-width bins (edges at
/// `i * d / bins`, mirroring [`chunk_bounds`]). One pass over the sorted
/// support.
pub fn bin_counts(t: &SparseTensor, bins: usize) -> Vec<u64> {
    let d = t.dense_len();
    let edges = chunk_bounds(d, bins);
    let mut out = vec![0u64; bins];
    let mut b = 0usize;
    for &i in t.indices() {
        while b + 1 < bins && (i as usize) >= edges[b + 1] {
            b += 1;
        }
        out[b] += 1;
    }
    out
}

/// Balanced chunk boundaries from a (globally summed) bin histogram:
/// boundary `c` is the smallest bin edge whose prefix weight reaches
/// `c/chunks` of the total estimated encoded bytes. Sparse entries all
/// weigh the same on the wire (8 B under the raw segment codec), so the
/// per-entry byte weight cancels in the ratio and the histogram counts
/// *are* the byte estimate. An all-zero histogram falls back to the
/// equal-width partition. Boundaries are monotone, land on bin edges,
/// and start/end at `0`/`d` — so `split_ranges` over them partitions
/// the domain even when some chunks come out empty.
pub fn balanced_bounds(counts: &[u64], d: usize, chunks: usize) -> Vec<usize> {
    let bins = counts.len();
    let total: u128 = counts.iter().map(|&c| c as u128).sum();
    if total == 0 || bins == 0 {
        return chunk_bounds(d, chunks);
    }
    let edges = chunk_bounds(d, bins);
    let mut out = Vec::with_capacity(chunks + 1);
    out.push(0);
    let mut prefix: u128 = 0;
    let mut e = 0usize;
    for c in 1..chunks {
        // first edge where prefix/total >= c/chunks, in exact integer
        // arithmetic (u128 keeps count * chunks from overflowing)
        while e < bins && prefix * chunks as u128 < c as u128 * total {
            prefix += counts[e] as u128;
            e += 1;
        }
        out.push(edges[e]);
    }
    out.push(d);
    out
}

/// Keep the `r` largest-magnitude entries (ties broken by lower index),
/// support returned sorted — the in-flight re-sparsification kernel.
pub fn top_r_sparse(t: &SparseTensor, r: usize) -> SparseTensor {
    if r >= t.nnz() {
        return t.clone();
    }
    let key = |p: usize| {
        let v = t.values()[p].abs();
        if v.is_nan() {
            f32::NEG_INFINITY
        } else {
            v
        }
    };
    let mut order: Vec<usize> = (0..t.nnz()).collect();
    order.sort_by(|&x, &y| key(y).partial_cmp(&key(x)).unwrap().then(x.cmp(&y)));
    let mut keep = order[..r].to_vec();
    keep.sort_unstable();
    let idx: Vec<u32> = keep.iter().map(|&p| t.indices()[p]).collect();
    let val: Vec<f32> = keep.iter().map(|&p| t.values()[p]).collect();
    SparseTensor::new(t.dense_len(), idx, val)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st(d: usize, iv: &[(u32, f32)]) -> SparseTensor {
        SparseTensor::new(d, iv.iter().map(|&(i, _)| i).collect(), iv.iter().map(|&(_, v)| v).collect())
    }

    #[test]
    fn merge_sums_shared_indices() {
        let a = st(10, &[(1, 1.0), (4, 2.0), (9, 3.0)]);
        let b = st(10, &[(0, 5.0), (4, -2.0), (9, 1.0)]);
        let m = merge_sum(&a, &b);
        assert_eq!(m.indices(), &[0, 1, 4, 9]);
        assert_eq!(m.values(), &[5.0, 1.0, 0.0, 4.0]);
        // commutative bit-for-bit (the recursive-doubling invariant)
        assert_eq!(merge_sum(&b, &a), m);
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let a = st(10, &[(3, 1.5)]);
        let e = st(10, &[]);
        assert_eq!(merge_sum(&a, &e), a);
        assert_eq!(merge_sum(&e, &a), a);
        assert_eq!(merge_sum(&e, &e).nnz(), 0);
    }

    #[test]
    fn density_probe() {
        assert_eq!(density(2, 10), 0.2);
        assert_eq!(density(0, 0), 0.0);
        assert_eq!(density(4, 4), 1.0);
        let t = st(10, &[(0, 1.0), (5, 1.0)]);
        assert_eq!(density(t.nnz(), t.dense_len()), 0.2);
    }

    #[test]
    fn chunk_split_covers_and_partitions() {
        let t = st(10, &[(0, 1.0), (3, 2.0), (4, 3.0), (9, 4.0)]);
        let bounds = chunk_bounds(10, 3); // [0, 3, 6, 10]
        assert_eq!(bounds, vec![0, 3, 6, 10]);
        let segs = split_ranges(&t, &bounds);
        assert_eq!(segs.len(), 3);
        assert_eq!(segs[0].indices(), &[0]);
        assert_eq!(segs[1].indices(), &[3, 4]);
        assert_eq!(segs[2].indices(), &[9]);
        // concatenation reassembles the original
        let total: usize = segs.iter().map(|s| s.nnz()).sum();
        assert_eq!(total, t.nnz());
    }

    #[test]
    fn chunk_bounds_degenerate() {
        // d < n: trailing chunks are empty but well-formed
        let b = chunk_bounds(2, 4);
        assert_eq!(b, vec![0, 0, 1, 1, 2]);
        let t = st(2, &[(0, 1.0), (1, 2.0)]);
        let segs = split_ranges(&t, &b);
        assert_eq!(segs.iter().map(|s| s.nnz()).collect::<Vec<_>>(), vec![0, 1, 0, 1]);
    }

    #[test]
    fn bin_counts_cover_the_support() {
        let t = st(12, &[(0, 1.0), (1, 1.0), (2, 1.0), (11, 1.0)]);
        let counts = bin_counts(&t, 4); // edges [0, 3, 6, 9, 12]
        assert_eq!(counts, vec![3, 0, 0, 1]);
        assert_eq!(counts.iter().sum::<u64>(), t.nnz() as u64);
        // degenerate: one bin swallows everything
        assert_eq!(bin_counts(&t, 1), vec![4]);
    }

    #[test]
    fn balanced_bounds_equalize_skewed_mass() {
        // all mass in the first quarter: equal-width bounds would give
        // chunk 0 everything; balanced bounds subdivide the hot region
        let d = 16usize;
        let counts = vec![8u64, 8, 0, 0]; // bins over [0,4),[4,8),[8,12),[12,16)
        let b = balanced_bounds(&counts, d, 4);
        assert_eq!(b.first(), Some(&0));
        assert_eq!(b.last(), Some(&16));
        assert!(b.windows(2).all(|w| w[0] <= w[1]), "{b:?}");
        // the two hot bins are split apart instead of lumped together
        assert!(b[1] <= 4 && b[2] <= 8, "{b:?}");
    }

    #[test]
    fn balanced_bounds_uniform_histogram_matches_equal_width() {
        let d = 8192usize;
        let chunks = 8usize;
        let bins = balance_bins(d, chunks);
        assert_eq!(bins, 32);
        let counts = vec![16u64; bins];
        assert_eq!(balanced_bounds(&counts, d, chunks), chunk_bounds(d, chunks));
    }

    #[test]
    fn balanced_bounds_empty_histogram_falls_back() {
        assert_eq!(balanced_bounds(&[0, 0, 0, 0], 10, 3), chunk_bounds(10, 3));
        assert_eq!(balanced_bounds(&[], 10, 3), chunk_bounds(10, 3));
        // tiny domains: bins capped at d, bounds still well-formed
        assert_eq!(balance_bins(2, 8), 2);
        let b = balanced_bounds(&[1, 1], 2, 8);
        assert_eq!((b[0], b[b.len() - 1], b.len()), (0, 2, 9));
        assert!(b.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn top_r_keeps_largest_magnitudes_sorted() {
        let t = st(10, &[(1, -5.0), (2, 0.5), (7, 3.0), (9, -1.0)]);
        let kept = top_r_sparse(&t, 2);
        assert_eq!(kept.indices(), &[1, 7]);
        assert_eq!(kept.values(), &[-5.0, 3.0]);
        assert_eq!(top_r_sparse(&t, 10), t);
        assert_eq!(top_r_sparse(&t, 0).nnz(), 0);
    }
}
