//! Wire format for sparse segments — the unit every schedule ships.
//!
//! A segment is the restriction of a sparse tensor to an index range
//! `[lo, hi)`. It travels in one of two representations, chosen by the
//! density probe against `dense_switch`:
//!
//! ```text
//! sparse: 0x00 | varint lo | varint hi | varint nnz
//!              | varint |idx| | idx bytes (IndexCodec over [0, hi-lo))
//!              | varint |val| | val bytes (ValueCodec)
//! dense:  0x01 | varint lo | varint hi | (hi-lo) × f32 LE
//! ```
//!
//! The index/value sections reuse the DeepReduce codec traits
//! ([`IndexCodec`] / [`ValueCodec`]), so any lossless instantiation —
//! including registry chains like `rle+deflate` — plugs straight into a
//! collective schedule. The default is raw/raw: exactly 8 bytes per
//! entry, which keeps the α–β byte models in `crate::simnet` exact.

use super::merge;
use crate::compress::{build_index_spec, build_value_spec, CodecRegistry, CompressSpec, IndexCodec, ValueCodec};
use crate::tensor::SparseTensor;
use crate::util::varint;

const TAG_SPARSE: u8 = 0;
const TAG_DENSE: u8 = 1;

/// Structured decode failures for segment frames (the wire-level mirror
/// of `compress::container::ContainerError`). Every length read off the
/// wire is validated against the remaining buffer *before* it is used as
/// an allocation size or slice bound, so a truncated or corrupted frame
/// fails with a typed error instead of a panic or an oversized
/// allocation.
#[derive(Debug, PartialEq, Eq)]
pub enum SegmentError {
    /// the frame ended before the named field could be read
    Truncated(&'static str),
    /// a field is structurally invalid (tag, range, count, section size)
    Malformed(String),
    /// decoded cleanly but bytes were left over
    TrailingBytes { extra: usize },
}

impl std::fmt::Display for SegmentError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Truncated(what) => write!(f, "segment truncated reading {what}"),
            Self::Malformed(why) => write!(f, "malformed segment: {why}"),
            Self::TrailingBytes { extra } => {
                write!(f, "segment has {extra} trailing byte(s)")
            }
        }
    }
}

impl std::error::Error for SegmentError {}

/// Read one varint, converting the untyped varint error into the
/// field-naming [`SegmentError`].
fn vint(bytes: &[u8], pos: &mut usize, what: &'static str) -> Result<u64, SegmentError> {
    varint::read_u64(bytes, pos).map_err(|_| SegmentError::Truncated(what))
}

/// Encoder/decoder for segments, parameterized by DeepReduce codecs.
pub struct SegmentCodec {
    index: Box<dyn IndexCodec>,
    value: Box<dyn ValueCodec>,
    /// density in [0, 1] at which segments ship dense
    pub dense_switch: f64,
}

impl SegmentCodec {
    /// Compose with arbitrary codecs. Index codecs must be lossless and
    /// value codecs order-preserving for the sum to be exact.
    pub fn new(index: Box<dyn IndexCodec>, value: Box<dyn ValueCodec>, dense_switch: f64) -> Self {
        Self { index, value, dense_switch }
    }

    /// The default raw/raw instantiation: 8 bytes per sparse entry.
    pub fn raw(dense_switch: f64) -> Self {
        Self::new(
            Box::new(crate::compress::index::RawIndex),
            Box::new(crate::compress::value::RawValue),
            dense_switch,
        )
    }

    /// Build from codec spec strings (the config-file/CLI surface);
    /// full chain specs with parameters resolve through the registry.
    pub fn by_name(index: &str, value: &str, dense_switch: f64) -> Option<Self> {
        Some(Self::new(
            build_index_spec(index, f64::NAN, 0).ok()?,
            build_value_spec(value, f64::NAN, 0).ok()?,
            dense_switch,
        ))
    }

    /// Compose from the trainer's typed [`CompressSpec`], falling back
    /// to raw for any side that would corrupt an allreduce sum: lossy
    /// index codecs (Bloom policies reconstruct S̃ ≠ S) and lossy value
    /// codecs. Lossless chains (e.g. `rle+deflate`) pass through whole;
    /// lossless value codecs in this crate are order-preserving.
    pub fn lossless_or_raw(compress: &CompressSpec, seed: u64, dense_switch: f64) -> Self {
        let registry = CodecRegistry::global();
        let idx = registry
            .build_index(&compress.index, seed)
            .ok()
            .filter(|c| c.lossless())
            .unwrap_or_else(|| Box::new(crate::compress::index::RawIndex));
        let val = registry
            .build_value(&compress.value, seed)
            .ok()
            .filter(|c| c.lossless())
            .unwrap_or_else(|| Box::new(crate::compress::value::RawValue));
        Self::new(idx, val, dense_switch)
    }

    /// A fresh codec with the same index/value stages and dense switch.
    /// Codec names are full canonical spec labels (chains and explicit
    /// parameters included), so rebuilding through the registry
    /// reproduces the exact pipeline. Used by the hierarchical schedule
    /// to hand its inner schedule an identical codec for the inter-node
    /// hop.
    pub fn duplicate(&self) -> Self {
        Self::by_name(self.index.name(), self.value.name(), self.dense_switch)
            .expect("segment codec labels roundtrip through the registry")
    }

    /// Encode the segment `[lo, hi)` of `t`. `t` must already be
    /// restricted to the range (see `merge::slice_range`).
    pub fn encode(&self, t: &SparseTensor, lo: usize, hi: usize) -> Vec<u8> {
        let mut sp = crate::obs::span(crate::obs::SpanKind::Pack);
        debug_assert!(lo <= hi && hi <= t.dense_len());
        debug_assert!(
            t.indices().iter().all(|&i| lo <= i as usize && (i as usize) < hi) || t.nnz() == 0,
            "segment entries outside [{lo}, {hi})"
        );
        let range = hi - lo;
        let nnz = t.nnz();
        let dense = range > 0 && merge::density(nnz, range) >= self.dense_switch;
        let mut out = Vec::with_capacity(16 + if dense { range * 4 } else { nnz * 8 });
        out.push(if dense { TAG_DENSE } else { TAG_SPARSE });
        varint::write_u64(&mut out, lo as u64);
        varint::write_u64(&mut out, hi as u64);
        if dense {
            let mut vals = vec![0.0f32; range];
            for (&i, &v) in t.indices().iter().zip(t.values()) {
                vals[i as usize - lo] = v;
            }
            for v in vals {
                out.extend_from_slice(&v.to_le_bytes());
            }
        } else {
            varint::write_u64(&mut out, nnz as u64);
            // rebase indices into the segment-local domain [0, range)
            let local: Vec<u32> = t.indices().iter().map(|&i| i - lo as u32).collect();
            let mut ibytes = Vec::with_capacity(nnz * 4 + 8);
            let effective = self.index.encode_into(range, &local, &mut ibytes);
            debug_assert!(
                match &effective {
                    None => true,
                    Some(e) => e == &local,
                },
                "lossy index codecs break allreduce sums"
            );
            let mut vbytes = Vec::with_capacity(nnz * 4);
            let perm = self.value.encode_into(t.values(), &mut vbytes);
            assert!(
                perm.is_none(),
                "order-destroying value codecs are not supported in collective segments"
            );
            varint::write_u64(&mut out, ibytes.len() as u64);
            out.extend_from_slice(&ibytes);
            varint::write_u64(&mut out, vbytes.len() as u64);
            out.extend_from_slice(&vbytes);
        }
        sp.set_bytes(out.len() as u64);
        crate::obs::count("wire.pack_calls", 1);
        crate::obs::count("wire.pack_bytes", out.len() as u64);
        out
    }

    /// Decode one segment back onto the full domain `[0, d)`; indices are
    /// re-absolutized. Dense segments drop explicit zeros.
    ///
    /// Every count and section length carried by the frame is checked
    /// against the remaining buffer (and against the declared range)
    /// before anything is allocated or sliced; structural failures
    /// surface as [`SegmentError`] values inside the `anyhow` error.
    pub fn decode(&self, d: usize, bytes: &[u8]) -> anyhow::Result<SparseTensor> {
        let mut sp = crate::obs::span(crate::obs::SpanKind::Decode);
        sp.set_bytes(bytes.len() as u64);
        crate::obs::count("wire.decode_calls", 1);
        let (tag, mut pos) = match bytes.first() {
            Some(&t) => (t, 1usize),
            None => return Err(SegmentError::Truncated("tag").into()),
        };
        let lo64 = vint(bytes, &mut pos, "lo")?;
        let hi64 = vint(bytes, &mut pos, "hi")?;
        // the +1 keeps hi == 2^32 (a full u32-addressed domain) legal:
        // indices themselves stay strictly below hi
        if lo64 > hi64 || hi64 > d as u64 || hi64 > u32::MAX as u64 + 1 {
            return Err(SegmentError::Malformed(format!(
                "range [{lo64}, {hi64}) outside domain {d}"
            ))
            .into());
        }
        let (lo, hi) = (lo64 as usize, hi64 as usize);
        let range = hi - lo;
        match tag {
            TAG_DENSE => {
                // overflow-safe: compare in u64, never trust range * 4
                let have = (bytes.len() - pos) as u64;
                if have != range as u64 * 4 {
                    return Err(SegmentError::Malformed(format!(
                        "dense payload {have} B != {range} elems * 4"
                    ))
                    .into());
                }
                let mut idx = Vec::new();
                let mut val = Vec::new();
                for (off, c) in bytes[pos..].chunks_exact(4).enumerate() {
                    let v = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                    if v != 0.0 {
                        // off < range and hi <= u32::MAX + 1, so this
                        // cannot wrap
                        idx.push((lo + off) as u32);
                        val.push(v);
                    }
                }
                Ok(SparseTensor::new(d, idx, val))
            }
            TAG_SPARSE => {
                let nnz64 = vint(bytes, &mut pos, "nnz")?;
                // bound the count before it sizes any decode: a segment
                // cannot carry more entries than its range has slots
                if nnz64 > range as u64 {
                    return Err(SegmentError::Malformed(format!(
                        "nnz {nnz64} exceeds range {range}"
                    ))
                    .into());
                }
                let nnz = nnz64 as usize;
                let ilen64 = vint(bytes, &mut pos, "index section length")?;
                // compare against what is left, never compute pos + ilen
                if ilen64 > (bytes.len() - pos) as u64 {
                    return Err(SegmentError::Truncated("index section").into());
                }
                let ilen = ilen64 as usize;
                let local = self.index.decode(range, &bytes[pos..pos + ilen])?;
                pos += ilen;
                if local.len() != nnz {
                    return Err(SegmentError::Malformed(format!(
                        "support length {} != declared nnz {nnz}",
                        local.len()
                    ))
                    .into());
                }
                // the index codec ran over untrusted bytes: re-validate
                // the tensor invariants (sorted, unique, inside the
                // range) the rest of the crate only debug-asserts
                if !local.windows(2).all(|w| w[0] < w[1])
                    || local.last().is_some_and(|&i| i as usize >= range)
                {
                    return Err(SegmentError::Malformed(
                        "decoded support not sorted/unique inside range".into(),
                    )
                    .into());
                }
                let vlen64 = vint(bytes, &mut pos, "value section length")?;
                let rest = (bytes.len() - pos) as u64;
                if vlen64 > rest {
                    return Err(SegmentError::Truncated("value section").into());
                }
                if vlen64 < rest {
                    return Err(SegmentError::TrailingBytes { extra: (rest - vlen64) as usize }
                        .into());
                }
                let values = self.value.decode(&bytes[pos..], nnz)?;
                if values.len() != nnz {
                    return Err(SegmentError::Malformed(format!(
                        "value count {} != declared nnz {nnz}",
                        values.len()
                    ))
                    .into());
                }
                let idx: Vec<u32> = local.iter().map(|&i| i + lo as u32).collect();
                Ok(SparseTensor::new(d, idx, values))
            }
            other => Err(SegmentError::Malformed(format!("unknown tag {other}")).into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st(d: usize, iv: &[(u32, f32)]) -> SparseTensor {
        SparseTensor::new(d, iv.iter().map(|&(i, _)| i).collect(), iv.iter().map(|&(_, v)| v).collect())
    }

    #[test]
    fn sparse_roundtrip_with_offset_range() {
        let codec = SegmentCodec::raw(0.5);
        let t = st(100, &[(20, 1.5), (25, -2.0), (39, 0.25)]);
        let bytes = codec.encode(&t, 20, 40);
        let back = codec.decode(100, &bytes).unwrap();
        assert_eq!(back, t);
        // 8 bytes/entry + small header
        assert!(bytes.len() <= 3 * 8 + 16, "{}", bytes.len());
    }

    #[test]
    fn dense_switch_engages_at_high_density() {
        let codec = SegmentCodec::raw(0.5);
        // 6 of 10 in range -> density 0.6 >= 0.5 -> dense tag
        let t = st(50, &[(10, 1.0), (11, 2.0), (12, 3.0), (14, 4.0), (15, 5.0), (19, 6.0)]);
        let bytes = codec.encode(&t, 10, 20);
        assert_eq!(bytes[0], 1, "expected dense representation");
        assert_eq!(codec.decode(50, &bytes).unwrap(), t);
        // below the switch: sparse tag
        let sparse = st(50, &[(10, 1.0), (19, 6.0)]);
        assert_eq!(codec.encode(&sparse, 10, 20)[0], 0);
    }

    #[test]
    fn empty_and_degenerate_ranges() {
        let codec = SegmentCodec::raw(0.5);
        let t = st(10, &[]);
        for (lo, hi) in [(0usize, 10usize), (4, 4), (0, 0)] {
            let bytes = codec.encode(&t, lo, hi);
            let back = codec.decode(10, &bytes).unwrap();
            assert_eq!(back.nnz(), 0);
            assert_eq!(back.dense_len(), 10);
        }
    }

    #[test]
    fn density_one_roundtrips_dense() {
        let codec = SegmentCodec::raw(0.5);
        let t = st(4, &[(0, 1.0), (1, 2.0), (2, 3.0), (3, 4.0)]);
        let bytes = codec.encode(&t, 0, 4);
        assert_eq!(bytes[0], 1);
        assert_eq!(codec.decode(4, &bytes).unwrap(), t);
    }

    #[test]
    fn composes_with_delta_varint_index() {
        let codec = SegmentCodec::by_name("delta_varint", "raw", 0.9).unwrap();
        let t = st(1000, &[(5, 1.0), (6, -1.0), (500, 2.5), (999, 0.125)]);
        let bytes = codec.encode(&t, 0, 1000);
        assert_eq!(codec.decode(1000, &bytes).unwrap(), t);
        // delta+varint beats raw 4B/idx on clustered supports
        let raw = SegmentCodec::raw(0.9).encode(&t, 0, 1000);
        assert!(bytes.len() < raw.len());
    }

    #[test]
    fn composes_with_codec_chains() {
        // a registry chain is just another lossless IndexCodec to the
        // segment wire — periodic clustered support makes the RLE
        // stream long and repetitive, so the deflate tail shrinks it
        let d = 10_240usize;
        let codec = SegmentCodec::by_name("rle+deflate", "raw", 0.95).unwrap();
        let iv: Vec<(u32, f32)> = (0..d as u32)
            .filter(|i| (i / 32) % 2 == 0)
            .map(|i| (i, (i % 7) as f32 - 3.0))
            .collect();
        let t = st(d, &iv);
        let bytes = codec.encode(&t, 0, d);
        assert_eq!(codec.decode(d, &bytes).unwrap(), t);
        let plain = SegmentCodec::by_name("rle", "raw", 0.95).unwrap().encode(&t, 0, d);
        assert!(bytes.len() < plain.len(), "{} vs {}", bytes.len(), plain.len());
        // duplicate() reproduces chains through the registry
        let dup = codec.duplicate();
        assert_eq!(dup.decode(d, &bytes).unwrap(), t);
    }

    #[test]
    fn lossless_or_raw_accepts_chains_and_rejects_lossy() {
        use crate::compress::CompressSpec;
        let chain = SegmentCodec::lossless_or_raw(
            &CompressSpec::parse("rle+deflate", "raw").unwrap(),
            1,
            0.5,
        );
        let t = st(100, &[(20, 1.5), (25, -2.0)]);
        let bytes = chain.encode(&t, 0, 100);
        assert_eq!(chain.decode(100, &bytes).unwrap(), t);
        // lossy head -> whole side falls back to raw
        let lossy = SegmentCodec::lossless_or_raw(
            &CompressSpec::parse("bloom_p2+deflate", "qsgd").unwrap(),
            1,
            0.5,
        );
        let bytes = lossy.encode(&t, 0, 100);
        assert_eq!(lossy.decode(100, &bytes).unwrap(), t);
    }

    #[test]
    fn decode_rejects_corruption() {
        let codec = SegmentCodec::raw(0.5);
        let t = st(10, &[(1, 1.0)]);
        let bytes = codec.encode(&t, 0, 10);
        assert!(codec.decode(10, &bytes[..bytes.len() - 1]).is_err());
        assert!(codec.decode(0, &bytes).is_err()); // range outside domain
        let mut bad = bytes.clone();
        bad[0] = 9;
        assert!(codec.decode(10, &bad).is_err());
    }

    /// Every strict prefix of a valid frame must fail to decode — no
    /// prefix may silently parse as a shorter segment (varints
    /// self-terminate, section lengths are validated against the
    /// remaining buffer, and trailing bytes are rejected).
    #[test]
    fn every_strict_prefix_fails_sparse_and_dense() {
        let codec = SegmentCodec::raw(0.5);
        let sparse = codec.encode(&st(100, &[(20, 1.5), (25, -2.0), (39, 0.25)]), 20, 40);
        assert_eq!(sparse[0], TAG_SPARSE);
        let dense = codec.encode(&st(50, &[(10, 1.0), (11, 2.0), (12, 3.0)]), 10, 14);
        assert_eq!(dense[0], TAG_DENSE);
        for frame in [&sparse, &dense] {
            for cut in 0..frame.len() {
                assert!(
                    codec.decode(100, &frame[..cut]).is_err(),
                    "prefix of {cut}/{} bytes decoded",
                    frame.len()
                );
            }
        }
    }

    /// Corrupting any single header byte must never panic: it either
    /// errors or decodes to a tensor that still satisfies the domain
    /// invariants (flipped value bytes are legitimately undetectable).
    #[test]
    fn corrupted_prefix_never_panics() {
        let codec = SegmentCodec::raw(0.5);
        let d = 1 << 20;
        let t = st(d, &[(100, 1.0), (5000, -2.0), (99_000, 3.5)]);
        let frame = codec.encode(&t, 0, 1 << 17);
        for i in 0..frame.len() {
            for fill in [0x00u8, 0x7f, 0x80, 0xff] {
                let mut bad = frame.clone();
                bad[i] = fill;
                if let Ok(out) = codec.decode(d, &bad) {
                    assert_eq!(out.dense_len(), d);
                    assert!(out.indices().windows(2).all(|w| w[0] < w[1]));
                    assert!(out.indices().iter().all(|&j| (j as usize) < d));
                }
            }
        }
    }

    /// Structural failures carry typed [`SegmentError`] values.
    #[test]
    fn structured_errors_downcast() {
        let codec = SegmentCodec::raw(0.5);
        let seg = |e: anyhow::Error| e.downcast::<SegmentError>().expect("SegmentError");
        // empty frame
        assert_eq!(seg(codec.decode(10, &[]).unwrap_err()), SegmentError::Truncated("tag"));
        // nnz lies past the range
        let mut f = vec![TAG_SPARSE];
        varint::write_u64(&mut f, 0); // lo
        varint::write_u64(&mut f, 10); // hi
        varint::write_u64(&mut f, 1000); // nnz > range
        varint::write_u64(&mut f, 0);
        varint::write_u64(&mut f, 0);
        assert!(matches!(seg(codec.decode(10, &f).unwrap_err()), SegmentError::Malformed(_)));
        // index section length exceeds the buffer
        let mut f = vec![TAG_SPARSE];
        varint::write_u64(&mut f, 0);
        varint::write_u64(&mut f, 10);
        varint::write_u64(&mut f, 1);
        varint::write_u64(&mut f, 1 << 40); // ilen: would overflow pos + ilen
        assert_eq!(
            seg(codec.decode(10, &f).unwrap_err()),
            SegmentError::Truncated("index section")
        );
        // trailing garbage after the value section
        let mut ok = codec.encode(&st(10, &[(1, 1.0)]), 0, 10);
        ok.push(0xAB);
        assert_eq!(
            seg(codec.decode(10, &ok).unwrap_err()),
            SegmentError::TrailingBytes { extra: 1 }
        );
        // hi beyond the u32-addressable domain
        let mut f = vec![TAG_DENSE];
        varint::write_u64(&mut f, 0);
        varint::write_u64(&mut f, 1 << 33);
        assert!(matches!(
            seg(codec.decode(usize::MAX, &f).unwrap_err()),
            SegmentError::Malformed(_)
        ));
    }

    /// Corrupt index bytes that decode to an out-of-range or unsorted
    /// support are rejected before a tensor is built (the tensor type
    /// only debug-asserts these invariants).
    #[test]
    fn out_of_range_decoded_support_is_rejected() {
        let codec = SegmentCodec::raw(0.5);
        // hand-build a sparse frame whose raw index section holds an
        // index >= range
        let mut f = vec![TAG_SPARSE];
        varint::write_u64(&mut f, 0); // lo
        varint::write_u64(&mut f, 10); // hi -> range 10
        varint::write_u64(&mut f, 1); // nnz
        varint::write_u64(&mut f, 4); // ilen
        f.extend_from_slice(&99u32.to_le_bytes()); // local index 99 >= 10
        varint::write_u64(&mut f, 4); // vlen
        f.extend_from_slice(&1.0f32.to_le_bytes());
        let err = codec.decode(10, &f).unwrap_err().downcast::<SegmentError>().unwrap();
        assert!(matches!(err, SegmentError::Malformed(_)), "{err}");
    }
}
