//! `GatherAll`: the topology-oblivious baseline the repo previously
//! hard-wired — every rank ships its whole compressed tensor to every
//! peer and sums locally. O(n·k) per worker; refactored behind the
//! [`SparseAllreduce`] trait so the better schedules are drop-in.
//!
//! Lockstep: `fleetsim::kernels::GatherAllTask` mirrors this send/recv
//! program order exactly — change one, change both (DESIGN.md §13).

use super::{merge, SegmentCodec, SparseAllreduce, SparseConfig};
use crate::collective::{all_gather_peers, Comm};
use crate::tensor::SparseTensor;

pub struct GatherAll {
    codec: SegmentCodec,
}

impl GatherAll {
    pub fn new(cfg: SparseConfig) -> Self {
        Self { codec: SegmentCodec::raw(cfg.dense_switch) }
    }

    /// Compose with non-default segment codecs.
    pub fn with_codec(codec: SegmentCodec) -> Self {
        Self { codec }
    }
}

impl SparseAllreduce for GatherAll {
    fn name(&self) -> &'static str {
        "gather_all"
    }

    fn allreduce(&self, ep: &dyn Comm, input: SparseTensor) -> anyhow::Result<SparseTensor> {
        let n = ep.world();
        if n == 1 {
            return Ok(input);
        }
        let d = input.dense_len();
        let blob = self.codec.encode(&input, 0, d);
        // own blob is not needed back: peers-only variant moves the final
        // send instead of cloning it
        let blobs = all_gather_peers(ep, blob);
        let mut acc = input;
        for (peer, bytes) in blobs.iter().enumerate() {
            if peer == ep.rank() {
                continue;
            }
            acc = merge::merge_sum(&acc, &self.codec.decode(d, bytes)?);
        }
        crate::obs::count("sched.gather_all_steps", 1);
        Ok(acc)
    }
}
