//! `RingRescatter`: Ok-Topk-style sparse ring allreduce. Phase 1 is a
//! sparse reduce-scatter over the dense-ring chunk partition — at step s
//! each rank forwards its accumulated copy of one chunk and merges the
//! chunk arriving from the previous rank, so after n−1 steps rank
//! `(c−1) mod n` owns the fully-reduced chunk c. The owner optionally
//! re-sparsifies its chunk back to ⌈k/n⌉ entries (the Ok-Topk move that
//! bounds the second phase at O(k) total). Phase 2 is the standard ring
//! allgather of the owned chunks.
//!
//! Per-chunk contents are determined entirely by the owner, so every
//! rank finishes with an identical result.
//!
//! Re-sparsification is a lossy approximation of the sum (Ok-Topk §4):
//! the dropped mass is *not* fed back into any error-feedback memory —
//! callers that need exact sums (or EF-accurate compensation) should use
//! the `resparsify: false` variant (`Schedule::RingRescatterExact`).
//!
//! Lockstep: `fleetsim::kernels::RingTask` mirrors this send/recv
//! program order exactly — change one, change both (DESIGN.md §13).

use super::{merge, SegmentCodec, SparseAllreduce, SparseConfig};
use crate::collective::Comm;
use crate::tensor::SparseTensor;
use crate::util::varint;

pub struct RingRescatter {
    codec: SegmentCodec,
    resparsify: bool,
}

impl RingRescatter {
    pub fn new(cfg: SparseConfig) -> Self {
        Self { codec: SegmentCodec::raw(cfg.dense_switch), resparsify: cfg.resparsify }
    }

    pub fn with_codec(codec: SegmentCodec, resparsify: bool) -> Self {
        Self { codec, resparsify }
    }
}

impl SparseAllreduce for RingRescatter {
    fn name(&self) -> &'static str {
        if self.resparsify {
            "ring_rescatter"
        } else {
            "ring_rescatter_exact"
        }
    }

    fn exact(&self) -> bool {
        !self.resparsify
    }

    fn allreduce(&self, ep: &dyn Comm, input: SparseTensor) -> anyhow::Result<SparseTensor> {
        let n = ep.world();
        let me = ep.rank();
        if n == 1 {
            return Ok(input);
        }
        let d = input.dense_len();
        let k_in = input.nnz();
        let bounds = merge::chunk_bounds(d, n);
        let mut segs = merge::split_ranges(&input, &bounds);
        let next = (me + 1) % n;
        let prev = (me + n - 1) % n;

        // reduce-scatter: step s sends chunk (me − s), merges chunk
        // (me − s − 1). Each message is prefixed with the running max of
        // the input nnz seen so far: it travels the whole ring, so after
        // n−1 hops every rank holds the *global* max k — the budget the
        // owner re-sparsifies against. Using the owner's local k instead
        // would let a rank with an empty input zero its whole chunk.
        let mut k_max = k_in as u64;
        for s in 0..n - 1 {
            let mut round = crate::obs::span(crate::obs::SpanKind::Round);
            round.label_with(|| format!("rs {s}"));
            let cs = (me + n - s) % n;
            let mut msg = Vec::new();
            varint::write_u64(&mut msg, k_max);
            msg.extend_from_slice(&self.codec.encode(&segs[cs], bounds[cs], bounds[cs + 1]));
            ep.send(next, msg);
            let cr = (me + n - s - 1) % n;
            let raw = ep.recv(prev);
            let mut pos = 0usize;
            k_max = k_max.max(varint::read_u64(&raw, &mut pos)?);
            let incoming = self.codec.decode(d, &raw[pos..])?;
            segs[cr] = merge::merge_sum(&segs[cr], &incoming);
        }

        // rank me now owns fully-reduced chunk (me + 1) % n
        let own = (me + 1) % n;
        if self.resparsify {
            segs[own] = merge::top_r_sparse(&segs[own], (k_max as usize).div_ceil(n));
        }

        // allgather: circulate the owned chunks around the ring
        for s in 0..n - 1 {
            let mut round = crate::obs::span(crate::obs::SpanKind::Round);
            round.label_with(|| format!("ag {s}"));
            let cs = (me + 1 + n - s) % n;
            ep.send(next, self.codec.encode(&segs[cs], bounds[cs], bounds[cs + 1]));
            let cr = (me + n - s) % n;
            segs[cr] = self.codec.decode(d, &ep.recv(prev))?;
        }

        // chunks are disjoint, ordered ranges: concatenate in chunk order
        let mut idx = Vec::with_capacity(segs.iter().map(|s| s.nnz()).sum());
        let mut val = Vec::with_capacity(idx.capacity());
        for seg in segs {
            let (_, i, v) = seg.into_parts();
            idx.extend(i);
            val.extend(v);
        }
        Ok(SparseTensor::new(d, idx, val))
    }
}
