//! `RecursiveDouble`: SparCML-style split allgather. Ranks pair up at
//! strides 1, 2, 4, … exchanging their accumulated sparse sums and
//! merging by index union — ⌈log₂ n⌉ rounds instead of n−1 transfers.
//! Payloads grow with the union, so each hop re-probes density and the
//! segment codec switches to dense representation past `dense_switch`
//! (the SparCML "dense switchover").
//!
//! Non-power-of-two worlds fold the `n − p` extra ranks into the first
//! `p = 2^⌊log₂ n⌋` before doubling and unfold the result after.
//!
//! Lockstep: `fleetsim::kernels::RecursiveDoubleTask` mirrors this
//! send/recv program order exactly — change one, change both
//! (DESIGN.md §13).

use super::{merge, prev_power_of_two, SegmentCodec, SparseAllreduce, SparseConfig};
use crate::collective::Comm;
use crate::tensor::SparseTensor;

pub struct RecursiveDouble {
    codec: SegmentCodec,
}

impl RecursiveDouble {
    pub fn new(cfg: SparseConfig) -> Self {
        Self { codec: SegmentCodec::raw(cfg.dense_switch) }
    }

    pub fn with_codec(codec: SegmentCodec) -> Self {
        Self { codec }
    }
}

impl SparseAllreduce for RecursiveDouble {
    fn name(&self) -> &'static str {
        "recursive_double"
    }

    fn allreduce(&self, ep: &dyn Comm, input: SparseTensor) -> anyhow::Result<SparseTensor> {
        let n = ep.world();
        let me = ep.rank();
        if n == 1 {
            return Ok(input);
        }
        let d = input.dense_len();
        let p = prev_power_of_two(n);
        let extras = n - p;
        let mut acc = input;

        if me >= p {
            // fold out: contribute to the partner, then receive the result
            let partner = me - p;
            let mut round = crate::obs::span(crate::obs::SpanKind::Round);
            round.label_with(|| "fold".to_string());
            ep.send(partner, self.codec.encode(&acc, 0, d));
            let bytes = ep.recv(partner);
            return self.codec.decode(d, &bytes);
        }
        if me < extras {
            let mut round = crate::obs::span(crate::obs::SpanKind::Round);
            round.label_with(|| "fold".to_string());
            let folded = self.codec.decode(d, &ep.recv(p + me))?;
            acc = merge::merge_sum(&acc, &folded);
        }

        // doubling rounds among the p participating ranks; both partners
        // send first (channels are unbounded), then merge — f32 addition
        // is commutative, so all ranks converge on bit-identical sums
        let mut stride = 1usize;
        while stride < p {
            let partner = me ^ stride;
            let mut round = crate::obs::span(crate::obs::SpanKind::Round);
            round.label_with(|| format!("stride {stride}"));
            ep.send(partner, self.codec.encode(&acc, 0, d));
            let theirs = self.codec.decode(d, &ep.recv(partner))?;
            acc = merge::merge_sum(&acc, &theirs);
            stride <<= 1;
        }

        if me < extras {
            let mut round = crate::obs::span(crate::obs::SpanKind::Round);
            round.label_with(|| "unfold".to_string());
            ep.send(p + me, self.codec.encode(&acc, 0, d));
        }
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prev_pow2() {
        assert_eq!(prev_power_of_two(1), 1);
        assert_eq!(prev_power_of_two(2), 2);
        assert_eq!(prev_power_of_two(3), 2);
        assert_eq!(prev_power_of_two(8), 8);
        assert_eq!(prev_power_of_two(12), 8);
        assert_eq!(prev_power_of_two(32), 32);
    }
}
