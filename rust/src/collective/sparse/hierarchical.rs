//! `Hierarchical`: leader-based two-level sparse allreduce over a
//! node × rank [`Topology`] (DESIGN.md §8).
//!
//! Real clusters are two-level — fast intra-node links, slow inter-node
//! links — and SparCML (Renggli et al.) and Ok-Topk (Li et al.) both
//! place the biggest communication wins there: only one rank per node
//! should ever talk across the slow boundary, and it should ship the
//! *node-reduced* gradient once instead of every member's copy. The
//! schedule runs three phases:
//!
//! 1. **intra reduce** — every non-leader sends its whole-tensor
//!    segment to its node leader, which index-union merges the node's
//!    contributions (the `merge::merge_sum` kernel).
//! 2. **inter exchange** — the leaders run a configurable *inner*
//!    schedule ([`GatherAll`] / [`RecursiveDouble`] / [`RingRescatter`])
//!    among themselves through a [`SubEndpoint`], exchanging node sums
//!    over the slow links only.
//! 3. **intra broadcast** — each leader ships the global sum back to
//!    its members.
//!
//! Every hop speaks the shared segment wire format, so the fabric's
//! intra/inter byte meters capture exactly what each link class moved;
//! `crate::simnet::hierarchical_bytes` mirrors the accounting
//! analytically and is cross-checked against the wire in tests.
//!
//! The result is the exact global sum whenever the inner schedule is
//! exact (any merge order yields the same support, and f32 summation
//! differences are the usual association noise — the differential tests
//! in `tests/sparse_allreduce.rs` pin byte-identical results on
//! integer-valued gradients).
//!
//! Lockstep: `fleetsim::kernels::HierTask` mirrors this send/recv
//! program order exactly — change one, change both (DESIGN.md §13).
//!
//! [`GatherAll`]: super::GatherAll
//! [`RecursiveDouble`]: super::RecursiveDouble
//! [`RingRescatter`]: super::RingRescatter

use super::{merge, SegmentCodec, SparseAllreduce};
use crate::collective::{Comm, SubEndpoint, Topology};
use crate::tensor::SparseTensor;

pub struct Hierarchical {
    codec: SegmentCodec,
    /// `None` = treat the whole world as one node (pure leader
    /// reduce + broadcast, no inter hop)
    topo: Option<Topology>,
    /// schedule run among the node leaders (phase 2)
    inner: Box<dyn SparseAllreduce>,
}

impl Hierarchical {
    /// Compose with a custom segment codec for the intra-node hops.
    /// `inner` must not itself be hierarchical (the leader group is
    /// flat by construction).
    pub fn with_codec(
        codec: SegmentCodec,
        topo: Option<Topology>,
        inner: Box<dyn SparseAllreduce>,
    ) -> Self {
        assert_ne!(inner.name(), "hierarchical", "inner schedule must be flat");
        Self { codec, topo, inner }
    }
}

impl SparseAllreduce for Hierarchical {
    fn name(&self) -> &'static str {
        "hierarchical"
    }

    fn exact(&self) -> bool {
        self.inner.exact()
    }

    fn allreduce(&self, ep: &dyn Comm, input: SparseTensor) -> anyhow::Result<SparseTensor> {
        let n = ep.world();
        if n == 1 {
            return Ok(input);
        }
        let topo = self.topo.unwrap_or_else(|| Topology::flat(n));
        anyhow::ensure!(
            topo.world() == n,
            "topology {} expects {} ranks, world is {n}",
            topo.label(),
            topo.world()
        );
        let d = input.dense_len();
        let me = ep.rank();
        let node = topo.node_of(me);
        let leader = topo.leader_of(node);
        let mut acc = input;

        if me != leader {
            // phase 1 (member side): contribute to the node leader …
            {
                let mut hop = crate::obs::span(crate::obs::SpanKind::Round);
                hop.label_with(|| "intra_reduce".to_string());
                ep.send(leader, self.codec.encode(&acc, 0, d));
            }
            // … phase 3 (member side): receive the global sum back
            let mut hop = crate::obs::span(crate::obs::SpanKind::Round);
            hop.label_with(|| "intra_bcast".to_string());
            return self.codec.decode(d, &ep.recv(leader));
        }

        // phase 1 (leader side): merge the node's contributions in rank
        // order — deterministic, so reruns are reproducible
        {
            let mut hop = crate::obs::span(crate::obs::SpanKind::Round);
            hop.label_with(|| "intra_reduce".to_string());
            for m in topo.members(node) {
                if m != me {
                    acc = merge::merge_sum(&acc, &self.codec.decode(d, &ep.recv(m))?);
                }
            }
        }

        // phase 2: node sums travel the slow links once, via the inner
        // schedule re-ranked onto the leader group
        if topo.nodes > 1 {
            let mut hop = crate::obs::span(crate::obs::SpanKind::Round);
            hop.label_with(|| format!("inter:{}", self.inner.name()));
            let sub = SubEndpoint::new(ep, topo.leaders());
            acc = self.inner.allreduce(&sub, acc)?;
        }

        // phase 3 (leader side): broadcast the result to the node —
        // encoded once (the payload is identical for every member)
        if topo.ranks_per_node > 1 {
            let mut hop = crate::obs::span(crate::obs::SpanKind::Round);
            hop.label_with(|| "intra_bcast".to_string());
            let blob = self.codec.encode(&acc, 0, d);
            for m in topo.members(node) {
                if m != me {
                    ep.send(m, blob.clone());
                }
            }
        }
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::sparse::{Schedule, SparseConfig};
    use crate::collective::Network;
    use std::thread;

    fn cfg(topo: Option<Topology>, inner: Schedule) -> SparseConfig {
        SparseConfig { topology: topo, inner, ..SparseConfig::default() }
    }

    fn run(cfg: SparseConfig, inputs: Vec<SparseTensor>, topo: Topology) -> Vec<SparseTensor> {
        let net = Network::with_topology(topo);
        let handles: Vec<_> = net
            .endpoints()
            .into_iter()
            .zip(inputs)
            .map(|(ep, t)| {
                thread::spawn(move || {
                    Schedule::Hierarchical.build(cfg).allreduce(&ep, t).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn two_by_two_sums_exactly() {
        let topo = Topology::new(2, 2);
        let d = 16;
        let inputs: Vec<SparseTensor> = (0..4)
            .map(|r| SparseTensor::new(d, vec![r as u32, (r + 4) as u32], vec![1.0, 2.0]))
            .collect();
        let outs = run(cfg(Some(topo), Schedule::GatherAll), inputs.clone(), topo);
        let mut want = vec![0.0f32; d];
        for t in &inputs {
            t.add_into(&mut want);
        }
        for out in outs {
            assert_eq!(out.to_dense().data(), want.as_slice());
        }
    }

    #[test]
    fn world_mismatch_is_an_error() {
        // topology says 2×4 = 8 ranks, fabric has 4: every rank errors
        // out before touching the network
        let net = Network::new(4);
        let ep = net.endpoints().remove(0);
        let sched = Schedule::Hierarchical
            .build(cfg(Some(Topology::new(2, 4)), Schedule::GatherAll));
        let t = SparseTensor::new(8, vec![1], vec![1.0]);
        assert!(sched.allreduce(&ep, t).is_err());
    }

    #[test]
    fn leader_only_traffic_crosses_nodes() {
        let topo = Topology::new(2, 4);
        let d = 64;
        let inputs: Vec<SparseTensor> = (0..8)
            .map(|r| SparseTensor::new(d, vec![r as u32 * 8], vec![1.0]))
            .collect();
        let net = Network::with_topology(topo);
        let handles: Vec<_> = net
            .endpoints()
            .into_iter()
            .zip(inputs)
            .map(|(ep, t)| {
                thread::spawn(move || {
                    Schedule::Hierarchical
                        .build(cfg(Some(topo), Schedule::GatherAll))
                        .allreduce(&ep, t)
                        .unwrap()
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // inter traffic = the two leaders exchanging node sums once:
        // 2 messages of a 4-entry sparse segment; everything else intra
        assert!(net.inter_bytes() > 0);
        assert!(net.intra_bytes() > net.inter_bytes());
        // exactly 2 inter messages, each one encoded 4-entry node sum
        let node0 = SparseTensor::new(d, vec![0, 8, 16, 24], vec![1.0; 4]);
        let one = SegmentCodec::raw(0.5).encode(&node0, 0, d).len() as u64;
        assert_eq!(net.inter_bytes(), 2 * one);
    }
}
