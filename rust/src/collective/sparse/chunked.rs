//! `ChunkedRescatter`: balanced chunked reduce-scatter + allgather with
//! intra-step streaming (DESIGN.md §12).
//!
//! The whole-bucket ring schedules move O(n·k) *accumulated* bytes
//! through every link — under a straggler, the slow rank's ports stay
//! saturated with forwarded traffic that never needed to touch it. This
//! schedule splits the support into `m·n` chunks balanced by estimated
//! encoded bytes and reduces each chunk with a pairwise direct exchange,
//! so each rank's own k entries cross its links exactly once:
//!
//! 1. **Histogram** — every rank allgathers a varint-encoded bin
//!    histogram of its support (`merge::bin_counts` over the
//!    deterministic `merge::balance_bins(d, m·n)` binning). The summed
//!    histogram drives `merge::balanced_bounds`, so all ranks derive the
//!    identical byte-balanced partition without further coordination.
//!    Group `g` (owned by rank `g`) covers sub-chunks `g·m .. (g+1)·m`.
//! 2. **Pairwise reduce-scatter** — at offset `s ∈ 1..n` each rank
//!    sends the `m` sub-chunks of group `(me+s) mod n` *directly* to
//!    that owner and merges the sub-chunks arriving from
//!    `(me−s) mod n` into its own accumulator. Ring-ordered offsets
//!    spread load; no accumulated payload is ever forwarded.
//! 3. **Ring allgather** — the fully-reduced groups circulate around
//!    the ring, `m` sub-chunk frames per step.
//!
//! Inside every phase-1 offset and phase-2 step the `m` sub-chunk
//! frames run through [`crate::pipeline::overlap::streamed`]: the
//! encoder lane packs sub-chunk `i+1` while sub-chunk `i` is in flight
//! (send/recv/merge on the calling thread). No re-sparsification
//! happens anywhere, so the result is the exact sum — byte-identical to
//! [`super::GatherAll`] on integer-valued gradients.
//!
//! Lockstep: `fleetsim::kernels::ChunkedTask` mirrors this send/recv
//! program order exactly — change one, change both (DESIGN.md §13).

use super::{merge, SegmentCodec, SparseAllreduce, SparseConfig};
use crate::collective::{all_gather_peers, Comm};
use crate::pipeline::overlap::streamed;
use crate::tensor::SparseTensor;
use crate::util::varint;

pub struct ChunkedRescatter {
    codec: SegmentCodec,
    chunks: usize,
}

impl ChunkedRescatter {
    pub fn new(cfg: SparseConfig) -> Self {
        Self { codec: SegmentCodec::raw(cfg.dense_switch), chunks: cfg.chunks }
    }

    pub fn with_codec(codec: SegmentCodec, chunks: usize) -> Self {
        Self { codec, chunks }
    }

    /// Sub-chunks per owner group: the `chunks` knob rounded up to a
    /// multiple of the world size, so every rank owns the same number of
    /// chunks. `0` = auto: one chunk per rank (`m = 1`), which the
    /// straggler sweeps show is the right default — extra sub-chunks buy
    /// finer streaming overlap at α cost per frame.
    pub fn sub_chunks(chunks: usize, n: usize) -> usize {
        if chunks == 0 {
            1
        } else {
            chunks.div_ceil(n).max(1)
        }
    }
}

impl SparseAllreduce for ChunkedRescatter {
    fn name(&self) -> &'static str {
        "chunked_rescatter"
    }

    fn allreduce(&self, ep: &dyn Comm, input: SparseTensor) -> anyhow::Result<SparseTensor> {
        let n = ep.world();
        let me = ep.rank();
        if n == 1 {
            return Ok(input);
        }
        let d = input.dense_len();
        let m = Self::sub_chunks(self.chunks, n);
        let p = m * n;

        // phase 0: histogram allgather → balanced bounds. The binning is
        // deterministic in (d, p) and the summed histogram is rank-order
        // independent, so every rank computes the identical partition. A
        // peer's histogram can only skew balance, never correctness: any
        // monotone edge list is a valid partition of [0, d).
        let bins = merge::balance_bins(d, p);
        let counts = merge::bin_counts(&input, bins);
        let mut blob = Vec::with_capacity(bins * 2);
        for &c in &counts {
            varint::write_u64(&mut blob, c);
        }
        let mut total = counts;
        {
            let mut round = crate::obs::span(crate::obs::SpanKind::Round);
            round.label_with(|| "hist".to_string());
            let peers = all_gather_peers(ep, blob);
            for (peer, pb) in peers.iter().enumerate() {
                if peer == me {
                    continue;
                }
                let mut pos = 0usize;
                for t in total.iter_mut() {
                    *t = t.saturating_add(varint::read_u64(pb, &mut pos)?);
                }
                if pos != pb.len() {
                    anyhow::bail!(
                        "rank {peer} histogram has {} trailing byte(s)",
                        pb.len() - pos
                    );
                }
            }
        }
        let bounds = merge::balanced_bounds(&total, d, p);

        // split my contribution once; my own group's slices seed the
        // accumulator (their segs slots are never encoded: no phase-1
        // offset targets me)
        let mut segs = merge::split_ranges(&input, &bounds);
        let mut acc: Vec<SparseTensor> = (0..m)
            .map(|j| {
                std::mem::replace(
                    &mut segs[me * m + j],
                    SparseTensor::new(d, Vec::new(), Vec::new()),
                )
            })
            .collect();

        // phase 1: pairwise direct exchange. At offset s send group
        // (me+s) mod n to its owner, merge the frames from (me−s) mod n.
        // Per-pair FIFO channels keep sub-chunk j the j-th arrival.
        let codec = &self.codec;
        for s in 1..n {
            let dst = (me + s) % n;
            let src = (me + n - s) % n;
            let mut round = crate::obs::span(crate::obs::SpanKind::Round);
            round.label_with(|| format!("px {s}"));
            let mut err: Option<anyhow::Error> = None;
            {
                let segs = &segs;
                let bounds = &bounds;
                streamed(
                    m,
                    1,
                    move |j| {
                        let c = dst * m + j;
                        codec.encode(&segs[c], bounds[c], bounds[c + 1])
                    },
                    |j, msg| {
                        ep.send(dst, msg);
                        let raw = ep.recv(src);
                        if err.is_none() {
                            match codec.decode(d, &raw) {
                                Ok(incoming) => acc[j] = merge::merge_sum(&acc[j], &incoming),
                                Err(e) => err = Some(e),
                            }
                        }
                    },
                );
            }
            if let Some(e) = err {
                return Err(e);
            }
        }

        // phase 2: ring allgather of the merged groups — own group goes
        // out first, then forward whatever arrived last step.
        let next = (me + 1) % n;
        let prev = (me + n - 1) % n;
        let mut groups: Vec<Vec<SparseTensor>> = (0..n).map(|_| Vec::new()).collect();
        groups[me] = acc;
        for s in 0..n - 1 {
            let gs = (me + n - s) % n;
            let gr = (me + n - s - 1) % n;
            let mut round = crate::obs::span(crate::obs::SpanKind::Round);
            round.label_with(|| format!("ag {s}"));
            // take the send group so the encoder's borrow cannot alias
            // the slot the incoming group lands in
            let send_group = std::mem::take(&mut groups[gs]);
            let mut recvd: Vec<SparseTensor> = Vec::with_capacity(m);
            let mut err: Option<anyhow::Error> = None;
            {
                let sg = &send_group;
                let bounds = &bounds;
                streamed(
                    m,
                    1,
                    move |j| {
                        let c = gs * m + j;
                        codec.encode(&sg[j], bounds[c], bounds[c + 1])
                    },
                    |_j, msg| {
                        ep.send(next, msg);
                        let raw = ep.recv(prev);
                        if err.is_none() {
                            match codec.decode(d, &raw) {
                                Ok(t) => recvd.push(t),
                                Err(e) => err = Some(e),
                            }
                        }
                    },
                );
            }
            groups[gs] = send_group;
            if let Some(e) = err {
                return Err(e);
            }
            groups[gr] = recvd;
        }

        // groups are disjoint ordered ranges (group g covers
        // [bounds[g·m], bounds[(g+1)·m])): concatenate in group order
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for g in groups {
            for sub in g {
                let (_, i, v) = sub.into_parts();
                idx.extend(i);
                val.extend(v);
            }
        }
        Ok(SparseTensor::new(d, idx, val))
    }
}
