//! In-process transport: N ranks, a blocking channel per ordered pair,
//! and exact byte accounting. Stands in for NCCL/Gloo point-to-point
//! (DESIGN.md §4 substitution table).
//!
//! The fabric knows the cluster [`Topology`]: every send is classified
//! as intra-node or inter-node and metered on a separate counter, so
//! schedules can be compared on the traffic class that actually hurts
//! (the slow inter-node links — DESIGN.md §8). `Network::new` is the
//! flat single-node special case where everything is intra.
//!
//! [`Comm`] is the rank-level communication surface the collective
//! algorithms are written against; [`SubEndpoint`] restricts it to a
//! subset of ranks (e.g. the node leaders) so any schedule can run
//! unchanged inside a sub-communicator.

use super::Topology;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// Point-to-point communication surface of one rank. Implemented by
/// [`Endpoint`] (the fabric handle) and [`SubEndpoint`] (a re-ranked
/// view onto a subset of the world, used for the inter-node leader
/// group of the hierarchical schedule).
///
/// Deliberately not `Send`/`Sync`: an endpoint belongs to exactly one
/// worker thread (the fabric's receivers are single-consumer), and the
/// collectives only ever use it from that thread.
pub trait Comm {
    /// This rank's id in `[0, world)`.
    fn rank(&self) -> usize;

    /// Number of ranks in this communicator.
    fn world(&self) -> usize;

    /// Blocking point-to-point send (never blocks on the in-process
    /// fabric: channels are unbounded).
    fn send(&self, dst: usize, payload: Vec<u8>);

    /// Blocking receive from a specific source rank.
    fn recv(&self, src: usize) -> Vec<u8>;
}

/// The fabric: construct once, hand one [`Endpoint`] to each worker
/// thread.
pub struct Network {
    topo: Topology,
    endpoints: std::sync::Mutex<Option<Vec<Endpoint>>>,
    bytes: Arc<AtomicU64>,
    intra: Arc<AtomicU64>,
    inter: Arc<AtomicU64>,
}

impl Network {
    /// Flat fabric: one node, `n` ranks — all traffic is intra-node.
    pub fn new(n: usize) -> Self {
        Self::with_topology(Topology::flat(n))
    }

    /// Fabric over a two-level node × rank grid: sends crossing a node
    /// boundary are metered on the inter-node counter.
    pub fn with_topology(topo: Topology) -> Self {
        let n = topo.world();
        assert!(n >= 1);
        let bytes = Arc::new(AtomicU64::new(0));
        let intra = Arc::new(AtomicU64::new(0));
        let inter = Arc::new(AtomicU64::new(0));
        // txs[dst][src], rxs[dst][src]
        let mut txs: Vec<Vec<Option<Sender<Vec<u8>>>>> = (0..n)
            .map(|_| (0..n).map(|_| None).collect())
            .collect();
        let mut rxs: Vec<Vec<Option<Receiver<Vec<u8>>>>> = (0..n)
            .map(|_| (0..n).map(|_| None).collect())
            .collect();
        for dst in 0..n {
            for src in 0..n {
                let (tx, rx) = channel();
                txs[dst][src] = Some(tx);
                rxs[dst][src] = Some(rx);
            }
        }
        // endpoint r holds: senders-to-every-dst (keyed dst), receivers-from-every-src
        let mut endpoints = Vec::with_capacity(n);
        let mut rxs_iter: Vec<Vec<Option<Receiver<Vec<u8>>>>> = rxs;
        for rank in 0..n {
            let to: Vec<Sender<Vec<u8>>> =
                (0..n).map(|dst| txs[dst][rank].clone().unwrap()).collect();
            let from: Vec<Receiver<Vec<u8>>> =
                (0..n).map(|src| rxs_iter[rank][src].take().unwrap()).collect();
            endpoints.push(Endpoint {
                rank,
                n,
                topo,
                to,
                from,
                bytes: Arc::clone(&bytes),
                intra: Arc::clone(&intra),
                inter: Arc::clone(&inter),
            });
        }
        Self { topo, endpoints: std::sync::Mutex::new(Some(endpoints)), bytes, intra, inter }
    }

    pub fn n(&self) -> usize {
        self.topo.world()
    }

    /// The grid this fabric classifies links against.
    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// Take all endpoints, erroring on a double-take. The fabric is
    /// single-use: handing out a second (empty) set used to make
    /// callers fail later in confusing ways (`pop().unwrap()` panics,
    /// zips silently doing nothing).
    pub fn try_endpoints(&self) -> anyhow::Result<Vec<Endpoint>> {
        self.endpoints.lock().unwrap().take().ok_or_else(|| {
            anyhow::anyhow!("fabric endpoints already handed out (Network is single-use)")
        })
    }

    /// Take all endpoints, also checking the caller's expected world
    /// size — a mismatched fabric (wrong-count misuse) is reported as a
    /// structured error instead of a downstream panic or deadlock.
    pub fn try_endpoints_for(&self, world: usize) -> anyhow::Result<Vec<Endpoint>> {
        let eps = self.try_endpoints()?;
        anyhow::ensure!(
            eps.len() == world,
            "fabric has {} ranks but the caller expected {world}",
            eps.len()
        );
        Ok(eps)
    }

    /// Take all endpoints (once), ordered by rank. Convenience form for
    /// tests and benches; panics on double-take — production callers
    /// use [`Network::try_endpoints`] / [`Network::try_endpoints_for`].
    pub fn endpoints(&self) -> Vec<Endpoint> {
        self.try_endpoints().expect("fabric endpoints")
    }

    /// Total bytes that crossed the fabric so far.
    pub fn total_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Bytes that stayed inside a node (always `total_bytes` on a flat
    /// fabric).
    pub fn intra_bytes(&self) -> u64 {
        self.intra.load(Ordering::Relaxed)
    }

    /// Bytes that crossed a node boundary — the slow-link traffic the
    /// hierarchical schedule minimizes.
    pub fn inter_bytes(&self) -> u64 {
        self.inter.load(Ordering::Relaxed)
    }

    pub fn reset_bytes(&self) {
        self.bytes.store(0, Ordering::Relaxed);
        self.intra.store(0, Ordering::Relaxed);
        self.inter.store(0, Ordering::Relaxed);
    }
}

/// A rank's handle onto the fabric.
pub struct Endpoint {
    rank: usize,
    n: usize,
    topo: Topology,
    to: Vec<Sender<Vec<u8>>>,
    from: Vec<Receiver<Vec<u8>>>,
    bytes: Arc<AtomicU64>,
    intra: Arc<AtomicU64>,
    inter: Arc<AtomicU64>,
}

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.n
    }

    /// The grid this endpoint's fabric was built with.
    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// Blocking point-to-point send.
    pub fn send(&self, dst: usize, payload: Vec<u8>) {
        assert_ne!(dst, self.rank, "self-send not allowed");
        self.bytes.fetch_add(payload.len() as u64, Ordering::Relaxed);
        if self.topo.is_intra(self.rank, dst) {
            self.intra.fetch_add(payload.len() as u64, Ordering::Relaxed);
        } else {
            self.inter.fetch_add(payload.len() as u64, Ordering::Relaxed);
        }
        self.to[dst].send(payload).expect("peer hung up");
    }

    /// Blocking receive from a specific source rank.
    pub fn recv(&self, src: usize) -> Vec<u8> {
        assert_ne!(src, self.rank);
        // wall-clock wait only: the instant fabric has no virtual time
        let mut wait = crate::obs::span(crate::obs::SpanKind::RecvWait);
        let payload = self.from[src].recv().expect("peer hung up");
        if wait.live() {
            wait.set_bytes(payload.len() as u64);
            wait.label_with(|| format!("from {src}"));
        }
        drop(wait);
        payload
    }

    /// Bytes sent across the whole fabric (shared counter).
    pub fn fabric_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
}

impl Comm for Endpoint {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.n
    }

    fn send(&self, dst: usize, payload: Vec<u8>) {
        Endpoint::send(self, dst, payload)
    }

    fn recv(&self, src: usize) -> Vec<u8> {
        Endpoint::recv(self, src)
    }
}

/// A communicator over a subset of another communicator's ranks: member
/// `j` of `members` becomes sub-rank `j`. Sends/receives are forwarded
/// to the parent after translating ranks, so any collective algorithm
/// written against [`Comm`] runs unchanged inside the group (the
/// hierarchical schedule runs its inner schedule among node leaders
/// this way).
pub struct SubEndpoint<'a> {
    parent: &'a dyn Comm,
    /// global ranks of the group, in sub-rank order
    members: Vec<usize>,
    /// this rank's position in `members`
    me: usize,
}

impl<'a> SubEndpoint<'a> {
    /// `members` lists the global ranks of the group (must contain the
    /// parent's own rank exactly once).
    pub fn new(parent: &'a dyn Comm, members: Vec<usize>) -> Self {
        let me = members
            .iter()
            .position(|&g| g == parent.rank())
            .expect("own rank not in sub-communicator");
        Self { parent, members, me }
    }
}

impl Comm for SubEndpoint<'_> {
    fn rank(&self) -> usize {
        self.me
    }

    fn world(&self) -> usize {
        self.members.len()
    }

    fn send(&self, dst: usize, payload: Vec<u8>) {
        self.parent.send(self.members[dst], payload)
    }

    fn recv(&self, src: usize) -> Vec<u8> {
        self.parent.recv(self.members[src])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn pairwise_ordering_preserved() {
        let net = Network::new(2);
        let mut eps = net.endpoints();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let t = thread::spawn(move || {
            for i in 0..100u8 {
                a.send(1, vec![i]);
            }
        });
        for i in 0..100u8 {
            assert_eq!(b.recv(0), vec![i]);
        }
        t.join().unwrap();
        assert_eq!(net.total_bytes(), 100);
    }

    #[test]
    fn bidirectional_no_deadlock() {
        let net = Network::new(2);
        let mut eps = net.endpoints();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let big = vec![0u8; 1 << 16];
        let big2 = big.clone();
        let t1 = thread::spawn(move || {
            a.send(1, big);
            a.recv(1)
        });
        let t2 = thread::spawn(move || {
            b.send(0, big2);
            b.recv(0)
        });
        assert_eq!(t1.join().unwrap().len(), 1 << 16);
        assert_eq!(t2.join().unwrap().len(), 1 << 16);
    }

    #[test]
    fn link_classes_metered_separately() {
        // 2 nodes × 2 ranks: 0,1 on node 0; 2,3 on node 1
        let net = Network::with_topology(Topology::new(2, 2));
        let mut eps = net.endpoints();
        let d = eps.pop().unwrap(); // rank 3
        let c = eps.pop().unwrap(); // rank 2
        let b = eps.pop().unwrap(); // rank 1
        let a = eps.pop().unwrap(); // rank 0
        let t = thread::spawn(move || {
            a.send(1, vec![0; 10]); // intra (node 0)
            a.send(2, vec![0; 100]); // inter
            a.send(3, vec![0; 1000]); // inter
        });
        let t2 = thread::spawn(move || {
            d.send(2, vec![0; 7]); // intra (node 1)
            d.recv(0)
        });
        assert_eq!(b.recv(0).len(), 10);
        assert_eq!(c.recv(0).len(), 100);
        assert_eq!(c.recv(3).len(), 7);
        t.join().unwrap();
        t2.join().unwrap();
        assert_eq!(net.intra_bytes(), 17);
        assert_eq!(net.inter_bytes(), 1100);
        assert_eq!(net.total_bytes(), 1117);
        net.reset_bytes();
        assert_eq!(net.intra_bytes() + net.inter_bytes() + net.total_bytes(), 0);
    }

    #[test]
    fn flat_fabric_is_all_intra() {
        let net = Network::new(2);
        let mut eps = net.endpoints();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let t = thread::spawn(move || a.send(1, vec![0; 42]));
        assert_eq!(b.recv(0).len(), 42);
        t.join().unwrap();
        assert_eq!(net.intra_bytes(), 42);
        assert_eq!(net.inter_bytes(), 0);
    }

    #[test]
    fn endpoint_handout_misuse_is_a_structured_error() {
        let net = Network::new(2);
        // wrong expected world: structured error (not a panic)
        let err = net.try_endpoints_for(3).unwrap_err();
        assert!(err.to_string().contains("expected 3"), "{err}");
        // the failed take still consumed the fabric: also a clean error
        let err = net.try_endpoints().unwrap_err();
        assert!(err.to_string().contains("already handed out"), "{err}");
        // correct usage on a fresh fabric
        let net = Network::new(2);
        assert_eq!(net.try_endpoints_for(2).unwrap().len(), 2);
        assert!(net.try_endpoints().is_err(), "double-take must error");
    }

    #[test]
    fn sub_endpoint_translates_ranks() {
        // leaders {0, 2} of a 2×2 grid talk through a sub-communicator
        let net = Network::with_topology(Topology::new(2, 2));
        let mut eps = net.endpoints();
        eps.pop(); // rank 3 unused
        let c = eps.pop().unwrap(); // rank 2
        eps.pop(); // rank 1 unused
        let a = eps.pop().unwrap(); // rank 0
        let t = thread::spawn(move || {
            let sub = SubEndpoint::new(&a, vec![0, 2]);
            assert_eq!(sub.rank(), 0);
            assert_eq!(sub.world(), 2);
            sub.send(1, vec![9; 5]); // sub-rank 1 = global rank 2
            sub.recv(1)
        });
        let sub = SubEndpoint::new(&c, vec![0, 2]);
        assert_eq!(sub.rank(), 1);
        assert_eq!(sub.recv(0), vec![9; 5]);
        sub.send(0, vec![7; 3]);
        assert_eq!(t.join().unwrap(), vec![7; 3]);
        // leader traffic crosses nodes: all inter
        assert_eq!(net.inter_bytes(), 8);
        assert_eq!(net.intra_bytes(), 0);
    }
}
