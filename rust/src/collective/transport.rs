//! In-process transport: N ranks, a blocking channel per ordered pair,
//! and exact byte accounting. Stands in for NCCL/Gloo point-to-point
//! (DESIGN.md §4 substitution table).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// The fabric: construct once, hand one [`Endpoint`] to each worker
/// thread.
pub struct Network {
    n: usize,
    endpoints: std::sync::Mutex<Vec<Endpoint>>,
    bytes: Arc<AtomicU64>,
}

impl Network {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let bytes = Arc::new(AtomicU64::new(0));
        // txs[dst][src], rxs[dst][src]
        let mut txs: Vec<Vec<Option<Sender<Vec<u8>>>>> = (0..n)
            .map(|_| (0..n).map(|_| None).collect())
            .collect();
        let mut rxs: Vec<Vec<Option<Receiver<Vec<u8>>>>> = (0..n)
            .map(|_| (0..n).map(|_| None).collect())
            .collect();
        for dst in 0..n {
            for src in 0..n {
                let (tx, rx) = channel();
                txs[dst][src] = Some(tx);
                rxs[dst][src] = Some(rx);
            }
        }
        // endpoint r holds: senders-to-every-dst (keyed dst), receivers-from-every-src
        let mut endpoints = Vec::with_capacity(n);
        let mut rxs_iter: Vec<Vec<Option<Receiver<Vec<u8>>>>> = rxs;
        for rank in 0..n {
            let to: Vec<Sender<Vec<u8>>> =
                (0..n).map(|dst| txs[dst][rank].clone().unwrap()).collect();
            let from: Vec<Receiver<Vec<u8>>> =
                (0..n).map(|src| rxs_iter[rank][src].take().unwrap()).collect();
            endpoints.push(Endpoint { rank, n, to, from, bytes: Arc::clone(&bytes) });
        }
        Self { n, endpoints: std::sync::Mutex::new(endpoints), bytes }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Take all endpoints (once). Ordered by rank.
    pub fn endpoints(&self) -> Vec<Endpoint> {
        std::mem::take(&mut *self.endpoints.lock().unwrap())
    }

    /// Total bytes that crossed the fabric so far.
    pub fn total_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn reset_bytes(&self) {
        self.bytes.store(0, Ordering::Relaxed);
    }
}

/// A rank's handle onto the fabric.
pub struct Endpoint {
    rank: usize,
    n: usize,
    to: Vec<Sender<Vec<u8>>>,
    from: Vec<Receiver<Vec<u8>>>,
    bytes: Arc<AtomicU64>,
}

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.n
    }

    /// Blocking point-to-point send.
    pub fn send(&self, dst: usize, payload: Vec<u8>) {
        assert_ne!(dst, self.rank, "self-send not allowed");
        self.bytes.fetch_add(payload.len() as u64, Ordering::Relaxed);
        self.to[dst].send(payload).expect("peer hung up");
    }

    /// Blocking receive from a specific source rank.
    pub fn recv(&self, src: usize) -> Vec<u8> {
        assert_ne!(src, self.rank);
        self.from[src].recv().expect("peer hung up")
    }

    /// Bytes sent across the whole fabric (shared counter).
    pub fn fabric_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn pairwise_ordering_preserved() {
        let net = Network::new(2);
        let mut eps = net.endpoints();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let t = thread::spawn(move || {
            for i in 0..100u8 {
                a.send(1, vec![i]);
            }
        });
        for i in 0..100u8 {
            assert_eq!(b.recv(0), vec![i]);
        }
        t.join().unwrap();
        assert_eq!(net.total_bytes(), 100);
    }

    #[test]
    fn bidirectional_no_deadlock() {
        let net = Network::new(2);
        let mut eps = net.endpoints();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let big = vec![0u8; 1 << 16];
        let big2 = big.clone();
        let t1 = thread::spawn(move || {
            a.send(1, big);
            a.recv(1)
        });
        let t2 = thread::spawn(move || {
            b.send(0, big2);
            b.recv(0)
        });
        assert_eq!(t1.join().unwrap().len(), 1 << 16);
        assert_eq!(t2.join().unwrap().len(), 1 << 16);
    }
}
