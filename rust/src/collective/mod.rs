//! Collective communication over an in-process, byte-counted transport.
//!
//! DeepReduce is oblivious to the topology (paper §3); we provide the two
//! collectives the evaluation uses — **Allgather** (sparse payloads, what
//! Horovod/NCCL use for variable-size tensors) and ring **Allreduce**
//! (dense baseline) — plus a parameter-server exchange. The transport
//! counts bytes exactly; wall-clock *network* time on a given link speed
//! is modelled by [`crate::simnet`] (the testbed substitution described
//! in DESIGN.md §4).
//!
//! The [`sparse`] submodule adds topology-*aware* sparse allreduce
//! schedules (recursive doubling, ring reduce-scatter with in-flight
//! re-sparsification, leader-based hierarchical) behind the
//! [`sparse::SparseAllreduce`] trait — see DESIGN.md §5 and §8.
//!
//! The fabric understands a two-level node × rank [`Topology`]: every
//! send is metered as intra-node or inter-node, so schedules are
//! compared on the link class that dominates real clusters (the slow
//! inter-node network). [`Comm`] abstracts the rank-level surface and
//! [`SubEndpoint`] restricts it to a rank subset, which is how the
//! hierarchical schedule reuses the flat schedules among node leaders.

mod ops;
pub mod sparse;
mod topology;
mod transport;

pub use ops::{all_gather, all_gather_peers, all_reduce_ring, ps_exchange};
pub use sparse::{Schedule, SparseAllreduce, SparseConfig};
pub use topology::Topology;
pub use transport::{Comm, Endpoint, Network, SubEndpoint};

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn allgather_collects_everyones_payload() {
        let n = 4;
        let net = Network::new(n);
        let mut eps = net.endpoints();
        let handles: Vec<_> = eps
            .drain(..)
            .map(|ep| {
                thread::spawn(move || {
                    let mine = vec![ep.rank() as u8; ep.rank() + 1];
                    let all = all_gather(&ep, mine);
                    (ep.rank(), all)
                })
            })
            .collect();
        for h in handles {
            let (rank, all) = h.join().unwrap();
            assert_eq!(all.len(), n);
            for (peer, blob) in all.iter().enumerate() {
                assert_eq!(blob, &vec![peer as u8; peer + 1], "rank {rank} peer {peer}");
            }
        }
        // wire accounting: each worker sends its blob to n-1 peers
        let expect: u64 = (0..n).map(|r| ((r + 1) * (n - 1)) as u64).sum();
        assert_eq!(net.total_bytes(), expect);
    }

    #[test]
    fn ring_allreduce_sums_dense_tensors() {
        let n = 4;
        let d = 1030; // not divisible by n: exercises uneven chunks
        let net = Network::new(n);
        let mut eps = net.endpoints();
        let handles: Vec<_> = eps
            .drain(..)
            .map(|ep| {
                thread::spawn(move || {
                    let mut x: Vec<f32> = (0..d).map(|i| (i * (ep.rank() + 1)) as f32).collect();
                    all_reduce_ring(&ep, &mut x);
                    x
                })
            })
            .collect();
        let results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let factor: f32 = (1..=n as u32).sum::<u32>() as f32; // 1+2+3+4
        for x in &results {
            for (i, &v) in x.iter().enumerate() {
                assert_eq!(v, i as f32 * factor);
            }
        }
        // ring allreduce moves 2*(n-1)/n * payload per worker
        let payload = (d * 4) as f64;
        let expected = (2.0 * (n as f64 - 1.0) / n as f64 * payload * n as f64) as u64;
        let got = net.total_bytes();
        // chunk-boundary padding allows small deviation
        assert!(
            (got as f64 - expected as f64).abs() / (expected as f64) < 0.02,
            "wire {got} vs model {expected}"
        );
    }

    #[test]
    fn ps_exchange_aggregates_and_broadcasts() {
        let n = 3;
        let net = Network::new(n);
        let mut eps = net.endpoints();
        let handles: Vec<_> = eps
            .drain(..)
            .map(|ep| {
                thread::spawn(move || {
                    let mine = vec![(ep.rank() + 1) as u8; 4];
                    ps_exchange(&ep, mine, |blobs| {
                        // server reduction: elementwise sum
                        let mut acc = vec![0u8; 4];
                        for b in blobs {
                            for (a, &v) in acc.iter_mut().zip(b.iter()) {
                                *a += v;
                            }
                        }
                        acc
                    })
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![6u8; 4]); // 1+2+3
        }
    }

    #[test]
    fn single_worker_noop() {
        let net = Network::new(1);
        let ep = net.endpoints().pop().unwrap();
        let all = all_gather(&ep, vec![42]);
        assert_eq!(all, vec![vec![42]]);
        let mut x = vec![1.0f32, 2.0];
        all_reduce_ring(&ep, &mut x);
        assert_eq!(x, vec![1.0, 2.0]);
        assert_eq!(net.total_bytes(), 0);
    }
}
