//! Cluster topology: a two-level node × rank grid.
//!
//! Real clusters are not flat — ranks on the same node talk over
//! NVLink/shared memory while ranks on different nodes cross a much
//! slower network. [`Topology`] describes the grid the fabric and the
//! hierarchical schedule agree on: `nodes` machines with
//! `ranks_per_node` workers each, ranks assigned to nodes in contiguous
//! blocks (rank `r` lives on node `r / ranks_per_node`, the Horovod /
//! MPI default placement). The first rank of each block is the node's
//! *leader* in the two-level schedule (`collective::sparse::Hierarchical`).
//!
//! Link *speeds* are deliberately not part of this type: the fabric
//! counts bytes per link class and `crate::simnet` applies separate
//! intra/inter α–β parameters to them (see `simnet::hierarchical_time`).

/// A two-level node × rank grid. World size is `nodes * ranks_per_node`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    /// number of machines
    pub nodes: usize,
    /// workers per machine (uniform)
    pub ranks_per_node: usize,
}

impl Topology {
    pub fn new(nodes: usize, ranks_per_node: usize) -> Self {
        assert!(nodes >= 1 && ranks_per_node >= 1, "degenerate topology");
        Self { nodes, ranks_per_node }
    }

    /// The flat (single-node) topology every rank-only setup implies:
    /// all traffic is intra-node.
    pub fn flat(world: usize) -> Self {
        Self::new(1, world.max(1))
    }

    /// Parse the CLI `NxR` form (e.g. `2x4` = 2 nodes × 4 ranks each).
    pub fn parse(s: &str) -> Option<Self> {
        let (n, r) = s.split_once(['x', 'X'])?;
        let nodes: usize = n.trim().parse().ok()?;
        let ranks: usize = r.trim().parse().ok()?;
        if nodes == 0 || ranks == 0 {
            return None;
        }
        Some(Self::new(nodes, ranks))
    }

    /// Total rank count.
    pub fn world(&self) -> usize {
        self.nodes * self.ranks_per_node
    }

    /// Node hosting `rank` (contiguous block placement).
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.ranks_per_node
    }

    /// The leader rank of `node`: the first rank in its block.
    pub fn leader_of(&self, node: usize) -> usize {
        node * self.ranks_per_node
    }

    pub fn is_leader(&self, rank: usize) -> bool {
        rank % self.ranks_per_node == 0
    }

    /// All ranks of `node` in ascending order (leader first).
    pub fn members(&self, node: usize) -> std::ops::Range<usize> {
        let lo = node * self.ranks_per_node;
        lo..lo + self.ranks_per_node
    }

    /// All leader ranks in node order — the inter-node sub-communicator.
    pub fn leaders(&self) -> Vec<usize> {
        (0..self.nodes).map(|m| self.leader_of(m)).collect()
    }

    /// Whether a `src → dst` transfer stays inside one node.
    pub fn is_intra(&self, src: usize, dst: usize) -> bool {
        self.node_of(src) == self.node_of(dst)
    }

    /// The canonical CLI spelling (`NxR`).
    pub fn label(&self) -> String {
        format!("{}x{}", self.nodes, self.ranks_per_node)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_and_rejects() {
        let t = Topology::parse("2x4").unwrap();
        assert_eq!(t, Topology::new(2, 4));
        assert_eq!(t.label(), "2x4");
        assert_eq!(Topology::parse(&t.label()), Some(t));
        assert_eq!(Topology::parse("3X3"), Some(Topology::new(3, 3)));
        for bad in ["", "8", "0x4", "2x0", "2x", "x4", "axb", "2x4x2"] {
            assert!(Topology::parse(bad).is_none(), "{bad}");
        }
    }

    #[test]
    fn block_placement() {
        let t = Topology::new(3, 4);
        assert_eq!(t.world(), 12);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(3), 0);
        assert_eq!(t.node_of(4), 1);
        assert_eq!(t.node_of(11), 2);
        assert_eq!(t.leader_of(2), 8);
        assert!(t.is_leader(0) && t.is_leader(4) && t.is_leader(8));
        assert!(!t.is_leader(1) && !t.is_leader(7));
        assert_eq!(t.members(1).collect::<Vec<_>>(), vec![4, 5, 6, 7]);
        assert_eq!(t.leaders(), vec![0, 4, 8]);
        assert!(t.is_intra(4, 7));
        assert!(!t.is_intra(3, 4));
    }

    #[test]
    fn flat_is_all_intra() {
        let t = Topology::flat(6);
        assert_eq!(t.world(), 6);
        assert_eq!(t.leaders(), vec![0]);
        for a in 0..6 {
            for b in 0..6 {
                assert!(t.is_intra(a, b));
            }
        }
    }
}
