//! Collective algorithms over the fabric: allgather (variable-size
//! payloads), bandwidth-optimal ring allreduce for dense f32 tensors, and
//! a parameter-server exchange.
//!
//! The allgather family is generic over [`Comm`], so it runs equally on
//! a whole-world [`Endpoint`] or inside a sub-communicator (e.g. the
//! node-leader group of the hierarchical schedule).

use super::{Comm, Endpoint};

/// Allgather: every rank contributes one blob; returns all blobs indexed
/// by rank. This is the collective used for sparse tensors (Horovod
/// Allgather, paper §6.4 "Total training runtime").
pub fn all_gather<C: Comm + ?Sized>(ep: &C, mine: Vec<u8>) -> Vec<Vec<u8>> {
    // n−1 clones are irreducible here: every peer needs an owned buffer
    // AND out[me] keeps the original. Callers that do not need their own
    // blob back should use `all_gather_peers` directly, where the final
    // send moves the buffer.
    let me = ep.rank();
    let mut out = all_gather_peers(ep, mine.clone());
    out[me] = mine;
    out
}

/// Allgather variant for callers that do not need their own blob back
/// (the sparse schedules merge their local tensor directly): the final
/// send *moves* `mine`, saving one full-blob copy per rank per step.
/// `out[rank]` is left empty.
///
/// Sends go out in ring order (`me+1, me+2, …`) and receives drain in
/// reverse ring order (`me−1, me−2, …`) — on the instant fabric this is
/// indistinguishable from any other order (per-pair FIFO channels, one
/// message per pair), but on the virtual-time fabric it is the
/// staggered schedule a real allgather runs: every rank's k-th send
/// targets a *different* peer, so no receiver becomes an ingress
/// hotspot and the measured critical path matches the
/// `simnet::gather_all_time` closed form.
pub fn all_gather_peers<C: Comm + ?Sized>(ep: &C, mine: Vec<u8>) -> Vec<Vec<u8>> {
    let n = ep.world();
    let me = ep.rank();
    let mut out: Vec<Vec<u8>> = vec![Vec::new(); n];
    for j in 1..n {
        let peer = (me + j) % n;
        if j + 1 == n {
            // final send moves the buffer
            ep.send(peer, mine);
            break;
        }
        ep.send(peer, mine.clone());
    }
    for j in 1..n {
        let peer = (me + n - j) % n;
        out[peer] = ep.recv(peer);
    }
    out
}

/// Bandwidth-optimal ring allreduce (sum) over a dense f32 buffer:
/// reduce-scatter then allgather, n−1 steps each, 2·(n−1)/n·|x| bytes
/// per worker on the wire.
pub fn all_reduce_ring(ep: &Endpoint, x: &mut [f32]) {
    let n = ep.world();
    if n == 1 {
        return;
    }
    let me = ep.rank();
    let next = (me + 1) % n;
    let prev = (me + n - 1) % n;
    let d = x.len();
    // chunk boundaries (chunk c covers [bounds[c], bounds[c+1]))
    let bounds: Vec<usize> = (0..=n).map(|c| c * d / n).collect();
    let chunk = |c: usize| (bounds[c % n], bounds[c % n + 1]);

    // reduce-scatter: step s, send chunk (me - s), recv chunk (me - s - 1)
    for s in 0..n - 1 {
        let (cs, ce) = chunk((me + n - s) % n);
        let payload: Vec<u8> = x[cs..ce].iter().flat_map(|v| v.to_le_bytes()).collect();
        ep.send(next, payload);
        let (rs, re) = chunk((me + n - s - 1) % n);
        let incoming = ep.recv(prev);
        debug_assert_eq!(incoming.len(), (re - rs) * 4);
        for (i, c) in incoming.chunks_exact(4).enumerate() {
            x[rs + i] += f32::from_le_bytes(c.try_into().unwrap());
        }
    }
    // allgather phase: circulate the fully-reduced chunks
    for s in 0..n - 1 {
        let (cs, ce) = chunk((me + 1 + n - s) % n);
        let payload: Vec<u8> = x[cs..ce].iter().flat_map(|v| v.to_le_bytes()).collect();
        ep.send(next, payload);
        let (rs, re) = chunk((me + n - s) % n);
        let incoming = ep.recv(prev);
        debug_assert_eq!(incoming.len(), (re - rs) * 4);
        for (i, c) in incoming.chunks_exact(4).enumerate() {
            x[rs + i] = f32::from_le_bytes(c.try_into().unwrap());
        }
    }
}

/// Parameter-server exchange: rank 0 acts as the server, applying
/// `reduce` to the n collected blobs and broadcasting the result.
/// Returns the reduced blob on every rank.
pub fn ps_exchange<F>(ep: &Endpoint, mine: Vec<u8>, reduce: F) -> Vec<u8>
where
    F: FnOnce(Vec<Vec<u8>>) -> Vec<u8>,
{
    let n = ep.world();
    if n == 1 {
        return reduce(vec![mine]);
    }
    if ep.rank() == 0 {
        let mut blobs = Vec::with_capacity(n);
        blobs.push(mine);
        for src in 1..n {
            blobs.push(ep.recv(src));
        }
        let out = reduce(blobs);
        for dst in 1..n {
            ep.send(dst, out.clone());
        }
        out
    } else {
        ep.send(0, mine);
        ep.recv(0)
    }
}

#[cfg(test)]
mod tests {
    use crate::collective::{all_gather_peers, all_reduce_ring, Network};
    use std::thread;

    #[test]
    fn all_gather_peers_collects_all_but_self() {
        let n = 4;
        let net = Network::new(n);
        let mut eps = net.endpoints();
        let handles: Vec<_> = eps
            .drain(..)
            .map(|ep| {
                thread::spawn(move || {
                    let mine = vec![ep.rank() as u8; ep.rank() + 1];
                    (ep.rank(), all_gather_peers(&ep, mine))
                })
            })
            .collect();
        for h in handles {
            let (rank, all) = h.join().unwrap();
            for (peer, blob) in all.iter().enumerate() {
                if peer == rank {
                    assert!(blob.is_empty(), "own slot must stay empty");
                } else {
                    assert_eq!(blob, &vec![peer as u8; peer + 1]);
                }
            }
        }
        // same wire traffic as the full allgather
        let expect: u64 = (0..n).map(|r| ((r + 1) * (n - 1)) as u64).sum();
        assert_eq!(net.total_bytes(), expect);
    }

    #[test]
    fn ring_allreduce_matches_direct_sum_many_sizes() {
        for n in [2usize, 3, 5, 8] {
            for d in [1usize, 2, 7, 64, 257] {
                let net = Network::new(n);
                let mut eps = net.endpoints();
                let handles: Vec<_> = eps
                    .drain(..)
                    .map(|ep| {
                        thread::spawn(move || {
                            let mut x: Vec<f32> =
                                (0..d).map(|i| (i + 1) as f32 * (ep.rank() + 1) as f32).collect();
                            all_reduce_ring(&ep, &mut x);
                            x
                        })
                    })
                    .collect();
                let factor: f32 = (1..=n as u32).sum::<u32>() as f32;
                for h in handles {
                    let x = h.join().unwrap();
                    for (i, &v) in x.iter().enumerate() {
                        let want = (i + 1) as f32 * factor;
                        assert!((v - want).abs() < 1e-3, "n={n} d={d} i={i}: {v} vs {want}");
                    }
                }
            }
        }
    }
}
