//! Polynomial least-squares fitting (the Fit-Poly primitive, paper §5).
//!
//! Fits `y ≈ Σ c_k x^k` over a segment by solving the Vandermonde normal
//! equations `(XᵀX) c = Xᵀy` with Cholesky. The x-domain is rescaled to
//! [-1, 1] before fitting to keep XᵀX well-conditioned at degree 5 — the
//! scale parameters are part of the serialized model.

use super::cholesky_solve;

/// A fitted polynomial over a segment `[x0, x1]` (inclusive indices).
#[derive(Clone, Debug, PartialEq)]
pub struct PolyFit {
    /// coefficients in the *rescaled* domain t ∈ [-1, 1], low order first
    pub coeffs: Vec<f32>,
    /// domain mapping: t = (x - mid) / half
    pub mid: f32,
    pub half: f32,
}

impl PolyFit {
    /// Evaluate at integer position x.
    #[inline]
    pub fn eval(&self, x: f64) -> f32 {
        let t = ((x - self.mid as f64) / self.half as f64).clamp(-1.5, 1.5);
        // Horner
        let mut acc = 0.0f64;
        for &c in self.coeffs.iter().rev() {
            acc = acc * t + c as f64;
        }
        acc as f32
    }

    /// Serialized size in bytes (coeffs + domain), for volume accounting.
    pub fn wire_bytes(&self) -> usize {
        4 * self.coeffs.len() + 8
    }
}

/// Fit a degree-`deg` polynomial to `y[i]` at positions `x0 + i`.
/// Returns None only if the system is irreparably singular.
pub fn polyfit(x0: usize, y: &[f64], deg: usize) -> Option<PolyFit> {
    let n = y.len();
    assert!(n >= 1);
    let deg = deg.min(n - 1); // cannot fit degree above n-1
    let m = deg + 1;
    let x1 = x0 + n - 1;
    let mid = (x0 + x1) as f64 / 2.0;
    let half = ((x1 - x0) as f64 / 2.0).max(1.0);

    // accumulate normal equations
    let mut xtx = vec![0.0f64; m * m];
    let mut xty = vec![0.0f64; m];
    let mut powers = vec![0.0f64; m];
    for (i, &yi) in y.iter().enumerate() {
        let t = ((x0 + i) as f64 - mid) / half;
        let mut p = 1.0;
        for slot in powers.iter_mut() {
            *slot = p;
            p *= t;
        }
        for a in 0..m {
            for b in a..m {
                xtx[a * m + b] += powers[a] * powers[b];
            }
            xty[a] += powers[a] * yi;
        }
    }
    // mirror lower triangle
    for a in 0..m {
        for b in 0..a {
            xtx[a * m + b] = xtx[b * m + a];
        }
    }
    let c = cholesky_solve(&xtx, &xty, m)?;
    Some(PolyFit {
        coeffs: c.iter().map(|&v| v as f32).collect(),
        mid: mid as f32,
        half: half as f32,
    })
}

/// Evaluate a fitted polynomial at all integer positions `x0..x0+n`.
pub fn polyval(fit: &PolyFit, x0: usize, n: usize) -> Vec<f32> {
    (0..n).map(|i| fit.eval((x0 + i) as f64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn exact_on_polynomial_data() {
        // y = 2 - 3x + 0.5x^2 sampled on x = 10..40
        let x0 = 10;
        let y: Vec<f64> =
            (0..30).map(|i| ((x0 + i) as f64).powi(2) * 0.5 - 3.0 * (x0 + i) as f64 + 2.0).collect();
        let fit = polyfit(x0, &y, 2).unwrap();
        let z = polyval(&fit, x0, 30);
        for (i, (&yi, &zi)) in y.iter().zip(&z).enumerate() {
            assert!((yi - zi as f64).abs() < 1e-2 * (1.0 + yi.abs()), "i={i}: {yi} vs {zi}");
        }
    }

    #[test]
    fn constant_and_single_point() {
        let fit = polyfit(0, &[5.0], 5).unwrap();
        assert_eq!(fit.eval(0.0), 5.0);
        let fit = polyfit(100, &[3.0, 3.0, 3.0], 0).unwrap();
        assert!((fit.eval(101.0) - 3.0).abs() < 1e-6);
    }

    #[test]
    fn degree_clamped_to_points() {
        // 2 points, degree 5 -> line through both
        let fit = polyfit(0, &[0.0, 10.0], 5).unwrap();
        assert_eq!(fit.coeffs.len(), 2);
        assert!((fit.eval(0.0) - 0.0).abs() < 1e-5);
        assert!((fit.eval(1.0) - 10.0).abs() < 1e-5);
    }

    #[test]
    fn noisy_fit_beats_mean_baseline() {
        let mut rng = Rng::new(60);
        // monotone sorted-gradient-like curve + noise
        let n = 500;
        let y: Vec<f64> = (0..n)
            .map(|i| {
                let t = i as f64 / n as f64;
                (1.0 - t).powi(3) * 2.0 + rng.next_gaussian() * 0.01
            })
            .collect();
        let fit = polyfit(0, &y, 5).unwrap();
        let z = polyval(&fit, 0, n);
        let mean = y.iter().sum::<f64>() / n as f64;
        let sse_fit: f64 = y.iter().zip(&z).map(|(&a, &b)| (a - b as f64).powi(2)).sum();
        let sse_mean: f64 = y.iter().map(|&a| (a - mean).powi(2)).sum();
        assert!(sse_fit < sse_mean * 0.05, "fit {sse_fit} vs mean {sse_mean}");
    }

    #[test]
    fn large_offset_domain_is_stable() {
        // regression guard: raw Vandermonde at x~1e6 would blow up
        let x0 = 1_000_000;
        let y: Vec<f64> = (0..100).map(|i| 0.001 * i as f64).collect();
        let fit = polyfit(x0, &y, 3).unwrap();
        let z = polyval(&fit, x0, 100);
        for (&yi, &zi) in y.iter().zip(&z) {
            assert!((yi - zi as f64).abs() < 1e-3);
        }
    }
}
