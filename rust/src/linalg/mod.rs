//! Small dense linear algebra for the curve-fitting value compressors:
//! Cholesky solves of the (tiny) normal equations for polynomial least
//! squares, and a Levenberg–Marquardt loop for the double-exponential
//! model. Everything here is ≤ 8×8, so simplicity beats blocking.

mod gauss_newton;
mod polyfit;

pub use gauss_newton::{fit_double_exp, DoubleExp};
pub use polyfit::{polyfit, polyval, PolyFit};

/// Solve `A x = b` for symmetric positive-definite `A` (row-major n×n)
/// via Cholesky with diagonal regularization on failure.
/// Returns None if A is irreparably singular.
pub fn cholesky_solve(a: &[f64], b: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    let mut lam = 0.0f64;
    let scale = (0..n).map(|i| a[i * n + i].abs()).fold(0.0f64, f64::max).max(1e-300);
    for _ in 0..8 {
        if let Some(x) = try_cholesky(a, b, n, lam) {
            return Some(x);
        }
        lam = if lam == 0.0 { scale * 1e-12 } else { lam * 100.0 };
    }
    None
}

fn try_cholesky(a: &[f64], b: &[f64], n: usize, lam: f64) -> Option<Vec<f64>> {
    // L lower-triangular, A + lam*I = L L^T
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j] + if i == j { lam } else { 0.0 };
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    // forward solve L y = b
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * y[k];
        }
        y[i] = s / l[i * n + i];
    }
    // back solve L^T x = y
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    if x.iter().all(|v| v.is_finite()) {
        Some(x)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_spd_system() {
        // A = [[4,2],[2,3]], b = [2,5] -> x = [-0.5, 2]
        let a = [4.0, 2.0, 2.0, 3.0];
        let b = [2.0, 5.0];
        let x = cholesky_solve(&a, &b, 2).unwrap();
        assert!((x[0] + 0.5).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn regularizes_near_singular() {
        // rank-1 matrix; regularization should still produce finite output
        let a = [1.0, 1.0, 1.0, 1.0];
        let b = [2.0, 2.0];
        let x = cholesky_solve(&a, &b, 2).unwrap();
        assert!(x.iter().all(|v| v.is_finite()));
        // solution approximately satisfies the system in least-norm sense
        let r0 = a[0] * x[0] + a[1] * x[1] - b[0];
        assert!(r0.abs() < 1e-3);
    }

    #[test]
    fn identity_solve() {
        let n = 5;
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let b: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let x = cholesky_solve(&a, &b, n).unwrap();
        for i in 0..n {
            assert!((x[i] - b[i]).abs() < 1e-14);
        }
    }
}
