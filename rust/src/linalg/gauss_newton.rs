//! Double-exponential regression `y = a·e^{bx} + c·e^{dx}` (Fit-DExp,
//! paper §5 "Nonlinear regression") via Levenberg–Marquardt.
//!
//! The sorted gradient curve is monotone and convex-ish, which a sum of
//! two exponentials captures with 4 parameters. The x-domain is rescaled
//! to [0, 1] for conditioning; scale is implicit (the decoder knows n).

use super::cholesky_solve;

/// Fitted double-exponential model over `n` points (x rescaled to [0,1]).
#[derive(Clone, Debug, PartialEq)]
pub struct DoubleExp {
    pub a: f32,
    pub b: f32,
    pub c: f32,
    pub d: f32,
}

impl DoubleExp {
    /// Evaluate at rescaled position t ∈ [0, 1].
    #[inline]
    pub fn eval_t(&self, t: f64) -> f32 {
        (self.a as f64 * (self.b as f64 * t).exp() + self.c as f64 * (self.d as f64 * t).exp())
            as f32
    }

    /// Evaluate at integer position i of n.
    #[inline]
    pub fn eval(&self, i: usize, n: usize) -> f32 {
        let t = if n > 1 { i as f64 / (n - 1) as f64 } else { 0.0 };
        self.eval_t(t)
    }

    pub fn wire_bytes(&self) -> usize {
        16
    }
}

/// Fit the model to `y` (positions 0..n rescaled to [0,1]).
/// Returns the fit and its sum of squared errors.
pub fn fit_double_exp(y: &[f64], max_iters: usize) -> Option<(DoubleExp, f64)> {
    let n = y.len();
    if n < 4 {
        return None;
    }
    let ts: Vec<f64> = (0..n).map(|i| i as f64 / (n - 1) as f64).collect();

    // Multi-start: the loss surface has local minima, so try a few
    // structurally different initializations and keep the best fit.
    let y0 = y[0];
    let y1 = y[n - 1];
    let ybar = y.iter().sum::<f64>() / n as f64;
    let e4 = 4.0f64.exp();
    let mut starts: Vec<[f64; 4]> = vec![
        // fast + slow decaying pair
        [0.75 * y0, decay_guess(y, &ts), 0.25 * y0, 0.0],
        // endpoint-anchored: a e^{bt} decays from y0; c e^{dt} grows to y1
        [y0, -4.0, y1 / e4, 4.0],
        // constant-ish slow component plus the transient above it
        [y0 - ybar, -3.0, ybar, 0.0],
    ];
    if y0.abs() < 1e-30 {
        starts.push([y1, 1.0, -y1, 0.5]);
    }
    let mut overall: Option<([f64; 4], f64)> = None;
    for p0 in starts {
        let (p, s) = lm_from(p0, y, &ts, max_iters);
        if overall.as_ref().is_none_or(|(_, bs)| s < *bs) {
            overall = Some((p, s));
        }
    }
    let best = overall?.0;
    finalize(best, y, &ts)
}

fn lm_from(mut p: [f64; 4], y: &[f64], ts: &[f64], max_iters: usize) -> ([f64; 4], f64) {
    let mut lambda = 1e-3;
    let mut best = p;
    let mut best_sse = sse(&p, y, ts);
    let mut stall = 0u32; // §Perf: stop after 4 near-zero-improvement steps
    for _ in 0..max_iters {
        // Jacobian and residuals at p
        let (jtj, jtr) = normal_eqs(&p, y, ts);
        // LM step: (JᵀJ + λ diag(JᵀJ)) δ = Jᵀr
        let mut aug = jtj.clone();
        for i in 0..4 {
            aug[i * 4 + i] += lambda * jtj[i * 4 + i].max(1e-12);
        }
        let Some(delta) = cholesky_solve(&aug, &jtr, 4) else {
            lambda *= 10.0;
            continue;
        };
        let cand = [
            p[0] + delta[0],
            (p[1] + delta[1]).clamp(-60.0, 60.0),
            p[2] + delta[2],
            (p[3] + delta[3]).clamp(-60.0, 60.0),
        ];
        let cand_sse = sse(&cand, y, ts);
        if cand_sse.is_finite() && cand_sse < best_sse {
            if best_sse - cand_sse < 1e-6 * best_sse {
                stall += 1;
            } else {
                stall = 0;
            }
            p = cand;
            best = cand;
            best_sse = cand_sse;
            lambda = (lambda * 0.3).max(1e-12);
            if best_sse < 1e-24 || stall >= 4 {
                break;
            }
        } else {
            lambda = (lambda * 10.0).min(1e12);
            if lambda >= 1e12 {
                break;
            }
        }
    }
    (best, best_sse)
}

fn finalize(best: [f64; 4], y: &[f64], ts: &[f64]) -> Option<(DoubleExp, f64)> {
    let model =
        DoubleExp { a: best[0] as f32, b: best[1] as f32, c: best[2] as f32, d: best[3] as f32 };
    // recompute SSE with f32-truncated params (what the wire carries)
    let sse_f32: f64 = y
        .iter()
        .zip(ts)
        .map(|(&yi, &t)| (yi - model.eval_t(t) as f64).powi(2))
        .sum();
    Some((model, sse_f32))
}

fn decay_guess(y: &[f64], ts: &[f64]) -> f64 {
    // crude log-slope between the first and middle positive samples
    let n = y.len();
    let m = n / 2;
    if y[0].abs() > 1e-12 && y[m].abs() > 1e-12 && (y[0] > 0.0) == (y[m] > 0.0) {
        let ratio: f64 = y[m] / y[0];
        if ratio > 0.0 {
            return (ratio.ln() / (ts[m] - ts[0])).clamp(-60.0, 60.0);
        }
    }
    -1.0
}

fn sse(p: &[f64; 4], y: &[f64], ts: &[f64]) -> f64 {
    y.iter()
        .zip(ts)
        .map(|(&yi, &t)| {
            let f = p[0] * (p[1] * t).exp() + p[2] * (p[3] * t).exp();
            (yi - f).powi(2)
        })
        .sum()
}

/// Build JᵀJ (4x4) and Jᵀr for the residual r = y - f(p).
fn normal_eqs(p: &[f64; 4], y: &[f64], ts: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let mut jtj = vec![0.0f64; 16];
    let mut jtr = vec![0.0f64; 4];
    for (&yi, &t) in y.iter().zip(ts) {
        let e1 = (p[1] * t).exp();
        let e2 = (p[3] * t).exp();
        let f = p[0] * e1 + p[2] * e2;
        let r = yi - f;
        // df/da, df/db, df/dc, df/dd
        let j = [e1, p[0] * t * e1, e2, p[2] * t * e2];
        for a in 0..4 {
            for b in a..4 {
                jtj[a * 4 + b] += j[a] * j[b];
            }
            jtr[a] += j[a] * r;
        }
    }
    for a in 0..4 {
        for b in 0..a {
            jtj[a * 4 + b] = jtj[b * 4 + a];
        }
    }
    (jtj, jtr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn recovers_exact_double_exponential() {
        let n = 200;
        let truth = DoubleExp { a: 2.0, b: -3.0, c: 0.5, d: -0.2 };
        let y: Vec<f64> =
            (0..n).map(|i| truth.eval_t(i as f64 / (n - 1) as f64) as f64).collect();
        let (fit, sse) = fit_double_exp(&y, 200).unwrap();
        assert!(sse < 1e-8, "sse {sse}, fit {fit:?}");
    }

    #[test]
    fn fits_sorted_gradient_shape() {
        // descending heavy-tailed curve: like sorted top-r magnitudes
        let mut rng = Rng::new(70);
        let n = 1000;
        let y: Vec<f64> = (0..n)
            .map(|i| {
                let t = i as f64 / (n - 1) as f64;
                2.0 * (-5.0 * t).exp() + 0.05 * (-0.5 * t).exp()
                    + rng.next_gaussian() * 1e-4
            })
            .collect();
        let (fit, sse) = fit_double_exp(&y, 100).unwrap();
        let norm: f64 = y.iter().map(|v| v * v).sum();
        assert!(sse / norm < 1e-3, "relative sse {}", sse / norm);
        // spot check monotone-ish decay
        assert!(fit.eval(0, n) > fit.eval(n - 1, n));
    }

    #[test]
    fn too_few_points_rejected() {
        assert!(fit_double_exp(&[1.0, 2.0, 3.0], 10).is_none());
    }

    #[test]
    fn handles_negative_curves() {
        // negative-value segment (sorted ascending negatives)
        let n = 100;
        let y: Vec<f64> = (0..n)
            .map(|i| {
                let t = i as f64 / (n - 1) as f64;
                -0.01 - 1.5 * (3.0 * (t - 1.0)).exp()
            })
            .collect();
        let (_, sse) = fit_double_exp(&y, 150).unwrap();
        let norm: f64 = y.iter().map(|v| v * v).sum();
        assert!(sse / norm < 0.05, "relative sse {}", sse / norm);
    }
}
