//! Hash-function kit for the Bloom-filter index codec.
//!
//! The paper uses k independent hash functions over the finite index
//! domain `[d]`, realized on GPU as a precomputed lookup table ℍ[d,k].
//! On this testbed we compute the hashes arithmetically: each hash is the
//! SplitMix64 finalizer applied to `index ⊕ seed_i`, reduced to `[0, m)`
//! by the multiply-shift (Lemire) map. This preserves the independence
//! assumption of Lemma 2 and is branch-free on the hot path.

use super::prng::{mix64, SplitMix64};

/// A family of k hash functions mapping u64 -> [0, m).
#[derive(Clone, Debug)]
pub struct HashFamily {
    seeds: Vec<u64>,
    m: u64,
}

impl HashFamily {
    /// `k` functions onto `[0, m)`, derived from `master_seed`.
    pub fn new(k: usize, m: u64, master_seed: u64) -> Self {
        assert!(m > 0, "hash range must be nonzero");
        let mut sm = SplitMix64::new(master_seed);
        let seeds = (0..k).map(|_| sm.next_u64()).collect();
        Self { seeds, m }
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.seeds.len()
    }

    #[inline]
    pub fn range(&self) -> u64 {
        self.m
    }

    /// Hash `x` with function `i`.
    #[inline(always)]
    pub fn hash(&self, i: usize, x: u64) -> u64 {
        let h = mix64(x ^ self.seeds[i]);
        // multiply-shift reduction, avoids the modulo bias + div latency
        (((h as u128) * (self.m as u128)) >> 64) as u64
    }

    /// All k hashes of `x` into a caller-provided buffer.
    #[inline]
    pub fn hash_all(&self, x: u64, out: &mut [u64]) {
        debug_assert_eq!(out.len(), self.k());
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = self.hash(i, x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let f1 = HashFamily::new(3, 1000, 42);
        let f2 = HashFamily::new(3, 1000, 42);
        for x in 0..100u64 {
            for i in 0..3 {
                assert_eq!(f1.hash(i, x), f2.hash(i, x));
            }
        }
    }

    #[test]
    fn in_range_and_spread() {
        let m = 977;
        let f = HashFamily::new(4, m, 7);
        let mut counts = vec![0usize; m as usize];
        for x in 0..50_000u64 {
            for i in 0..4 {
                let h = f.hash(i, x);
                assert!(h < m);
                counts[h as usize] += 1;
            }
        }
        // every bucket hit at least once, max not wildly off uniform
        let expected = 200_000.0 / m as f64;
        for (b, &c) in counts.iter().enumerate() {
            assert!(c > 0, "bucket {b} never hit");
            assert!((c as f64) < expected * 2.0, "bucket {b} count {c}");
        }
    }

    #[test]
    fn functions_are_distinct() {
        let f = HashFamily::new(5, 1 << 30, 9);
        // two distinct functions should disagree on most inputs
        for i in 0..5 {
            for j in (i + 1)..5 {
                let agree = (0..1000u64).filter(|&x| f.hash(i, x) == f.hash(j, x)).count();
                assert!(agree < 5, "h{i} vs h{j} agree {agree}/1000");
            }
        }
    }

    #[test]
    fn pairwise_collision_rate_near_uniform() {
        // For m buckets and n items, expected pairwise collisions under
        // uniform hashing ~= C(n,2)/m. Check within 3x.
        let m = 1u64 << 16;
        let f = HashFamily::new(1, m, 11);
        let n = 10_000u64;
        let mut set = std::collections::HashSet::new();
        let mut coll = 0usize;
        for x in 0..n {
            if !set.insert(f.hash(0, x)) {
                coll += 1;
            }
        }
        let expected = (n * (n - 1)) as f64 / 2.0 / m as f64;
        assert!((coll as f64) < expected * 3.0 + 10.0, "collisions {coll}, expected ~{expected}");
    }
}
