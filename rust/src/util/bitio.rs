//! Bit-granular writer/reader over byte buffers.
//!
//! This is the shared substrate for RLE, Huffman, Elias and QSGD codecs:
//! everything on the wire is bit-packed. Bits are written LSB-first within
//! a little-endian 64-bit accumulator, which keeps the hot append path to
//! a shift+or and an occasional 8-byte store.

/// Append-only bit writer.
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    acc: u64,
    /// number of valid bits currently in `acc` (0..64)
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bytes: usize) -> Self {
        Self { buf: Vec::with_capacity(bytes), acc: 0, nbits: 0 }
    }

    /// Write the low `n` bits of `v` (n <= 64).
    #[inline]
    pub fn write_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        debug_assert!(n == 64 || v < (1u64 << n), "value {v} does not fit in {n} bits");
        if n == 0 {
            return;
        }
        let free = 64 - self.nbits;
        if n <= free {
            self.acc |= v << self.nbits;
            self.nbits += n;
            if self.nbits == 64 {
                self.flush_acc();
            }
        } else {
            // split across the accumulator boundary
            self.acc |= v << self.nbits;
            let lo = free;
            self.nbits = 64;
            self.flush_acc();
            self.acc = v >> lo;
            self.nbits = n - lo;
        }
    }

    #[inline]
    pub fn write_bit(&mut self, b: bool) {
        self.write_bits(b as u64, 1);
    }

    /// Write `n` consecutive identical bits (used by bit-level RLE).
    pub fn write_run(&mut self, bit: bool, mut n: u64) {
        let word = if bit { u64::MAX } else { 0 };
        while n >= 64 {
            self.write_bits(word, 64);
            n -= 64;
        }
        if n > 0 {
            self.write_bits(word & ((1u64 << n) - 1), n as u32);
        }
    }

    #[inline]
    fn flush_acc(&mut self) {
        self.buf.extend_from_slice(&self.acc.to_le_bytes());
        self.acc = 0;
        self.nbits = 0;
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> u64 {
        (self.buf.len() as u64) * 8 + self.nbits as u64
    }

    /// Finish and return the byte buffer (final partial byte zero-padded).
    pub fn finish(mut self) -> Vec<u8> {
        let extra_bytes = self.nbits.div_ceil(8) as usize;
        let bytes = self.acc.to_le_bytes();
        self.buf.extend_from_slice(&bytes[..extra_bytes]);
        self.buf
    }
}

/// Sequential bit reader mirroring [`BitWriter`].
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// absolute bit cursor
    pos: u64,
}

#[derive(Debug, PartialEq, Eq)]
pub struct BitUnderflow {
    pub need: u32,
    pub pos: u64,
    pub have: u64,
}

impl std::fmt::Display for BitUnderflow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "bit stream exhausted: need {} bits at position {}, have {}",
            self.need, self.pos, self.have
        )
    }
}

impl std::error::Error for BitUnderflow {}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn bits_remaining(&self) -> u64 {
        (self.buf.len() as u64) * 8 - self.pos
    }

    pub fn bit_pos(&self) -> u64 {
        self.pos
    }

    /// Read `n` bits (n <= 64) as the low bits of the result.
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Result<u64, BitUnderflow> {
        debug_assert!(n <= 64);
        if n == 0 {
            return Ok(0);
        }
        if self.bits_remaining() < n as u64 {
            return Err(BitUnderflow { need: n, pos: self.pos, have: self.bits_remaining() });
        }
        let mut out = 0u64;
        let mut got = 0u32;
        while got < n {
            let byte_idx = (self.pos / 8) as usize;
            let bit_off = (self.pos % 8) as u32;
            let avail = 8 - bit_off;
            let take = (n - got).min(avail);
            let chunk = ((self.buf[byte_idx] as u64) >> bit_off) & ((1u64 << take) - 1);
            out |= chunk << got;
            got += take;
            self.pos += take as u64;
        }
        Ok(out)
    }

    #[inline]
    pub fn read_bit(&mut self) -> Result<bool, BitUnderflow> {
        Ok(self.read_bits(1)? == 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_simple() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_bits(0xFFFF, 16);
        w.write_bit(false);
        w.write_bits(42, 7);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.read_bits(3).unwrap(), 0b101);
        assert_eq!(r.read_bits(16).unwrap(), 0xFFFF);
        assert!(!r.read_bit().unwrap());
        assert_eq!(r.read_bits(7).unwrap(), 42);
    }

    #[test]
    fn roundtrip_randomized_widths() {
        // property: any sequence of (value,width) writes reads back exactly
        let mut rng = Rng::new(0xBEEF);
        for case in 0..50 {
            let mut w = BitWriter::new();
            let mut expect = Vec::new();
            for _ in 0..500 {
                let n = 1 + (rng.below(64)) as u32;
                let v = if n == 64 { rng.next_u64() } else { rng.next_u64() & ((1u64 << n) - 1) };
                w.write_bits(v, n);
                expect.push((v, n));
            }
            let total = w.bit_len();
            let buf = w.finish();
            assert!(buf.len() as u64 * 8 >= total);
            let mut r = BitReader::new(&buf);
            for &(v, n) in &expect {
                assert_eq!(r.read_bits(n).unwrap(), v, "case {case}");
            }
        }
    }

    #[test]
    fn write_run_roundtrip() {
        let mut w = BitWriter::new();
        w.write_run(true, 3);
        w.write_run(false, 130);
        w.write_run(true, 64);
        w.write_run(false, 1);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for _ in 0..3 {
            assert!(r.read_bit().unwrap());
        }
        for _ in 0..130 {
            assert!(!r.read_bit().unwrap());
        }
        for _ in 0..64 {
            assert!(r.read_bit().unwrap());
        }
        assert!(!r.read_bit().unwrap());
    }

    #[test]
    fn underflow_reported() {
        let mut w = BitWriter::new();
        w.write_bits(0b11, 2);
        let buf = w.finish(); // one byte, 8 bits available after padding
        let mut r = BitReader::new(&buf);
        assert_eq!(r.read_bits(8).unwrap(), 0b11);
        assert!(r.read_bits(1).is_err());
    }

    #[test]
    fn bit_len_accounting() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.write_bits(1, 1);
        assert_eq!(w.bit_len(), 1);
        w.write_bits(0, 63);
        assert_eq!(w.bit_len(), 64);
        w.write_bits(7, 3);
        assert_eq!(w.bit_len(), 67);
        assert_eq!(w.finish().len(), 9);
    }
}
