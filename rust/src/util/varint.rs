//! LEB128 variable-length integers and zig-zag mapping.
//!
//! Used by the delta-varint index codec and the container format headers.

/// Append `v` as LEB128 to `out`.
#[inline]
pub fn write_u64(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Decode one LEB128 integer starting at `buf[*pos]`, advancing `pos`.
#[inline]
pub fn read_u64(buf: &[u8], pos: &mut usize) -> Result<u64, VarintError> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *buf.get(*pos).ok_or(VarintError::Truncated)?;
        *pos += 1;
        if shift == 63 && b > 1 {
            return Err(VarintError::Overflow);
        }
        v |= ((b & 0x7F) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift > 63 {
            return Err(VarintError::Overflow);
        }
    }
}

#[derive(Debug, PartialEq, Eq)]
pub enum VarintError {
    Truncated,
    Overflow,
}

impl std::fmt::Display for VarintError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            VarintError::Truncated => write!(f, "varint truncated"),
            VarintError::Overflow => write!(f, "varint overflows u64"),
        }
    }
}

impl std::error::Error for VarintError {}

/// Zig-zag encode a signed value so small magnitudes get small codes.
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag`].
#[inline]
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

/// Encoded length in bytes without materializing.
#[inline]
pub fn encoded_len(v: u64) -> usize {
    if v == 0 {
        1
    } else {
        (64 - v.leading_zeros()).div_ceil(7) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_edges() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            write_u64(&mut buf, v);
            assert_eq!(buf.len(), encoded_len(v));
            let mut pos = 0;
            assert_eq!(read_u64(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn roundtrip_random() {
        let mut rng = Rng::new(1);
        let mut buf = Vec::new();
        let mut vals = Vec::new();
        for _ in 0..10_000 {
            // mix of magnitudes
            let shift = rng.below(64) as u32;
            let v = rng.next_u64() >> shift;
            write_u64(&mut buf, v);
            vals.push(v);
        }
        let mut pos = 0;
        for v in vals {
            assert_eq!(read_u64(&buf, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn truncated_and_overflow() {
        let mut pos = 0;
        assert_eq!(read_u64(&[0x80], &mut pos), Err(VarintError::Truncated));
        // 11 continuation bytes overflow u64
        let bad = [0xFFu8; 11];
        let mut pos = 0;
        assert_eq!(read_u64(&bad, &mut pos), Err(VarintError::Overflow));
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [0i64, 1, -1, 2, -2, i64::MAX, i64::MIN, 123456, -987654] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
    }
}
