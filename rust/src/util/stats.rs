//! Small statistics helpers shared by metrics and the bench harness.

/// Online mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for n < 2.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Percentile with linear interpolation; `q` in [0,1]. Sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Percentile on pre-sorted data.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 0.5)
}

/// Median absolute deviation (robust spread), scaled for normal consistency.
pub fn mad(xs: &[f64]) -> f64 {
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    1.4826 * median(&dev)
}

/// `ℓ2` norm squared of an f32 slice (f64 accumulator).
pub fn l2_sq(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum()
}

/// Relative `ℓ2` error ‖a−b‖/‖a‖ (0 if both empty/zero).
pub fn rel_l2_err(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum();
    let den = l2_sq(a);
    if den == 0.0 {
        num.sqrt()
    } else {
        (num / den).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let mean = 4.0;
        let var: f64 = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / 4.0;
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert_eq!(percentile(&[5.0], 0.7), 5.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&xs) < 1.0);
    }

    #[test]
    fn rel_err() {
        let a = [1.0f32, 0.0, 0.0];
        let b = [0.0f32, 0.0, 0.0];
        assert!((rel_l2_err(&a, &b) - 1.0).abs() < 1e-6);
        assert_eq!(rel_l2_err(&a, &a), 0.0);
    }
}
