//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with robust statistics
//! (median / MAD), throughput reporting, and markdown table emission used
//! by the paper-figure benches. Benches opt out of the libtest harness
//! (`harness = false`) and drive this directly from `main`.

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::{mad, median, percentile};
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Schema version stamped into every `BENCH_*.json` (see also
/// [`crate::obs::export::TRACE_SCHEMA_VERSION`] for `TRACE_*.json`).
/// Bump when the top-level shape of the summary changes; version 1 is
/// the pre-versioned shape (no `schema_version` key at all).
pub const SCHEMA_VERSION: u32 = 2;

/// One measured benchmark.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// per-iteration wall time, seconds
    pub samples: Vec<f64>,
    /// optional bytes processed per iteration (for GB/s reporting)
    pub bytes_per_iter: Option<u64>,
    /// optional items processed per iteration (for Melem/s reporting)
    pub items_per_iter: Option<u64>,
}

impl Measurement {
    pub fn median_s(&self) -> f64 {
        median(&self.samples)
    }

    pub fn mad_s(&self) -> f64 {
        mad(&self.samples)
    }

    pub fn p95_s(&self) -> f64 {
        percentile(&self.samples, 0.95)
    }

    pub fn throughput_gbps(&self) -> Option<f64> {
        self.bytes_per_iter.map(|b| b as f64 / self.median_s() / 1e9)
    }

    pub fn throughput_melems(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n as f64 / self.median_s() / 1e6)
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "{:<44} {:>12} ± {:>10}",
            self.name,
            fmt_duration(self.median_s()),
            fmt_duration(self.mad_s())
        );
        if let Some(g) = self.throughput_gbps() {
            s.push_str(&format!("  {g:>8.3} GB/s"));
        }
        if let Some(m) = self.throughput_melems() {
            s.push_str(&format!("  {m:>9.2} Melem/s"));
        }
        s
    }
}

pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Debug)]
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 5,
            max_iters: 10_000_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick profile for heavier macro benches (whole training runs).
    pub fn macro_bench() -> Self {
        Self {
            warmup: Duration::ZERO,
            measure: Duration::ZERO,
            min_iters: 1,
            max_iters: 1,
            results: Vec::new(),
        }
    }

    /// Time `f`, which performs ONE unit of work per call.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Measurement {
        self.run_with(name, None, None, &mut f)
    }

    /// Time with a bytes-per-iteration annotation (GB/s output).
    pub fn run_bytes<F: FnMut()>(&mut self, name: &str, bytes: u64, mut f: F) -> &Measurement {
        self.run_with(name, Some(bytes), None, &mut f)
    }

    /// Time with an items-per-iteration annotation (Melem/s output).
    pub fn run_items<F: FnMut()>(&mut self, name: &str, items: u64, mut f: F) -> &Measurement {
        self.run_with(name, None, Some(items), &mut f)
    }

    fn run_with(
        &mut self,
        name: &str,
        bytes: Option<u64>,
        items: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> &Measurement {
        // warmup
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        // choose batch size so each sample is ~1ms, bounding timer noise
        let probe = Instant::now();
        f();
        let once = probe.elapsed().as_secs_f64().max(1e-9);
        let batch = ((1e-3 / once).round() as usize).clamp(1, 1_000_000);

        let mut samples = Vec::new();
        let t1 = Instant::now();
        let mut iters = 0usize;
        while (t1.elapsed() < self.measure || samples.len() < self.min_iters)
            && iters < self.max_iters
        {
            let s = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(s.elapsed().as_secs_f64() / batch as f64);
            iters += batch;
        }
        let m = Measurement {
            name: name.to_string(),
            samples,
            bytes_per_iter: bytes,
            items_per_iter: items,
        };
        eprintln!("{}", m.summary());
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Record an externally measured value (for macro experiments where the
    /// "benchmark" is e.g. final accuracy or a modelled time).
    pub fn record(&mut self, name: &str, seconds: f64) {
        let m = Measurement {
            name: name.to_string(),
            samples: vec![seconds],
            bytes_per_iter: None,
            items_per_iter: None,
        };
        eprintln!("{}", m.summary());
        self.results.push(m);
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Markdown table builder for the paper-figure benches: each bench prints
/// the same rows/series the paper reports.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n### {}\n\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r));
        }
        out
    }

    /// Print to stdout (captured by `cargo bench ... | tee`).
    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Machine-readable bench summary: metadata plus rows of JSON objects,
/// written to `BENCH_<name>.json` at the repo root. The markdown
/// [`Table`]s are for humans; these files are the persisted perf
/// trajectory — CI uploads them as artifacts so bench results survive
/// the run instead of scrolling away in a log.
pub struct BenchSummary {
    name: String,
    meta: BTreeMap<String, Json>,
    rows: Vec<Json>,
}

impl BenchSummary {
    /// `name` must match the bench target (`BENCH_<name>.json`).
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), meta: BTreeMap::new(), rows: Vec::new() }
    }

    /// Prefix the artifact stem with a job identifier
    /// (`BENCH_<job>_<name>.json`), so two concurrent reduction-service
    /// tenants writing the same bench never clobber each other. The job
    /// also lands in the payload's metadata.
    pub fn for_job(mut self, job: &str) -> Self {
        self.name = format!("{job}_{}", self.name);
        self.meta.insert("job".into(), Json::Str(job.to_string()));
        self
    }

    /// Attach a top-level metadata field (sweep parameters, pass/fail
    /// counters, anything a trajectory plot wants without row parsing).
    pub fn set(&mut self, key: &str, value: Json) {
        self.meta.insert(key.to_string(), value);
    }

    /// Append one result row.
    pub fn row(&mut self, fields: &[(&str, Json)]) {
        let obj: BTreeMap<String, Json> =
            fields.iter().map(|(k, v)| (k.to_string(), v.clone())).collect();
        self.rows.push(Json::Obj(obj));
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let mut top = self.meta.clone();
        top.insert("schema_version".into(), Json::Num(SCHEMA_VERSION as f64));
        top.insert("bench".into(), Json::Str(self.name.clone()));
        top.insert("rows".into(), Json::Arr(self.rows.clone()));
        Json::Obj(top)
    }

    /// Write `BENCH_<name>.json` at the repo root (the parent of the
    /// cargo manifest directory) and return the path.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."));
        let path = root.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_string())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            ..Bench::default()
        };
        let mut acc = 0u64;
        let m = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(m.median_s() > 0.0);
        assert!(m.median_s() < 0.1);
        assert!(!m.samples.is_empty());
    }

    #[test]
    fn throughput_annotations() {
        let m = Measurement {
            name: "x".into(),
            samples: vec![0.001],
            bytes_per_iter: Some(1_000_000),
            items_per_iter: Some(1000),
        };
        assert!((m.throughput_gbps().unwrap() - 1.0).abs() < 1e-9);
        assert!((m.throughput_melems().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("Fig X", &["method", "volume", "acc"]);
        t.row(&["Top-r".into(), "0.01".into(), "90.1".into()]);
        t.row(&["BF-P2".into(), "0.0066".into(), "90.4".into()]);
        let r = t.render();
        assert!(r.contains("Fig X"));
        assert!(r.contains("| BF-P2"));
        assert_eq!(r.matches('\n').count(), 7);
    }

    #[test]
    fn bench_summary_roundtrips() {
        let mut s = BenchSummary::new("unit_test");
        assert!(s.is_empty());
        s.set("sweep", Json::Str("n x density".into()));
        s.row(&[("n", Json::Num(4.0)), ("schedule", Json::Str("gather_all".into()))]);
        s.row(&[("n", Json::Num(8.0)), ("schedule", Json::Str("ring".into()))]);
        assert_eq!(s.len(), 2);
        let parsed = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("unit_test"));
        assert_eq!(
            parsed.get("schema_version").unwrap().as_usize(),
            Some(SCHEMA_VERSION as usize)
        );
        assert_eq!(parsed.get("sweep").unwrap().as_str(), Some("n x density"));
        let rows = parsed.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].get("n").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn fmt_durations() {
        assert!(fmt_duration(5e-10).ends_with("ns"));
        assert!(fmt_duration(5e-5).ends_with("µs"));
        assert!(fmt_duration(5e-2).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with(" s"));
    }
}
