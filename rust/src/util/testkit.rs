//! Property-testing harness (proptest is unavailable offline).
//!
//! `forall` drives a closure with `n` deterministic random cases from a
//! seeded [`Rng`]; on failure it retries with progressively simpler
//! regenerated inputs (size shrinking by halving the generator budget)
//! and reports the failing seed so the case is reproducible.

use super::prng::Rng;

/// Generation budget passed to the case generator: `size` bounds the
/// magnitude/length of generated structures.
#[derive(Clone, Copy, Debug)]
pub struct Gen {
    pub seed: u64,
    pub size: usize,
}

/// Run `cases` random property checks. `gen` builds an input from an Rng
/// and a size budget; `prop` returns Err(description) on violation.
///
/// Panics with the seed and shrunk input description on failure.
pub fn forall<T, G, P>(name: &str, cases: usize, max_size: usize, mut generate: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let master = 0xD5EE_D000 ^ fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = master.wrapping_add(case as u64);
        // ramp size up over the run so early cases are small
        let size = 1 + (max_size.saturating_sub(1)) * case / cases.max(1);
        let mut rng = Rng::new(seed);
        let input = generate(&mut rng, size);
        if let Err(msg) = prop(&input) {
            // shrink: regenerate at smaller sizes with the same seed and
            // keep the smallest failing input
            let mut best: (usize, T, String) = (size, input, msg);
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Rng::new(seed);
                let candidate = generate(&mut rng, s);
                if let Err(m) = prop(&candidate) {
                    best = (s, candidate, m);
                }
                if s == 1 {
                    break;
                }
                s /= 2;
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, size {}):\n  {}\n  input: {:?}",
                best.0, best.2, best.1
            );
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Convenience: generate a random f32 vector with heavy-tailed magnitudes
/// (resembles gradient value distributions: many near-zero, few large).
pub fn gradient_like(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n)
        .map(|_| {
            let g = rng.next_gaussian() as f32;
            let scale = 10f32.powf((rng.next_f32() * 6.0) - 4.0); // 1e-4..1e2
            g * scale
        })
        .collect()
}

/// Convenience: random strictly-increasing u32 indices in [0, d).
pub fn sorted_support(rng: &mut Rng, d: usize, r: usize) -> Vec<u32> {
    let mut idx = rng.sample_indices(d, r.min(d));
    idx.sort_unstable();
    idx
}

/// Deterministic scenario corpus for fabric differential / determinism
/// tests and the scaling bench: the cross product of straggler
/// placement × link jitter × heterogeneous node links × a link flap,
/// all derived from `seed` so two calls with the same arguments build
/// byte-identical scenarios. `world` scales rank/node references so
/// the same corpus works from 2 to 10k ranks.
pub fn scenario_corpus(seed: u64, world: usize) -> Vec<crate::vfabric::Scenario> {
    use crate::vfabric::{LinkFlap, Scenario};
    let mut out = vec![Scenario::none(seed)];

    let mut straggled = Scenario::none(seed ^ 1);
    straggled.stragglers = vec![(0, 2.0), (world / 2, 1.5)];
    out.push(straggled);

    let mut jittery = Scenario::none(seed ^ 2);
    jittery.link_jitter = 0.25;
    out.push(jittery);

    let mut hetero = Scenario::none(seed ^ 3);
    hetero.node_mbps = vec![(0, 400.0), (1, 900.0)];
    out.push(hetero);

    let mut flappy = Scenario::none(seed ^ 4);
    flappy.link_flaps = vec![LinkFlap { node: 0, start_s: 0.0, end_s: 1e6, factor: 4.0 }];
    out.push(flappy);

    let mut stormy = Scenario::none(seed ^ 5);
    stormy.stragglers = vec![(world.saturating_sub(1), 1.7)];
    stormy.link_jitter = 0.1;
    stormy.node_mbps = vec![(0, 600.0)];
    stormy.link_flaps = vec![LinkFlap { node: 1, start_s: 0.0, end_s: 1e6, factor: 2.5 }];
    out.push(stormy);

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(
            "vec-len",
            50,
            100,
            |rng, size| {
                let n = rng.below(size as u64 + 1) as usize;
                vec![0u8; n]
            },
            |v| {
                if v.len() <= 100 {
                    Ok(())
                } else {
                    Err("too long".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'must-fail' failed")]
    fn forall_reports_failure() {
        forall(
            "must-fail",
            20,
            50,
            |rng, size| rng.below(size as u64 + 10),
            |&v| if v < 5 { Ok(()) } else { Err(format!("v={v} >= 5")) },
        );
    }

    #[test]
    fn scenario_corpus_is_deterministic_and_varied() {
        let a = scenario_corpus(7, 8);
        let b = scenario_corpus(7, 8);
        assert_eq!(a.len(), b.len());
        assert_eq!(a.len(), 6);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(format!("{x:?}"), format!("{y:?}"));
        }
        assert!(a.iter().any(|s| !s.stragglers.is_empty()));
        assert!(a.iter().any(|s| s.link_jitter > 0.0));
        assert!(a.iter().any(|s| !s.node_mbps.is_empty()));
        assert!(a.iter().any(|s| !s.link_flaps.is_empty()));
    }

    #[test]
    fn generators_shapes() {
        let mut rng = Rng::new(1);
        let g = gradient_like(&mut rng, 1000);
        assert_eq!(g.len(), 1000);
        assert!(g.iter().any(|&x| x.abs() > 1.0));
        assert!(g.iter().any(|&x| x.abs() < 1e-2));
        let s = sorted_support(&mut rng, 100, 30);
        assert_eq!(s.len(), 30);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        assert!(*s.last().unwrap() < 100);
    }
}
