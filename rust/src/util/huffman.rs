//! Canonical Huffman coding over byte symbols.
//!
//! Used by the Huffman index codec (paper §2: encode the byte planes of
//! gradient indices) and by SKCompress (Huffman over bucket ids and delta
//! key prefixes). The codec serializes only the code lengths (canonical
//! form), so the table costs ≤ 256 bytes on the wire; alternatively a
//! codec built from a *shared* model (e.g. "all indices 0..d-1") can skip
//! the table entirely, as the paper's implementation does.

use super::bitio::{BitReader, BitWriter};

const MAX_LEN: u32 = 32;

/// A canonical Huffman code over symbols `0..=255`.
#[derive(Clone, Debug)]
pub struct Huffman {
    /// code length per symbol (0 = unused)
    lens: [u8; 256],
    /// canonical code per symbol (MSB-first, `lens[s]` bits)
    codes: [u32; 256],
    /// decoding: sorted (len, symbol) plus per-length first-code tables
    first_code: [u32; 33],
    first_index: [u32; 33],
    count: [u32; 33],
    sorted_syms: Vec<u8>,
}

#[derive(Debug, PartialEq, Eq)]
pub enum HuffmanError {
    Empty,
    BadTable,
    Underflow,
    BadCode,
}

impl std::fmt::Display for HuffmanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let msg = match self {
            HuffmanError::Empty => "cannot build a code over zero symbols",
            HuffmanError::BadTable => "invalid code length table",
            HuffmanError::Underflow => "bit stream exhausted",
            HuffmanError::BadCode => "invalid code in stream",
        };
        f.write_str(msg)
    }
}

impl std::error::Error for HuffmanError {}

impl Huffman {
    /// Build from symbol frequencies (zeros allowed). Code lengths are
    /// limited to `MAX_LEN` via frequency clamping (package-merge is
    /// overkill at 256 symbols; clamping heavy tails suffices and keeps
    /// optimality within a fraction of a percent).
    pub fn from_freqs(freqs: &[u64; 256]) -> Result<Self, HuffmanError> {
        let used = freqs.iter().filter(|&&f| f > 0).count();
        if used == 0 {
            return Err(HuffmanError::Empty);
        }
        let mut lens = [0u8; 256];
        if used == 1 {
            // single symbol: 1-bit code by convention
            let s = freqs.iter().position(|&f| f > 0).unwrap();
            lens[s] = 1;
            return Self::from_lens(lens);
        }

        // Heap-free O(n log n) Huffman on sorted frequencies (n = 256).
        #[derive(Clone, Copy)]
        struct Node {
            freq: u64,
            // -1..=-256 leaf (symbol = -id-1); >=0 internal index
            left: i32,
            right: i32,
        }
        let mut nodes: Vec<Node> = Vec::with_capacity(512);
        let mut leaves: Vec<(u64, usize)> =
            freqs.iter().enumerate().filter(|(_, &f)| f > 0).map(|(s, &f)| (f, s)).collect();
        leaves.sort_unstable();
        // two queues: sorted leaves + FIFO of merged nodes (freqs ascending)
        let mut li = 0usize;
        let mut merged: std::collections::VecDeque<usize> = Default::default();
        let take_min = |li: &mut usize,
                        merged: &mut std::collections::VecDeque<usize>,
                        nodes: &mut Vec<Node>,
                        leaves: &[(u64, usize)]|
         -> i32 {
            let leaf_f = leaves.get(*li).map(|&(f, _)| f);
            let node_f = merged.front().map(|&i| nodes[i].freq);
            match (leaf_f, node_f) {
                (Some(lf), Some(nf)) if lf <= nf => {
                    let s = leaves[*li].1;
                    *li += 1;
                    -(s as i32) - 1
                }
                (Some(_), None) => {
                    let s = leaves[*li].1;
                    *li += 1;
                    -(s as i32) - 1
                }
                (_, Some(_)) => merged.pop_front().unwrap() as i32,
                (None, None) => unreachable!(),
            }
        };
        let total_leaves = leaves.len();
        for _ in 0..total_leaves - 1 {
            let a = take_min(&mut li, &mut merged, &mut nodes, &leaves);
            let b = take_min(&mut li, &mut merged, &mut nodes, &leaves);
            let fa = if a < 0 { leaves_freq(&leaves, a) } else { nodes[a as usize].freq };
            let fb = if b < 0 { leaves_freq(&leaves, b) } else { nodes[b as usize].freq };
            nodes.push(Node { freq: fa + fb, left: a, right: b });
            merged.push_back(nodes.len() - 1);
        }
        fn leaves_freq(leaves: &[(u64, usize)], id: i32) -> u64 {
            let sym = (-id - 1) as usize;
            leaves.iter().find(|&&(_, s)| s == sym).map(|&(f, _)| f).unwrap()
        }
        // depth-assign
        let root = nodes.len() - 1;
        let mut stack = vec![(root as i32, 0u32)];
        while let Some((id, d)) = stack.pop() {
            if id < 0 {
                let sym = (-id - 1) as usize;
                lens[sym] = d.clamp(1, MAX_LEN) as u8;
            } else {
                let n = nodes[id as usize];
                stack.push((n.left, d + 1));
                stack.push((n.right, d + 1));
            }
        }
        // if clamping broke Kraft, rebuild with flattened freqs
        if kraft(&lens) > 1.0 + 1e-12 {
            let mut flat = *freqs;
            for f in flat.iter_mut() {
                if *f > 0 {
                    *f = 1 + (*f >> 20);
                }
            }
            return Self::from_freqs(&flat);
        }
        Self::from_lens(lens)
    }

    /// Build from an explicit code-length table (canonical reconstruction —
    /// the deserialization path).
    pub fn from_lens(lens: [u8; 256]) -> Result<Self, HuffmanError> {
        let used = lens.iter().filter(|&&l| l > 0).count();
        if used == 0 {
            return Err(HuffmanError::Empty);
        }
        let k = kraft(&lens);
        // allow the degenerate single-symbol code (kraft = 0.5)
        if k > 1.0 + 1e-12 {
            return Err(HuffmanError::BadTable);
        }
        // canonical assignment: sort by (len, symbol)
        let mut sorted: Vec<u8> = (0..=255u8).filter(|&s| lens[s as usize] > 0).collect();
        sorted.sort_by_key(|&s| (lens[s as usize], s));

        let mut codes = [0u32; 256];
        let mut first_code = [0u32; 33];
        let mut first_index = [0u32; 33];
        let mut count = [0u32; 33];
        for &s in &sorted {
            count[lens[s as usize] as usize] += 1;
        }
        let mut code = 0u32;
        let mut idx = 0u32;
        for len in 1..=MAX_LEN as usize {
            first_code[len] = code;
            first_index[len] = idx;
            code = (code + count[len]) << 1;
            idx += count[len];
        }
        {
            let mut next = first_code;
            for &s in &sorted {
                let l = lens[s as usize] as usize;
                codes[s as usize] = next[l];
                next[l] += 1;
            }
        }
        Ok(Self { lens, codes, first_code, first_index, count, sorted_syms: sorted })
    }

    /// Serialized table: 256 bytes of code lengths.
    pub fn table_bytes(&self) -> [u8; 256] {
        self.lens
    }

    #[inline]
    pub fn encode_symbol(&self, w: &mut BitWriter, sym: u8) {
        let l = self.lens[sym as usize] as u32;
        debug_assert!(l > 0, "symbol {sym} not in code");
        let c = self.codes[sym as usize];
        // MSB-first emission
        for i in (0..l).rev() {
            w.write_bit((c >> i) & 1 == 1);
        }
    }

    #[inline]
    pub fn decode_symbol(&self, r: &mut BitReader) -> Result<u8, HuffmanError> {
        let mut code = 0u32;
        for len in 1..=MAX_LEN as usize {
            code = (code << 1) | r.read_bit().map_err(|_| HuffmanError::Underflow)? as u32;
            let cnt = self.count[len];
            if cnt > 0 && code >= self.first_code[len] && code < self.first_code[len] + cnt {
                let idx = self.first_index[len] + (code - self.first_code[len]);
                return Ok(self.sorted_syms[idx as usize]);
            }
        }
        Err(HuffmanError::BadCode)
    }


    /// Encode a byte slice; returns the bit stream.
    pub fn encode(&self, data: &[u8]) -> Vec<u8> {
        let mut w = BitWriter::with_capacity(data.len());
        for &b in data {
            self.encode_symbol(&mut w, b);
        }
        w.finish()
    }

    /// Decode exactly `n` symbols.
    pub fn decode(&self, bits: &[u8], n: usize) -> Result<Vec<u8>, HuffmanError> {
        let mut r = BitReader::new(bits);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.decode_symbol(&mut r)?);
        }
        Ok(out)
    }

    /// Expected bits/symbol under `freqs` (cost model for codec selection).
    pub fn expected_bits(&self, freqs: &[u64; 256]) -> f64 {
        let total: u64 = freqs.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let mut bits = 0.0;
        for s in 0..256 {
            if freqs[s] > 0 {
                bits += freqs[s] as f64 * self.lens[s] as f64;
            }
        }
        bits / total as f64
    }
}

fn kraft(lens: &[u8; 256]) -> f64 {
    lens.iter().filter(|&&l| l > 0).map(|&l| 0.5f64.powi(l as i32)).sum()
}

/// Count byte frequencies.
pub fn byte_freqs(data: &[u8]) -> [u64; 256] {
    let mut f = [0u64; 256];
    for &b in data {
        f[b as usize] += 1;
    }
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn roundtrip(data: &[u8]) {
        let freqs = byte_freqs(data);
        let h = Huffman::from_freqs(&freqs).unwrap();
        let enc = h.encode(data);
        let dec = h.decode(&enc, data.len()).unwrap();
        assert_eq!(dec, data);
        // canonical reconstruction from lengths must decode identically
        let h2 = Huffman::from_lens(h.table_bytes()).unwrap();
        let dec2 = h2.decode(&enc, data.len()).unwrap();
        assert_eq!(dec2, data);
    }

    #[test]
    fn paper_example() {
        // "aaaabaacaabaa" from §2 — 'a' must get a 1-bit code
        let data = b"aaaabaacaabaa";
        let freqs = byte_freqs(data);
        let h = Huffman::from_freqs(&freqs).unwrap();
        assert_eq!(h.lens[b'a' as usize], 1);
        assert_eq!(h.lens[b'b' as usize], 2);
        assert_eq!(h.lens[b'c' as usize], 2);
        let enc = h.encode(data);
        // paper: 16 bits total -> 2 bytes
        assert_eq!(enc.len(), 2);
        roundtrip(data);
    }

    #[test]
    fn single_symbol() {
        roundtrip(&[7u8; 100]);
    }

    #[test]
    fn two_symbols() {
        roundtrip(b"abababbbaaab");
    }

    #[test]
    fn all_bytes_uniform() {
        let data: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        roundtrip(&data);
    }

    #[test]
    fn skewed_random() {
        let mut rng = Rng::new(10);
        // zipf-ish skew, like index byte planes
        let data: Vec<u8> =
            (0..20_000).map(|_| ((rng.next_f64().powi(4) * 255.0) as u8)).collect();
        let freqs = byte_freqs(&data);
        let h = Huffman::from_freqs(&freqs).unwrap();
        let enc = h.encode(&data);
        assert!(enc.len() < data.len(), "skewed data must compress");
        roundtrip(&data);
    }

    #[test]
    fn compression_close_to_entropy() {
        let mut rng = Rng::new(12);
        let data: Vec<u8> = (0..50_000)
            .map(|_| {
                let r = rng.next_f64();
                if r < 0.7 {
                    0
                } else if r < 0.9 {
                    1
                } else {
                    (rng.below(254) + 2) as u8
                }
            })
            .collect();
        let freqs = byte_freqs(&data);
        let total: u64 = freqs.iter().sum();
        let entropy: f64 = freqs
            .iter()
            .filter(|&&f| f > 0)
            .map(|&f| {
                let p = f as f64 / total as f64;
                -p * p.log2()
            })
            .sum();
        let h = Huffman::from_freqs(&freqs).unwrap();
        let got = h.expected_bits(&freqs);
        assert!(got >= entropy - 1e-9);
        assert!(got <= entropy + 1.0, "huffman within 1 bit of entropy: {got} vs {entropy}");
        roundtrip(&data);
    }

    #[test]
    fn bad_table_rejected() {
        let mut lens = [0u8; 256];
        lens[0] = 1;
        lens[1] = 1;
        lens[2] = 1; // kraft = 1.5
        assert_eq!(Huffman::from_lens(lens).unwrap_err(), HuffmanError::BadTable);
        assert_eq!(Huffman::from_freqs(&[0u64; 256]).unwrap_err(), HuffmanError::Empty);
    }
}
