//! Minimal JSON parser + writer (no serde offline).
//!
//! Covers the artifact manifests written by `python/compile/aot.py` and the
//! metrics reports: objects, arrays, strings (with escapes), numbers,
//! booleans, null. Numbers are parsed as f64; integer accessors check
//! exactness.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as usize)
            }
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Append the canonical JSON string literal for `s` — surrounding quotes
/// included; `"`, `\`, and all control characters below 0x20 escaped.
/// This is the **only** escaper in the repo: every artifact writer
/// (`BENCH_*` via [`crate::util::benchkit`], `TRACE_*` and `HEALTH_*` via
/// [`Json::write`], plus [`crate::obs::export::json_escape`]) emits
/// strings through it, so escaping bugs can only exist in one place.
pub fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // no surrogate-pair support; manifests are ASCII
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let s = r#"{
            "name": "mlp_train_step",
            "params": [
                {"name": "w1", "shape": [3072, 128], "numel": 393216},
                {"name": "b1", "shape": [128], "numel": 128}
            ],
            "batch": 64,
            "loss_first": true
        }"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "mlp_train_step");
        let params = j.get("params").unwrap().as_arr().unwrap();
        assert_eq!(params.len(), 2);
        assert_eq!(params[0].get("numel").unwrap().as_usize().unwrap(), 393216);
        assert_eq!(
            params[0].get("shape").unwrap().as_arr().unwrap()[1].as_usize().unwrap(),
            128
        );
        assert!(j.get("loss_first").unwrap().as_bool().unwrap());
    }

    #[test]
    fn roundtrip() {
        let s = r#"{"a":[1,2.5,-3],"b":"x\ny","c":null,"d":false}"#;
        let j = Json::parse(s).unwrap();
        let out = j.to_string();
        let j2 = Json::parse(&out).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-0.5e2").unwrap().as_f64().unwrap(), -50.0);
        assert_eq!(Json::parse("18446744073709551615").unwrap().as_f64().unwrap(), 1.8446744073709552e19);
        assert_eq!(Json::parse("3").unwrap().as_usize().unwrap(), 3);
        assert!(Json::parse("3.5").unwrap().as_usize().is_none());
    }
}
