//! Deterministic pseudo-random number generation.
//!
//! No `rand` crate offline, so we carry our own small, well-known
//! generators: SplitMix64 (seeding / hashing) and Xoshiro256** (bulk
//! stream). Both are reproducible across platforms, which the experiment
//! harness relies on for paper-figure regeneration.

/// SplitMix64 — tiny, high-quality 64-bit generator; also used as the
/// finalizer in the Bloom-filter hash kit.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        mix64(self.state)
    }
}

/// SplitMix64 finalizer: a strong 64-bit mixing function.
#[inline(always)]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256** — the workhorse stream generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`, 53-bit precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (bound.wrapping_neg() % bound) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal via Box–Muller (polar form avoided for determinism).
    pub fn next_gaussian(&mut self) -> f64 {
        // avoid log(0)
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct elements from `0..n` (Floyd's algorithm for
    /// small k, partial shuffle otherwise). Result order is random.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        if k * 4 >= n {
            let mut all: Vec<u32> = (0..n as u32).collect();
            // partial Fisher–Yates: first k slots
            for i in 0..k {
                let j = i + self.below((n - i) as u64) as usize;
                all.swap(i, j);
            }
            all.truncate(k);
            all
        } else {
            use std::collections::HashSet;
            let mut seen = HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.below(j as u64 + 1) as usize;
                let pick = if seen.insert(t as u32) { t as u32 } else { j as u32 };
                if pick as usize == j {
                    seen.insert(j as u32);
                }
                out.push(pick);
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 (from the public-domain
        // splitmix64.c by Sebastiano Vigna).
        let mut sm = SplitMix64::new(1234567);
        let expected = [
            6457827717110365317u64,
            3203168211198807973,
            9817491932198370423,
            4593380528125082431,
            16408922859458223821,
        ];
        for &e in &expected {
            assert_eq!(sm.next_u64(), e);
        }
    }

    #[test]
    fn xoshiro_determinism_and_spread() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        let same = (0..100).filter(|_| a.next_u64() == c.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_and_complete() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(100usize, 10usize), (100, 90), (1000, 3), (50, 50), (1, 1), (10, 0)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates for n={n} k={k}");
            assert!(sorted.iter().all(|&i| (i as usize) < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..1000).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..1000).collect::<Vec<_>>());
        assert_ne!(v, (0..1000).collect::<Vec<_>>());
    }
}
