//! TOML-subset parser for experiment configuration files.
//!
//! Supports the subset the config system needs: `[section]` and
//! `[section.sub]` headers, `key = value` with strings, integers, floats,
//! booleans, and flat arrays; `#` comments. Dotted keys inside sections
//! are flattened to `section.sub.key` paths.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    /// Float accessor that also accepts integers (common in configs).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Flat `section.key -> value` map.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    map: BTreeMap<String, TomlValue>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<Self, TomlError> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated section"))?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(err("empty section name"));
                }
                section = name.to_string();
            } else {
                let eq = line.find('=').ok_or_else(|| err("expected key = value"))?;
                let key = line[..eq].trim();
                if key.is_empty() {
                    return Err(err("empty key"));
                }
                let val = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
                let full = if section.is_empty() {
                    key.to_string()
                } else {
                    format!("{section}.{key}")
                };
                map.insert(full, val);
            }
        }
        Ok(Self { map })
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.map.get(path)
    }

    pub fn get_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(|v| v.as_str())
    }

    pub fn get_usize(&self, path: &str) -> Option<usize> {
        self.get(path).and_then(|v| v.as_usize())
    }

    pub fn get_f64(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(|v| v.as_f64())
    }

    pub fn get_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(|v| v.as_bool())
    }

    /// Keys under a `section.` prefix.
    pub fn keys_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        let want = format!("{prefix}.");
        self.map.keys().filter_map(move |k| k.strip_prefix(&want))
    }

    pub fn insert(&mut self, path: &str, v: TomlValue) {
        self.map.insert(path.to_string(), v);
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string must not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        // minimal escapes
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    _ => return Err("bad escape".into()),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(TomlValue::Str(out));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        let inner = inner.trim();
        if !inner.is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

/// Split on commas not inside strings (arrays are flat; no nesting needed).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_experiment_config() {
        let text = r#"
# experiment config
name = "fig6"

[train]
model = "mlp_cifar"
workers = 4
epochs = 30
lr = 0.1            # step size
momentum = 0.9
use_ef = true

[compress]
sparsifier = "topk"
ratio = 0.01
index = "bloom_p2"
fprs = [0.0001, 0.001, 0.01, 0.1]
"#;
        let doc = TomlDoc::parse(text).unwrap();
        assert_eq!(doc.get_str("name"), Some("fig6"));
        assert_eq!(doc.get_usize("train.workers"), Some(4));
        assert_eq!(doc.get_f64("train.lr"), Some(0.1));
        assert_eq!(doc.get_bool("train.use_ef"), Some(true));
        assert_eq!(doc.get_str("compress.index"), Some("bloom_p2"));
        let fprs = doc.get("compress.fprs").unwrap().as_arr().unwrap();
        assert_eq!(fprs.len(), 4);
        assert_eq!(fprs[1].as_f64(), Some(0.001));
    }

    #[test]
    fn strings_with_hash_and_escapes() {
        let doc = TomlDoc::parse("k = \"a#b\\nc\"").unwrap();
        assert_eq!(doc.get_str("k"), Some("a#b\nc"));
    }

    #[test]
    fn int_vs_float() {
        let doc = TomlDoc::parse("a = 3\nb = 3.0\nc = 1_000").unwrap();
        assert_eq!(doc.get("a"), Some(&TomlValue::Int(3)));
        assert_eq!(doc.get("b"), Some(&TomlValue::Float(3.0)));
        assert_eq!(doc.get_usize("c"), Some(1000));
        // int usable as float
        assert_eq!(doc.get_f64("a"), Some(3.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = TomlDoc::parse("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(TomlDoc::parse("[oops\n").is_err());
        assert!(TomlDoc::parse("x = [1, 2\n").is_err());
    }

    #[test]
    fn keys_under_prefix() {
        let doc = TomlDoc::parse("[a]\nx = 1\ny = 2\n[b]\nz = 3").unwrap();
        let keys: Vec<&str> = doc.keys_under("a").collect();
        assert_eq!(keys, vec!["x", "y"]);
    }
}
