//! Shared substrate: bit I/O, integer codes, PRNG/hashing, statistics,
//! JSON/TOML-lite parsing, and the bench/property-test harnesses.

pub mod benchkit;
pub mod bitio;
pub mod elias;
pub mod hashkit;
pub mod huffman;
pub mod json;
pub mod prng;
pub mod stats;
pub mod testkit;
pub mod toml_lite;
pub mod varint;

/// f32 <-> IEEE-754 half (binary16) conversion, used by the fp16 value
/// codec and the fp16 rows of Fig 11. Round-to-nearest-even.
pub mod f16 {
    /// Convert an f32 to its binary16 bit pattern.
    pub fn f32_to_f16_bits(x: f32) -> u16 {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let man = bits & 0x7F_FFFF;

        if exp == 0xFF {
            // inf / nan
            return sign | 0x7C00 | if man != 0 { 0x0200 } else { 0 };
        }
        // unbiased exponent
        let e = exp - 127 + 15;
        if e >= 0x1F {
            return sign | 0x7C00; // overflow -> inf
        }
        if e <= 0 {
            // subnormal or zero
            if e < -10 {
                return sign; // underflow to zero
            }
            // add implicit leading 1, shift into subnormal position
            let man = man | 0x80_0000;
            let shift = (14 - e) as u32;
            let half_man = man >> shift;
            // round to nearest even
            let rem = man & ((1 << shift) - 1);
            let halfway = 1u32 << (shift - 1);
            let rounded = if rem > halfway || (rem == halfway && (half_man & 1) == 1) {
                half_man + 1
            } else {
                half_man
            };
            return sign | rounded as u16;
        }
        // normal case: keep top 10 mantissa bits, round-nearest-even
        let half_man = man >> 13;
        let rem = man & 0x1FFF;
        let mut out = sign | ((e as u16) << 10) | half_man as u16;
        if rem > 0x1000 || (rem == 0x1000 && (half_man & 1) == 1) {
            out = out.wrapping_add(1); // may carry into exponent: correct
        }
        out
    }

    /// Convert a binary16 bit pattern to f32.
    pub fn f16_bits_to_f32(h: u16) -> f32 {
        let sign = ((h & 0x8000) as u32) << 16;
        let exp = ((h >> 10) & 0x1F) as u32;
        let man = (h & 0x3FF) as u32;
        let bits = if exp == 0 {
            if man == 0 {
                sign
            } else {
                // subnormal: normalize (value = man * 2^-24)
                let mut e = 0i32;
                let mut m = man;
                while m & 0x400 == 0 {
                    m <<= 1;
                    e -= 1;
                }
                m &= 0x3FF;
                sign | (((127 - 15 + e + 1) as u32) << 23) | (m << 13)
            }
        } else if exp == 0x1F {
            sign | 0x7F80_0000 | (man << 13)
        } else {
            sign | ((exp + 127 - 15) << 23) | (man << 13)
        };
        f32::from_bits(bits)
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::util::prng::Rng;

        #[test]
        fn exact_values() {
            for &(f, h) in &[
                (0.0f32, 0x0000u16),
                (-0.0, 0x8000),
                (1.0, 0x3C00),
                (-2.0, 0xC000),
                (0.5, 0x3800),
                (65504.0, 0x7BFF), // f16 max
                (f32::INFINITY, 0x7C00),
            ] {
                assert_eq!(f32_to_f16_bits(f), h, "f={f}");
                if f.is_finite() {
                    assert_eq!(f16_bits_to_f32(h), f);
                }
            }
            assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        }

        #[test]
        fn roundtrip_error_bounded() {
            let mut rng = Rng::new(6);
            for _ in 0..20_000 {
                let x = (rng.next_f32() - 0.5) * 100.0;
                let y = f16_bits_to_f32(f32_to_f16_bits(x));
                // half precision: 11-bit significand -> rel err <= 2^-11
                assert!((x - y).abs() <= x.abs() * 4.9e-4 + 6e-8, "x={x} y={y}");
            }
        }

        #[test]
        fn overflow_and_subnormals() {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e9)), f32::INFINITY);
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-1e9)), f32::NEG_INFINITY);
            let tiny = 2.0e-8f32; // below min subnormal/2 (~2.98e-8) -> 0
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(tiny)), 0.0);
            let sub = 5.0e-6f32; // representable as subnormal
            let y = f16_bits_to_f32(f32_to_f16_bits(sub));
            assert!((sub - y).abs() / sub < 0.05);
        }
    }
}
