//! Elias gamma / omega codes over [`BitWriter`]/[`BitReader`].
//!
//! QSGD (Alistarh et al., 2017) encodes quantized gradient integers with
//! Elias codes; we provide gamma (simple, good for small ints) and the
//! recursive omega code the paper references. Codes operate on v >= 1;
//! callers map 0-based data with `v+1`.

use super::bitio::{BitReader, BitUnderflow, BitWriter};

/// Elias gamma: unary length prefix + binary remainder. v >= 1.
pub fn gamma_encode(w: &mut BitWriter, v: u64) {
    assert!(v >= 1, "gamma code domain is v >= 1");
    let n = 63 - v.leading_zeros(); // floor(log2 v)
    // n zeros, then the (n+1)-bit value MSB-first. We emit MSB-first so the
    // decoder can scan the unary prefix naturally.
    w.write_run(false, n as u64);
    for i in (0..=n).rev() {
        w.write_bit((v >> i) & 1 == 1);
    }
}

pub fn gamma_decode(r: &mut BitReader) -> Result<u64, BitUnderflow> {
    let mut n = 0u32;
    while !r.read_bit()? {
        n += 1;
        if n > 63 {
            return Err(BitUnderflow { need: 1, pos: r.bit_pos(), have: 0 });
        }
    }
    let mut v = 1u64;
    for _ in 0..n {
        v = (v << 1) | r.read_bit()? as u64;
    }
    Ok(v)
}

/// Elias omega (recursive) code. v >= 1.
pub fn omega_encode(w: &mut BitWriter, v: u64) {
    assert!(v >= 1, "omega code domain is v >= 1");
    // Build groups back-to-front.
    let mut groups: Vec<(u64, u32)> = Vec::new();
    let mut n = v;
    while n > 1 {
        let len = 64 - n.leading_zeros(); // bits in n
        groups.push((n, len));
        n = (len - 1) as u64;
    }
    for &(g, len) in groups.iter().rev() {
        for i in (0..len).rev() {
            w.write_bit((g >> i) & 1 == 1);
        }
    }
    w.write_bit(false); // terminator
}

pub fn omega_decode(r: &mut BitReader) -> Result<u64, BitUnderflow> {
    let mut n = 1u64;
    loop {
        if !r.read_bit()? {
            return Ok(n);
        }
        // the bit we just read is the MSB (always 1) of an (n+1)-bit group
        let mut g = 1u64;
        for _ in 0..n {
            g = (g << 1) | r.read_bit()? as u64;
        }
        n = g;
    }
}

/// Bit length of the gamma code of v (for cost models).
pub fn gamma_len(v: u64) -> u64 {
    debug_assert!(v >= 1);
    2 * (63 - v.leading_zeros()) as u64 + 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn gamma_known_codes() {
        // 1 -> "1"; 2 -> "010"; 3 -> "011"; 4 -> "00100"
        let mut w = BitWriter::new();
        for v in 1..=4u64 {
            gamma_encode(&mut w, v);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for v in 1..=4u64 {
            assert_eq!(gamma_decode(&mut r).unwrap(), v);
        }
    }

    #[test]
    fn gamma_roundtrip_random() {
        let mut rng = Rng::new(2);
        let mut w = BitWriter::new();
        let mut vals = Vec::new();
        for _ in 0..5000 {
            let v = 1 + (rng.next_u64() >> rng.below(63) as u32);
            gamma_encode(&mut w, v);
            vals.push(v);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for v in vals {
            assert_eq!(gamma_decode(&mut r).unwrap(), v);
        }
    }

    #[test]
    fn omega_roundtrip_exhaustive_small() {
        let mut w = BitWriter::new();
        for v in 1..=1000u64 {
            omega_encode(&mut w, v);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for v in 1..=1000u64 {
            assert_eq!(omega_decode(&mut r).unwrap(), v);
        }
    }

    #[test]
    fn omega_roundtrip_random_large() {
        let mut rng = Rng::new(3);
        let mut w = BitWriter::new();
        let mut vals = Vec::new();
        for _ in 0..2000 {
            let v = 1 + (rng.next_u64() >> rng.below(40) as u32);
            omega_encode(&mut w, v);
            vals.push(v);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for v in vals {
            assert_eq!(omega_decode(&mut r).unwrap(), v);
        }
    }

    #[test]
    fn gamma_len_matches() {
        let mut rng = Rng::new(4);
        for _ in 0..200 {
            let v = 1 + rng.below(1 << 30);
            let mut w = BitWriter::new();
            gamma_encode(&mut w, v);
            assert_eq!(w.bit_len(), gamma_len(v));
        }
    }
}
