//! Virtual-time fabric: a discrete-event, virtual-clock transport
//! (DESIGN.md §9).
//!
//! The instant fabric ([`crate::collective::Network`]) delivers every
//! message immediately, so all wall-time claims in the repo used to come
//! from the closed-form α–β formulas in [`crate::simnet`] — hand-derived
//! per schedule, blind to link contention, critical paths, and slow
//! ranks. This fabric makes time *emerge from the schedule execution*
//! instead: each rank carries a virtual clock, each port serializes
//! transfers, and `recv` advances the receiver to the message's delivery
//! time. Per-step critical-path time and per-rank idle time then fall
//! out of running the existing collectives **unchanged** (they are
//! written against [`Comm`]).
//!
//! # Event model
//!
//! Every rank owns a clock plus one egress and one ingress port per
//! link class (intra-node / inter-node). A transfer of `b` bytes on a
//! link with latency `α` and bandwidth `β` occupies a port for
//! `busy = α + b/β` (store-and-forward with per-message setup cost, the
//! same accounting the simnet closed forms use):
//!
//! - `send`: `depart = max(clock, egress_free)`; the egress port is
//!   busy until `depart + busy`; the message ships with its
//!   `(depart, busy)` stamps. Sends never block (channels are
//!   unbounded), mirroring an async NIC.
//! - `recv`: `delivery = max(ingress_free, depart) + busy`; the ingress
//!   port is busy until `delivery`, and the receiver's clock advances to
//!   `max(clock, delivery)` — time spent waiting is accounted as idle.
//!
//! Because virtual time flows *only* through message stamps and
//! rank-local state (never through shared mutable time), measured times
//! are deterministic: they depend on the schedule's message pattern,
//! not on OS thread interleaving. On homogeneous links with no jitter
//! the measured critical paths agree with the simnet closed forms to
//! within a fraction of a percent (pinned at ±10% in
//! `tests/vfabric.rs`); with a [`Scenario`] active they diverge in
//! exactly the ways the formulas cannot see — which is the point.
//!
//! # Example
//!
//! ```
//! use deepreduce::collective::{Schedule, SparseConfig, Topology};
//! use deepreduce::simnet::Link;
//! use deepreduce::tensor::SparseTensor;
//! use deepreduce::vfabric::{Scenario, VirtualNetwork};
//!
//! let net = VirtualNetwork::new(
//!     Topology::flat(2),
//!     Link::mbps(100.0),
//!     Link::mbps(100.0),
//!     Scenario::none(0),
//! );
//! let handles: Vec<_> = net
//!     .endpoints()
//!     .into_iter()
//!     .enumerate()
//!     .map(|(rank, ep)| {
//!         std::thread::spawn(move || {
//!             let support = if rank == 0 { vec![0u32, 2] } else { vec![2, 4] };
//!             let mine = SparseTensor::new(6, support, vec![1.0; 2]);
//!             let sched = Schedule::GatherAll.build(SparseConfig::default());
//!             sched.allreduce(&ep, mine).unwrap()
//!         })
//!     })
//!     .collect();
//! for h in handles {
//!     assert_eq!(h.join().unwrap().indices(), &[0, 2, 4]);
//! }
//! // the exchange took measurable virtual time
//! assert!(net.max_clock_s() > 0.0);
//! ```

mod scenario;

pub use scenario::{stable_unit, LinkFlap, Scenario};

use crate::collective::{Comm, Topology};
use crate::obs;
use crate::simnet::Link;
use crate::util::prng::{mix64, Rng};
use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

/// Link-class index: intra-node.
pub(crate) const INTRA: usize = 0;
/// Link-class index: inter-node.
pub(crate) const INTER: usize = 1;

/// One in-flight transfer with its virtual-time stamps.
struct Msg {
    /// when the sender's egress port released the last byte
    depart: f64,
    /// port occupancy of this transfer (`α + bytes/β`, jitter applied)
    busy: f64,
    payload: Vec<u8>,
}

/// Shared byte meters (same accounting as the instant fabric).
struct Meters {
    bytes: AtomicU64,
    intra: AtomicU64,
    inter: AtomicU64,
}

/// Per-rank published virtual-time state. Endpoints store `f64` bits on
/// every clock change so the owning thread of the network can read
/// final clocks after joining the workers.
struct RankClock {
    clock: AtomicU64,
    idle: AtomicU64,
}

impl RankClock {
    fn zero() -> Self {
        Self { clock: AtomicU64::new(0), idle: AtomicU64::new(0) }
    }
}

/// The virtual-time fabric: construct once, hand one
/// [`VirtualEndpoint`] to each worker thread. Byte meters match
/// [`crate::collective::Network`]; on top of them the fabric reports
/// the measured virtual clocks ([`VirtualNetwork::max_clock_s`]) and
/// accumulated per-rank idle time.
pub struct VirtualNetwork {
    topo: Topology,
    endpoints: Mutex<Option<Vec<VirtualEndpoint>>>,
    meters: Arc<Meters>,
    clocks: Arc<Vec<RankClock>>,
}

impl VirtualNetwork {
    /// Build the fabric over `topo` with per-class link parameters and
    /// a [`Scenario`] (stragglers / jitter / per-node overrides).
    pub fn new(topo: Topology, intra: Link, inter: Link, scenario: Scenario) -> Self {
        let n = topo.world();
        assert!(n >= 1);
        let meters = Arc::new(Meters {
            bytes: AtomicU64::new(0),
            intra: AtomicU64::new(0),
            inter: AtomicU64::new(0),
        });
        let clocks: Arc<Vec<RankClock>> = Arc::new((0..n).map(|_| RankClock::zero()).collect());
        // txs[dst][src], rxs[dst][src] — same mesh as the instant fabric
        let mut txs: Vec<Vec<Option<Sender<Msg>>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        let mut rxs: Vec<Vec<Option<Receiver<Msg>>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        for dst in 0..n {
            for src in 0..n {
                let (tx, rx) = channel();
                txs[dst][src] = Some(tx);
                rxs[dst][src] = Some(rx);
            }
        }
        let mut endpoints = Vec::with_capacity(n);
        for rank in 0..n {
            let to: Vec<Sender<Msg>> = (0..n).map(|dst| txs[dst][rank].clone().unwrap()).collect();
            let from: Vec<Receiver<Msg>> =
                (0..n).map(|src| rxs[rank][src].take().unwrap()).collect();
            // per-peer effective link parameters, resolved up front so
            // the hot path is a table lookup
            let mut alpha = Vec::with_capacity(n);
            let mut beta = Vec::with_capacity(n);
            let mut class = Vec::with_capacity(n);
            for dst in 0..n {
                let (a, b, c) = resolve_link(topo, rank, dst, intra, inter, &scenario);
                alpha.push(a);
                beta.push(b);
                class.push(c);
            }
            endpoints.push(VirtualEndpoint {
                rank,
                n,
                topo,
                to,
                from,
                alpha,
                beta,
                class,
                clock: Cell::new(0.0),
                idle: Cell::new(0.0),
                egress_free: [Cell::new(0.0), Cell::new(0.0)],
                ingress_free: [Cell::new(0.0), Cell::new(0.0)],
                scenario: scenario.clone(),
                rng: RefCell::new(Rng::new(scenario.seed ^ mix64(rank as u64))),
                meters: Arc::clone(&meters),
                clocks: Arc::clone(&clocks),
            });
        }
        Self { topo, endpoints: Mutex::new(Some(endpoints)), meters, clocks }
    }

    /// Flat single-node fabric with one link everywhere and no scenario.
    pub fn flat(n: usize, link: Link) -> Self {
        Self::new(Topology::flat(n), link, link, Scenario::none(0))
    }

    pub fn n(&self) -> usize {
        self.topo.world()
    }

    /// The grid this fabric classifies links against.
    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// Take all endpoints, erroring (instead of silently handing out an
    /// empty vector) when they were already taken — the fabric is
    /// single-use.
    pub fn try_endpoints(&self) -> anyhow::Result<Vec<VirtualEndpoint>> {
        self.endpoints.lock().unwrap().take().ok_or_else(|| {
            anyhow::anyhow!("virtual fabric endpoints already handed out (single-use)")
        })
    }

    /// Take all endpoints (once), panicking on double-take — the
    /// convenience form for tests and benches; production callers use
    /// [`VirtualNetwork::try_endpoints`].
    pub fn endpoints(&self) -> Vec<VirtualEndpoint> {
        self.try_endpoints().expect("virtual fabric endpoints")
    }

    /// Total bytes that crossed the fabric so far.
    pub fn total_bytes(&self) -> u64 {
        self.meters.bytes.load(Ordering::Relaxed)
    }

    /// Bytes that stayed inside a node.
    pub fn intra_bytes(&self) -> u64 {
        self.meters.intra.load(Ordering::Relaxed)
    }

    /// Bytes that crossed a node boundary.
    pub fn inter_bytes(&self) -> u64 {
        self.meters.inter.load(Ordering::Relaxed)
    }

    pub fn reset_bytes(&self) {
        self.meters.bytes.store(0, Ordering::Relaxed);
        self.meters.intra.store(0, Ordering::Relaxed);
        self.meters.inter.store(0, Ordering::Relaxed);
    }

    /// Latest published virtual clock of `rank`, seconds. Reliable once
    /// the rank's worker thread has been joined.
    pub fn clock_s(&self, rank: usize) -> f64 {
        f64::from_bits(self.clocks[rank].clock.load(Ordering::Relaxed))
    }

    /// The fabric-wide virtual time: the maximum rank clock — the
    /// critical-path completion time of everything run so far.
    pub fn max_clock_s(&self) -> f64 {
        (0..self.n()).map(|r| self.clock_s(r)).fold(0.0, f64::max)
    }

    /// Accumulated recv-wait idle time of `rank`, seconds.
    pub fn idle_s(&self, rank: usize) -> f64 {
        f64::from_bits(self.clocks[rank].idle.load(Ordering::Relaxed))
    }

    /// Total recv-wait idle time across all ranks, seconds.
    pub fn total_idle_s(&self) -> f64 {
        (0..self.n()).map(|r| self.idle_s(r)).sum()
    }
}

/// Effective `(α, β, class)` of the `rank → dst` link under a scenario:
/// per-node inter bandwidth overrides take the min over both endpoints,
/// and a straggler divides β on every link touching it. Shared with the
/// fleet runner (`crate::fleetsim`), which resolves links on the fly
/// instead of precomputing per-peer tables — same pure function, so the
/// two fabrics agree bit-for-bit.
pub(crate) fn resolve_link(
    topo: Topology,
    rank: usize,
    dst: usize,
    intra: Link,
    inter: Link,
    scenario: &Scenario,
) -> (f64, f64, usize) {
    let straggle = scenario.straggler_factor(rank).max(scenario.straggler_factor(dst));
    if rank == dst || topo.is_intra(rank, dst) {
        (intra.latency_s, intra.bandwidth_bps / straggle, INTRA)
    } else {
        let b = scenario
            .node_beta(topo.node_of(rank), inter.bandwidth_bps)
            .min(scenario.node_beta(topo.node_of(dst), inter.bandwidth_bps));
        (inter.latency_s, b / straggle, INTER)
    }
}

/// Port occupancy of one transfer: `α + bytes/β` with the scenario's
/// timed link flaps (inter links only, evaluated at the sender's clock
/// when the transfer is initiated) and per-transfer jitter applied.
///
/// Both fabrics — the threaded [`VirtualEndpoint`] and the fleet
/// runner's rank contexts — compute occupancy through this one
/// function, so the exact f64 operation order is shared by
/// construction and the differential tests can pin **bit** equality,
/// not just ±ε. With no flaps active the β division is by exactly 1.0
/// (an identity on f64), so adding the flap path changed no existing
/// measured time.
#[allow(clippy::too_many_arguments)]
pub(crate) fn transfer_busy(
    alpha: f64,
    beta: f64,
    class: usize,
    bytes: usize,
    clock: f64,
    node_src: usize,
    node_dst: usize,
    scenario: &Scenario,
    rng: &mut Rng,
) -> f64 {
    let beta = if class == INTER && !scenario.link_flaps.is_empty() {
        beta / scenario.flap_factor(node_src, node_dst, clock)
    } else {
        beta
    };
    let mut busy = alpha + bytes as f64 / beta;
    if scenario.link_jitter > 0.0 {
        busy *= 1.0 + scenario.link_jitter * rng.next_f64();
    }
    busy
}

/// A rank's handle onto the virtual-time fabric. Owned by exactly one
/// worker thread (like [`crate::collective::Endpoint`]); all virtual
/// time state is rank-local, so it uses plain `Cell`s.
pub struct VirtualEndpoint {
    rank: usize,
    n: usize,
    topo: Topology,
    to: Vec<Sender<Msg>>,
    from: Vec<Receiver<Msg>>,
    /// per-peer effective latency, seconds
    alpha: Vec<f64>,
    /// per-peer effective bandwidth, bytes/second
    beta: Vec<f64>,
    /// per-peer link class (`INTRA` / `INTER`)
    class: Vec<usize>,
    clock: Cell<f64>,
    idle: Cell<f64>,
    egress_free: [Cell<f64>; 2],
    ingress_free: [Cell<f64>; 2],
    scenario: Scenario,
    rng: RefCell<Rng>,
    meters: Arc<Meters>,
    clocks: Arc<Vec<RankClock>>,
}

impl VirtualEndpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.n
    }

    /// The grid this endpoint's fabric was built with.
    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// This rank's virtual clock, seconds.
    pub fn now(&self) -> f64 {
        self.clock.get()
    }

    /// Accumulated time this rank spent waiting in `recv`, seconds.
    pub fn idle_s(&self) -> f64 {
        self.idle.get()
    }

    /// Local work: advance this rank's clock by `dt` seconds (compute,
    /// encode — anything that keeps the rank busy off the network).
    pub fn elapse(&self, dt: f64) {
        if dt > 0.0 {
            self.clock.set(self.clock.get() + dt);
            self.publish();
        }
    }

    /// Barrier alignment: advance the clock to at least `t` *without*
    /// counting the gap as idle (callers that account barrier idle
    /// themselves — e.g. the trainer's step barrier — use this).
    pub fn sync_to(&self, t: f64) {
        if t > self.clock.get() {
            self.clock.set(t);
        }
        // publish even when no catch-up happened: this seeds the
        // tracer's per-thread virtual clock at step start, so the very
        // first span of a step carries a virtual start stamp
        self.publish();
    }

    fn publish(&self) {
        let slot = &self.clocks[self.rank];
        slot.clock.store(self.clock.get().to_bits(), Ordering::Relaxed);
        slot.idle.store(self.idle.get().to_bits(), Ordering::Relaxed);
        // tell the tracing layer where this rank's virtual clock is, so
        // spans opened on this thread carry virtual stamps
        obs::vclock(self.clock.get());
    }

    /// Port occupancy of a transfer to `dst` (flap + jitter applied —
    /// the jitter draw comes from this rank's own deterministic stream).
    fn occupancy(&self, dst: usize, bytes: usize) -> f64 {
        transfer_busy(
            self.alpha[dst],
            self.beta[dst],
            self.class[dst],
            bytes,
            self.clock.get(),
            self.topo.node_of(self.rank),
            self.topo.node_of(dst),
            &self.scenario,
            &mut self.rng.borrow_mut(),
        )
    }

    /// Non-blocking virtual send: books the egress port, stamps the
    /// delivery window, meters the bytes.
    pub fn send(&self, dst: usize, payload: Vec<u8>) {
        assert_ne!(dst, self.rank, "self-send not allowed");
        let len = payload.len() as u64;
        self.meters.bytes.fetch_add(len, Ordering::Relaxed);
        let c = self.class[dst];
        if c == INTRA {
            self.meters.intra.fetch_add(len, Ordering::Relaxed);
        } else {
            self.meters.inter.fetch_add(len, Ordering::Relaxed);
        }
        let busy = self.occupancy(dst, payload.len());
        let depart = self.clock.get().max(self.egress_free[c].get());
        self.egress_free[c].set(depart + busy);
        // egress port occupancy + queueing delay behind earlier sends
        obs::port_span(obs::SpanKind::Send, obs::Lane::egress(c), depart, depart + busy, len);
        obs::count(if c == INTRA { "vfabric.intra_bytes" } else { "vfabric.inter_bytes" }, len);
        obs::observe("vfabric.egress_backlog_s", depart - self.clock.get());
        self.to[dst].send(Msg { depart, busy, payload }).expect("peer hung up");
    }

    /// Blocking receive from `src`: books the ingress port and advances
    /// this rank's clock to the delivery time (waiting counts as idle).
    pub fn recv(&self, src: usize) -> Vec<u8> {
        assert_ne!(src, self.rank);
        // the wait span's virtual extent is [clock before, clock after]:
        // exactly the idle this recv adds (zero when the message already
        // arrived). Wall extent covers the blocking channel recv.
        obs::vclock(self.clock.get());
        let mut wait = obs::span(obs::SpanKind::RecvWait);
        let msg = self.from[src].recv().expect("peer hung up");
        let c = self.class[src];
        let delivery = self.ingress_free[c].get().max(msg.depart) + msg.busy;
        self.ingress_free[c].set(delivery);
        let now = self.clock.get();
        if delivery > now {
            self.idle.set(self.idle.get() + (delivery - now));
            self.clock.set(delivery);
        }
        self.publish();
        if wait.live() {
            wait.set_bytes(msg.payload.len() as u64);
            wait.label_with(|| format!("from {src}"));
            // ingress port occupancy for this message
            obs::port_span(
                obs::SpanKind::Recv,
                obs::Lane::ingress(c),
                delivery - msg.busy,
                delivery,
                msg.payload.len() as u64,
            );
        }
        drop(wait);
        msg.payload
    }
}

impl Comm for VirtualEndpoint {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.n
    }

    fn send(&self, dst: usize, payload: Vec<u8>) {
        VirtualEndpoint::send(self, dst, payload)
    }

    fn recv(&self, src: usize) -> Vec<u8> {
        VirtualEndpoint::recv(self, src)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn link(alpha: f64, bps: f64) -> Link {
        Link { bandwidth_bps: bps, latency_s: alpha }
    }

    #[test]
    fn ideal_link_keeps_clocks_at_zero() {
        let net = VirtualNetwork::flat(2, Link::ideal());
        let mut eps = net.endpoints();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let t = thread::spawn(move || {
            for i in 0..10u8 {
                a.send(1, vec![i; 100]);
            }
            a.now()
        });
        for i in 0..10u8 {
            assert_eq!(b.recv(0), vec![i; 100]);
        }
        assert_eq!(t.join().unwrap(), 0.0);
        assert_eq!(b.now(), 0.0);
        assert_eq!(b.idle_s(), 0.0);
        assert_eq!(net.total_bytes(), 1000);
        assert_eq!(net.max_clock_s(), 0.0);
    }

    #[test]
    fn ports_serialize_and_clock_advances() {
        // α = 1s, β = 100 B/s: a 100-byte transfer occupies 2s
        let net = VirtualNetwork::flat(3, link(1.0, 100.0));
        let mut eps = net.endpoints();
        let c = eps.pop().unwrap();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let t = thread::spawn(move || {
            // both sends leave rank 0's intra egress port back to back:
            // departs at 0 and 2
            a.send(1, vec![0; 100]);
            a.send(2, vec![0; 100]);
        });
        let tb = thread::spawn(move || {
            b.recv(0);
            (b.now(), b.idle_s())
        });
        let (nb, ib) = tb.join().unwrap();
        assert!((nb - 2.0).abs() < 1e-12, "first delivery at 2s, got {nb}");
        assert!((ib - 2.0).abs() < 1e-12);
        c.recv(0);
        assert!((c.now() - 4.0).abs() < 1e-12, "second departs at 2, lands at 4: {}", c.now());
        t.join().unwrap();
        assert!((net.max_clock_s() - 4.0).abs() < 1e-12);
        assert!((net.total_idle_s() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn ingress_serializes_concurrent_senders() {
        // two senders ship to rank 2 at virtual time 0; the receiver's
        // single ingress port takes them one after the other
        let net = VirtualNetwork::flat(3, link(0.0, 100.0));
        let mut eps = net.endpoints();
        let c = eps.pop().unwrap();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let t1 = thread::spawn(move || a.send(2, vec![0; 100]));
        let t2 = thread::spawn(move || b.send(2, vec![0; 100]));
        t1.join().unwrap();
        t2.join().unwrap();
        c.recv(0);
        c.recv(1);
        assert!((c.now() - 2.0).abs() < 1e-12, "ingress must serialize: {}", c.now());
    }

    #[test]
    fn elapse_defers_departure() {
        let net = VirtualNetwork::flat(2, link(0.0, 100.0));
        let mut eps = net.endpoints();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let t = thread::spawn(move || {
            a.elapse(5.0);
            a.send(1, vec![0; 100]);
            a.now()
        });
        assert_eq!(b.recv(0).len(), 100);
        assert_eq!(t.join().unwrap(), 5.0);
        assert!((b.now() - 6.0).abs() < 1e-12, "departs at 5, lands at 6: {}", b.now());
        // sync_to does not count as idle
        b.sync_to(10.0);
        assert_eq!(b.now(), 10.0);
        assert!((b.idle_s() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn straggler_slows_its_links_both_ways() {
        let scen = |stragglers| Scenario { stragglers, seed: 1, ..Scenario::default() };
        let topo = Topology::flat(2);
        let l = link(0.0, 100.0);
        for (stragglers, want) in [
            (vec![], 1.0),
            (vec![(0usize, 4.0)], 4.0),
            (vec![(1usize, 8.0)], 8.0),
        ] {
            let net = VirtualNetwork::new(topo, l, l, scen(stragglers));
            let mut eps = net.endpoints();
            let b = eps.pop().unwrap();
            let a = eps.pop().unwrap();
            let t = thread::spawn(move || a.send(1, vec![0; 100]));
            b.recv(0);
            t.join().unwrap();
            assert!((b.now() - want).abs() < 1e-12, "want {want}, got {}", b.now());
        }
    }

    #[test]
    fn hetero_node_override_caps_inter_bandwidth() {
        // 2×1 grid: the only link is inter; node 1 capped at 8 Mbps
        // (= 1e6 B/s), so 1e6 bytes take 1 virtual second
        let topo = Topology::new(2, 1);
        let fast = link(0.0, 1e9);
        let scen = Scenario { node_mbps: vec![(1, 8.0)], seed: 1, ..Scenario::default() };
        let net = VirtualNetwork::new(topo, fast, fast, scen);
        let mut eps = net.endpoints();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let t = thread::spawn(move || a.send(1, vec![0; 1_000_000]));
        b.recv(0);
        t.join().unwrap();
        assert!((b.now() - 1.0).abs() < 1e-9, "got {}", b.now());
        assert_eq!(net.inter_bytes(), 1_000_000);
        assert_eq!(net.intra_bytes(), 0);
    }

    #[test]
    fn link_jitter_is_deterministic_across_runs() {
        let run = || {
            let scen = Scenario { link_jitter: 0.5, seed: 99, ..Scenario::default() };
            let net =
                VirtualNetwork::new(Topology::flat(2), link(0.0, 100.0), link(0.0, 100.0), scen);
            let mut eps = net.endpoints();
            let b = eps.pop().unwrap();
            let a = eps.pop().unwrap();
            let t = thread::spawn(move || {
                for _ in 0..16 {
                    a.send(1, vec![0; 100]);
                }
            });
            for _ in 0..16 {
                b.recv(0);
            }
            t.join().unwrap();
            b.now()
        };
        let (x, y) = (run(), run());
        assert_eq!(x, y, "jitter must be reproducible");
        // 16 transfers of 1s base, jitter in [1, 1.5): total in [16, 24)
        assert!((16.0..24.0).contains(&x), "got {x}");
        assert!(x > 16.0, "jitter must actually perturb the transfers");
    }

    #[test]
    fn link_flap_slows_inter_transfers_in_its_window() {
        // 2×1 grid: only inter links. β = 100 B/s; node 0 flaps ×4
        // during [0, 10): a transfer initiated inside the window takes
        // 4× longer, one initiated after it runs at full rate.
        let topo = Topology::new(2, 1);
        let l = link(0.0, 100.0);
        let scen = Scenario {
            link_flaps: vec![LinkFlap { node: 0, start_s: 0.0, end_s: 10.0, factor: 4.0 }],
            seed: 1,
            ..Scenario::default()
        };
        let net = VirtualNetwork::new(topo, l, l, scen);
        let mut eps = net.endpoints();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let t = thread::spawn(move || {
            a.send(1, vec![0; 100]); // initiated at clock 0 → flapped, 4s
            a.elapse(20.0); // move past the flap window
            a.send(1, vec![0; 100]); // full rate, 1s
        });
        b.recv(0);
        assert!((b.now() - 4.0).abs() < 1e-12, "flapped transfer: {}", b.now());
        t.join().unwrap();
        b.recv(0);
        // second departs at 20 (past egress_free = 4), lands at 21
        assert!((b.now() - 21.0).abs() < 1e-12, "got {}", b.now());
    }

    #[test]
    fn double_take_is_a_structured_error() {
        let net = VirtualNetwork::flat(2, Link::ideal());
        let _eps = net.try_endpoints().unwrap();
        let err = net.try_endpoints().unwrap_err();
        assert!(err.to_string().contains("already handed out"), "{err}");
    }
}
