//! Scenario model for the virtual-time fabric: stragglers, jitter, and
//! heterogeneous per-node links.
//!
//! A [`Scenario`] is pure data plus deterministic sampling — every
//! random draw is a hash of `(seed, rank, step)` or comes from a
//! per-rank [`crate::util::prng::Rng`] stream owned by that rank's
//! endpoint, so measured virtual times are reproducible regardless of
//! OS thread interleaving.

use crate::util::prng::mix64;

/// The conditions a virtual-time run simulates (CLI `--straggler`,
/// `--compute-jitter`, `--link-jitter`, `--node-mbps`).
#[derive(Clone, Debug, Default)]
pub struct Scenario {
    /// `(rank, factor)` pairs: rank's compute is `factor`× slower and
    /// every transfer touching the rank runs at `β / factor` (an
    /// overloaded host is slow on both its cores and its NIC).
    pub stragglers: Vec<(usize, f64)>,
    /// multiplicative compute jitter amplitude σ: per `(rank, step)`
    /// the compute time is scaled by `1 + σ·u`, `u ~ U[0, 1)`
    pub compute_jitter: f64,
    /// multiplicative transfer jitter amplitude σ: each transfer's
    /// port occupancy is scaled by `1 + σ·u`, `u ~ U[0, 1)`
    pub link_jitter: f64,
    /// per-node inter-link bandwidth overrides `(node, Mbps)`: an
    /// inter-node transfer runs at the slower of its two endpoints'
    /// node bandwidths (heterogeneous clusters)
    pub node_mbps: Vec<(usize, f64)>,
    /// seed of every deterministic draw
    pub seed: u64,
}

impl Scenario {
    /// The trivial scenario: no stragglers, no jitter, no overrides.
    pub fn none(seed: u64) -> Self {
        Self { seed, ..Self::default() }
    }

    /// Whether any knob is set (`false` = homogeneous, deterministic
    /// links — the configuration the simnet closed forms describe).
    pub fn is_active(&self) -> bool {
        !self.stragglers.is_empty()
            || self.compute_jitter > 0.0
            || self.link_jitter > 0.0
            || !self.node_mbps.is_empty()
    }

    /// Straggler slowdown of `rank` (1.0 when not a straggler).
    pub fn straggler_factor(&self, rank: usize) -> f64 {
        self.stragglers
            .iter()
            .filter(|&&(r, _)| r == rank)
            .map(|&(_, f)| f)
            .fold(1.0f64, f64::max)
    }

    /// Deterministic compute-time multiplier for one `(rank, step)`:
    /// the straggler factor times the sampled jitter.
    pub fn compute_factor(&self, rank: usize, step: usize) -> f64 {
        let mut f = self.straggler_factor(rank);
        if self.compute_jitter > 0.0 {
            f *= 1.0 + self.compute_jitter * unit(self.seed, rank as u64, step as u64);
        }
        f
    }

    /// Inter-link bandwidth (bytes/s) of `node`, after overrides.
    pub fn node_beta(&self, node: usize, default_bps: f64) -> f64 {
        self.node_mbps
            .iter()
            .filter(|&&(m, _)| m == node)
            .map(|&(_, mbps)| mbps * 1e6 / 8.0)
            .fold(default_bps, f64::min)
    }

    /// Parse the CLI straggler list `R:F[,R:F…]` (e.g. `0:8` = rank 0
    /// is 8× slow). Empty input parses to no stragglers.
    pub fn parse_stragglers(s: &str) -> anyhow::Result<Vec<(usize, f64)>> {
        parse_pairs(s, "straggler", |f| f >= 1.0, "factor must be >= 1")
    }

    /// Parse the CLI per-node override list `N:MBPS[,N:MBPS…]`
    /// (e.g. `1:10` = node 1's inter links run at 10 Mbps).
    pub fn parse_node_mbps(s: &str) -> anyhow::Result<Vec<(usize, f64)>> {
        parse_pairs(s, "node-mbps", |f| f > 0.0, "Mbps must be > 0")
    }
}

fn parse_pairs(
    s: &str,
    what: &str,
    ok: fn(f64) -> bool,
    why: &str,
) -> anyhow::Result<Vec<(usize, f64)>> {
    let mut out = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (idx, val) = part
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("bad {what} entry {part:?}, expected INDEX:VALUE"))?;
        let idx: usize = idx
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad {what} index in {part:?}"))?;
        let val: f64 = val
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad {what} value in {part:?}"))?;
        anyhow::ensure!(val.is_finite() && ok(val), "bad {what} entry {part:?}: {why}");
        out.push((idx, val));
    }
    Ok(out)
}

/// Deterministic `U[0, 1)` draw from a `(seed, a, b)` triple.
fn unit(seed: u64, a: u64, b: u64) -> f64 {
    let h = mix64(
        seed ^ a.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ b.wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
    );
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_and_rejects() {
        assert_eq!(Scenario::parse_stragglers("").unwrap(), vec![]);
        assert_eq!(Scenario::parse_stragglers("0:8").unwrap(), vec![(0, 8.0)]);
        assert_eq!(Scenario::parse_stragglers("1:2, 3:1.5").unwrap(), vec![(1, 2.0), (3, 1.5)]);
        assert!(Scenario::parse_stragglers("1").is_err());
        assert!(Scenario::parse_stragglers("a:2").is_err());
        assert!(Scenario::parse_stragglers("1:0.5").is_err(), "factor < 1 rejected");
        assert_eq!(Scenario::parse_node_mbps("0:100,1:10").unwrap(), vec![(0, 100.0), (1, 10.0)]);
        assert!(Scenario::parse_node_mbps("0:0").is_err());
    }

    #[test]
    fn factors_default_to_one() {
        let s = Scenario::none(7);
        assert!(!s.is_active());
        assert_eq!(s.straggler_factor(0), 1.0);
        assert_eq!(s.compute_factor(3, 10), 1.0);
        assert_eq!(s.node_beta(2, 1e6), 1e6);
    }

    #[test]
    fn straggler_and_override_apply() {
        let s = Scenario {
            stragglers: vec![(1, 4.0)],
            node_mbps: vec![(0, 8.0)],
            seed: 1,
            ..Scenario::default()
        };
        assert!(s.is_active());
        assert_eq!(s.straggler_factor(1), 4.0);
        assert_eq!(s.straggler_factor(0), 1.0);
        // 8 Mbps = 1e6 bytes/s, below the 1e9 default
        assert_eq!(s.node_beta(0, 1e9), 1e6);
        assert_eq!(s.node_beta(1, 1e9), 1e9);
    }

    #[test]
    fn compute_jitter_is_deterministic_and_bounded() {
        let s = Scenario { compute_jitter: 0.5, seed: 42, ..Scenario::default() };
        for rank in 0..4 {
            for step in 0..16 {
                let f = s.compute_factor(rank, step);
                assert!((1.0..1.5).contains(&f), "factor {f}");
                assert_eq!(f, s.compute_factor(rank, step), "same draw must repeat");
            }
        }
        // draws vary across (rank, step)
        let a = s.compute_factor(0, 0);
        let b = s.compute_factor(1, 0);
        let c = s.compute_factor(0, 1);
        assert!(a != b || a != c);
    }
}
