//! Scenario model for the virtual-time fabric: stragglers, jitter,
//! heterogeneous per-node links, link flaps, and rank crash/rejoin.
//!
//! A [`Scenario`] is pure data plus deterministic sampling — every
//! random draw is a hash of `(seed, rank, step)` through the pinned
//! [`stable_unit`] path or comes from a per-rank
//! [`crate::util::prng::Rng`] stream owned by that rank's endpoint, so
//! measured virtual times are reproducible regardless of OS thread
//! interleaving, OS, or architecture (no `DefaultHasher` or other
//! platform-varying hashing anywhere on the draw path — regression
//! tests pin golden draw sequences).

use crate::util::prng::mix64;

/// One inter-link degradation window: every inter-node transfer
/// touching `node` during virtual seconds `[start_s, end_s)` runs at
/// `β / factor` (a flapping switch port, an incast burst, a cable
/// renegotiating its rate). CLI `--link-flap NODE:START-END:FACTOR`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkFlap {
    /// Node whose inter links degrade.
    pub node: usize,
    /// Virtual time the flap starts (inclusive), seconds.
    pub start_s: f64,
    /// Virtual time the flap ends (exclusive), seconds.
    pub end_s: f64,
    /// Bandwidth divisor while active (`>= 1`).
    pub factor: f64,
}

/// The conditions a virtual-time run simulates (CLI `--straggler`,
/// `--compute-jitter`, `--link-jitter`, `--node-mbps`, `--link-flap`,
/// `--crash`).
#[derive(Clone, Debug, Default)]
pub struct Scenario {
    /// `(rank, factor)` pairs: rank's compute is `factor`× slower and
    /// every transfer touching the rank runs at `β / factor` (an
    /// overloaded host is slow on both its cores and its NIC).
    pub stragglers: Vec<(usize, f64)>,
    /// multiplicative compute jitter amplitude σ: per `(rank, step)`
    /// the compute time is scaled by `1 + σ·u`, `u ~ U[0, 1)`
    pub compute_jitter: f64,
    /// multiplicative transfer jitter amplitude σ: each transfer's
    /// port occupancy is scaled by `1 + σ·u`, `u ~ U[0, 1)`
    pub link_jitter: f64,
    /// per-node inter-link bandwidth overrides `(node, Mbps)`: an
    /// inter-node transfer runs at the slower of its two endpoints'
    /// node bandwidths (heterogeneous clusters)
    pub node_mbps: Vec<(usize, f64)>,
    /// timed inter-link degradation windows; a transfer is slowed by
    /// the worst flap active at the moment the sender initiates it
    pub link_flaps: Vec<LinkFlap>,
    /// `(rank, crash_step, rejoin_step)`: rank is down — absent from
    /// the collective — for steps in `[crash_step, rejoin_step)`.
    /// Realised by the fleet runner's elastic membership; the
    /// one-thread-per-rank fabric cannot drop a rank mid-run.
    pub crashes: Vec<(usize, usize, usize)>,
    /// seed of every deterministic draw
    pub seed: u64,
}

impl Scenario {
    /// The trivial scenario: no stragglers, no jitter, no overrides.
    pub fn none(seed: u64) -> Self {
        Self { seed, ..Self::default() }
    }

    /// Whether any knob is set (`false` = homogeneous, deterministic
    /// links — the configuration the simnet closed forms describe).
    pub fn is_active(&self) -> bool {
        !self.stragglers.is_empty()
            || self.compute_jitter > 0.0
            || self.link_jitter > 0.0
            || !self.node_mbps.is_empty()
            || !self.link_flaps.is_empty()
            || !self.crashes.is_empty()
    }

    /// Straggler slowdown of `rank` (1.0 when not a straggler).
    pub fn straggler_factor(&self, rank: usize) -> f64 {
        self.stragglers
            .iter()
            .filter(|&&(r, _)| r == rank)
            .map(|&(_, f)| f)
            .fold(1.0f64, f64::max)
    }

    /// Deterministic compute-time multiplier for one `(rank, step)`:
    /// the straggler factor times the sampled jitter.
    pub fn compute_factor(&self, rank: usize, step: usize) -> f64 {
        let mut f = self.straggler_factor(rank);
        if self.compute_jitter > 0.0 {
            f *= 1.0 + self.compute_jitter * stable_unit(self.seed, rank as u64, step as u64);
        }
        f
    }

    /// Bandwidth divisor the worst link flap touching `node_a` or
    /// `node_b` imposes at virtual time `t` (1.0 when none is active).
    /// Both fabrics evaluate this at the **sender's clock when the
    /// transfer is initiated** — the one instant the two runners agree
    /// on by construction — so flap timing cannot introduce
    /// thread-interleaving nondeterminism.
    pub fn flap_factor(&self, node_a: usize, node_b: usize, t: f64) -> f64 {
        self.link_flaps
            .iter()
            .filter(|f| (f.node == node_a || f.node == node_b) && f.start_s <= t && t < f.end_s)
            .map(|f| f.factor)
            .fold(1.0f64, f64::max)
    }

    /// Whether `rank` participates in `step` (crashed ranks are down
    /// for steps in `[crash_step, rejoin_step)`).
    pub fn alive(&self, rank: usize, step: usize) -> bool {
        !self
            .crashes
            .iter()
            .any(|&(r, from, to)| r == rank && from <= step && step < to)
    }

    /// The ranks of a `world`-sized job alive at `step`, ascending.
    pub fn alive_members(&self, world: usize, step: usize) -> Vec<usize> {
        (0..world).filter(|&r| self.alive(r, step)).collect()
    }

    /// Inter-link bandwidth (bytes/s) of `node`, after overrides.
    pub fn node_beta(&self, node: usize, default_bps: f64) -> f64 {
        self.node_mbps
            .iter()
            .filter(|&&(m, _)| m == node)
            .map(|&(_, mbps)| mbps * 1e6 / 8.0)
            .fold(default_bps, f64::min)
    }

    /// Parse the CLI straggler list `R:F[,R:F…]` (e.g. `0:8` = rank 0
    /// is 8× slow). Empty input parses to no stragglers.
    pub fn parse_stragglers(s: &str) -> anyhow::Result<Vec<(usize, f64)>> {
        parse_pairs(s, "straggler", |f| f >= 1.0, "factor must be >= 1")
    }

    /// Parse the CLI per-node override list `N:MBPS[,N:MBPS…]`
    /// (e.g. `1:10` = node 1's inter links run at 10 Mbps).
    pub fn parse_node_mbps(s: &str) -> anyhow::Result<Vec<(usize, f64)>> {
        parse_pairs(s, "node-mbps", |f| f > 0.0, "Mbps must be > 0")
    }

    /// Parse the CLI link-flap list `NODE:START-END:FACTOR[,…]`
    /// (e.g. `0:0.5-1.5:8` = node 0's inter links run at β/8 during
    /// virtual seconds [0.5, 1.5)). Empty input parses to no flaps.
    pub fn parse_link_flaps(s: &str) -> anyhow::Result<Vec<LinkFlap>> {
        let mut out = Vec::new();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let fields: Vec<&str> = part.split(':').collect();
            anyhow::ensure!(
                fields.len() == 3,
                "bad link-flap entry {part:?}, expected NODE:START-END:FACTOR"
            );
            let node: usize = fields[0]
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad link-flap node in {part:?}"))?;
            let (a, b) = fields[1]
                .split_once('-')
                .ok_or_else(|| anyhow::anyhow!("bad link-flap window in {part:?}, expected START-END"))?;
            let start_s: f64 = a
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad link-flap start in {part:?}"))?;
            let end_s: f64 = b
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad link-flap end in {part:?}"))?;
            let factor: f64 = fields[2]
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad link-flap factor in {part:?}"))?;
            anyhow::ensure!(
                start_s.is_finite() && end_s.is_finite() && start_s >= 0.0 && end_s > start_s,
                "bad link-flap entry {part:?}: window must satisfy 0 <= START < END"
            );
            anyhow::ensure!(
                factor.is_finite() && factor >= 1.0,
                "bad link-flap entry {part:?}: factor must be >= 1"
            );
            out.push(LinkFlap { node, start_s, end_s, factor });
        }
        Ok(out)
    }

    /// Parse the CLI crash list `R:A-B[,…]` (e.g. `2:3-5` = rank 2 is
    /// down for steps 3 and 4, rejoining at step 5). Empty input
    /// parses to no crashes.
    pub fn parse_crashes(s: &str) -> anyhow::Result<Vec<(usize, usize, usize)>> {
        let mut out = Vec::new();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (rank, window) = part
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("bad crash entry {part:?}, expected RANK:FROM-TO"))?;
            let rank: usize = rank
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad crash rank in {part:?}"))?;
            let (a, b) = window
                .split_once('-')
                .ok_or_else(|| anyhow::anyhow!("bad crash window in {part:?}, expected FROM-TO"))?;
            let from: usize = a
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad crash start step in {part:?}"))?;
            let to: usize = b
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad crash rejoin step in {part:?}"))?;
            anyhow::ensure!(from < to, "bad crash entry {part:?}: FROM must be < TO");
            out.push((rank, from, to));
        }
        Ok(out)
    }
}

fn parse_pairs(
    s: &str,
    what: &str,
    ok: fn(f64) -> bool,
    why: &str,
) -> anyhow::Result<Vec<(usize, f64)>> {
    let mut out = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (idx, val) = part
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("bad {what} entry {part:?}, expected INDEX:VALUE"))?;
        let idx: usize = idx
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad {what} index in {part:?}"))?;
        let val: f64 = val
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad {what} value in {part:?}"))?;
        anyhow::ensure!(val.is_finite() && ok(val), "bad {what} entry {part:?}: {why}");
        out.push((idx, val));
    }
    Ok(out)
}

/// Deterministic `U[0, 1)` draw from a `(seed, a, b)` triple — the
/// **pinned, platform-stable** hash path behind every scenario knob
/// draw (`compute_factor` jitter today; any future keyed draw must go
/// through here too). The mix is SplitMix64's finalizer over a fixed
/// odd-constant key schedule: pure integer arithmetic, identical on
/// every OS/architecture, never `std::hash`-dependent (whose
/// `DefaultHasher`/`RandomState` are seeded per-process and explicitly
/// unstable across releases). Golden draw sequences are pinned in the
/// tests below and in `tests/fleetsim_equivalence.rs`.
pub fn stable_unit(seed: u64, a: u64, b: u64) -> f64 {
    let h = mix64(
        seed ^ a.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ b.wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
    );
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_and_rejects() {
        assert_eq!(Scenario::parse_stragglers("").unwrap(), vec![]);
        assert_eq!(Scenario::parse_stragglers("0:8").unwrap(), vec![(0, 8.0)]);
        assert_eq!(Scenario::parse_stragglers("1:2, 3:1.5").unwrap(), vec![(1, 2.0), (3, 1.5)]);
        assert!(Scenario::parse_stragglers("1").is_err());
        assert!(Scenario::parse_stragglers("a:2").is_err());
        assert!(Scenario::parse_stragglers("1:0.5").is_err(), "factor < 1 rejected");
        assert_eq!(Scenario::parse_node_mbps("0:100,1:10").unwrap(), vec![(0, 100.0), (1, 10.0)]);
        assert!(Scenario::parse_node_mbps("0:0").is_err());
    }

    #[test]
    fn factors_default_to_one() {
        let s = Scenario::none(7);
        assert!(!s.is_active());
        assert_eq!(s.straggler_factor(0), 1.0);
        assert_eq!(s.compute_factor(3, 10), 1.0);
        assert_eq!(s.node_beta(2, 1e6), 1e6);
    }

    #[test]
    fn straggler_and_override_apply() {
        let s = Scenario {
            stragglers: vec![(1, 4.0)],
            node_mbps: vec![(0, 8.0)],
            seed: 1,
            ..Scenario::default()
        };
        assert!(s.is_active());
        assert_eq!(s.straggler_factor(1), 4.0);
        assert_eq!(s.straggler_factor(0), 1.0);
        // 8 Mbps = 1e6 bytes/s, below the 1e9 default
        assert_eq!(s.node_beta(0, 1e9), 1e6);
        assert_eq!(s.node_beta(1, 1e9), 1e9);
    }

    #[test]
    fn compute_jitter_is_deterministic_and_bounded() {
        let s = Scenario { compute_jitter: 0.5, seed: 42, ..Scenario::default() };
        for rank in 0..4 {
            for step in 0..16 {
                let f = s.compute_factor(rank, step);
                assert!((1.0..1.5).contains(&f), "factor {f}");
                assert_eq!(f, s.compute_factor(rank, step), "same draw must repeat");
            }
        }
        // draws vary across (rank, step)
        let a = s.compute_factor(0, 0);
        let b = s.compute_factor(1, 0);
        let c = s.compute_factor(0, 1);
        assert!(a != b || a != c);
    }

    /// Golden draw sequence for the pinned platform-stable hash path:
    /// these exact f64 bit patterns must come out of `stable_unit` on
    /// every OS/arch (cross-checked against an independent Python
    /// implementation of the SplitMix64 finalizer). A failure here
    /// means the scenario draw path changed and every seeded virtual
    /// time in every golden artifact silently moved.
    #[test]
    fn stable_unit_golden_sequence() {
        let golden: &[(u64, u64, u64, f64)] = &[
            (42, 0, 0, 0.6537157389870545),
            (42, 1, 0, 0.7415648787718233),
            (42, 0, 1, 0.6653188465641034),
            (7, 3, 10, 0.16231468011096262),
            (0xDEAD_BEEF, 123, 456, 0.2765967376101355),
        ];
        for &(seed, a, b, want) in golden {
            let got = stable_unit(seed, a, b);
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "stable_unit({seed},{a},{b}) = {got:?}, golden {want:?}"
            );
        }
        // and the compute_factor composition on top of it
        let s = Scenario { compute_jitter: 0.5, seed: 42, ..Scenario::default() };
        let f = s.compute_factor(0, 0);
        assert_eq!(f.to_bits(), (1.0 + 0.5 * 0.6537157389870545f64).to_bits());
    }

    #[test]
    fn link_flap_parse_and_factor() {
        let flaps = Scenario::parse_link_flaps("0:0.5-1.5:8, 2:1-2:4").unwrap();
        assert_eq!(
            flaps,
            vec![
                LinkFlap { node: 0, start_s: 0.5, end_s: 1.5, factor: 8.0 },
                LinkFlap { node: 2, start_s: 1.0, end_s: 2.0, factor: 4.0 },
            ]
        );
        assert_eq!(Scenario::parse_link_flaps("").unwrap(), vec![]);
        assert!(Scenario::parse_link_flaps("0:1-2").is_err(), "missing factor");
        assert!(Scenario::parse_link_flaps("0:2-1:8").is_err(), "inverted window");
        assert!(Scenario::parse_link_flaps("0:1-2:0.5").is_err(), "factor < 1");

        let s = Scenario { link_flaps: flaps, seed: 1, ..Scenario::default() };
        assert!(s.is_active());
        // inactive before the window, worst active flap inside it
        assert_eq!(s.flap_factor(0, 1, 0.25), 1.0);
        assert_eq!(s.flap_factor(0, 1, 0.5), 8.0, "start is inclusive");
        assert_eq!(s.flap_factor(1, 0, 1.0), 8.0, "either endpoint matches");
        assert_eq!(s.flap_factor(0, 2, 1.25), 8.0, "max over active flaps");
        assert_eq!(s.flap_factor(2, 3, 1.75), 4.0);
        assert_eq!(s.flap_factor(0, 1, 1.5), 1.0, "end is exclusive");
        assert_eq!(s.flap_factor(3, 4, 1.0), 1.0, "untouched nodes");
    }

    #[test]
    fn crash_parse_and_membership() {
        let crashes = Scenario::parse_crashes("2:3-5, 0:1-2").unwrap();
        assert_eq!(crashes, vec![(2, 3, 5), (0, 1, 2)]);
        assert_eq!(Scenario::parse_crashes("").unwrap(), vec![]);
        assert!(Scenario::parse_crashes("2:5-3").is_err(), "inverted window");
        assert!(Scenario::parse_crashes("2:3").is_err(), "missing rejoin");

        let s = Scenario { crashes, seed: 1, ..Scenario::default() };
        assert!(s.is_active());
        assert!(s.alive(2, 2));
        assert!(!s.alive(2, 3), "crash step is inclusive");
        assert!(!s.alive(2, 4));
        assert!(s.alive(2, 5), "rejoin step is exclusive");
        assert_eq!(s.alive_members(4, 0), vec![0, 1, 2, 3]);
        assert_eq!(s.alive_members(4, 1), vec![1, 2, 3]);
        assert_eq!(s.alive_members(4, 3), vec![0, 1, 3]);
        assert_eq!(s.alive_members(4, 5), vec![0, 1, 2, 3]);
    }
}
