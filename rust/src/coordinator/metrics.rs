//! Training metrics: per-step losses, exact wire-byte accounting and
//! codec timing — the raw material for every paper figure.

use crate::util::json::Json;
use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct StepMetrics {
    pub step: usize,
    pub loss: f32,
    /// accuracy / hit-rate / aux metric averaged over workers
    pub aux: f32,
    /// compressed bytes one worker contributes this step (container sizes)
    pub bytes_per_worker: u64,
    /// exact fabric traffic of the collective exchange this step, summed
    /// over all workers (0 unless a topology-aware schedule ran)
    pub fabric_bytes: u64,
    /// portion of `fabric_bytes` that stayed inside a node (equals
    /// `fabric_bytes` on a flat fabric; split per the `--topology` grid)
    pub intra_bytes: u64,
    /// portion of `fabric_bytes` that crossed a node boundary — the
    /// slow-link traffic the hierarchical schedule minimizes
    pub inter_bytes: u64,
    /// uncompressed dense gradient bytes (baseline volume)
    pub dense_bytes: u64,
    pub encode_s: f64,
    pub decode_s: f64,
    /// train-step (fwd+bwd) execution time summed over workers
    pub compute_s: f64,
    /// gradient-pipeline buckets per worker this step (0 when the
    /// compression pipeline did not run)
    pub bucket_count: u64,
    /// distinct `index|value` codec pairs the autotuner picked this
    /// step, sorted (the static pair when autotuning is off)
    pub autotune_choices: Vec<String>,
    /// modelled per-worker step time without encode/transfer overlap
    /// (mean over workers; measured encode + α–β transfer per bucket)
    pub pipeline_serial_s: f64,
    /// same with double-buffered overlap — the win is the gap to
    /// `pipeline_serial_s`
    pub pipeline_overlap_s: f64,
    /// **measured** step time on the virtual-time fabric: the
    /// critical-path virtual seconds from the step barrier to the last
    /// rank finishing its collective (0 on the instant fabric). When
    /// present this is the primary time number — it emerges from the
    /// actual schedule execution, unlike the modelled
    /// `pipeline_*`/α–β figures
    pub measured_step_s: f64,
    /// mean virtual seconds a rank spent idle this step (recv waits
    /// plus the end-of-step barrier) — the load-imbalance signal
    /// stragglers produce. `None` on the instant fabric, which does
    /// not measure idleness: it serialises as `null` so downstream
    /// plots don't average fake zeros into real measurements
    pub rank_idle_s: Option<f64>,
}

#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub name: String,
    pub workers: usize,
    pub steps: Vec<StepMetrics>,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        self.steps.last().map(|s| s.loss).unwrap_or(f32::NAN)
    }

    /// Mean aux metric over the last `k` steps (the "best quality" proxy).
    pub fn final_aux(&self, k: usize) -> f32 {
        let n = self.steps.len();
        if n == 0 {
            return f32::NAN;
        }
        let tail = &self.steps[n.saturating_sub(k)..];
        tail.iter().map(|s| s.aux).sum::<f32>() / tail.len() as f32
    }

    /// Total compressed bytes per worker over the run.
    pub fn total_bytes_per_worker(&self) -> u64 {
        self.steps.iter().map(|s| s.bytes_per_worker).sum()
    }

    /// Total collective fabric traffic over the run (all workers; 0 when
    /// no topology-aware schedule was configured).
    pub fn total_fabric_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.fabric_bytes).sum()
    }

    /// Fabric traffic split by link class over the run:
    /// `(intra_node, inter_node)` bytes.
    pub fn total_link_bytes(&self) -> (u64, u64) {
        (
            self.steps.iter().map(|s| s.intra_bytes).sum(),
            self.steps.iter().map(|s| s.inter_bytes).sum(),
        )
    }

    /// Volume relative to the no-compression baseline (the y-axis of
    /// Fig 6/9/15 and Table 2).
    pub fn relative_volume(&self) -> f64 {
        let dense: u64 = self.steps.iter().map(|s| s.dense_bytes).sum();
        if dense == 0 {
            return f64::NAN;
        }
        self.total_bytes_per_worker() as f64 / dense as f64
    }

    pub fn total_encode_s(&self) -> f64 {
        self.steps.iter().map(|s| s.encode_s).sum()
    }

    pub fn total_decode_s(&self) -> f64 {
        self.steps.iter().map(|s| s.decode_s).sum()
    }

    pub fn total_compute_s(&self) -> f64 {
        self.steps.iter().map(|s| s.compute_s).sum()
    }

    /// Every codec pair the autotuner picked over the run, sorted
    /// distinct (one entry — the static pair — when autotuning is off).
    pub fn distinct_autotune_choices(&self) -> Vec<String> {
        let set: std::collections::BTreeSet<String> =
            self.steps.iter().flat_map(|s| s.autotune_choices.iter().cloned()).collect();
        set.into_iter().collect()
    }

    /// Modelled step-time totals over the run: (serial, overlapped).
    pub fn pipeline_times_s(&self) -> (f64, f64) {
        (
            self.steps.iter().map(|s| s.pipeline_serial_s).sum(),
            self.steps.iter().map(|s| s.pipeline_overlap_s).sum(),
        )
    }

    /// Total **measured** virtual step time over the run (0 unless the
    /// run used the virtual-time fabric).
    pub fn total_measured_s(&self) -> f64 {
        self.steps.iter().map(|s| s.measured_step_s).sum()
    }

    /// Total mean-per-rank idle time over the run. Steps without an
    /// idle measurement (instant fabric) contribute 0.
    pub fn total_rank_idle_s(&self) -> f64 {
        self.steps.iter().filter_map(|s| s.rank_idle_s).sum()
    }

    /// JSON dump for post-processing / plotting.
    pub fn to_json(&self) -> Json {
        let steps: Vec<Json> = self
            .steps
            .iter()
            .map(|s| {
                let mut m = BTreeMap::new();
                m.insert("step".into(), Json::Num(s.step as f64));
                m.insert("loss".into(), Json::Num(s.loss as f64));
                m.insert("aux".into(), Json::Num(s.aux as f64));
                m.insert("bytes".into(), Json::Num(s.bytes_per_worker as f64));
                m.insert("fabric_bytes".into(), Json::Num(s.fabric_bytes as f64));
                m.insert("intra_bytes".into(), Json::Num(s.intra_bytes as f64));
                m.insert("inter_bytes".into(), Json::Num(s.inter_bytes as f64));
                m.insert("dense_bytes".into(), Json::Num(s.dense_bytes as f64));
                m.insert("encode_s".into(), Json::Num(s.encode_s));
                m.insert("decode_s".into(), Json::Num(s.decode_s));
                m.insert("compute_s".into(), Json::Num(s.compute_s));
                m.insert("bucket_count".into(), Json::Num(s.bucket_count as f64));
                m.insert(
                    "autotune_choices".into(),
                    Json::Arr(s.autotune_choices.iter().map(|c| Json::Str(c.clone())).collect()),
                );
                m.insert("pipeline_serial_s".into(), Json::Num(s.pipeline_serial_s));
                m.insert("pipeline_overlap_s".into(), Json::Num(s.pipeline_overlap_s));
                m.insert("measured_step_s".into(), Json::Num(s.measured_step_s));
                m.insert(
                    "rank_idle_s".into(),
                    match s.rank_idle_s {
                        Some(v) => Json::Num(v),
                        None => Json::Null,
                    },
                );
                Json::Obj(m)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("name".into(), Json::Str(self.name.clone()));
        top.insert("workers".into(), Json::Num(self.workers as f64));
        top.insert("relative_volume".into(), Json::Num(self.relative_volume()));
        top.insert("measured_total_s".into(), Json::Num(self.total_measured_s()));
        top.insert("rank_idle_total_s".into(), Json::Num(self.total_rank_idle_s()));
        top.insert("final_loss".into(), Json::Num(self.final_loss() as f64));
        top.insert("steps".into(), Json::Arr(steps));
        Json::Obj(top)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TrainReport {
        TrainReport {
            name: "t".into(),
            workers: 2,
            steps: (0..10)
                .map(|i| StepMetrics {
                    step: i,
                    loss: 10.0 - i as f32,
                    aux: i as f32 / 10.0,
                    bytes_per_worker: 100,
                    fabric_bytes: 30,
                    intra_bytes: 20,
                    inter_bytes: 10,
                    dense_bytes: 1000,
                    encode_s: 0.01,
                    decode_s: 0.02,
                    compute_s: 0.1,
                    bucket_count: 3,
                    autotune_choices: vec![if i < 5 { "raw|raw" } else { "elias|raw" }.into()],
                    pipeline_serial_s: 0.2,
                    pipeline_overlap_s: 0.15,
                    measured_step_s: 0.3,
                    rank_idle_s: Some(0.05),
                })
                .collect(),
        }
    }

    #[test]
    fn aggregates() {
        let r = sample();
        assert_eq!(r.final_loss(), 1.0);
        assert!((r.final_aux(3) - 0.8).abs() < 1e-6);
        assert_eq!(r.total_bytes_per_worker(), 1000);
        assert_eq!(r.total_fabric_bytes(), 300);
        assert_eq!(r.total_link_bytes(), (200, 100));
        assert!((r.relative_volume() - 0.1).abs() < 1e-9);
        assert!((r.total_encode_s() - 0.1).abs() < 1e-9);
        assert_eq!(r.distinct_autotune_choices(), vec!["elias|raw", "raw|raw"]);
        let (serial, overlap) = r.pipeline_times_s();
        assert!((serial - 2.0).abs() < 1e-9 && (overlap - 1.5).abs() < 1e-9);
        assert!((r.total_measured_s() - 3.0).abs() < 1e-9);
        assert!((r.total_rank_idle_s() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrips() {
        let j = sample().to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("workers").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("steps").unwrap().as_arr().unwrap().len(), 10);
    }

    #[test]
    fn unmeasured_idle_is_null_not_zero() {
        let mut r = sample();
        r.steps[0].rank_idle_s = None;
        let parsed = Json::parse(&r.to_json().to_string()).unwrap();
        let s0 = &parsed.get("steps").unwrap().as_arr().unwrap()[0];
        assert_eq!(s0.get("rank_idle_s"), Some(&Json::Null));
        // totals skip unmeasured steps instead of counting fake zeros
        assert!((r.total_rank_idle_s() - 0.45).abs() < 1e-9);
    }

    #[test]
    fn empty_report() {
        let r = TrainReport::default();
        assert!(r.final_loss().is_nan());
        assert!(r.relative_volume().is_nan());
    }
}
