//! L3 coordinator: the data-parallel training loop with DeepReduce on
//! the gradient exchange path.
//!
//! Per step, per worker: execute the train-step artifact on the worker's
//! shard → per-tensor error-feedback → sparsify → DeepReduce encode →
//! (byte-counted) allgather → decode → aggregate → optimizer. The leader
//! owns the parameters (rust is the parameter store; artifacts are
//! stateless).

mod metrics;
mod trainer;

pub use metrics::{StepMetrics, TrainReport};
pub use trainer::{CompressionSpec, ModelKind, TrainConfig, Trainer};
