//! The distributed trainer (leader + n simulated workers).

use super::metrics::{StepMetrics, TrainReport};
use crate::collective::sparse::{SegmentCodec, SparseAllreduce};
use crate::collective::{Comm, Endpoint, Network, Schedule, SparseConfig, Topology};
use crate::compress::{CodecRegistry, CodecSet, CompressSpec};
use crate::obs::{self, Lane, Span, SpanKind, StepWindow, TraceLevel, TraceReport, Tracer};
use crate::pipeline::{unfuse, Bucket, CostSource, GradientPipeline, StepTimeline};
use crate::runtime::{Artifact, BatchInput};
use crate::sparsify::{self, ErrorFeedback, Sparsifier};
use crate::tensor::{SparseTensor, Tensor};
use crate::util::json::Json;
use crate::vfabric::{Scenario, VirtualEndpoint, VirtualNetwork};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Which benchmark family an artifact belongs to (drives the dataset).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Mlp,
    Ncf,
    Transformer,
}

impl ModelKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "mlp" | "cifar" => ModelKind::Mlp,
            "ncf" => ModelKind::Ncf,
            "transformer" | "lm" => ModelKind::Transformer,
            _ => return None,
        })
    }
}

/// One DeepReduce instantiation on the gradient path.
#[derive(Clone, Debug)]
pub struct CompressionSpec {
    /// sparsifier name (`topk`, `randomk`, `threshold`, `identity`)
    pub sparsifier: String,
    /// r/d for topk/randomk; τ for threshold
    pub ratio: f64,
    /// the typed codec pipelines (index chain + value chain, stage
    /// parameters included) — see `compress::CompressSpec` and
    /// DESIGN.md §10. Replaces the old flat string codec fields
    /// (`index`/`index_param`/`value`/`value_param`); the string
    /// constructors below keep every legacy spelling parsing.
    pub compress: CompressSpec,
    /// error-feedback memory compensation (paper §6.3 enables it)
    pub error_feedback: bool,
    /// tensors smaller than this bypass compression (biases etc.)
    pub min_compress: usize,
    /// sparse allreduce schedule (see `collective::Schedule::parse`).
    /// Every schedule — including the default `gather_all` — runs the
    /// gradient sum over the in-process fabric, so `fabric_bytes` meters
    /// all of them comparably. Note: error feedback compensates codec
    /// loss only — `ring_rescatter` drops re-sparsified mass without
    /// feeding it back (the Ok-Topk approximation); use
    /// `ring_rescatter_exact` when exact sums matter
    pub schedule: String,
    /// node × rank grid in `NxR` form (CLI `--topology`, e.g. `2x4`);
    /// empty = flat. When set, the fabric meters intra vs inter bytes
    /// for *every* schedule, and `hierarchical` reduces over the grid.
    /// `nodes * ranks_per_node` must equal `workers`
    pub topology: String,
    /// inter-node schedule the hierarchical leaders run (CLI
    /// `--inner-schedule`; any flat schedule name, default `gather_all`)
    pub inner_schedule: String,
    /// `chunked_rescatter` chunk count (CLI `--chunks`), rounded up to
    /// a multiple of the world size; 0 = auto (one chunk per rank)
    pub chunks: usize,
    /// modelled intra-node link bandwidth, Mbps (CLI `--intra-mbps`;
    /// fast by default — node-local interconnects)
    pub intra_mbps: f64,
    /// modelled inter-node link bandwidth, Mbps (CLI `--inter-mbps`;
    /// the paper's 100 Mbps default — the slow boundary)
    pub inter_mbps: f64,
    /// gradient-pipeline bucket cap in bytes (fp32 elements × 4): the
    /// per-step tensor list is fused greedily into buckets of at most
    /// this size, each travelling as one sparse segment stream. 0 = one
    /// bucket per tensor (the legacy per-tensor path)
    pub bucket_bytes: usize,
    /// per-bucket cost-model codec autotuning (DESIGN.md §6): pick the
    /// index/value pair by measured density + calibrated throughput +
    /// α–β link model; off = always the static `index`/`value` pair
    pub autotune: bool,
    /// modelled link bandwidth (Mbps) the pipeline's α–β terms use —
    /// autotune comm costs and the `pipeline_{serial,overlap}_s`
    /// step-time metrics (matches the paper's 100 Mbps default)
    pub pipeline_link_mbps: f64,
    /// which fabric the gradient exchange runs on: `instant` (default;
    /// zero-time delivery, formula-only timing), `virtual` — the
    /// event-driven virtual-time fabric (`crate::vfabric`) that
    /// *measures* `measured_step_s`/`rank_idle_s` and enables the
    /// scenario knobs below — or `fleet`, the single-threaded
    /// event-loop twin (`crate::fleetsim`): same virtual clock and byte
    /// meters, no OS threads, scales to 10k+ ranks and supports
    /// `--crash`
    pub fabric: String,
    /// straggler list `R:F[,R:F…]` (CLI `--straggler`): rank R computes
    /// F× slower and its links run at β/F. Virtual fabric only;
    /// empty = none
    pub straggler: String,
    /// multiplicative compute-jitter amplitude σ (CLI
    /// `--compute-jitter`; virtual fabric only)
    pub compute_jitter: f64,
    /// multiplicative per-transfer jitter amplitude σ (CLI
    /// `--link-jitter`; virtual fabric only)
    pub link_jitter: f64,
    /// per-node inter-link bandwidth overrides `N:MBPS[,…]` (CLI
    /// `--node-mbps`; heterogeneous clusters, virtual fabric only)
    pub node_mbps: String,
    /// transient inter-link degradation windows
    /// `NODE:START-END:FACTOR[,…]` (CLI `--link-flap`; virtual and
    /// fleet fabrics)
    pub link_flap: String,
    /// rank crash/rejoin windows `R:A-B[,…]` (CLI `--crash`): rank R
    /// sits out steps `[A, B)` and its gradient is lost those steps
    /// (synchronous lost-worker semantics — the divisor stays the world
    /// size). Fleet fabric only, flat topology only
    pub crash: String,
    /// autotuner comm-cost source (CLI `--autotune-cost`): `formula`
    /// (α–β closed form) or `measured` (virtual-fabric feedback — see
    /// [`CostSource`])
    pub autotune_cost: String,
    /// structured tracing level (CLI `--trace`): `off` (default — the
    /// instrumentation reduces to a thread-local byte read), `step`
    /// (per-rank step anatomy: compute/exchange/barrier), or `full`
    /// (codec, wire, schedule rounds, port occupancy, recv waits).
    /// See `crate::obs` and DESIGN.md §11
    pub trace: String,
    pub seed: u64,
}

impl CompressionSpec {
    /// `DR_idx^val` on top of Top-r from a typed [`CompressSpec`] — the
    /// preferred construction route (chains, `key=value` parameters).
    pub fn with_spec(ratio: f64, compress: CompressSpec) -> Self {
        Self {
            sparsifier: "topk".into(),
            ratio,
            compress,
            error_feedback: true,
            min_compress: 1024,
            schedule: "gather_all".into(),
            topology: String::new(),
            inner_schedule: "gather_all".into(),
            chunks: 0,
            intra_mbps: 10_000.0,
            inter_mbps: 100.0,
            bucket_bytes: 0,
            autotune: false,
            pipeline_link_mbps: 100.0,
            fabric: "instant".into(),
            straggler: String::new(),
            compute_jitter: 0.0,
            link_jitter: 0.0,
            node_mbps: String::new(),
            link_flap: String::new(),
            crash: String::new(),
            autotune_cost: "formula".into(),
            trace: "off".into(),
            seed: 0xDEE9,
        }
    }

    /// `DR_idx^val` on top of Top-r, the paper's default arrangement.
    /// Legacy string shim over [`CompressionSpec::with_spec`]: `index`/
    /// `value` are codec spec strings (old plain spellings and chain
    /// specs both parse; panics on malformed syntax — the CLI path
    /// parses with proper errors before reaching this), and the two
    /// `f64`s map onto the head stages' declared legacy keys (bloom
    /// FPR; qsgd bits / fitpoly degree / sketch quantiles).
    pub fn topk(ratio: f64, index: &str, index_param: f64, value: &str, value_param: f64) -> Self {
        let mut compress = CompressSpec::parse(index, value)
            .unwrap_or_else(|e| panic!("bad codec spec {index:?}/{value:?}: {e}"));
        let registry = CodecRegistry::global();
        registry.apply_legacy_param(CodecSet::Index, &mut compress.index, index_param);
        registry.apply_legacy_param(CodecSet::Value, &mut compress.value, value_param);
        Self::with_spec(ratio, compress)
    }

    /// For inherently sparse models (NCF): no explicit sparsifier.
    pub fn identity(index: &str, index_param: f64, value: &str, value_param: f64) -> Self {
        let mut s = Self::topk(1.0, index, index_param, value, value_param);
        s.sparsifier = "identity".into();
        s.error_feedback = false;
        s
    }

    pub fn build_sparsifier(&self, worker_seed: u64) -> anyhow::Result<Box<dyn Sparsifier>> {
        sparsify::by_name(&self.sparsifier, self.ratio, self.seed ^ worker_seed)
            .ok_or_else(|| anyhow::anyhow!("unknown sparsifier {}", self.sparsifier))
    }

    pub fn label(&self) -> String {
        format!("DR[{}+{}]", self.sparsifier, self.compress.label())
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: ModelKind,
    /// artifact name under `artifacts/`
    pub artifact: String,
    pub workers: usize,
    pub steps: usize,
    pub optimizer: String,
    pub lr: f32,
    /// None = dense no-compression baseline
    pub compression: Option<CompressionSpec>,
    /// dense 3LC path (Fig 9 stand-alone baseline): sparsity multiplier
    pub dense_3lc: Option<f32>,
    pub seed: u64,
    /// print a progress line every k steps (0 = silent)
    pub log_every: usize,
}

impl TrainConfig {
    pub fn new(model: ModelKind, artifact: &str) -> Self {
        Self {
            model,
            artifact: artifact.to_string(),
            workers: 4,
            steps: 100,
            optimizer: match model {
                ModelKind::Mlp => "momentum".into(),
                _ => "adam".into(),
            },
            lr: match model {
                ModelKind::Ncf => 0.01,
                ModelKind::Transformer => 0.003,
                ModelKind::Mlp => 0.05,
            },
            compression: None,
            dense_3lc: None,
            seed: 42,
            log_every: 0,
        }
    }
}

enum Shard {
    Images(crate::data::SynthImages),
    Ncf(crate::data::SynthNcf),
    Corpus(crate::data::TinyCorpus),
}

impl Shard {
    fn next_batch(&mut self) -> Vec<BatchInput> {
        match self {
            Shard::Images(d) => d.next_batch(),
            Shard::Ncf(d) => d.next_batch(),
            Shard::Corpus(d) => d.next_batch(),
        }
    }
}

/// One step's work for a rank's persistent collective worker.
struct StepJob {
    /// decoded fused buckets to allreduce, in bucket order
    tensors: Vec<SparseTensor>,
    /// local busy time (compute + codec, scenario-scaled) to book on
    /// the virtual clock before entering the exchange (0 on the
    /// instant fabric)
    advance_s: f64,
    /// step barrier: the virtual time the previous step ended at
    sync_to: f64,
}

/// One rank's step result. Only rank 0 ships the summed tensors back
/// (all ranks hold identical sums; n copies would be pure overhead).
struct StepOut {
    tensors: Option<Vec<SparseTensor>>,
    /// virtual clock when the rank entered the exchange
    start_s: f64,
    /// virtual clock when the rank finished the exchange
    end_s: f64,
    /// recv-wait idle accumulated during this step
    idle_s: f64,
}

/// The fabric a collective pool runs on. Both variants expose the same
/// per-link-class byte meters.
enum FabricHandle {
    Instant(Network),
    Virtual(VirtualNetwork),
}

impl FabricHandle {
    fn total_bytes(&self) -> u64 {
        match self {
            FabricHandle::Instant(n) => n.total_bytes(),
            FabricHandle::Virtual(n) => n.total_bytes(),
        }
    }

    fn intra_bytes(&self) -> u64 {
        match self {
            FabricHandle::Instant(n) => n.intra_bytes(),
            FabricHandle::Virtual(n) => n.intra_bytes(),
        }
    }

    fn inter_bytes(&self) -> u64 {
        match self {
            FabricHandle::Instant(n) => n.inter_bytes(),
            FabricHandle::Virtual(n) => n.inter_bytes(),
        }
    }

    fn reset_bytes(&self) {
        match self {
            FabricHandle::Instant(n) => n.reset_bytes(),
            FabricHandle::Virtual(n) => n.reset_bytes(),
        }
    }
}

/// A rank's endpoint on either fabric, so the pool workers run the
/// schedules unchanged on instant or virtual time.
enum AnyEndpoint {
    Instant(Endpoint),
    Virtual(VirtualEndpoint),
}

impl Comm for AnyEndpoint {
    fn rank(&self) -> usize {
        match self {
            AnyEndpoint::Instant(e) => e.rank(),
            AnyEndpoint::Virtual(e) => e.rank(),
        }
    }

    fn world(&self) -> usize {
        match self {
            AnyEndpoint::Instant(e) => e.world(),
            AnyEndpoint::Virtual(e) => e.world(),
        }
    }

    fn send(&self, dst: usize, payload: Vec<u8>) {
        match self {
            AnyEndpoint::Instant(e) => e.send(dst, payload),
            AnyEndpoint::Virtual(e) => e.send(dst, payload),
        }
    }

    fn recv(&self, src: usize) -> Vec<u8> {
        match self {
            AnyEndpoint::Instant(e) => e.recv(src),
            AnyEndpoint::Virtual(e) => e.recv(src),
        }
    }
}

impl AnyEndpoint {
    /// Virtual-time hooks; no-ops on the instant fabric.
    fn sync_to(&self, t: f64) {
        if let AnyEndpoint::Virtual(e) = self {
            e.sync_to(t);
        }
    }

    fn elapse(&self, dt: f64) {
        if let AnyEndpoint::Virtual(e) = self {
            e.elapse(dt);
        }
    }

    fn now(&self) -> f64 {
        match self {
            AnyEndpoint::Instant(_) => 0.0,
            AnyEndpoint::Virtual(e) => e.now(),
        }
    }

    fn idle_s(&self) -> f64 {
        match self {
            AnyEndpoint::Instant(_) => 0.0,
            AnyEndpoint::Virtual(e) => e.idle_s(),
        }
    }
}

/// The persistent collective machinery: one fabric plus one long-lived
/// worker thread per rank, each owning its endpoint, schedule, and
/// segment codec. Built once in [`Trainer::new`] and reused by every
/// step (the old per-step fabric/thread churn was pure overhead — and
/// would have reset the virtual clocks).
struct CollectivePool {
    fabric: FabricHandle,
    jobs: Vec<Sender<StepJob>>,
    results: Vec<Receiver<anyhow::Result<StepOut>>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// the virtual time the last completed step ended at (the next
    /// step's barrier)
    virtual_now: f64,
}

impl CollectivePool {
    fn new(
        fabric: FabricHandle,
        sched: Schedule,
        cfg: SparseConfig,
        spec: &CompressionSpec,
        workers: usize,
        tracer: Option<Arc<Tracer>>,
    ) -> anyhow::Result<Self> {
        let endpoints: Vec<AnyEndpoint> = match &fabric {
            FabricHandle::Instant(net) => {
                net.try_endpoints_for(workers)?.into_iter().map(AnyEndpoint::Instant).collect()
            }
            FabricHandle::Virtual(net) => {
                let eps = net.try_endpoints()?;
                anyhow::ensure!(
                    eps.len() == workers,
                    "virtual fabric has {} ranks but the trainer expected {workers}",
                    eps.len()
                );
                eps.into_iter().map(AnyEndpoint::Virtual).collect()
            }
        };
        let mut jobs = Vec::with_capacity(workers);
        let mut results = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for ep in endpoints {
            // segments reuse the spec's codecs where they are lossless
            // (chains included); lossy stages fall back to raw
            let codec = SegmentCodec::lossless_or_raw(&spec.compress, spec.seed, cfg.dense_switch);
            let sr = sched.build_with(cfg, codec);
            let (jtx, jrx) = channel::<StepJob>();
            let (rtx, rrx) = channel::<anyhow::Result<StepOut>>();
            let tr = tracer.clone();
            handles.push(std::thread::spawn(move || worker_loop(ep, sr, jrx, rtx, tr)));
            jobs.push(jtx);
            results.push(rrx);
        }
        Ok(Self { fabric, jobs, results, handles, virtual_now: 0.0 })
    }

    /// Explicit graceful teardown: close the job channels, drain any
    /// in-flight step results, and join every worker thread. Returns
    /// the number of threads joined (0 on repeat calls — shutdown is
    /// idempotent and `Drop` delegates here). A worker stuck
    /// mid-collective is unblocked by its failing peer's endpoint drop
    /// ("peer hung up"), so the drain and joins cannot hang.
    fn shutdown(&mut self) -> usize {
        // closing every job sender ends the workers' receive loops
        self.jobs.clear();
        // drain in-flight results until each worker drops its sender —
        // a step submitted but never collected must complete, not leak
        for rx in self.results.drain(..) {
            while rx.recv().is_ok() {}
        }
        let joined = self.handles.len();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        joined
    }
}

impl Drop for CollectivePool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Body of one persistent collective worker thread.
fn worker_loop(
    ep: AnyEndpoint,
    sr: Box<dyn SparseAllreduce>,
    jobs: Receiver<StepJob>,
    results: Sender<anyhow::Result<StepOut>>,
    tracer: Option<Arc<Tracer>>,
) {
    let rank = ep.rank();
    let _bind = tracer.as_ref().map(|t| t.install(rank));
    while let Ok(job) = jobs.recv() {
        ep.sync_to(job.sync_to);
        {
            // replayed local busy time: the compute share of the rank's
            // virtual timeline (a point in wall time)
            let mut sp = obs::span(SpanKind::Compute);
            sp.label_with(|| "replay".to_string());
            ep.elapse(job.advance_s);
        }
        let start_s = ep.now();
        let idle0 = ep.idle_s();
        let mut summed = Vec::with_capacity(job.tensors.len());
        let mut failure: Option<anyhow::Error> = None;
        {
            let mut ex = obs::span(SpanKind::Exchange);
            ex.label_with(|| sr.name().to_string());
            // per-tensor collectives run in order, so messages stay
            // matched on the pairwise FIFO channels
            for (bi, t) in job.tensors.into_iter().enumerate() {
                let mut bsp = obs::span(SpanKind::Bucket);
                bsp.label_with(|| format!("bucket {bi}"));
                bsp.set_bytes(t.nnz() as u64 * 8);
                match sr.allreduce(&ep, t) {
                    Ok(r) => summed.push(r),
                    Err(e) => {
                        failure = Some(e);
                        break;
                    }
                }
            }
        }
        // merge this thread's span buffer before the trainer can drain
        // the step (it only does so after receiving every result)
        obs::flush();
        let out = match failure {
            Some(e) => Err(anyhow::anyhow!("rank {rank} sparse allreduce failed: {e}")),
            None => Ok(StepOut {
                tensors: (rank == 0).then_some(summed),
                start_s,
                end_s: ep.now(),
                idle_s: ep.idle_s() - idle0,
            }),
        };
        let failed = out.is_err();
        if results.send(out).is_err() || failed {
            // trainer gone, or this rank failed: drop the endpoint so
            // peers unblock ("peer hung up") instead of deadlocking
            break;
        }
    }
}

/// The fleet-fabric counterpart of [`CollectivePool`]: no threads, no
/// channels — every rank's collective runs as a state machine inside
/// the shared fleet event loop, on the same virtual clock and byte
/// meters as the threaded virtual fabric. This is the path that scales
/// past thread-per-rank (10k+ ranks) and the one that supports elastic
/// membership (`--crash`).
///
/// Since the service refactor the trainer no longer owns the fabric:
/// it is a single-tenant *client* of
/// [`crate::service::ReductionService`] — same admission, metering, and
/// accounting path as the multi-tenant `serve` daemon, with an
/// unmetered frame budget (fair-share is moot for one tenant).
struct FleetPool {
    service: crate::service::ReductionService,
    job: crate::service::JobId,
    /// the virtual time the last completed step ended at
    virtual_now: f64,
}

impl FleetPool {
    /// Run one step's exchange: replay each alive rank's busy time,
    /// then allreduce every bucket over the alive membership. Returns
    /// the summed buckets plus `(start, end, idle)` per world rank
    /// (crashed ranks report a zero-width window at the barrier).
    #[allow(clippy::type_complexity)]
    fn exchange(
        &mut self,
        pending: Vec<Vec<SparseTensor>>,
        advance_s: &[f64],
        step_start: f64,
        step: usize,
        scenario: &Scenario,
    ) -> anyhow::Result<(Vec<SparseTensor>, Vec<(f64, f64, f64)>)> {
        let n = self.service.world();
        let alive = scenario.alive_members(n, step);
        anyhow::ensure!(!alive.is_empty(), "every rank is crashed at step {step}");
        for &r in &alive {
            self.service.sync_member(r, step_start);
            self.service.elapse_member(r, advance_s[r]);
        }
        let starts: Vec<f64> = (0..n).map(|r| self.service.clock_s(r)).collect();
        let idle0: Vec<f64> = (0..n).map(|r| self.service.idle_s(r)).collect();
        let buckets = pending[alive[0]].len();
        let mut feeds: Vec<std::vec::IntoIter<SparseTensor>> =
            pending.into_iter().map(|v| v.into_iter()).collect();
        let mut summed = Vec::with_capacity(buckets);
        for _ in 0..buckets {
            let inputs: Vec<SparseTensor> = alive
                .iter()
                .map(|&r| feeds[r].next().expect("bucket counts match across ranks"))
                .collect();
            let outs = self.service.collective(self.job, &alive, inputs)?;
            // all members hold identical sums; keep the first
            summed.push(outs.into_iter().next().expect("nonempty membership"));
        }
        let windows = (0..n)
            .map(|r| {
                if scenario.alive(r, step) {
                    (starts[r], self.service.clock_s(r), self.service.idle_s(r) - idle0[r])
                } else {
                    (step_start, step_start, 0.0)
                }
            })
            .collect();
        Ok((summed, windows))
    }

    /// Metered fabric bytes attributed to the trainer's job so far,
    /// `[intra, inter]`.
    fn job_bytes(&self) -> [u64; 2] {
        self.service.job(self.job).map(|j| j.bytes).unwrap_or([0, 0])
    }

    /// Retire the job: release its ranks and fair share in the service.
    /// Idempotent; returns whether this call retired it.
    fn shutdown(&mut self) -> bool {
        let was_running = self
            .service
            .job(self.job)
            .is_some_and(|j| j.state == crate::service::JobState::Running);
        let _ = self.service.finish(self.job);
        was_running
    }
}

impl Drop for FleetPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

pub struct Trainer {
    cfg: TrainConfig,
    artifact: Artifact,
    params: Vec<Tensor>,
    opt: Box<dyn crate::optim::Optimizer>,
    shards: Vec<Shard>,
    sparsifiers: Vec<Box<dyn Sparsifier>>,
    /// Some(_) whenever compression is on: the bucketed gradient
    /// pipeline (fuse → per-bucket codec → encode/decode) the step
    /// drives instead of a per-tensor codec loop
    pipeline: Option<GradientPipeline>,
    threelc: Option<crate::baselines::ThreeLC>,
    /// `ef[worker][tensor]`
    ef: Vec<Vec<ErrorFeedback>>,
    /// Some(_) whenever compression is on and the fabric is threaded:
    /// the persistent fabric + worker threads that run the gradient
    /// exchange every step
    pool: Option<CollectivePool>,
    /// Some(_) when `--fabric fleet`: the inline event-loop exchange
    /// (mutually exclusive with `pool`)
    fleet: Option<FleetPool>,
    /// parsed scenario knobs (trivial unless the virtual fabric is on)
    scenario: Scenario,
    /// whether the exchange runs on the virtual-time fabric
    fabric_virtual: bool,
    /// Some(_) when `--trace` is `step` or `full`: the process-wide
    /// span collector every instrumented layer writes through
    tracer: Option<Arc<Tracer>>,
    /// spans drained so far, stamped with their step id
    trace_spans: Vec<Span>,
    /// per-step timing envelopes the span attribution reconciles with
    trace_steps: Vec<StepWindow>,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> anyhow::Result<Self> {
        let artifact = Artifact::load_default(&cfg.artifact)?;
        let params = artifact.init_params(cfg.seed);
        let opt = crate::optim::by_name(&cfg.optimizer, cfg.lr)
            .ok_or_else(|| anyhow::anyhow!("unknown optimizer {}", cfg.optimizer))?;
        let man = &artifact.manifest;
        let cu = |k: &str| -> anyhow::Result<usize> {
            man.config_usize(k).ok_or_else(|| anyhow::anyhow!("manifest missing config {k}"))
        };
        let mut shards = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            shards.push(match cfg.model {
                ModelKind::Mlp => Shard::Images(crate::data::SynthImages::shard(
                    cu("input_dim")?,
                    cu("classes")?,
                    cu("batch")?,
                    cfg.seed,
                    w,
                )),
                ModelKind::Ncf => Shard::Ncf(crate::data::SynthNcf::shard(
                    cu("users")?,
                    cu("items")?,
                    cu("batch")?,
                    cfg.seed,
                    w,
                )),
                ModelKind::Transformer => Shard::Corpus(crate::data::TinyCorpus::shard(
                    cu("vocab")?,
                    cu("seq")?,
                    cu("batch")?,
                    cfg.seed,
                    w,
                )),
            });
        }
        let threelc = cfg.dense_3lc.map(crate::baselines::ThreeLC::new);
        let ef_all = |params: &[Tensor]| {
            (0..cfg.workers)
                .map(|_| params.iter().map(|p| ErrorFeedback::new(p.numel())).collect::<Vec<_>>())
                .collect::<Vec<_>>()
        };
        let collective_schedule = match &cfg.compression {
            Some(spec) => Some(Schedule::parse(&spec.schedule).ok_or_else(|| {
                anyhow::anyhow!("unknown collective schedule {}", spec.schedule)
            })?),
            None => None,
        };
        // the two-level grid: validated against the worker count, fed to
        // the fabric (per-class byte meters) and to every schedule build
        let (topology, sparse_cfg) = match &cfg.compression {
            Some(spec) => {
                let topo = if spec.topology.is_empty() {
                    None
                } else {
                    let t = Topology::parse(&spec.topology).ok_or_else(|| {
                        anyhow::anyhow!("bad topology {:?}, expected NxR (e.g. 2x4)", spec.topology)
                    })?;
                    anyhow::ensure!(
                        t.world() == cfg.workers,
                        "topology {} describes {} ranks but --workers is {}",
                        t.label(),
                        t.world(),
                        cfg.workers
                    );
                    Some(t)
                };
                let inner = Schedule::parse(&spec.inner_schedule).ok_or_else(|| {
                    anyhow::anyhow!("unknown inner schedule {}", spec.inner_schedule)
                })?;
                anyhow::ensure!(
                    inner != Schedule::Hierarchical,
                    "--inner-schedule must be a flat schedule"
                );
                (
                    topo,
                    SparseConfig {
                        topology: topo,
                        inner,
                        chunks: spec.chunks,
                        ..SparseConfig::default()
                    },
                )
            }
            None => (None, SparseConfig::default()),
        };
        let (sparsifiers, mut pipeline, ef) = match &cfg.compression {
            None if threelc.is_some() => (Vec::new(), None, ef_all(&params)),
            None => (Vec::new(), None, Vec::new()),
            Some(spec) => {
                let sp = (0..cfg.workers)
                    .map(|w| spec.build_sparsifier(w as u64))
                    .collect::<anyhow::Result<Vec<_>>>()?;
                // compressible tensors in exchange order; smaller ones
                // bypass the pipeline (raw kv on the wire)
                let members: Vec<(usize, usize)> = params
                    .iter()
                    .enumerate()
                    .filter(|(_, p)| p.numel() >= spec.min_compress)
                    .map(|(ti, p)| (ti, p.numel()))
                    .collect();
                let pipeline = GradientPipeline::new(
                    &members,
                    spec.bucket_bytes,
                    spec.autotune,
                    spec.error_feedback,
                    &spec.compress,
                    spec.seed,
                    crate::simnet::Link::mbps(spec.pipeline_link_mbps),
                    cfg.workers,
                )?;
                let ef = (0..cfg.workers)
                    .map(|_| {
                        params.iter().map(|p| ErrorFeedback::new(p.numel())).collect::<Vec<_>>()
                    })
                    .collect();
                (sp, Some(pipeline), ef)
            }
        };
        if let (Some(pipe), Some(topo), Some(spec)) =
            (pipeline.as_mut(), topology, cfg.compression.as_ref())
        {
            // per-hop codec advice for the two-level exchange (only
            // surfaces when autotuning is on)
            pipe.set_hierarchy(
                topo,
                crate::simnet::Link::mbps(spec.intra_mbps),
                crate::simnet::Link::mbps(spec.inter_mbps),
            );
        }
        if let (Some(pipe), Some(spec)) = (pipeline.as_mut(), cfg.compression.as_ref()) {
            let source = CostSource::parse(&spec.autotune_cost).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown autotune cost source {} (expected formula|measured)",
                    spec.autotune_cost
                )
            })?;
            pipe.set_cost_source(source);
        }
        // tracing: the collector is only constructed above `off`, so the
        // default path keeps its zero-overhead contract (no tracer, and
        // every obs entry point gates on a thread-local byte)
        let tracer = match cfg.compression.as_ref() {
            Some(spec) => {
                let level = TraceLevel::parse(&spec.trace)?;
                (level != TraceLevel::Off).then(|| Tracer::new(level, cfg.workers))
            }
            None => None,
        };
        // the persistent collective machinery: fabric + one worker
        // thread per rank, built once here and reused by every step
        let (pool, fleet, scenario, fabric_virtual) =
            match (cfg.compression.as_ref(), collective_schedule) {
                (Some(spec), Some(sched)) => {
                    let fabric_fleet = matches!(spec.fabric.as_str(), "fleet" | "fleetsim");
                    let fabric_virtual = fabric_fleet
                        || match spec.fabric.as_str() {
                            "" | "instant" | "fleet" | "fleetsim" => false,
                            "virtual" | "vfabric" | "event" => true,
                            other => {
                                anyhow::bail!(
                                    "unknown fabric {other} (expected instant|virtual|fleet)"
                                )
                            }
                        };
                    let scenario = Scenario {
                        stragglers: Scenario::parse_stragglers(&spec.straggler)?,
                        compute_jitter: spec.compute_jitter,
                        link_jitter: spec.link_jitter,
                        node_mbps: Scenario::parse_node_mbps(&spec.node_mbps)?,
                        link_flaps: Scenario::parse_link_flaps(&spec.link_flap)?,
                        crashes: Scenario::parse_crashes(&spec.crash)?,
                        seed: spec.seed,
                    };
                    let grid = topology.unwrap_or_else(|| Topology::flat(cfg.workers));
                    for &(r, _) in &scenario.stragglers {
                        anyhow::ensure!(
                            r < cfg.workers,
                            "straggler rank {r} out of range (workers = {})",
                            cfg.workers
                        );
                    }
                    for &(m, _) in &scenario.node_mbps {
                        anyhow::ensure!(
                            m < grid.nodes,
                            "node-mbps node {m} out of range (nodes = {})",
                            grid.nodes
                        );
                    }
                    for f in &scenario.link_flaps {
                        anyhow::ensure!(
                            f.node < grid.nodes,
                            "link-flap node {} out of range (nodes = {})",
                            f.node,
                            grid.nodes
                        );
                    }
                    for &(r, _, _) in &scenario.crashes {
                        anyhow::ensure!(
                            r < cfg.workers,
                            "crash rank {r} out of range (workers = {})",
                            cfg.workers
                        );
                    }
                    anyhow::ensure!(
                        fabric_virtual || !scenario.is_active(),
                        "--straggler / --compute-jitter / --link-jitter / --node-mbps / \
                         --link-flap / --crash require --fabric virtual or fleet"
                    );
                    // elastic membership only works where the collective
                    // can run over a rank subset: the fleet event loop
                    // with a flat grid (a two-level hierarchy pins ranks
                    // to leader roles that a crash would orphan)
                    anyhow::ensure!(
                        scenario.crashes.is_empty() || fabric_fleet,
                        "--crash requires --fabric fleet"
                    );
                    anyhow::ensure!(
                        scenario.crashes.is_empty() || spec.topology.is_empty(),
                        "--crash requires a flat topology"
                    );
                    anyhow::ensure!(
                        fabric_virtual
                            || CostSource::parse(&spec.autotune_cost)
                                != Some(CostSource::Measured),
                        "--autotune-cost measured requires --fabric virtual \
                         (the feedback is measured on the virtual clock)"
                    );
                    if fabric_fleet {
                        // single-tenant client of the reduction service:
                        // same fabric, admission, and per-job metering
                        // path as the multi-tenant `serve` daemon, with
                        // the frame budget unmetered (no peers to be
                        // fair to) and the trainer's exact SparseConfig
                        // threaded through verbatim
                        let svc_cfg = crate::service::ServiceConfig::new(
                            grid,
                            crate::simnet::Link::mbps(spec.intra_mbps),
                            crate::simnet::Link::mbps(spec.inter_mbps),
                        )
                        .unmetered()
                        .with_scenario(scenario.clone());
                        let mut service = crate::service::ReductionService::new(svc_cfg);
                        let job = service
                            .submit(crate::service::JobRequest {
                                name: "train".into(),
                                model: cfg.artifact.clone(),
                                ranks: cfg.workers,
                                weight: 1.0,
                                // the byte estimate only matters for
                                // fair-share metering, which is off here
                                dim: 1,
                                density: 1.0,
                                schedule: sched,
                                chunks: sparse_cfg.chunks,
                                compress: spec.compress.clone(),
                                autotune: false,
                                seed: spec.seed,
                                sparse: Some(sparse_cfg),
                            })
                            .map_err(|e| anyhow::anyhow!("trainer job admission: {e}"))?;
                        let fleet = FleetPool { service, job, virtual_now: 0.0 };
                        (None, Some(fleet), scenario, fabric_virtual)
                    } else {
                        let fabric = if fabric_virtual {
                            FabricHandle::Virtual(VirtualNetwork::new(
                                grid,
                                crate::simnet::Link::mbps(spec.intra_mbps),
                                crate::simnet::Link::mbps(spec.inter_mbps),
                                scenario.clone(),
                            ))
                        } else {
                            FabricHandle::Instant(match topology {
                                Some(t) => Network::with_topology(t),
                                None => Network::new(cfg.workers),
                            })
                        };
                        let pool = CollectivePool::new(
                            fabric,
                            sched,
                            sparse_cfg,
                            spec,
                            cfg.workers,
                            tracer.clone(),
                        )?;
                        (Some(pool), None, scenario, fabric_virtual)
                    }
                }
                _ => (None, None, Scenario::none(cfg.seed), false),
            };
        Ok(Self {
            cfg,
            artifact,
            params,
            opt,
            shards,
            sparsifiers,
            pipeline,
            threelc,
            ef,
            pool,
            fleet,
            scenario,
            fabric_virtual,
            tracer,
            trace_spans: Vec::new(),
            trace_steps: Vec::new(),
        })
    }

    pub fn params(&self) -> &[Tensor] {
        &self.params
    }

    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    /// Explicit graceful teardown of the collective machinery: drain
    /// in-flight steps and join the pool's worker threads (threaded
    /// fabrics), or retire the trainer's job in the reduction service
    /// (fleet fabric). Idempotent; `Drop` performs the same teardown,
    /// this just makes the ordering deterministic for callers that keep
    /// the `Trainer` alive after training.
    pub fn shutdown(&mut self) {
        if let Some(pool) = self.pool.as_mut() {
            pool.shutdown();
        }
        if let Some(fleet) = self.fleet.as_mut() {
            fleet.shutdown();
        }
    }

    /// Run the configured number of steps, returning the full report.
    pub fn run(&mut self) -> anyhow::Result<TrainReport> {
        let mut report = TrainReport {
            name: self
                .cfg
                .compression
                .as_ref()
                .map(|c| c.label())
                .unwrap_or_else(|| {
                    if self.threelc.is_some() { "3lc".into() } else { "baseline".into() }
                }),
            workers: self.cfg.workers,
            steps: Vec::with_capacity(self.cfg.steps),
        };
        for step in 0..self.cfg.steps {
            let m = self.step(step)?;
            if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                eprintln!(
                    "[{}] step {:>5}  loss {:.4}  aux {:.4}  bytes/worker {}",
                    report.name, step, m.loss, m.aux, m.bytes_per_worker
                );
            }
            report.steps.push(m);
        }
        Ok(report)
    }

    /// One synchronous data-parallel step across all workers.
    pub fn step(&mut self, step: usize) -> anyhow::Result<StepMetrics> {
        let step_wall0 = Instant::now();
        let n = self.cfg.workers;
        let total_params = self.artifact.manifest.total_params();
        let mut agg: Vec<Vec<f32>> = self.params.iter().map(|p| vec![0.0; p.numel()]).collect();
        // per-worker decoded fused buckets in bucket order (identical
        // across workers), for the fabric gradient exchange
        let mut pending: Vec<Vec<SparseTensor>> = (0..n).map(|_| Vec::new()).collect();
        // the step-invariant bucket layout (cloned out so worker-local
        // mutable borrows of the pipeline stay disjoint)
        let buckets: Vec<Bucket> = self
            .pipeline
            .as_ref()
            .map(|p| p.plan().buckets.clone())
            .unwrap_or_default();
        let mut metrics = StepMetrics {
            step,
            dense_bytes: (total_params * 4) as u64, // one worker's dense payload
            ..Default::default()
        };
        // per-worker measured local busy time (compute + codec) — the
        // base the virtual fabric replays, scenario-scaled, before the
        // exchange
        let mut busy_s = vec![0.0f64; n];
        // bucketed container bytes only (excludes the below-min_compress
        // bypass, which never crosses the collective) — the denominator
        // of the measured-cost feedback
        let mut bucketed_bytes = 0u64;
        for w in 0..n {
            let batch = self.shards[w].next_batch();
            // bind this thread to rank w while its share of the step is
            // prepared, so coordinator-side spans land on its lanes
            let _bind = self.tracer.as_ref().map(|t| t.install(w));
            let t0 = Instant::now();
            let out = {
                let _sp = obs::span(SpanKind::Compute);
                self.artifact.train_step(&self.params, &batch)?
            };
            let compute = t0.elapsed().as_secs_f64();
            metrics.compute_s += compute;
            busy_s[w] += compute;
            metrics.loss += out.loss / n as f32;
            metrics.aux += out.aux / n as f32;

            match (&mut self.pipeline, self.cfg.compression.as_ref()) {
                (Some(pipe), Some(spec)) => {
                    // stage 1: per-tensor error feedback + sparsify;
                    // tensors below min_compress bypass the pipeline
                    let mut prepared: Vec<Option<(Vec<f32>, SparseTensor)>> =
                        (0..out.grads.len()).map(|_| None).collect();
                    {
                        let _sp = obs::span(SpanKind::Sparsify);
                        for (ti, grad) in out.grads.iter().enumerate() {
                            let flat = grad.data();
                            if flat.len() < spec.min_compress {
                                // bypass: raw kv on the wire
                                metrics.bytes_per_worker += (flat.len() * 4) as u64;
                                for (a, &g) in agg[ti].iter_mut().zip(flat) {
                                    *a += g;
                                }
                                continue;
                            }
                            let corrected: Vec<f32> = if spec.error_feedback {
                                self.ef[w][ti].apply(flat)
                            } else {
                                flat.to_vec()
                            };
                            let sp = self.sparsifiers[w].sparsify(&corrected);
                            prepared[ti] = Some((corrected, sp));
                        }
                    }
                    // stage 2: fuse each bucket, pick its codec, encode
                    // and locally decode; the decoded fused payload is
                    // what the collective sums
                    let mut timeline = StepTimeline::new();
                    for (bi, bucket) in buckets.iter().enumerate() {
                        let parts: Vec<&SparseTensor> = bucket
                            .tensors
                            .iter()
                            .map(|&ti| {
                                let p = prepared[ti].as_ref().expect("bucketed tensor prepared");
                                &p.1
                            })
                            .collect();
                        let dense_parts: Vec<&[f32]> = bucket
                            .tensors
                            .iter()
                            .map(|&ti| {
                                let p = prepared[ti].as_ref().expect("bucketed tensor prepared");
                                p.0.as_slice()
                            })
                            .collect();
                        let enc = {
                            let mut sp = obs::span(SpanKind::Encode);
                            sp.label_with(|| format!("bucket {bi}"));
                            let enc = pipe.encode_bucket(bucket, &parts, &dense_parts)?;
                            sp.set_bytes(enc.wire_bytes);
                            enc
                        };
                        metrics.encode_s += enc.encode_s;
                        metrics.decode_s += enc.decode_s;
                        busy_s[w] += enc.encode_s + enc.decode_s;
                        // bytes_per_worker is always the container upload
                        // volume (keeps relative_volume comparable across
                        // schedules); collective traffic is metered
                        // separately as fabric_bytes
                        metrics.bytes_per_worker += enc.wire_bytes;
                        bucketed_bytes += enc.wire_bytes;
                        timeline.push(enc.encode_s, enc.comm_model_s);
                        if !metrics.autotune_choices.contains(&enc.choice_label) {
                            metrics.autotune_choices.push(enc.choice_label.clone());
                        }
                        // per-hop advice on a two-level grid, reported
                        // alongside the container pick (inter only when
                        // the grid actually has inter-node links)
                        if let Some((leader, inter)) = &enc.hier_choices {
                            let mut labels = vec![format!("intra:{leader}")];
                            if let Some(inter) = inter {
                                labels.push(format!("inter:{inter}"));
                            }
                            for lbl in labels {
                                if !metrics.autotune_choices.contains(&lbl) {
                                    metrics.autotune_choices.push(lbl);
                                }
                            }
                        }
                        if spec.error_feedback {
                            // residual vs what was actually reconstructed
                            let dec_parts = unfuse(bucket, &enc.decoded);
                            for (j, &ti) in bucket.tensors.iter().enumerate() {
                                let corrected =
                                    &prepared[ti].as_ref().expect("bucketed tensor prepared").0;
                                self.ef[w][ti].update(corrected, &dec_parts[j]);
                            }
                        }
                        pending[w].push(enc.decoded);
                    }
                    // modelled step-time accounting (mean over workers)
                    metrics.pipeline_serial_s += timeline.serial_s() / n as f64;
                    metrics.pipeline_overlap_s += timeline.pipelined_s() / n as f64;
                    if w == 0 {
                        metrics.bucket_count = buckets.len() as u64;
                    }
                }
                _ if self.threelc.is_some() => {
                    let tlc = self.threelc.as_ref().unwrap();
                    for (ti, grad) in out.grads.iter().enumerate() {
                        let corrected = self.ef[w][ti].apply(grad.data());
                        let t1 = Instant::now();
                        let enc = tlc.encode(&corrected);
                        metrics.encode_s += t1.elapsed().as_secs_f64();
                        metrics.bytes_per_worker += enc.len() as u64;
                        let t2 = Instant::now();
                        let dec = tlc.decode(&enc)?;
                        metrics.decode_s += t2.elapsed().as_secs_f64();
                        let kept = SparseTensor::from_dense(&dec);
                        self.ef[w][ti].update(&corrected, &kept);
                        for (a, &g) in agg[ti].iter_mut().zip(&dec) {
                            *a += g;
                        }
                    }
                }
                _ => {
                    // dense baseline: full gradient on the wire
                    metrics.bytes_per_worker += (total_params * 4) as u64 / n as u64;
                    for (ti, grad) in out.grads.iter().enumerate() {
                        for (a, &g) in agg[ti].iter_mut().zip(grad.data()) {
                            *a += g;
                        }
                    }
                }
            }
        }
        // gradient exchange: hand each rank's fused buckets to its
        // persistent collective worker — one collective per bucket,
        // each a single sparse segment stream. Fabric, threads, codecs
        // and schedules were all built once in `Trainer::new`
        if let Some(pool) = self.pool.as_mut() {
            if !buckets.is_empty() {
                let step_start = pool.virtual_now;
                for (w, tensors) in pending.drain(..).enumerate() {
                    // on the virtual fabric the rank first replays its
                    // measured local busy time, scaled by the scenario's
                    // straggler/jitter factors
                    let advance_s = if self.fabric_virtual {
                        busy_s[w] * self.scenario.compute_factor(w, step)
                    } else {
                        0.0
                    };
                    pool.jobs[w]
                        .send(StepJob { tensors, advance_s, sync_to: step_start })
                        .map_err(|_| anyhow::anyhow!("collective worker {w} is gone"))?;
                }
                let mut rank0: Option<Vec<SparseTensor>> = None;
                let mut ends = vec![0.0f64; n];
                let mut max_start = step_start;
                let mut idle_sum = 0.0f64;
                for (w, result) in pool.results.iter().enumerate() {
                    let out = result
                        .recv()
                        .map_err(|_| anyhow::anyhow!("collective worker {w} died"))??;
                    if out.tensors.is_some() {
                        rank0 = out.tensors;
                    }
                    ends[w] = out.end_s;
                    max_start = max_start.max(out.start_s);
                    idle_sum += out.idle_s;
                }
                let step_end = ends.iter().copied().fold(step_start, f64::max);
                // end-of-step barrier: ranks that finish early wait for
                // the critical path (synchronous SGD)
                for &e in &ends {
                    idle_sum += step_end - e;
                }
                if self.fabric_virtual {
                    if let Some(tracer) = self.tracer.as_ref() {
                        // synthesised barrier spans (virtual clock only:
                        // the gap is known only after the slowest rank
                        // reports in, so there is no wall window)
                        for (w, &e) in ends.iter().enumerate() {
                            tracer.record(Span {
                                kind: SpanKind::Barrier,
                                lane: Lane::Cpu,
                                rank: w as u32,
                                step: 0, // stamped at drain
                                depth: 0,
                                bytes: 0,
                                label: None,
                                wall0: f64::NAN,
                                wall1: f64::NAN,
                                virt0: e,
                                virt1: step_end,
                            });
                        }
                    }
                }
                let summed_buckets =
                    rank0.ok_or_else(|| anyhow::anyhow!("rank 0 collective result missing"))?;
                for (bucket, summed) in buckets.iter().zip(summed_buckets) {
                    // unfuse the summed bucket back onto its member
                    // tensors' domains
                    let parts = unfuse(bucket, &summed);
                    for (part, &ti) in parts.iter().zip(&bucket.tensors) {
                        part.add_into(&mut agg[ti]);
                    }
                }
                // exact fabric traffic of this step's gradient exchange,
                // summed over all workers and split by link class (the
                // persistent fabric's meters are drained per step)
                metrics.fabric_bytes += pool.fabric.total_bytes();
                metrics.intra_bytes += pool.fabric.intra_bytes();
                metrics.inter_bytes += pool.fabric.inter_bytes();
                pool.fabric.reset_bytes();
                if self.fabric_virtual {
                    // the primary time numbers: measured on the virtual
                    // fabric, emerging from the schedule execution
                    metrics.measured_step_s = step_end - step_start;
                    metrics.rank_idle_s = Some(idle_sum / n as f64);
                    pool.virtual_now = step_end;
                    // feed the measured exchange back to the autotuner
                    // (per-worker *bucketed* container bytes ↦ virtual
                    // seconds — bypass tensors never hit the fabric);
                    // only consulted under --autotune-cost measured
                    let per_worker_bytes = bucketed_bytes as f64 / n as f64;
                    let comm_s = (step_end - max_start).max(0.0);
                    if let Some(pipe) = self.pipeline.as_mut() {
                        pipe.observe_comm(per_worker_bytes, comm_s);
                    }
                }
            }
        }
        // fleet fabric: the same exchange, run inline through the
        // single-threaded event loop (no jobs/results plumbing), over
        // the alive membership of this step
        if !buckets.is_empty() {
            if let Some(fleet) = self.fleet.as_mut() {
                let step_start = fleet.virtual_now;
                let advance: Vec<f64> =
                    (0..n).map(|w| busy_s[w] * self.scenario.compute_factor(w, step)).collect();
                // bind the coordinator thread to the tracer for the
                // duration of the event loop: the runner's per-message
                // spans (Send/Recv/RecvWait) and byte counters are
                // recorded through the thread-local collector, and at
                // --trace sampled they fold into the fleet aggregate
                // inside the loop instead of materialising per rank
                let obs_bind = self.tracer.as_ref().map(|t| t.install(0));
                let bytes0 = fleet.job_bytes();
                let exchanged = fleet.exchange(
                    std::mem::take(&mut pending),
                    &advance,
                    step_start,
                    step,
                    &self.scenario,
                );
                drop(obs_bind);
                let (summed_buckets, windows) = exchanged?;
                let step_end = windows.iter().fold(step_start, |a, w| a.max(w.1));
                let mut max_start = step_start;
                let mut idle_sum = 0.0f64;
                for (w, &(s0, e, idle)) in windows.iter().enumerate() {
                    if !self.scenario.alive(w, step) {
                        continue;
                    }
                    max_start = max_start.max(s0);
                    // recv-wait idle plus the end-of-step barrier wait
                    idle_sum += idle + (step_end - e);
                }
                if let Some(tracer) = self.tracer.as_ref() {
                    // synthesised per-rank compute + exchange + barrier
                    // spans: the event loop multiplexes every rank on one
                    // thread, so only the virtual windows are meaningful.
                    // The three kinds tile [step_start, step_end] per
                    // rank, which is what the trace-summary coverage and
                    // the health detector's per-rank totals key off.
                    for (w, &(s0, e, _)) in windows.iter().enumerate() {
                        if !self.scenario.alive(w, step) {
                            continue;
                        }
                        for (kind, v0, v1) in [
                            (SpanKind::Compute, step_start, s0),
                            (SpanKind::Exchange, s0, e),
                            (SpanKind::Barrier, e, step_end),
                        ] {
                            tracer.record(Span {
                                kind,
                                lane: Lane::Cpu,
                                rank: w as u32,
                                step: 0, // stamped at drain
                                depth: 0,
                                bytes: 0,
                                label: None,
                                wall0: f64::NAN,
                                wall1: f64::NAN,
                                virt0: v0,
                                virt1: v1,
                            });
                        }
                    }
                }
                for (bucket, summed) in buckets.iter().zip(summed_buckets) {
                    let parts = unfuse(bucket, &summed);
                    for (part, &ti) in parts.iter().zip(&bucket.tensors) {
                        part.add_into(&mut agg[ti]);
                    }
                }
                // the service attributes metered bytes per job, so the
                // step's traffic is the job-counter delta (no global
                // meter reset — other tenants' bytes stay untouched)
                let bytes1 = fleet.job_bytes();
                metrics.intra_bytes += bytes1[0] - bytes0[0];
                metrics.inter_bytes += bytes1[1] - bytes0[1];
                metrics.fabric_bytes += (bytes1[0] - bytes0[0]) + (bytes1[1] - bytes0[1]);
                metrics.measured_step_s = step_end - step_start;
                metrics.rank_idle_s = Some(idle_sum / n as f64);
                fleet.service.note_step(fleet.job, step_end - step_start);
                fleet.virtual_now = step_end;
                let per_worker_bytes = bucketed_bytes as f64 / n as f64;
                let comm_s = (step_end - max_start).max(0.0);
                if let Some(pipe) = self.pipeline.as_mut() {
                    pipe.observe_comm(per_worker_bytes, comm_s);
                }
            }
        }
        // bytes_per_worker accumulated across workers -> average
        if self.pipeline.is_some() || self.threelc.is_some() {
            metrics.bytes_per_worker /= n as u64;
        } else {
            metrics.bytes_per_worker = (total_params * 4) as u64;
        }
        // stable, deduped across workers already; sorted for reports
        metrics.autotune_choices.sort();
        // average + apply
        let grads: Vec<Tensor> = agg
            .into_iter()
            .zip(&self.params)
            .map(|(mut v, p)| {
                for x in v.iter_mut() {
                    *x /= n as f32;
                }
                Tensor::new(p.shape().to_vec(), v)
            })
            .collect();
        self.opt.step(&mut self.params, &grads);
        // close the step's trace window: drain every flushed span (the
        // worker threads flush before sending their results, the
        // coordinator guards flush on drop) and stamp it with this step
        if let Some(tracer) = self.tracer.clone() {
            let (measured_s, virt0, virt1) = if self.fabric_virtual {
                let v1 = self
                    .pool
                    .as_ref()
                    .map(|p| p.virtual_now)
                    .or_else(|| self.fleet.as_ref().map(|p| p.virtual_now))
                    .unwrap_or(f64::NAN);
                (metrics.measured_step_s, v1 - metrics.measured_step_s, v1)
            } else {
                (step_wall0.elapsed().as_secs_f64(), f64::NAN, f64::NAN)
            };
            self.trace_steps.push(StepWindow {
                step: step as u32,
                measured_s,
                idle_mean_s: metrics.rank_idle_s.unwrap_or(f64::NAN),
                virt0,
                virt1,
            });
            self.trace_spans.extend(tracer.drain(step as u32));
            // at --trace sampled: freeze the streaming aggregate's step
            // (detector + flag log + exemplar refresh); no-op otherwise
            tracer.end_health_step(
                step as u32,
                measured_s,
                (virt0, virt1),
                Some(&self.scenario),
            );
        }
        Ok(metrics)
    }

    /// Run metadata shared by the TRACE and HEALTH artifacts.
    fn trace_meta(&self) -> std::collections::BTreeMap<String, Json> {
        let mut meta = std::collections::BTreeMap::new();
        meta.insert("artifact".to_string(), Json::Str(self.cfg.artifact.clone()));
        if let Some(spec) = self.cfg.compression.as_ref() {
            meta.insert("schedule".to_string(), Json::Str(spec.schedule.clone()));
            meta.insert(
                "fabric".to_string(),
                Json::Str(if spec.fabric.is_empty() {
                    "instant".to_string()
                } else {
                    spec.fabric.clone()
                }),
            );
            if !spec.straggler.is_empty() {
                meta.insert("straggler".to_string(), Json::Str(spec.straggler.clone()));
            }
            if !spec.link_flap.is_empty() {
                meta.insert("link_flap".to_string(), Json::Str(spec.link_flap.clone()));
            }
        }
        meta
    }

    /// Take the accumulated trace as an exportable [`TraceReport`]
    /// (spans, per-step windows, metrics snapshot). `None` unless the
    /// spec asked for `--trace step|sampled|full`; at `sampled` the span
    /// list holds only the exemplar ranks' spans.
    pub fn take_trace(&mut self) -> Option<TraceReport> {
        let tracer = self.tracer.as_ref()?;
        let meta = self.trace_meta();
        Some(TraceReport {
            name: "train".to_string(),
            level: tracer.level(),
            ranks: tracer.ranks(),
            meta,
            steps: std::mem::take(&mut self.trace_steps),
            spans: std::mem::take(&mut self.trace_spans),
            registry: tracer.registry().snapshot(),
        })
    }

    /// Take the fleet-health aggregate as an exportable
    /// [`crate::obs::HealthReport`] (per-step percentile series, flag
    /// log with attributed causes, exemplar-trace section). `None` unless
    /// the spec asked for `--trace sampled`. The report's name matches
    /// [`Self::take_trace`]'s, so `HEALTH_train.json` points at
    /// `TRACE_train.json` for the exemplar timelines.
    pub fn take_health(&mut self) -> Option<crate::obs::HealthReport> {
        let meta = self.trace_meta();
        let telemetry = self.tracer.as_ref()?.take_health()?;
        Some(telemetry.report("train", meta))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SparseTensor;

    fn tiny_spec() -> CompressionSpec {
        CompressionSpec::with_spec(0.1, CompressSpec::raw())
    }

    /// The graceful-teardown satellite: repeated create → step →
    /// shutdown cycles must join every worker thread each time and
    /// leave nothing running. A leak here compounds fast — the old
    /// Drop-only path relied on channel-hangup ordering.
    #[test]
    fn collective_pool_shutdown_joins_all_workers_repeatedly() {
        let workers = 4;
        let spec = tiny_spec();
        for round in 0..50 {
            let fabric = FabricHandle::Instant(Network::new(workers));
            let mut pool = CollectivePool::new(
                fabric,
                Schedule::GatherAll,
                SparseConfig::default(),
                &spec,
                workers,
                None,
            )
            .unwrap();
            // leave an in-flight step un-collected on odd rounds:
            // shutdown must drain it rather than deadlock or leak
            if round % 2 == 1 {
                for jtx in &pool.jobs {
                    let t = SparseTensor::new(64, vec![1, 5], vec![1.0, 2.0]);
                    jtx.send(StepJob { tensors: vec![t], advance_s: 0.0, sync_to: 0.0 })
                        .unwrap();
                }
            }
            assert_eq!(pool.shutdown(), workers, "round {round} leaked a worker");
            assert_eq!(pool.shutdown(), 0, "shutdown is idempotent");
        }
    }

    /// The fleet pool retires its service job on shutdown, releasing
    /// the fabric ranks; repeat calls are no-ops.
    #[test]
    fn fleet_pool_shutdown_retires_the_job() {
        for _ in 0..20 {
            let mut service = crate::service::ReductionService::new(
                crate::service::ServiceConfig::new(
                    Topology::flat(4),
                    crate::simnet::Link::mbps(1000.0),
                    crate::simnet::Link::mbps(1000.0),
                )
                .unmetered(),
            );
            let job = service
                .submit(crate::service::JobRequest::synthetic("train", 4, 256, 0.1))
                .unwrap();
            let mut fleet = FleetPool { service, job, virtual_now: 0.0 };
            let inputs: Vec<Vec<SparseTensor>> = (0..4)
                .map(|_| vec![SparseTensor::new(256, vec![0, 9], vec![1.0, 1.0])])
                .collect();
            let (summed, windows) = fleet
                .exchange(inputs, &[0.0; 4], 0.0, 0, &Scenario::none(0))
                .unwrap();
            assert_eq!(summed.len(), 1);
            assert_eq!(windows.len(), 4);
            assert!(fleet.job_bytes()[0] > 0, "exchange meters intra bytes");
            assert!(fleet.shutdown(), "first shutdown retires the job");
            assert!(!fleet.shutdown(), "second shutdown is a no-op");
            assert_eq!(fleet.service.free_ranks(), 4, "ranks released");
        }
    }
}
