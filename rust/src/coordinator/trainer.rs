//! The distributed trainer (leader + n simulated workers).

use super::metrics::{StepMetrics, TrainReport};
use crate::collective::sparse::SegmentCodec;
use crate::collective::{Network, Schedule, SparseConfig, Topology};
use crate::pipeline::{unfuse, Bucket, GradientPipeline, StepTimeline};
use crate::runtime::{Artifact, BatchInput};
use crate::sparsify::{self, ErrorFeedback, Sparsifier};
use crate::tensor::{SparseTensor, Tensor};
use std::time::Instant;

/// Which benchmark family an artifact belongs to (drives the dataset).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Mlp,
    Ncf,
    Transformer,
}

impl ModelKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "mlp" | "cifar" => ModelKind::Mlp,
            "ncf" => ModelKind::Ncf,
            "transformer" | "lm" => ModelKind::Transformer,
            _ => return None,
        })
    }
}

/// One DeepReduce instantiation on the gradient path.
#[derive(Clone, Debug)]
pub struct CompressionSpec {
    /// sparsifier name (`topk`, `randomk`, `threshold`, `identity`)
    pub sparsifier: String,
    /// r/d for topk/randomk; τ for threshold
    pub ratio: f64,
    /// index codec name (see `compress::index_by_name`)
    pub index: String,
    /// index codec parameter (FPR for bloom)
    pub index_param: f64,
    /// value codec name (see `compress::value_by_name`)
    pub value: String,
    /// value codec parameter (bits for qsgd, degree for fitpoly)
    pub value_param: f64,
    /// error-feedback memory compensation (paper §6.3 enables it)
    pub error_feedback: bool,
    /// tensors smaller than this bypass compression (biases etc.)
    pub min_compress: usize,
    /// sparse allreduce schedule (see `collective::Schedule::parse`).
    /// Every schedule — including the default `gather_all` — runs the
    /// gradient sum over the in-process fabric, so `fabric_bytes` meters
    /// all of them comparably. Note: error feedback compensates codec
    /// loss only — `ring_rescatter` drops re-sparsified mass without
    /// feeding it back (the Ok-Topk approximation); use
    /// `ring_rescatter_exact` when exact sums matter
    pub schedule: String,
    /// node × rank grid in `NxR` form (CLI `--topology`, e.g. `2x4`);
    /// empty = flat. When set, the fabric meters intra vs inter bytes
    /// for *every* schedule, and `hierarchical` reduces over the grid.
    /// `nodes * ranks_per_node` must equal `workers`
    pub topology: String,
    /// inter-node schedule the hierarchical leaders run (CLI
    /// `--inner-schedule`; any flat schedule name, default `gather_all`)
    pub inner_schedule: String,
    /// modelled intra-node link bandwidth, Mbps (CLI `--intra-mbps`;
    /// fast by default — node-local interconnects)
    pub intra_mbps: f64,
    /// modelled inter-node link bandwidth, Mbps (CLI `--inter-mbps`;
    /// the paper's 100 Mbps default — the slow boundary)
    pub inter_mbps: f64,
    /// gradient-pipeline bucket cap in bytes (fp32 elements × 4): the
    /// per-step tensor list is fused greedily into buckets of at most
    /// this size, each travelling as one sparse segment stream. 0 = one
    /// bucket per tensor (the legacy per-tensor path)
    pub bucket_bytes: usize,
    /// per-bucket cost-model codec autotuning (DESIGN.md §6): pick the
    /// index/value pair by measured density + calibrated throughput +
    /// α–β link model; off = always the static `index`/`value` pair
    pub autotune: bool,
    /// modelled link bandwidth (Mbps) the pipeline's α–β terms use —
    /// autotune comm costs and the `pipeline_{serial,overlap}_s`
    /// step-time metrics (matches the paper's 100 Mbps default)
    pub pipeline_link_mbps: f64,
    pub seed: u64,
}

impl CompressionSpec {
    /// `DR_idx^val` on top of Top-r, the paper's default arrangement.
    pub fn topk(ratio: f64, index: &str, index_param: f64, value: &str, value_param: f64) -> Self {
        Self {
            sparsifier: "topk".into(),
            ratio,
            index: index.into(),
            index_param,
            value: value.into(),
            value_param,
            error_feedback: true,
            min_compress: 1024,
            schedule: "gather_all".into(),
            topology: String::new(),
            inner_schedule: "gather_all".into(),
            intra_mbps: 10_000.0,
            inter_mbps: 100.0,
            bucket_bytes: 0,
            autotune: false,
            pipeline_link_mbps: 100.0,
            seed: 0xDEE9,
        }
    }

    /// For inherently sparse models (NCF): no explicit sparsifier.
    pub fn identity(index: &str, index_param: f64, value: &str, value_param: f64) -> Self {
        let mut s = Self::topk(1.0, index, index_param, value, value_param);
        s.sparsifier = "identity".into();
        s.error_feedback = false;
        s
    }

    pub fn build_sparsifier(&self, worker_seed: u64) -> anyhow::Result<Box<dyn Sparsifier>> {
        sparsify::by_name(&self.sparsifier, self.ratio, self.seed ^ worker_seed)
            .ok_or_else(|| anyhow::anyhow!("unknown sparsifier {}", self.sparsifier))
    }

    pub fn label(&self) -> String {
        format!("DR[{}+{}|{}]", self.sparsifier, self.index, self.value)
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: ModelKind,
    /// artifact name under `artifacts/`
    pub artifact: String,
    pub workers: usize,
    pub steps: usize,
    pub optimizer: String,
    pub lr: f32,
    /// None = dense no-compression baseline
    pub compression: Option<CompressionSpec>,
    /// dense 3LC path (Fig 9 stand-alone baseline): sparsity multiplier
    pub dense_3lc: Option<f32>,
    pub seed: u64,
    /// print a progress line every k steps (0 = silent)
    pub log_every: usize,
}

impl TrainConfig {
    pub fn new(model: ModelKind, artifact: &str) -> Self {
        Self {
            model,
            artifact: artifact.to_string(),
            workers: 4,
            steps: 100,
            optimizer: match model {
                ModelKind::Mlp => "momentum".into(),
                _ => "adam".into(),
            },
            lr: match model {
                ModelKind::Ncf => 0.01,
                ModelKind::Transformer => 0.003,
                ModelKind::Mlp => 0.05,
            },
            compression: None,
            dense_3lc: None,
            seed: 42,
            log_every: 0,
        }
    }
}

enum Shard {
    Images(crate::data::SynthImages),
    Ncf(crate::data::SynthNcf),
    Corpus(crate::data::TinyCorpus),
}

impl Shard {
    fn next_batch(&mut self) -> Vec<BatchInput> {
        match self {
            Shard::Images(d) => d.next_batch(),
            Shard::Ncf(d) => d.next_batch(),
            Shard::Corpus(d) => d.next_batch(),
        }
    }
}

pub struct Trainer {
    cfg: TrainConfig,
    artifact: Artifact,
    params: Vec<Tensor>,
    opt: Box<dyn crate::optim::Optimizer>,
    shards: Vec<Shard>,
    sparsifiers: Vec<Box<dyn Sparsifier>>,
    /// Some(_) whenever compression is on: the bucketed gradient
    /// pipeline (fuse → per-bucket codec → encode/decode) the step
    /// drives instead of a per-tensor codec loop
    pipeline: Option<GradientPipeline>,
    threelc: Option<crate::baselines::ThreeLC>,
    /// `ef[worker][tensor]`
    ef: Vec<Vec<ErrorFeedback>>,
    /// Some(_) whenever compression is on: the sparse allreduce schedule
    /// that runs the gradient exchange over the in-process fabric
    collective_schedule: Option<Schedule>,
    /// parsed `CompressionSpec.topology` (None = flat fabric)
    topology: Option<Topology>,
    /// schedule tuning handed to every collective build (carries the
    /// grid and the hierarchical inner schedule)
    sparse_cfg: SparseConfig,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> anyhow::Result<Self> {
        let artifact = Artifact::load_default(&cfg.artifact)?;
        let params = artifact.init_params(cfg.seed);
        let opt = crate::optim::by_name(&cfg.optimizer, cfg.lr)
            .ok_or_else(|| anyhow::anyhow!("unknown optimizer {}", cfg.optimizer))?;
        let man = &artifact.manifest;
        let cu = |k: &str| -> anyhow::Result<usize> {
            man.config_usize(k).ok_or_else(|| anyhow::anyhow!("manifest missing config {k}"))
        };
        let mut shards = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            shards.push(match cfg.model {
                ModelKind::Mlp => Shard::Images(crate::data::SynthImages::shard(
                    cu("input_dim")?,
                    cu("classes")?,
                    cu("batch")?,
                    cfg.seed,
                    w,
                )),
                ModelKind::Ncf => Shard::Ncf(crate::data::SynthNcf::shard(
                    cu("users")?,
                    cu("items")?,
                    cu("batch")?,
                    cfg.seed,
                    w,
                )),
                ModelKind::Transformer => Shard::Corpus(crate::data::TinyCorpus::shard(
                    cu("vocab")?,
                    cu("seq")?,
                    cu("batch")?,
                    cfg.seed,
                    w,
                )),
            });
        }
        let threelc = cfg.dense_3lc.map(crate::baselines::ThreeLC::new);
        let ef_all = |params: &[Tensor]| {
            (0..cfg.workers)
                .map(|_| params.iter().map(|p| ErrorFeedback::new(p.numel())).collect::<Vec<_>>())
                .collect::<Vec<_>>()
        };
        let collective_schedule = match &cfg.compression {
            Some(spec) => Some(Schedule::parse(&spec.schedule).ok_or_else(|| {
                anyhow::anyhow!("unknown collective schedule {}", spec.schedule)
            })?),
            None => None,
        };
        // the two-level grid: validated against the worker count, fed to
        // the fabric (per-class byte meters) and to every schedule build
        let (topology, sparse_cfg) = match &cfg.compression {
            Some(spec) => {
                let topo = if spec.topology.is_empty() {
                    None
                } else {
                    let t = Topology::parse(&spec.topology).ok_or_else(|| {
                        anyhow::anyhow!("bad topology {:?}, expected NxR (e.g. 2x4)", spec.topology)
                    })?;
                    anyhow::ensure!(
                        t.world() == cfg.workers,
                        "topology {} describes {} ranks but --workers is {}",
                        t.label(),
                        t.world(),
                        cfg.workers
                    );
                    Some(t)
                };
                let inner = Schedule::parse(&spec.inner_schedule).ok_or_else(|| {
                    anyhow::anyhow!("unknown inner schedule {}", spec.inner_schedule)
                })?;
                anyhow::ensure!(
                    inner != Schedule::Hierarchical,
                    "--inner-schedule must be a flat schedule"
                );
                (topo, SparseConfig { topology: topo, inner, ..SparseConfig::default() })
            }
            None => (None, SparseConfig::default()),
        };
        let (sparsifiers, mut pipeline, ef) = match &cfg.compression {
            None if threelc.is_some() => (Vec::new(), None, ef_all(&params)),
            None => (Vec::new(), None, Vec::new()),
            Some(spec) => {
                let sp = (0..cfg.workers)
                    .map(|w| spec.build_sparsifier(w as u64))
                    .collect::<anyhow::Result<Vec<_>>>()?;
                // compressible tensors in exchange order; smaller ones
                // bypass the pipeline (raw kv on the wire)
                let members: Vec<(usize, usize)> = params
                    .iter()
                    .enumerate()
                    .filter(|(_, p)| p.numel() >= spec.min_compress)
                    .map(|(ti, p)| (ti, p.numel()))
                    .collect();
                let pipeline = GradientPipeline::new(
                    &members,
                    spec.bucket_bytes,
                    spec.autotune,
                    spec.error_feedback,
                    &spec.index,
                    spec.index_param,
                    &spec.value,
                    spec.value_param,
                    spec.seed,
                    crate::simnet::Link::mbps(spec.pipeline_link_mbps),
                    cfg.workers,
                )?;
                let ef = (0..cfg.workers)
                    .map(|_| {
                        params.iter().map(|p| ErrorFeedback::new(p.numel())).collect::<Vec<_>>()
                    })
                    .collect();
                (sp, Some(pipeline), ef)
            }
        };
        if let (Some(pipe), Some(topo), Some(spec)) =
            (pipeline.as_mut(), topology, cfg.compression.as_ref())
        {
            // per-hop codec advice for the two-level exchange (only
            // surfaces when autotuning is on)
            pipe.set_hierarchy(
                topo,
                crate::simnet::Link::mbps(spec.intra_mbps),
                crate::simnet::Link::mbps(spec.inter_mbps),
            );
        }
        Ok(Self {
            cfg,
            artifact,
            params,
            opt,
            shards,
            sparsifiers,
            pipeline,
            threelc,
            ef,
            collective_schedule,
            topology,
            sparse_cfg,
        })
    }

    pub fn params(&self) -> &[Tensor] {
        &self.params
    }

    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    /// Run the configured number of steps, returning the full report.
    pub fn run(&mut self) -> anyhow::Result<TrainReport> {
        let mut report = TrainReport {
            name: self
                .cfg
                .compression
                .as_ref()
                .map(|c| c.label())
                .unwrap_or_else(|| {
                    if self.threelc.is_some() { "3lc".into() } else { "baseline".into() }
                }),
            workers: self.cfg.workers,
            steps: Vec::with_capacity(self.cfg.steps),
        };
        for step in 0..self.cfg.steps {
            let m = self.step(step)?;
            if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                eprintln!(
                    "[{}] step {:>5}  loss {:.4}  aux {:.4}  bytes/worker {}",
                    report.name, step, m.loss, m.aux, m.bytes_per_worker
                );
            }
            report.steps.push(m);
        }
        Ok(report)
    }

    /// One synchronous data-parallel step across all workers.
    pub fn step(&mut self, step: usize) -> anyhow::Result<StepMetrics> {
        let n = self.cfg.workers;
        let total_params = self.artifact.manifest.total_params();
        let mut agg: Vec<Vec<f32>> = self.params.iter().map(|p| vec![0.0; p.numel()]).collect();
        // per-worker decoded fused buckets in bucket order (identical
        // across workers), for the fabric gradient exchange
        let mut pending: Vec<Vec<SparseTensor>> = (0..n).map(|_| Vec::new()).collect();
        // the step-invariant bucket layout (cloned out so worker-local
        // mutable borrows of the pipeline stay disjoint)
        let buckets: Vec<Bucket> = self
            .pipeline
            .as_ref()
            .map(|p| p.plan().buckets.clone())
            .unwrap_or_default();
        let mut metrics = StepMetrics {
            step,
            dense_bytes: (total_params * 4) as u64, // one worker's dense payload
            ..Default::default()
        };
        for w in 0..n {
            let batch = self.shards[w].next_batch();
            let t0 = Instant::now();
            let out = self.artifact.train_step(&self.params, &batch)?;
            metrics.compute_s += t0.elapsed().as_secs_f64();
            metrics.loss += out.loss / n as f32;
            metrics.aux += out.aux / n as f32;

            match (&mut self.pipeline, self.cfg.compression.as_ref()) {
                (Some(pipe), Some(spec)) => {
                    // stage 1: per-tensor error feedback + sparsify;
                    // tensors below min_compress bypass the pipeline
                    let mut prepared: Vec<Option<(Vec<f32>, SparseTensor)>> =
                        (0..out.grads.len()).map(|_| None).collect();
                    for (ti, grad) in out.grads.iter().enumerate() {
                        let flat = grad.data();
                        if flat.len() < spec.min_compress {
                            // bypass: raw kv on the wire
                            metrics.bytes_per_worker += (flat.len() * 4) as u64;
                            for (a, &g) in agg[ti].iter_mut().zip(flat) {
                                *a += g;
                            }
                            continue;
                        }
                        let corrected: Vec<f32> = if spec.error_feedback {
                            self.ef[w][ti].apply(flat)
                        } else {
                            flat.to_vec()
                        };
                        let sp = self.sparsifiers[w].sparsify(&corrected);
                        prepared[ti] = Some((corrected, sp));
                    }
                    // stage 2: fuse each bucket, pick its codec, encode
                    // and locally decode; the decoded fused payload is
                    // what the collective sums
                    let mut timeline = StepTimeline::new();
                    for bucket in &buckets {
                        let parts: Vec<&SparseTensor> = bucket
                            .tensors
                            .iter()
                            .map(|&ti| {
                                let p = prepared[ti].as_ref().expect("bucketed tensor prepared");
                                &p.1
                            })
                            .collect();
                        let dense_parts: Vec<&[f32]> = bucket
                            .tensors
                            .iter()
                            .map(|&ti| {
                                let p = prepared[ti].as_ref().expect("bucketed tensor prepared");
                                p.0.as_slice()
                            })
                            .collect();
                        let enc = pipe.encode_bucket(bucket, &parts, &dense_parts)?;
                        metrics.encode_s += enc.encode_s;
                        metrics.decode_s += enc.decode_s;
                        // bytes_per_worker is always the container upload
                        // volume (keeps relative_volume comparable across
                        // schedules); collective traffic is metered
                        // separately as fabric_bytes
                        metrics.bytes_per_worker += enc.wire_bytes;
                        timeline.push(enc.encode_s, enc.comm_model_s);
                        if !metrics.autotune_choices.contains(&enc.choice_label) {
                            metrics.autotune_choices.push(enc.choice_label.clone());
                        }
                        // per-hop advice on a two-level grid, reported
                        // alongside the container pick (inter only when
                        // the grid actually has inter-node links)
                        if let Some((leader, inter)) = &enc.hier_choices {
                            let mut labels = vec![format!("intra:{leader}")];
                            if let Some(inter) = inter {
                                labels.push(format!("inter:{inter}"));
                            }
                            for lbl in labels {
                                if !metrics.autotune_choices.contains(&lbl) {
                                    metrics.autotune_choices.push(lbl);
                                }
                            }
                        }
                        if spec.error_feedback {
                            // residual vs what was actually reconstructed
                            let dec_parts = unfuse(bucket, &enc.decoded);
                            for (j, &ti) in bucket.tensors.iter().enumerate() {
                                let corrected =
                                    &prepared[ti].as_ref().expect("bucketed tensor prepared").0;
                                self.ef[w][ti].update(corrected, &dec_parts[j]);
                            }
                        }
                        pending[w].push(enc.decoded);
                    }
                    // modelled step-time accounting (mean over workers)
                    metrics.pipeline_serial_s += timeline.serial_s() / n as f64;
                    metrics.pipeline_overlap_s += timeline.pipelined_s() / n as f64;
                    if w == 0 {
                        metrics.bucket_count = buckets.len() as u64;
                    }
                }
                _ if self.threelc.is_some() => {
                    let tlc = self.threelc.as_ref().unwrap();
                    for (ti, grad) in out.grads.iter().enumerate() {
                        let corrected = self.ef[w][ti].apply(grad.data());
                        let t1 = Instant::now();
                        let enc = tlc.encode(&corrected);
                        metrics.encode_s += t1.elapsed().as_secs_f64();
                        metrics.bytes_per_worker += enc.len() as u64;
                        let t2 = Instant::now();
                        let dec = tlc.decode(&enc)?;
                        metrics.decode_s += t2.elapsed().as_secs_f64();
                        let kept = SparseTensor::from_dense(&dec);
                        self.ef[w][ti].update(&corrected, &kept);
                        for (a, &g) in agg[ti].iter_mut().zip(&dec) {
                            *a += g;
                        }
                    }
                }
                _ => {
                    // dense baseline: full gradient on the wire
                    metrics.bytes_per_worker += (total_params * 4) as u64 / n as u64;
                    for (ti, grad) in out.grads.iter().enumerate() {
                        for (a, &g) in agg[ti].iter_mut().zip(grad.data()) {
                            *a += g;
                        }
                    }
                }
            }
        }
        // gradient exchange: run the configured schedule over the
        // byte-counted in-process fabric — one collective per fused
        // bucket, each a single sparse segment stream
        if let Some(sched) = self.collective_schedule {
            if !buckets.is_empty() {
                let spec = self.cfg.compression.as_ref().expect("schedule implies compression");
                // one fabric + one thread per worker for the whole step;
                // each worker runs the per-tensor collectives in order, so
                // messages stay matched on the pairwise FIFO channels.
                // The fabric carries the node × rank grid so every byte
                // is metered per link class (intra vs inter)
                let net = match self.topology {
                    Some(topo) => Network::with_topology(topo),
                    None => Network::new(n),
                };
                let sparse_cfg = self.sparse_cfg;
                let handles: Vec<_> = net
                    .endpoints()
                    .into_iter()
                    .zip(pending.drain(..))
                    .map(|(ep, tensors)| {
                        // segments reuse the spec's codecs where they are
                        // lossless; lossy stages fall back to raw
                        let codec = SegmentCodec::lossless_or_raw(
                            &spec.index,
                            spec.index_param,
                            &spec.value,
                            spec.value_param,
                            spec.seed,
                            sparse_cfg.dense_switch,
                        );
                        std::thread::spawn(move || -> Vec<SparseTensor> {
                            let sr = sched.build_with(sparse_cfg, codec);
                            // a failed rank panics; dropping its endpoint
                            // unblocks every peer ("peer hung up"), so no
                            // thread is leaked or deadlocked
                            tensors
                                .into_iter()
                                .map(|t| {
                                    sr.allreduce(&ep, t)
                                        .expect("in-process sparse allreduce failed")
                                })
                                .collect()
                        })
                    })
                    .collect();
                // join every thread before reporting the first failure
                let joined: Vec<_> = handles.into_iter().map(|h| h.join()).collect();
                let mut rank0: Option<Vec<SparseTensor>> = None;
                let mut panicked = false;
                for (i, j) in joined.into_iter().enumerate() {
                    match j {
                        Ok(v) => {
                            if i == 0 {
                                rank0 = Some(v);
                            }
                        }
                        Err(_) => panicked = true,
                    }
                }
                anyhow::ensure!(!panicked, "collective worker thread panicked");
                for (bucket, summed) in
                    buckets.iter().zip(rank0.expect("world size >= 1"))
                {
                    // unfuse the summed bucket back onto its member
                    // tensors' domains
                    let parts = unfuse(bucket, &summed);
                    for (part, &ti) in parts.iter().zip(&bucket.tensors) {
                        part.add_into(&mut agg[ti]);
                    }
                }
                // exact fabric traffic of this step's gradient exchange,
                // summed over all workers and split by link class
                metrics.fabric_bytes += net.total_bytes();
                metrics.intra_bytes += net.intra_bytes();
                metrics.inter_bytes += net.inter_bytes();
            }
        }
        // bytes_per_worker accumulated across workers -> average
        if self.pipeline.is_some() || self.threelc.is_some() {
            metrics.bytes_per_worker /= n as u64;
        } else {
            metrics.bytes_per_worker = (total_params * 4) as u64;
        }
        // stable, deduped across workers already; sorted for reports
        metrics.autotune_choices.sort();
        // average + apply
        let grads: Vec<Tensor> = agg
            .into_iter()
            .zip(&self.params)
            .map(|(mut v, p)| {
                for x in v.iter_mut() {
                    *x /= n as f32;
                }
                Tensor::new(p.shape().to_vec(), v)
            })
            .collect();
        self.opt.step(&mut self.params, &grads);
        Ok(metrics)
    }
}
