//! The distributed trainer (leader + n simulated workers).

use super::metrics::{StepMetrics, TrainReport};
use crate::collective::sparse::SegmentCodec;
use crate::collective::{Network, Schedule, SparseConfig};
use crate::compress::{index_by_name, value_by_name, DeepReduce};
use crate::runtime::{Artifact, BatchInput};
use crate::sparsify::{self, ErrorFeedback, Sparsifier};
use crate::tensor::{SparseTensor, Tensor};
use std::time::Instant;

/// Which benchmark family an artifact belongs to (drives the dataset).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Mlp,
    Ncf,
    Transformer,
}

impl ModelKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "mlp" | "cifar" => ModelKind::Mlp,
            "ncf" => ModelKind::Ncf,
            "transformer" | "lm" => ModelKind::Transformer,
            _ => return None,
        })
    }
}

/// One DeepReduce instantiation on the gradient path.
#[derive(Clone, Debug)]
pub struct CompressionSpec {
    /// sparsifier name (`topk`, `randomk`, `threshold`, `identity`)
    pub sparsifier: String,
    /// r/d for topk/randomk; τ for threshold
    pub ratio: f64,
    /// index codec name (see `compress::index_by_name`)
    pub index: String,
    /// index codec parameter (FPR for bloom)
    pub index_param: f64,
    /// value codec name (see `compress::value_by_name`)
    pub value: String,
    /// value codec parameter (bits for qsgd, degree for fitpoly)
    pub value_param: f64,
    /// error-feedback memory compensation (paper §6.3 enables it)
    pub error_feedback: bool,
    /// tensors smaller than this bypass compression (biases etc.)
    pub min_compress: usize,
    /// sparse allreduce schedule (see `collective::Schedule::parse`).
    /// Every schedule — including the default `gather_all` — runs the
    /// gradient sum over the in-process fabric, so `fabric_bytes` meters
    /// all of them comparably. Note: error feedback compensates codec
    /// loss only — `ring_rescatter` drops re-sparsified mass without
    /// feeding it back (the Ok-Topk approximation); use
    /// `ring_rescatter_exact` when exact sums matter
    pub schedule: String,
    pub seed: u64,
}

impl CompressionSpec {
    /// `DR_idx^val` on top of Top-r, the paper's default arrangement.
    pub fn topk(ratio: f64, index: &str, index_param: f64, value: &str, value_param: f64) -> Self {
        Self {
            sparsifier: "topk".into(),
            ratio,
            index: index.into(),
            index_param,
            value: value.into(),
            value_param,
            error_feedback: true,
            min_compress: 1024,
            schedule: "gather_all".into(),
            seed: 0xDEE9,
        }
    }

    /// For inherently sparse models (NCF): no explicit sparsifier.
    pub fn identity(index: &str, index_param: f64, value: &str, value_param: f64) -> Self {
        let mut s = Self::topk(1.0, index, index_param, value, value_param);
        s.sparsifier = "identity".into();
        s.error_feedback = false;
        s
    }

    pub fn build_sparsifier(&self, worker_seed: u64) -> anyhow::Result<Box<dyn Sparsifier>> {
        sparsify::by_name(&self.sparsifier, self.ratio, self.seed ^ worker_seed)
            .ok_or_else(|| anyhow::anyhow!("unknown sparsifier {}", self.sparsifier))
    }

    pub fn build_codec(&self) -> anyhow::Result<DeepReduce> {
        Ok(DeepReduce::new(
            index_by_name(&self.index, self.index_param, self.seed)
                .ok_or_else(|| anyhow::anyhow!("unknown index codec {}", self.index))?,
            value_by_name(&self.value, self.value_param, self.seed)
                .ok_or_else(|| anyhow::anyhow!("unknown value codec {}", self.value))?,
        ))
    }

    pub fn label(&self) -> String {
        format!("DR[{}+{}|{}]", self.sparsifier, self.index, self.value)
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: ModelKind,
    /// artifact name under `artifacts/`
    pub artifact: String,
    pub workers: usize,
    pub steps: usize,
    pub optimizer: String,
    pub lr: f32,
    /// None = dense no-compression baseline
    pub compression: Option<CompressionSpec>,
    /// dense 3LC path (Fig 9 stand-alone baseline): sparsity multiplier
    pub dense_3lc: Option<f32>,
    pub seed: u64,
    /// print a progress line every k steps (0 = silent)
    pub log_every: usize,
}

impl TrainConfig {
    pub fn new(model: ModelKind, artifact: &str) -> Self {
        Self {
            model,
            artifact: artifact.to_string(),
            workers: 4,
            steps: 100,
            optimizer: match model {
                ModelKind::Mlp => "momentum".into(),
                _ => "adam".into(),
            },
            lr: match model {
                ModelKind::Ncf => 0.01,
                ModelKind::Transformer => 0.003,
                ModelKind::Mlp => 0.05,
            },
            compression: None,
            dense_3lc: None,
            seed: 42,
            log_every: 0,
        }
    }
}

enum Shard {
    Images(crate::data::SynthImages),
    Ncf(crate::data::SynthNcf),
    Corpus(crate::data::TinyCorpus),
}

impl Shard {
    fn next_batch(&mut self) -> Vec<BatchInput> {
        match self {
            Shard::Images(d) => d.next_batch(),
            Shard::Ncf(d) => d.next_batch(),
            Shard::Corpus(d) => d.next_batch(),
        }
    }
}

pub struct Trainer {
    cfg: TrainConfig,
    artifact: Artifact,
    params: Vec<Tensor>,
    opt: Box<dyn crate::optim::Optimizer>,
    shards: Vec<Shard>,
    sparsifiers: Vec<Box<dyn Sparsifier>>,
    codec: Option<DeepReduce>,
    threelc: Option<crate::baselines::ThreeLC>,
    /// ef[worker][tensor]
    ef: Vec<Vec<ErrorFeedback>>,
    /// Some(_) whenever compression is on: the sparse allreduce schedule
    /// that runs the gradient exchange over the in-process fabric
    collective_schedule: Option<Schedule>,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> anyhow::Result<Self> {
        let artifact = Artifact::load_default(&cfg.artifact)?;
        let params = artifact.init_params(cfg.seed);
        let opt = crate::optim::by_name(&cfg.optimizer, cfg.lr)
            .ok_or_else(|| anyhow::anyhow!("unknown optimizer {}", cfg.optimizer))?;
        let man = &artifact.manifest;
        let cu = |k: &str| -> anyhow::Result<usize> {
            man.config_usize(k).ok_or_else(|| anyhow::anyhow!("manifest missing config {k}"))
        };
        let mut shards = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            shards.push(match cfg.model {
                ModelKind::Mlp => Shard::Images(crate::data::SynthImages::shard(
                    cu("input_dim")?,
                    cu("classes")?,
                    cu("batch")?,
                    cfg.seed,
                    w,
                )),
                ModelKind::Ncf => Shard::Ncf(crate::data::SynthNcf::shard(
                    cu("users")?,
                    cu("items")?,
                    cu("batch")?,
                    cfg.seed,
                    w,
                )),
                ModelKind::Transformer => Shard::Corpus(crate::data::TinyCorpus::shard(
                    cu("vocab")?,
                    cu("seq")?,
                    cu("batch")?,
                    cfg.seed,
                    w,
                )),
            });
        }
        let threelc = cfg.dense_3lc.map(crate::baselines::ThreeLC::new);
        let ef_all = |params: &[Tensor]| {
            (0..cfg.workers)
                .map(|_| params.iter().map(|p| ErrorFeedback::new(p.numel())).collect::<Vec<_>>())
                .collect::<Vec<_>>()
        };
        let collective_schedule = match &cfg.compression {
            Some(spec) => Some(Schedule::parse(&spec.schedule).ok_or_else(|| {
                anyhow::anyhow!("unknown collective schedule {}", spec.schedule)
            })?),
            None => None,
        };
        let (sparsifiers, codec, ef) = match &cfg.compression {
            None if threelc.is_some() => (Vec::new(), None, ef_all(&params)),
            None => (Vec::new(), None, Vec::new()),
            Some(spec) => {
                let sp = (0..cfg.workers)
                    .map(|w| spec.build_sparsifier(w as u64))
                    .collect::<anyhow::Result<Vec<_>>>()?;
                let codec = spec.build_codec()?;
                let ef = (0..cfg.workers)
                    .map(|_| {
                        params.iter().map(|p| ErrorFeedback::new(p.numel())).collect::<Vec<_>>()
                    })
                    .collect();
                (sp, Some(codec), ef)
            }
        };
        Ok(Self {
            cfg,
            artifact,
            params,
            opt,
            shards,
            sparsifiers,
            codec,
            threelc,
            ef,
            collective_schedule,
        })
    }

    pub fn params(&self) -> &[Tensor] {
        &self.params
    }

    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    /// Run the configured number of steps, returning the full report.
    pub fn run(&mut self) -> anyhow::Result<TrainReport> {
        let mut report = TrainReport {
            name: self
                .cfg
                .compression
                .as_ref()
                .map(|c| c.label())
                .unwrap_or_else(|| {
                    if self.threelc.is_some() { "3lc".into() } else { "baseline".into() }
                }),
            workers: self.cfg.workers,
            steps: Vec::with_capacity(self.cfg.steps),
        };
        for step in 0..self.cfg.steps {
            let m = self.step(step)?;
            if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                eprintln!(
                    "[{}] step {:>5}  loss {:.4}  aux {:.4}  bytes/worker {}",
                    report.name, step, m.loss, m.aux, m.bytes_per_worker
                );
            }
            report.steps.push(m);
        }
        Ok(report)
    }

    /// One synchronous data-parallel step across all workers.
    pub fn step(&mut self, step: usize) -> anyhow::Result<StepMetrics> {
        let n = self.cfg.workers;
        let total_params = self.artifact.manifest.total_params();
        let mut agg: Vec<Vec<f32>> = self.params.iter().map(|p| vec![0.0; p.numel()]).collect();
        // per-worker decoded gradients in tensor order (identical across
        // workers), for the fabric gradient exchange
        let mut pending: Vec<Vec<SparseTensor>> = (0..n).map(|_| Vec::new()).collect();
        let mut pending_tis: Vec<usize> = Vec::new();
        let mut metrics = StepMetrics {
            step,
            dense_bytes: (total_params * 4) as u64, // one worker's dense payload
            ..Default::default()
        };
        for w in 0..n {
            let batch = self.shards[w].next_batch();
            let t0 = Instant::now();
            let out = self.artifact.train_step(&self.params, &batch)?;
            metrics.compute_s += t0.elapsed().as_secs_f64();
            metrics.loss += out.loss / n as f32;
            metrics.aux += out.aux / n as f32;

            match (&self.codec, self.cfg.compression.as_ref()) {
                (Some(codec), Some(spec)) => {
                    for (ti, grad) in out.grads.iter().enumerate() {
                        let flat = grad.data();
                        if flat.len() < spec.min_compress {
                            // bypass: raw kv on the wire
                            metrics.bytes_per_worker += (flat.len() * 4) as u64;
                            for (a, &g) in agg[ti].iter_mut().zip(flat) {
                                *a += g;
                            }
                            continue;
                        }
                        let corrected: Vec<f32> = if spec.error_feedback {
                            self.ef[w][ti].apply(flat)
                        } else {
                            flat.to_vec()
                        };
                        let sp = self.sparsifiers[w].sparsify(&corrected);
                        let t1 = Instant::now();
                        let container = codec.encode(&sp, Some(&corrected));
                        metrics.encode_s += t1.elapsed().as_secs_f64();
                        let t2 = Instant::now();
                        let decoded: SparseTensor = codec.decode(&container)?;
                        metrics.decode_s += t2.elapsed().as_secs_f64();
                        if spec.error_feedback {
                            // residual vs what was actually reconstructed
                            self.ef[w][ti].update(&corrected, &decoded);
                        }
                        // bytes_per_worker is always the container upload
                        // volume (keeps relative_volume comparable across
                        // schedules); collective traffic is metered
                        // separately as fabric_bytes
                        metrics.bytes_per_worker += container.wire_bytes() as u64;
                        if self.collective_schedule.is_some() {
                            if w == 0 {
                                pending_tis.push(ti);
                            }
                            pending[w].push(decoded);
                        } else {
                            decoded.add_into(&mut agg[ti]);
                        }
                    }
                }
                _ if self.threelc.is_some() => {
                    let tlc = self.threelc.as_ref().unwrap();
                    for (ti, grad) in out.grads.iter().enumerate() {
                        let corrected = self.ef[w][ti].apply(grad.data());
                        let t1 = Instant::now();
                        let enc = tlc.encode(&corrected);
                        metrics.encode_s += t1.elapsed().as_secs_f64();
                        metrics.bytes_per_worker += enc.len() as u64;
                        let t2 = Instant::now();
                        let dec = tlc.decode(&enc)?;
                        metrics.decode_s += t2.elapsed().as_secs_f64();
                        let kept = SparseTensor::from_dense(&dec);
                        self.ef[w][ti].update(&corrected, &kept);
                        for (a, &g) in agg[ti].iter_mut().zip(&dec) {
                            *a += g;
                        }
                    }
                }
                _ => {
                    // dense baseline: full gradient on the wire
                    metrics.bytes_per_worker += (total_params * 4) as u64 / n as u64;
                    for (ti, grad) in out.grads.iter().enumerate() {
                        for (a, &g) in agg[ti].iter_mut().zip(grad.data()) {
                            *a += g;
                        }
                    }
                }
            }
        }
        // gradient exchange: run the configured schedule over the
        // byte-counted in-process fabric
        if let Some(sched) = self.collective_schedule {
            if !pending_tis.is_empty() {
                let spec = self.cfg.compression.as_ref().expect("schedule implies compression");
                // one fabric + one thread per worker for the whole step;
                // each worker runs the per-tensor collectives in order, so
                // messages stay matched on the pairwise FIFO channels
                let net = Network::new(n);
                let handles: Vec<_> = net
                    .endpoints()
                    .into_iter()
                    .zip(pending.drain(..))
                    .map(|(ep, tensors)| {
                        // segments reuse the spec's codecs where they are
                        // lossless; lossy stages fall back to raw
                        let codec = SegmentCodec::lossless_or_raw(
                            &spec.index,
                            spec.index_param,
                            &spec.value,
                            spec.value_param,
                            spec.seed,
                            SparseConfig::default().dense_switch,
                        );
                        std::thread::spawn(move || -> Vec<SparseTensor> {
                            let sr = sched.build_with(SparseConfig::default(), codec);
                            // a failed rank panics; dropping its endpoint
                            // unblocks every peer ("peer hung up"), so no
                            // thread is leaked or deadlocked
                            tensors
                                .into_iter()
                                .map(|t| {
                                    sr.allreduce(&ep, t)
                                        .expect("in-process sparse allreduce failed")
                                })
                                .collect()
                        })
                    })
                    .collect();
                // join every thread before reporting the first failure
                let joined: Vec<_> = handles.into_iter().map(|h| h.join()).collect();
                let mut rank0: Option<Vec<SparseTensor>> = None;
                let mut panicked = false;
                for (i, j) in joined.into_iter().enumerate() {
                    match j {
                        Ok(v) => {
                            if i == 0 {
                                rank0 = Some(v);
                            }
                        }
                        Err(_) => panicked = true,
                    }
                }
                anyhow::ensure!(!panicked, "collective worker thread panicked");
                for (&ti, summed) in pending_tis.iter().zip(rank0.expect("world size >= 1")) {
                    summed.add_into(&mut agg[ti]);
                }
                // exact fabric traffic of this step's gradient exchange,
                // summed over all workers
                metrics.fabric_bytes += net.total_bytes();
            }
        }
        // bytes_per_worker accumulated across workers -> average
        if self.codec.is_some() || self.threelc.is_some() {
            metrics.bytes_per_worker /= n as u64;
        } else {
            metrics.bytes_per_worker = (total_params * 4) as u64;
        }
        // average + apply
        let grads: Vec<Tensor> = agg
            .into_iter()
            .zip(&self.params)
            .map(|(mut v, p)| {
                for x in v.iter_mut() {
                    *x /= n as f32;
                }
                Tensor::new(p.shape().to_vec(), v)
            })
            .collect();
        self.opt.step(&mut self.params, &grads);
        Ok(metrics)
    }
}
