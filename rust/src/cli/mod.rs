//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `deepreduce <subcommand> [--key value]... [--flag]...`

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> anyhow::Result<Self> {
        let mut it = argv.into_iter();
        let subcommand = it.next().unwrap_or_default();
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        let mut pending: Option<String> = None;
        for a in it {
            if let Some(key) = a.strip_prefix("--") {
                if let Some(prev) = pending.take() {
                    flags.push(prev);
                }
                if let Some((k, v)) = key.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else {
                    pending = Some(key.to_string());
                }
            } else if let Some(key) = pending.take() {
                opts.insert(key, a);
            } else {
                anyhow::bail!("unexpected positional argument: {a}");
            }
        }
        if let Some(prev) = pending {
            flags.push(prev);
        }
        Ok(Self { subcommand, opts, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => Ok(s.parse()?),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => Ok(s.parse()?),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn basic() {
        let a = parse("train --model mlp --workers 8 --lr=0.1 --verbose");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get("model"), Some("mlp"));
        assert_eq!(a.get_usize("workers", 4).unwrap(), 8);
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.1);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("bench");
        assert_eq!(a.get_usize("steps", 100).unwrap(), 100);
        assert!(Args::parse(["x".into(), "oops".into()]).is_err());
        let bad = parse("t --workers abc");
        assert!(bad.get_usize("workers", 1).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("train --ef");
        assert!(a.flag("ef"));
    }
}
