//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `deepreduce <subcommand> [--key value]... [--flag]...`
//!
//! [`usage`] renders the full help text; a unit test pins every flag
//! the binary parses to a line in it, so help cannot silently rot.

use std::collections::BTreeMap;

/// Every `--flag` the `deepreduce` binary parses, one per subcommand
/// group. The guard is two-directional: the help test pins each entry
/// to a line of [`usage`], and the binary rejects any flag *not* in
/// this table ([`Args::check_known`]) — so a flag added to `main.rs`
/// without extending the table errors the first time it is passed,
/// and extending the table without documenting it fails the test.
pub const KNOWN_FLAGS: &[&str] = &[
    // train: run setup
    "model", "artifact", "workers", "steps", "lr", "optimizer", "seed", "log-every",
    // train: DeepReduce instantiation
    "index", "value", "sparsifier", "ratio", "fpr", "value-param", "no-ef",
    // train: collective schedule + topology
    "schedule", "topology", "inner-schedule", "chunks", "intra-mbps", "inter-mbps",
    // train: virtual-time fabric + scenarios
    "fabric", "straggler", "compute-jitter", "link-jitter", "node-mbps",
    "link-flap", "crash",
    // train: gradient pipeline
    "bucket-bytes", "autotune", "pipeline-link-mbps", "autotune-cost",
    // train: observability
    "trace", "trace-summary", "health-summary",
    // codecs
    "dim",
    // serve: multi-tenant reduction service
    "tenants", "dense-tenants", "ranks-per-job", "rounds", "profile-dir",
];

/// The full help text (also printed by `deepreduce` with no arguments
/// and by the `help` subcommand).
pub fn usage() -> String {
    "\
usage: deepreduce <train|serve|smoke|codecs|list-codecs|info|help> [--opts]

train — run distributed training with a DeepReduce instantiation
  --model <mlp|ncf|transformer>   benchmark family (default mlp)
  --artifact <name>               artifact under artifacts/ (default per model)
  --workers <n>                   data-parallel workers (default 4)
  --steps <n>                     training steps (default 100)
  --lr <f>                        learning rate (default per model)
  --optimizer <name>              momentum|adam|... (default per model)
  --seed <n>                      run seed (default 42)
  --log-every <k>                 progress line every k steps (0 = silent)

  compression (any of these activates the DeepReduce pipeline):
  --index <spec>                  index codec spec: a registry name
                                  (raw|bitmap|rle|huffman|delta_varint|elias|
                                  bloom_p0|bloom_p1|bloom_p2), optionally with
                                  key=value params and +chained byte stages,
                                  e.g. rle+deflate or bloom_p2(fpr=0.01)+zstd
                                  (see `deepreduce list-codecs`)
  --value <spec>                  value codec spec: raw|fp16|deflate|zstd|qsgd|
                                  fitpoly|fitdexp, same chain/param syntax,
                                  e.g. qsgd(bits=6) or raw+zstd
  --sparsifier <name>             topk|randomk|threshold|identity (default topk)
  --ratio <f>                     sparsifier keep ratio r/d (default 0.01)
  --fpr <f>                       legacy shim for bloom fpr= (default 0.001)
  --value-param <f>               legacy shim: qsgd bits / fitpoly degree
  --no-ef                         disable error-feedback memory

  collective schedule + topology:
  --schedule <name>               gather_all|recursive_double|ring_rescatter|
                                  ring_rescatter_exact|chunked_rescatter|
                                  hierarchical
  --topology <NxR>                node grid, e.g. 2x4 (N nodes × R ranks;
                                  implies --schedule hierarchical if unset)
  --inner-schedule <name>         flat schedule the node leaders run
                                  (default gather_all)
  --chunks <n>                    chunked_rescatter chunk count, rounded up to
                                  a multiple of the world size (0 = auto)
  --intra-mbps <f>                modelled intra-node link, Mbps (default 10000)
  --inter-mbps <f>                modelled inter-node link, Mbps (default 100)

  virtual-time fabric (scenario knobs imply --fabric virtual):
  --fabric <instant|virtual|fleet> instant = zero-time delivery (default);
                                  virtual = event-driven virtual clocks, adds
                                  measured_step_s / rank_idle_s to the report;
                                  fleet = single-threaded event-loop twin of
                                  virtual (same clocks and byte meters, no OS
                                  threads — scales to 10k+ ranks)
  --straggler <R:F[,R:F...]>      rank R computes Fx slower, links at beta/F
  --compute-jitter <f>            per-step compute jitter amplitude (e.g. 0.3)
  --link-jitter <f>               per-transfer time jitter amplitude
  --node-mbps <N:MBPS[,...]>      per-node inter-link bandwidth overrides
                                  (heterogeneous clusters)
  --link-flap <N:A-B:F[,...]>     node N's inter links run F x slower in the
                                  virtual-time window [A, B) seconds
  --crash <R:A-B[,...]>           rank R sits out steps [A, B) (lost-gradient
                                  semantics; implies --fabric fleet, flat
                                  topology only)

  gradient pipeline:
  --bucket-bytes <n>              fused bucket cap in bytes (0 = per-tensor)
  --autotune [on|off]             per-bucket cost-model codec choice
  --pipeline-link-mbps <f>        modelled link for pipeline step-time metrics
                                  (default 100)
  --autotune-cost <src>           comm term of the autotuner cost:
                                  formula (alpha-beta model, default) |
                                  measured (virtual-fabric feedback)

  observability (see DESIGN.md §11, §14):
  --trace <off|step|sampled|full> structured span tracing: off (default,
                                  zero-overhead), step (per-rank step anatomy),
                                  full (codec/wire/rounds/ports/waits),
                                  sampled (fleet-scale: streaming per-step
                                  aggregation + anomaly detection, full spans
                                  kept only for K exemplar ranks; writes
                                  HEALTH_train.json too); writes
                                  TRACE_train.json (open in Perfetto)
  --trace-summary                 print the per-step critical-path breakdown
  --health-summary                print the fleet health report (percentiles,
                                  flagged ranks; requires --trace sampled)

serve — run the multi-tenant reduction service with synthetic tenants
  --topology <NxR>                fabric grid (default 4x4)
  --tenants <n>                   sparse tenants to admit (default 3)
  --dense-tenants <n>             dense (high-density) tenants (default 1)
  --ranks-per-job <n>             placement width per job (default one node)
  --rounds <n>                    fair-share scheduling rounds (default 10)
  --dim <n>                       gradient dimensionality (default 65536)
  --ratio <f>                     sparse tenants' gradient density (default 0.01)
  --intra-mbps <f>                intra-node link, Mbps (default 10000)
  --inter-mbps <f>                inter-node link, Mbps (default 100)
  --autotune [on|off]             calibrate/warm-start codec policy per job
  --profile-dir <path>            PROFILE_*.json store (default repo root;
                                  enables warm starts across invocations)
  --seed <n>                      run seed (default 42)

smoke — load the pallas smoke artifact through PJRT and execute it

codecs — codec volume table on a synthetic sparse gradient
  --dim <n>                       gradient dimensionality (default 36864)
  --ratio <f>                     top-r keep ratio (default 0.01)

list-codecs — print the codec registry: every index/value codec and
  chain byte stage with its typed parameter schema (key:type=default),
  losslessness, and chainability

info — list artifacts and their manifests
"
    .to_string()
}

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> anyhow::Result<Self> {
        let mut it = argv.into_iter();
        let subcommand = it.next().unwrap_or_default();
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        let mut pending: Option<String> = None;
        for a in it {
            if let Some(key) = a.strip_prefix("--") {
                if let Some(prev) = pending.take() {
                    flags.push(prev);
                }
                if let Some((k, v)) = key.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else {
                    pending = Some(key.to_string());
                }
            } else if let Some(key) = pending.take() {
                opts.insert(key, a);
            } else {
                anyhow::bail!("unexpected positional argument: {a}");
            }
        }
        if let Some(prev) = pending {
            flags.push(prev);
        }
        Ok(Self { subcommand, opts, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => Ok(s.parse()?),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => Ok(s.parse()?),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Error on any `--key`/`--flag` outside `known` — catches typos
    /// (`--toplogy` would otherwise be silently ignored) and keeps
    /// [`KNOWN_FLAGS`]/[`usage`] in sync with what `main.rs` parses.
    pub fn check_known(&self, known: &[&str]) -> anyhow::Result<()> {
        for key in self.opts.keys().chain(self.flags.iter()) {
            anyhow::ensure!(
                known.contains(&key.as_str()),
                "unknown flag --{key} (see `deepreduce help`)"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn basic() {
        let a = parse("train --model mlp --workers 8 --lr=0.1 --verbose");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get("model"), Some("mlp"));
        assert_eq!(a.get_usize("workers", 4).unwrap(), 8);
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.1);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("bench");
        assert_eq!(a.get_usize("steps", 100).unwrap(), 100);
        assert!(Args::parse(["x".into(), "oops".into()]).is_err());
        let bad = parse("t --workers abc");
        assert!(bad.get_usize("workers", 1).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("train --ef");
        assert!(a.flag("ef"));
    }

    #[test]
    fn check_known_rejects_typos() {
        let a = parse("train --workers 4 --toplogy 2x4");
        assert!(a.check_known(&["workers", "topology"]).is_err());
        assert!(a.check_known(&["workers", "toplogy"]).is_ok());
        assert!(parse("train --verbose").check_known(&["workers"]).is_err());
        assert!(parse("train").check_known(&[]).is_ok());
    }

    /// Every flag the binary parses must be documented in the help
    /// text (the regression this guards: adding a CLI knob in main.rs
    /// and forgetting the usage string).
    #[test]
    fn usage_documents_every_parsed_flag() {
        let text = usage();
        for flag in KNOWN_FLAGS {
            assert!(
                text.contains(&format!("--{flag}")),
                "help text is missing --{flag}"
            );
        }
        // and every subcommand
        for sub in ["train", "serve", "smoke", "codecs", "list-codecs", "info"] {
            assert!(text.contains(sub), "help text is missing {sub}");
        }
        // the chain syntax is documented where users look for codecs
        assert!(text.contains("rle+deflate"), "help text is missing the chain syntax example");
    }
}
