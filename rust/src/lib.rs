//! DeepReduce: a sparse-tensor communication framework for distributed
//! deep learning — Rust + JAX + Pallas reproduction.
//!
//! See DESIGN.md for the architecture and the per-experiment index.

pub mod baselines;
pub mod collective;
pub mod cli;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod optim;
pub mod pipeline;
pub mod runtime;
pub mod simnet;
pub mod sparsify;
pub mod tensor;
pub mod util;
pub mod xp;
