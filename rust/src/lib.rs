//! DeepReduce: a sparse-tensor communication framework for distributed
//! deep learning — Rust + JAX + Pallas reproduction.
//!
//! The paper decomposes a sparse gradient into an index set and a value
//! array, compresses each with pluggable codecs, and ships the result
//! through the collective exchange of a data-parallel trainer. This
//! crate reproduces that framework end to end on a single-machine
//! testbed with exact wire-byte accounting (see `DESIGN.md` for the
//! architecture and the per-experiment index; the top-level `README.md`
//! has the quickstart).
//!
//! # Module map
//!
//! Gradient path, in data-flow order:
//!
//! - [`sparsify`] — Top-r / Random-r / threshold sparsifiers plus the
//!   error-feedback memory.
//! - [`compress`] — the DeepReduce codec framework: [`compress::index`]
//!   codecs × [`compress::value`] codecs packed into self-describing
//!   containers.
//! - [`pipeline`] — bucket fusion, per-bucket codec autotuning, and
//!   encode/transfer overlap accounting.
//! - [`collective`] — the byte-counted in-process fabric, the sparse
//!   allreduce schedules ([`collective::sparse`]), and the two-level
//!   node × rank [`collective::Topology`].
//! - [`coordinator`] — the data-parallel trainer and its metrics.
//!
//! Supporting layers:
//!
//! - [`runtime`] — loads AOT-compiled JAX/Pallas artifacts through the
//!   PJRT CPU client (the only model interface at train time).
//! - [`simnet`] — α–β network-time models applied to exact wire bytes,
//!   including the two-link-class hierarchical models.
//! - [`vfabric`] — the discrete-event virtual-time fabric: per-rank
//!   virtual clocks, port serialization, and scenario knobs
//!   (stragglers, jitter, heterogeneous links); measured step times
//!   cross-validated against the [`simnet`] closed forms.
//! - [`fleetsim`] — the fleet-scale twin of [`vfabric`]: a
//!   single-threaded deterministic event-loop runner that executes
//!   every rank's collective as a resumable state machine on the same
//!   virtual clock, pinned byte- and time-identical to the threaded
//!   fabric by a differential test harness and usable to 10k+ ranks.
//! - [`obs`] — structured tracing + metrics: per-rank typed spans on
//!   both the wall and virtual clocks, a counter/histogram registry,
//!   Chrome-trace / terminal exporters, and the fleet-scale sampled
//!   telemetry plane (`--trace off|step|sampled|full`) with streaming
//!   aggregation, straggler detection, and `HEALTH_*.json` export.
//! - [`service`] — the multi-tenant reduction service: admission +
//!   weighted deficit fair-share over shared fleet fabric capacity,
//!   disjoint per-job rank placements, and persistent
//!   `PROFILE_*.json` autotune profiles for warm-started jobs.
//! - [`data`] — deterministic synthetic shards (CIFAR / NCF / corpus
//!   stand-ins).
//! - [`tensor`], [`linalg`], [`optim`], [`util`] — dense/sparse tensors,
//!   fitting kernels, optimizers, and offline-friendly utilities.
//! - [`baselines`] — 3LC / SketchML / SKCompress comparison codecs.
//! - [`cli`], [`xp`] — argument parsing + experiment harness glue.

pub mod baselines;
pub mod cli;
pub mod collective;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod fleetsim;
pub mod linalg;
pub mod obs;
pub mod optim;
pub mod pipeline;
pub mod runtime;
pub mod service;
pub mod simnet;
pub mod sparsify;
pub mod tensor;
pub mod util;
pub mod vfabric;
pub mod xp;
