//! Typed codec specs: the parsed form of a codec pipeline string.
//!
//! A *codec spec* names a chain of stages: a leading index or value
//! codec, optionally followed by `+`-joined lossless byte stages, each
//! stage optionally carrying `key=value` parameters:
//!
//! ```text
//! rle                      single stage, default parameters
//! qsgd(bits=6)             single stage, one typed parameter
//! rle+deflate              two-stage chain (RLE, then Deflate bytes)
//! bloom_p2(fpr=0.01)+zstd  lossy head with a parameter, byte tail
//! ```
//!
//! Parsing here is purely *syntactic* — stage names are resolved (and
//! parameters validated against the codec's declared schema) by
//! [`CodecRegistry`](crate::compress::CodecRegistry) at build time, so
//! a [`CodecSpec`] can be constructed, stored and shipped around before
//! any registry exists. [`CodecSpec::label`] renders the canonical
//! spelling back; it is what travels in the container header and in
//! `autotune_choices` labels.

/// One stage of a codec chain: a name plus raw `key=value` parameters
/// (typed against the codec's schema at registry-build time).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageSpec {
    pub name: String,
    /// parameters exactly as written, in spec order
    pub params: Vec<(String, String)>,
}

impl StageSpec {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), params: Vec::new() }
    }

    /// The raw value of parameter `key`, if given.
    pub fn param(&self, key: &str) -> Option<&str> {
        self.params.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Set (or replace) parameter `key`.
    pub fn set_param(&mut self, key: &str, value: impl ToString) {
        let value = value.to_string();
        match self.params.iter_mut().find(|(k, _)| k == key) {
            Some((_, v)) => *v = value,
            None => self.params.push((key.to_string(), value)),
        }
    }

    /// Canonical spelling: `name` or `name(k=v,k2=v2)`.
    pub fn label(&self) -> String {
        if self.params.is_empty() {
            self.name.clone()
        } else {
            let kv: Vec<String> =
                self.params.iter().map(|(k, v)| format!("{k}={v}")).collect();
            format!("{}({})", self.name, kv.join(","))
        }
    }
}

impl std::fmt::Display for StageSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// A full codec pipeline for one set (index or value): a non-empty
/// stage chain. The head stage is an index/value codec (the only place
/// a lossy stage may appear); every later stage must resolve to a
/// lossless byte stage.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CodecSpec {
    pub stages: Vec<StageSpec>,
}

impl CodecSpec {
    /// A single-stage spec with default parameters.
    pub fn single(name: &str) -> Self {
        Self { stages: vec![StageSpec::new(name)] }
    }

    /// Parse a chain spec string, e.g. `rle+deflate` or
    /// `bloom_p2(fpr=0.01)+zstd`. Purely syntactic: stage names are not
    /// resolved here. `+` splits stages only outside parentheses, so
    /// parameter values may contain exponents (`fpr=1e+0`... would
    /// still be rejected later by the typed schema if out of range).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let s = s.trim();
        anyhow::ensure!(!s.is_empty(), "empty codec spec");
        let mut stages = Vec::new();
        let mut depth = 0i32;
        let mut start = 0usize;
        for (i, c) in s.char_indices() {
            match c {
                '(' => depth += 1,
                ')' => {
                    depth -= 1;
                    anyhow::ensure!(depth >= 0, "unbalanced ')' in codec spec {s:?}");
                }
                '+' if depth == 0 => {
                    stages.push(Self::parse_stage(&s[start..i], s)?);
                    start = i + 1;
                }
                _ => {}
            }
        }
        anyhow::ensure!(depth == 0, "unbalanced '(' in codec spec {s:?}");
        stages.push(Self::parse_stage(&s[start..], s)?);
        Ok(Self { stages })
    }

    fn parse_stage(stage: &str, whole: &str) -> anyhow::Result<StageSpec> {
        let stage = stage.trim();
        let (name, inner) = match stage.find('(') {
            None => (stage, None),
            Some(open) => {
                anyhow::ensure!(
                    stage.ends_with(')'),
                    "stage {stage:?} in codec spec {whole:?}: parameters must close with ')'"
                );
                (stage[..open].trim(), Some(&stage[open + 1..stage.len() - 1]))
            }
        };
        anyhow::ensure!(
            !name.is_empty() && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
            "bad stage name {name:?} in codec spec {whole:?}"
        );
        let mut params: Vec<(String, String)> = Vec::new();
        if let Some(inner) = inner {
            for kv in inner.split(',') {
                let kv = kv.trim();
                if kv.is_empty() {
                    continue;
                }
                let (k, v) = kv.split_once('=').ok_or_else(|| {
                    anyhow::anyhow!(
                        "parameter {kv:?} of stage {name:?} must be key=value \
                         (codec spec {whole:?})"
                    )
                })?;
                let (k, v) = (k.trim(), v.trim());
                anyhow::ensure!(
                    !k.is_empty() && !v.is_empty(),
                    "empty parameter key or value in stage {name:?} (codec spec {whole:?})"
                );
                anyhow::ensure!(
                    !params.iter().any(|(pk, _)| pk == k),
                    "duplicate parameter {k:?} in stage {name:?} (codec spec {whole:?})"
                );
                params.push((k.to_string(), v.to_string()));
            }
        }
        Ok(StageSpec { name: name.to_string(), params })
    }

    /// The leading stage (the index/value codec proper).
    pub fn head(&self) -> &StageSpec {
        &self.stages[0]
    }

    /// Whether more than one stage is chained.
    pub fn is_chain(&self) -> bool {
        self.stages.len() > 1
    }

    /// Canonical spelling: stage labels joined with `+`.
    pub fn label(&self) -> String {
        let parts: Vec<String> = self.stages.iter().map(|s| s.label()).collect();
        parts.join("+")
    }
}

impl std::fmt::Display for CodecSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// The typed compression spec of one DeepReduce instantiation: the
/// index pipeline and the value pipeline. Replaces the old flat string
/// fields (`index`/`index_param`/`value`/`value_param`) of the trainer
/// config — parameters now live inside the stage specs where the codec
/// that declares them can validate them.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CompressSpec {
    pub index: CodecSpec,
    pub value: CodecSpec,
}

impl CompressSpec {
    /// Parse both sides from spec strings.
    pub fn parse(index: &str, value: &str) -> anyhow::Result<Self> {
        Ok(Self { index: CodecSpec::parse(index)?, value: CodecSpec::parse(value)? })
    }

    /// The `raw|raw` bypass pair.
    pub fn raw() -> Self {
        Self { index: CodecSpec::single("raw"), value: CodecSpec::single("raw") }
    }

    /// Canonical `index|value` label (the autotune-choice format).
    pub fn label(&self) -> String {
        format!("{}|{}", self.index.label(), self.value.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_singles_chains_and_params() {
        let s = CodecSpec::parse("rle").unwrap();
        assert_eq!(s.stages.len(), 1);
        assert!(!s.is_chain());
        assert_eq!(s.label(), "rle");

        let c = CodecSpec::parse("rle+deflate").unwrap();
        assert_eq!(c.stages.len(), 2);
        assert!(c.is_chain());
        assert_eq!(c.head().name, "rle");
        assert_eq!(c.stages[1].name, "deflate");
        assert_eq!(c.label(), "rle+deflate");

        let p = CodecSpec::parse("bloom_p2(fpr=0.01)+zstd(level=5)").unwrap();
        assert_eq!(p.head().param("fpr"), Some("0.01"));
        assert_eq!(p.stages[1].param("level"), Some("5"));
        assert_eq!(p.label(), "bloom_p2(fpr=0.01)+zstd(level=5)");
        // label parses back to the same spec
        assert_eq!(CodecSpec::parse(&p.label()).unwrap(), p);

        let multi = CodecSpec::parse("qsgd(bits=6,bucket=256)").unwrap();
        assert_eq!(multi.head().param("bits"), Some("6"));
        assert_eq!(multi.head().param("bucket"), Some("256"));
    }

    #[test]
    fn whitespace_is_tolerated() {
        let s = CodecSpec::parse(" rle + deflate ( level = 9 ) ").unwrap();
        assert_eq!(s.label(), "rle+deflate(level=9)");
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            "   ",
            "+rle",
            "rle+",
            "rle++deflate",
            "rle(",
            "rle)",
            "rle(fpr)",
            "rle(=3)",
            "rle(fpr=)",
            "bad-name",
            "qsgd(bits=6,bits=7)",
        ] {
            assert!(CodecSpec::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn set_param_replaces_or_appends() {
        let mut s = CodecSpec::single("bloom_p2");
        s.stages[0].set_param("fpr", 0.01);
        assert_eq!(s.label(), "bloom_p2(fpr=0.01)");
        s.stages[0].set_param("fpr", 0.5);
        assert_eq!(s.label(), "bloom_p2(fpr=0.5)");
    }

    #[test]
    fn compress_spec_round_trips() {
        let cs = CompressSpec::parse("rle+deflate", "qsgd(bits=6)").unwrap();
        assert_eq!(cs.label(), "rle+deflate|qsgd(bits=6)");
        assert_eq!(CompressSpec::raw().label(), "raw|raw");
        assert!(CompressSpec::parse("", "raw").is_err());
    }
}
