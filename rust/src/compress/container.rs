//! Wire container: the single blob DeepReduce hands to the communication
//! library (paper §3 — "combines in one container the compressed index
//! and value structures, the reordering information and any required
//! metadata").
//!
//! The header is versioned. v1 (magic `DR1\n`) is emitted whenever both
//! codec specs are plain single-stage names — byte-identical to the
//! pre-chain format, so golden fixtures and cross-version interop hold.
//! v2 (magic `DR2\n` + a format-version byte) carries full codec *spec*
//! strings — chain labels like `rle+deflate`, parameters included — so
//! the wire stays self-describing for composed pipelines
//! ([`DeepReduce::for_container`](super::DeepReduce::for_container)
//! rebuilds the decoder from the header alone).
//!
//! Layout (all integers LEB128 unless noted):
//! ```text
//! magic "DR1\n"                 | d | num_values | idx spec | val spec
//! magic "DR2\n" | version (u8)  | ... same fields ...
//! | idx len | idx bytes | val len | val bytes
//! | perm flag (0/1) [| perm bit-width | perm len | packed perm]
//! | crc32 (LE u32, over everything before it)
//! ```
//!
//! Parsing never panics: every malformed, truncated or corrupt input
//! returns a structured [`ContainerError`].

use crate::util::bitio::{BitReader, BitWriter};
use crate::util::varint;

const MAGIC_V1: &[u8; 4] = b"DR1\n";
const MAGIC_V2: &[u8; 4] = b"DR2\n";

/// Newest container format version this build reads and writes.
pub const FORMAT_VERSION: u8 = 2;

/// Structured parse error of [`Container::from_bytes`].
#[derive(Debug, PartialEq, Eq)]
pub enum ContainerError {
    /// shorter than the smallest possible container
    TooShort { len: usize },
    /// CRC-32 over the body does not match the stored checksum
    ChecksumMismatch { want: u32, got: u32 },
    /// neither the v1 nor the v2 magic
    BadMagic,
    /// v2 magic with a version byte this build does not understand
    UnsupportedVersion(u8),
    /// a length field points past the end of the buffer
    Truncated(&'static str),
    /// a field failed to decode (varint, utf-8, bit stream, range)
    Malformed(String),
    /// well-formed container followed by extra bytes
    TrailingBytes { extra: usize },
}

impl std::fmt::Display for ContainerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ContainerError::TooShort { len } => {
                write!(f, "container too short ({len} bytes)")
            }
            ContainerError::ChecksumMismatch { want, got } => {
                write!(f, "container checksum mismatch (stored {want:#010x}, computed {got:#010x})")
            }
            ContainerError::BadMagic => write!(f, "bad container magic"),
            ContainerError::UnsupportedVersion(v) => {
                write!(f, "unsupported container format version {v} (this build reads <= {FORMAT_VERSION})")
            }
            ContainerError::Truncated(what) => write!(f, "container {what} truncated"),
            ContainerError::Malformed(what) => write!(f, "malformed container: {what}"),
            ContainerError::TrailingBytes { extra } => {
                write!(f, "{extra} trailing bytes after container")
            }
        }
    }
}

impl std::error::Error for ContainerError {}

fn vint(body: &[u8], pos: &mut usize, what: &'static str) -> Result<u64, ContainerError> {
    varint::read_u64(body, pos).map_err(|e| ContainerError::Malformed(format!("{what}: {e}")))
}

/// Bounds-checked slice take (overflow-safe: `pos + n` is checked).
fn take<'a>(
    body: &'a [u8],
    pos: &mut usize,
    n: usize,
    what: &'static str,
) -> Result<&'a [u8], ContainerError> {
    let end = pos
        .checked_add(n)
        .filter(|&e| e <= body.len())
        .ok_or(ContainerError::Truncated(what))?;
    let s = &body[*pos..end];
    *pos = end;
    Ok(s)
}

fn rstr(body: &[u8], pos: &mut usize, what: &'static str) -> Result<String, ContainerError> {
    let n = vint(body, pos, what)? as usize;
    let raw = take(body, pos, n, what)?;
    std::str::from_utf8(raw)
        .map(|s| s.to_string())
        .map_err(|e| ContainerError::Malformed(format!("{what}: {e}")))
}

/// Decoded container. `perm[j]` = original position of wire value j.
#[derive(Clone, Debug, PartialEq)]
pub struct Container {
    pub dense_len: usize,
    pub num_values: usize,
    /// index codec spec (full chain label for composed pipelines)
    pub index_codec: String,
    /// value codec spec (full chain label for composed pipelines)
    pub value_codec: String,
    pub index_bytes: Vec<u8>,
    pub value_bytes: Vec<u8>,
    pub perm: Option<Vec<u32>>,
    /// cached header size for the volume breakdown
    header_bytes: usize,
    reorder_bytes: usize,
}

impl Container {
    pub fn pack(
        dense_len: usize,
        num_values: usize,
        index_codec: &str,
        value_codec: &str,
        index_bytes: &[u8],
        value_bytes: &[u8],
        perm: Option<&[u32]>,
    ) -> Self {
        Self::pack_owned(
            dense_len,
            num_values,
            index_codec,
            value_codec,
            index_bytes.to_vec(),
            value_bytes.to_vec(),
            perm.map(|p| p.to_vec()),
        )
    }

    /// Like [`Container::pack`] but takes ownership of the payload
    /// buffers — the hot-path route (no per-tensor payload copy).
    pub fn pack_owned(
        dense_len: usize,
        num_values: usize,
        index_codec: &str,
        value_codec: &str,
        index_bytes: Vec<u8>,
        value_bytes: Vec<u8>,
        perm: Option<Vec<u32>>,
    ) -> Self {
        Self {
            dense_len,
            num_values,
            index_codec: index_codec.to_string(),
            value_codec: value_codec.to_string(),
            index_bytes,
            value_bytes,
            perm,
            header_bytes: 0,
            reorder_bytes: 0,
        }
    }

    /// Whether the header needs the v2 format: chain or parameterized
    /// specs cannot be represented in the v1 plain-name header.
    fn wire_version(&self) -> u8 {
        let plain = |s: &str| !s.contains('+') && !s.contains('(');
        if plain(&self.index_codec) && plain(&self.value_codec) {
            1
        } else {
            FORMAT_VERSION
        }
    }

    /// Serialize to the wire format (v1 when both specs are plain
    /// single-stage names, v2 otherwise).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            32 + self.index_bytes.len() + self.value_bytes.len() + self.index_codec.len(),
        );
        match self.wire_version() {
            1 => out.extend_from_slice(MAGIC_V1),
            v => {
                out.extend_from_slice(MAGIC_V2);
                out.push(v);
            }
        }
        varint::write_u64(&mut out, self.dense_len as u64);
        varint::write_u64(&mut out, self.num_values as u64);
        write_str(&mut out, &self.index_codec);
        write_str(&mut out, &self.value_codec);
        varint::write_u64(&mut out, self.index_bytes.len() as u64);
        out.extend_from_slice(&self.index_bytes);
        varint::write_u64(&mut out, self.value_bytes.len() as u64);
        out.extend_from_slice(&self.value_bytes);
        match &self.perm {
            None => out.push(0),
            Some(p) => {
                out.push(1);
                // ⌈log₂ n⌉ bits per entry (paper §5.1)
                let width = perm_width(p.len());
                out.push(width as u8);
                let mut w = BitWriter::with_capacity(p.len() * width as usize / 8 + 8);
                for &v in p {
                    w.write_bits(v as u64, width);
                }
                let bits = w.finish();
                varint::write_u64(&mut out, bits.len() as u64);
                out.extend_from_slice(&bits);
            }
        }
        let crc = crc32fast_hash(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse from the wire format, verifying the checksum. Returns a
    /// structured [`ContainerError`] on any malformed input — no input
    /// can panic this path.
    pub fn from_bytes(buf: &[u8]) -> Result<Self, ContainerError> {
        if buf.len() < 8 {
            return Err(ContainerError::TooShort { len: buf.len() });
        }
        let (body, crc_bytes) = buf.split_at(buf.len() - 4);
        let want = u32::from_le_bytes(crc_bytes.try_into().expect("4-byte split"));
        let got = crc32fast_hash(body);
        if want != got {
            return Err(ContainerError::ChecksumMismatch { want, got });
        }
        let mut pos = 4usize;
        if &body[..4] == MAGIC_V1 {
            // v1: no version byte
        } else if &body[..4] == MAGIC_V2 {
            let v = *body.get(pos).ok_or(ContainerError::Truncated("format version"))?;
            if !(2..=FORMAT_VERSION).contains(&v) {
                return Err(ContainerError::UnsupportedVersion(v));
            }
            pos += 1;
        } else {
            return Err(ContainerError::BadMagic);
        }
        let dense_len = vint(body, &mut pos, "dense_len")? as usize;
        let num_values = vint(body, &mut pos, "num_values")? as usize;
        let index_codec = rstr(body, &mut pos, "index codec spec")?;
        let value_codec = rstr(body, &mut pos, "value codec spec")?;
        let ilen = vint(body, &mut pos, "index length")? as usize;
        let index_bytes = take(body, &mut pos, ilen, "index section")?.to_vec();
        let vlen = vint(body, &mut pos, "value length")? as usize;
        let value_bytes = take(body, &mut pos, vlen, "value section")?.to_vec();
        let header_bytes = pos - ilen - vlen + 4; // all non-payload so far + crc
        let flag = *body.get(pos).ok_or(ContainerError::Truncated("perm flag"))?;
        pos += 1;
        let (perm, reorder_bytes) = match flag {
            0 => (None, 0),
            1 => {
                let width =
                    *body.get(pos).ok_or(ContainerError::Truncated("perm width"))? as u32;
                pos += 1;
                if !(1..=32).contains(&width) {
                    return Err(ContainerError::Malformed(format!("perm bit width {width}")));
                }
                let blen = vint(body, &mut pos, "perm length")? as usize;
                let packed = take(body, &mut pos, blen, "perm section")?;
                // bit budget check before allocating num_values slots
                if (num_values as u64).saturating_mul(width as u64) > (blen as u64) * 8 {
                    return Err(ContainerError::Truncated("perm bit stream"));
                }
                let mut r = BitReader::new(packed);
                let mut p = Vec::with_capacity(num_values);
                for _ in 0..num_values {
                    let v = r
                        .read_bits(width)
                        .map_err(|e| ContainerError::Malformed(format!("perm entry: {e}")))?;
                    p.push(v as u32);
                }
                (Some(p), blen + 2)
            }
            other => {
                return Err(ContainerError::Malformed(format!("perm flag {other}")));
            }
        };
        if pos != body.len() {
            return Err(ContainerError::TrailingBytes { extra: body.len() - pos });
        }
        Ok(Self {
            dense_len,
            num_values,
            index_codec,
            value_codec,
            index_bytes,
            value_bytes,
            perm,
            header_bytes,
            reorder_bytes,
        })
    }

    /// Total wire size without materializing `to_bytes`.
    pub fn wire_bytes(&self) -> usize {
        self.to_bytes().len()
    }

    /// Volume split for Fig 10a. (Header includes codec names + crc.)
    pub fn breakdown(&self) -> super::VolumeBreakdown {
        let total = self.wire_bytes();
        let reorder = match &self.perm {
            Some(p) => {
                let width = perm_width(p.len()) as usize;
                (p.len() * width).div_ceil(8) + 2
            }
            None => 0,
        };
        super::VolumeBreakdown {
            index_bytes: self.index_bytes.len(),
            value_bytes: self.value_bytes.len(),
            reorder_bytes: reorder,
            header_bytes: total - self.index_bytes.len() - self.value_bytes.len() - reorder,
        }
    }
}

fn perm_width(n: usize) -> u32 {
    if n <= 1 {
        1
    } else {
        usize::BITS - (n - 1).leading_zeros()
    }
}

fn write_str(out: &mut Vec<u8>, s: &str) {
    varint::write_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn crc32fast_hash(data: &[u8]) -> u32 {
    let mut h = crc32fast::Hasher::new();
    h.update(data);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_with_and_without_perm() {
        let c = Container::pack(1000, 3, "bitmap", "fitpoly", &[1, 2, 3], &[9; 10], Some(&[2, 0, 1]));
        let bytes = c.to_bytes();
        let back = Container::from_bytes(&bytes).unwrap();
        assert_eq!(back.dense_len, 1000);
        assert_eq!(back.num_values, 3);
        assert_eq!(back.index_codec, "bitmap");
        assert_eq!(back.perm, Some(vec![2, 0, 1]));
        assert_eq!(back.index_bytes, vec![1, 2, 3]);

        let c2 = Container::pack(10, 0, "raw", "raw", &[], &[], None);
        let back2 = Container::from_bytes(&c2.to_bytes()).unwrap();
        assert_eq!(back2.perm, None);
        assert_eq!(back2.num_values, 0);
    }

    #[test]
    fn plain_specs_stay_on_the_v1_wire() {
        let c = Container::pack(100, 1, "raw", "raw", &[5], &[6], None);
        assert_eq!(&c.to_bytes()[..4], b"DR1\n");
    }

    #[test]
    fn chain_and_param_specs_use_the_v2_wire() {
        for (idx, val) in [
            ("rle+deflate", "raw"),
            ("raw", "qsgd(bits=6)"),
            ("bloom_p2(fpr=0.01)+zstd", "raw+deflate"),
        ] {
            let c = Container::pack(500, 2, idx, val, &[1, 2], &[3, 4], None);
            let bytes = c.to_bytes();
            assert_eq!(&bytes[..4], b"DR2\n", "{idx}|{val}");
            assert_eq!(bytes[4], FORMAT_VERSION);
            let back = Container::from_bytes(&bytes).unwrap();
            assert_eq!(back.index_codec, idx);
            assert_eq!(back.value_codec, val);
            assert_eq!(back.index_bytes, vec![1, 2]);
        }
    }

    #[test]
    fn future_versions_are_rejected_with_a_structured_error() {
        let c = Container::pack(500, 0, "rle+deflate", "raw", &[], &[], None);
        let mut bytes = c.to_bytes();
        // bump the version byte and re-seal the checksum
        bytes[4] = FORMAT_VERSION + 1;
        let body_len = bytes.len() - 4;
        let crc = crc32fast_hash(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&crc.to_le_bytes());
        assert_eq!(
            Container::from_bytes(&bytes),
            Err(ContainerError::UnsupportedVersion(FORMAT_VERSION + 1))
        );
    }

    #[test]
    fn corruption_detected() {
        let c = Container::pack(100, 1, "raw", "raw", &[5], &[6], None);
        let mut bytes = c.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(Container::from_bytes(&bytes).is_err());
        // truncation
        let ok = c.to_bytes();
        assert!(Container::from_bytes(&ok[..ok.len() - 1]).is_err());
    }

    /// Re-seal a body prefix with a fresh checksum so the parser (not
    /// the CRC gate) has to survive every truncation point.
    fn seal(body: &[u8]) -> Vec<u8> {
        let mut out = body.to_vec();
        out.extend_from_slice(&crc32fast_hash(body).to_le_bytes());
        out
    }

    #[test]
    fn every_truncation_point_yields_a_structured_error() {
        for c in [
            Container::pack(5000, 3, "elias", "deflate", &[7; 40], &[9; 30], Some(&[2, 0, 1])),
            Container::pack(5000, 3, "rle+deflate", "qsgd(bits=6)", &[7; 40], &[9; 30], None),
        ] {
            let full = c.to_bytes();
            let body = &full[..full.len() - 4];
            // valid-CRC prefixes: the parser must error (never panic) at
            // every possible cut point, including cuts inside varints,
            // spec strings, payload sections and the perm block
            for cut in 0..body.len() {
                let sealed = seal(&body[..cut]);
                let err = Container::from_bytes(&sealed)
                    .expect_err(&format!("prefix of {cut} bytes parsed"));
                match err {
                    ContainerError::ChecksumMismatch { .. } => {
                        panic!("seal() should have made the checksum valid at cut {cut}")
                    }
                    _ => {}
                }
            }
            // raw truncations (stale CRC): also all errors
            for cut in 0..full.len() {
                assert!(Container::from_bytes(&full[..cut]).is_err(), "cut {cut}");
            }
            // and garbage of assorted sizes
            for len in [0usize, 1, 7, 8, 9, 64] {
                let garbage = vec![0x5Au8; len];
                assert!(Container::from_bytes(&garbage).is_err(), "garbage len {len}");
                assert!(Container::from_bytes(&seal(&garbage)).is_err(), "sealed garbage {len}");
            }
        }
    }

    #[test]
    fn perm_bit_budget_is_checked_before_allocation() {
        // hand-build a v1 body claiming 2^40 values with a 1-byte perm
        let mut body = Vec::new();
        body.extend_from_slice(MAGIC_V1);
        varint::write_u64(&mut body, 100);
        varint::write_u64(&mut body, 1u64 << 40); // num_values: absurd
        write_str(&mut body, "raw");
        write_str(&mut body, "raw");
        varint::write_u64(&mut body, 0); // index len
        varint::write_u64(&mut body, 0); // value len
        body.push(1); // perm flag
        body.push(16); // perm width
        varint::write_u64(&mut body, 1); // perm byte length
        body.push(0xFF);
        let sealed = seal(&body);
        assert_eq!(
            Container::from_bytes(&sealed),
            Err(ContainerError::Truncated("perm bit stream"))
        );
    }

    #[test]
    fn breakdown_sums_to_total() {
        let c = Container::pack(5000, 4, "bloom_p2", "qsgd", &[0; 100], &[0; 50], Some(&[3, 1, 0, 2]));
        let b = c.breakdown();
        assert_eq!(b.total(), c.wire_bytes());
        assert_eq!(b.index_bytes, 100);
        assert_eq!(b.value_bytes, 50);
        assert!(b.reorder_bytes >= 1);
        // v2 container: breakdown still sums exactly
        let c2 = Container::pack(5000, 4, "rle+deflate", "qsgd(bits=6)", &[0; 10], &[0; 5], None);
        assert_eq!(c2.breakdown().total(), c2.wire_bytes());
    }

    #[test]
    fn perm_width_is_ceil_log2() {
        assert_eq!(perm_width(1), 1);
        assert_eq!(perm_width(2), 1);
        assert_eq!(perm_width(3), 2);
        assert_eq!(perm_width(369), 9);
        assert_eq!(perm_width(65536), 16);
    }
}
