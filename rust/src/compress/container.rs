//! Wire container: the single blob DeepReduce hands to the communication
//! library (paper §3 — "combines in one container the compressed index
//! and value structures, the reordering information and any required
//! metadata").
//!
//! Layout (all integers LEB128 unless noted):
//! ```text
//! magic "DR1\n" | d | num_values | idx name | val name
//! | idx len | idx bytes | val len | val bytes
//! | perm flag (0/1) [| perm bit-width | packed perm]
//! | crc32 (LE u32, over everything before it)
//! ```

use crate::util::bitio::{BitReader, BitWriter};
use crate::util::varint;

const MAGIC: &[u8; 4] = b"DR1\n";

/// Decoded container. `perm[j]` = original position of wire value j.
#[derive(Clone, Debug, PartialEq)]
pub struct Container {
    pub dense_len: usize,
    pub num_values: usize,
    pub index_codec: String,
    pub value_codec: String,
    pub index_bytes: Vec<u8>,
    pub value_bytes: Vec<u8>,
    pub perm: Option<Vec<u32>>,
    /// cached header size for the volume breakdown
    header_bytes: usize,
    reorder_bytes: usize,
}

impl Container {
    pub fn pack(
        dense_len: usize,
        num_values: usize,
        index_codec: &str,
        value_codec: &str,
        index_bytes: &[u8],
        value_bytes: &[u8],
        perm: Option<&[u32]>,
    ) -> Self {
        Self {
            dense_len,
            num_values,
            index_codec: index_codec.to_string(),
            value_codec: value_codec.to_string(),
            index_bytes: index_bytes.to_vec(),
            value_bytes: value_bytes.to_vec(),
            perm: perm.map(|p| p.to_vec()),
            header_bytes: 0,
            reorder_bytes: 0,
        }
    }

    /// Serialize to the wire format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            32 + self.index_bytes.len() + self.value_bytes.len() + self.index_codec.len(),
        );
        out.extend_from_slice(MAGIC);
        varint::write_u64(&mut out, self.dense_len as u64);
        varint::write_u64(&mut out, self.num_values as u64);
        write_str(&mut out, &self.index_codec);
        write_str(&mut out, &self.value_codec);
        varint::write_u64(&mut out, self.index_bytes.len() as u64);
        out.extend_from_slice(&self.index_bytes);
        varint::write_u64(&mut out, self.value_bytes.len() as u64);
        out.extend_from_slice(&self.value_bytes);
        match &self.perm {
            None => out.push(0),
            Some(p) => {
                out.push(1);
                // ⌈log₂ n⌉ bits per entry (paper §5.1)
                let width = perm_width(p.len());
                out.push(width as u8);
                let mut w = BitWriter::with_capacity(p.len() * width as usize / 8 + 8);
                for &v in p {
                    w.write_bits(v as u64, width);
                }
                let bits = w.finish();
                varint::write_u64(&mut out, bits.len() as u64);
                out.extend_from_slice(&bits);
            }
        }
        let crc = crc32fast_hash(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse from the wire format, verifying the checksum.
    pub fn from_bytes(buf: &[u8]) -> anyhow::Result<Self> {
        anyhow::ensure!(buf.len() >= 8, "container too short");
        let (body, crc_bytes) = buf.split_at(buf.len() - 4);
        let want = u32::from_le_bytes(crc_bytes.try_into().unwrap());
        let got = crc32fast_hash(body);
        anyhow::ensure!(want == got, "container checksum mismatch");
        anyhow::ensure!(&body[..4] == MAGIC, "bad container magic");
        let mut pos = 4usize;
        let dense_len = varint::read_u64(body, &mut pos)? as usize;
        let num_values = varint::read_u64(body, &mut pos)? as usize;
        let index_codec = read_str(body, &mut pos)?;
        let value_codec = read_str(body, &mut pos)?;
        let ilen = varint::read_u64(body, &mut pos)? as usize;
        anyhow::ensure!(pos + ilen <= body.len(), "index section truncated");
        let index_bytes = body[pos..pos + ilen].to_vec();
        pos += ilen;
        let vlen = varint::read_u64(body, &mut pos)? as usize;
        anyhow::ensure!(pos + vlen <= body.len(), "value section truncated");
        let value_bytes = body[pos..pos + vlen].to_vec();
        pos += vlen;
        let header_bytes = pos - ilen - vlen + 4; // all non-payload so far + crc
        let flag = *body.get(pos).ok_or_else(|| anyhow::anyhow!("missing perm flag"))?;
        pos += 1;
        let (perm, reorder_bytes) = if flag == 1 {
            let width = *body.get(pos).ok_or_else(|| anyhow::anyhow!("missing perm width"))?
                as u32;
            pos += 1;
            anyhow::ensure!((1..=32).contains(&width), "bad perm width {width}");
            let blen = varint::read_u64(body, &mut pos)? as usize;
            anyhow::ensure!(pos + blen <= body.len(), "perm section truncated");
            let mut r = BitReader::new(&body[pos..pos + blen]);
            let mut p = Vec::with_capacity(num_values);
            for _ in 0..num_values {
                p.push(r.read_bits(width)? as u32);
            }
            pos += blen;
            (Some(p), blen + 2)
        } else {
            (None, 0)
        };
        anyhow::ensure!(pos == body.len(), "trailing bytes in container");
        Ok(Self {
            dense_len,
            num_values,
            index_codec,
            value_codec,
            index_bytes,
            value_bytes,
            perm,
            header_bytes,
            reorder_bytes,
        })
    }

    /// Total wire size without materializing `to_bytes`.
    pub fn wire_bytes(&self) -> usize {
        self.to_bytes().len()
    }

    /// Volume split for Fig 10a. (Header includes codec names + crc.)
    pub fn breakdown(&self) -> super::VolumeBreakdown {
        let total = self.wire_bytes();
        let reorder = match &self.perm {
            Some(p) => {
                let width = perm_width(p.len()) as usize;
                (p.len() * width).div_ceil(8) + 2
            }
            None => 0,
        };
        super::VolumeBreakdown {
            index_bytes: self.index_bytes.len(),
            value_bytes: self.value_bytes.len(),
            reorder_bytes: reorder,
            header_bytes: total - self.index_bytes.len() - self.value_bytes.len() - reorder,
        }
    }
}

fn perm_width(n: usize) -> u32 {
    if n <= 1 {
        1
    } else {
        usize::BITS - (n - 1).leading_zeros()
    }
}

fn write_str(out: &mut Vec<u8>, s: &str) {
    varint::write_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn read_str(buf: &[u8], pos: &mut usize) -> anyhow::Result<String> {
    let n = varint::read_u64(buf, pos)? as usize;
    anyhow::ensure!(*pos + n <= buf.len(), "string truncated");
    let s = std::str::from_utf8(&buf[*pos..*pos + n])?.to_string();
    *pos += n;
    Ok(s)
}

fn crc32fast_hash(data: &[u8]) -> u32 {
    let mut h = crc32fast::Hasher::new();
    h.update(data);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_with_and_without_perm() {
        let c = Container::pack(1000, 3, "bitmap", "fitpoly", &[1, 2, 3], &[9; 10], Some(&[2, 0, 1]));
        let bytes = c.to_bytes();
        let back = Container::from_bytes(&bytes).unwrap();
        assert_eq!(back.dense_len, 1000);
        assert_eq!(back.num_values, 3);
        assert_eq!(back.index_codec, "bitmap");
        assert_eq!(back.perm, Some(vec![2, 0, 1]));
        assert_eq!(back.index_bytes, vec![1, 2, 3]);

        let c2 = Container::pack(10, 0, "raw", "raw", &[], &[], None);
        let back2 = Container::from_bytes(&c2.to_bytes()).unwrap();
        assert_eq!(back2.perm, None);
        assert_eq!(back2.num_values, 0);
    }

    #[test]
    fn corruption_detected() {
        let c = Container::pack(100, 1, "raw", "raw", &[5], &[6], None);
        let mut bytes = c.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(Container::from_bytes(&bytes).is_err());
        // truncation
        let ok = c.to_bytes();
        assert!(Container::from_bytes(&ok[..ok.len() - 1]).is_err());
    }

    #[test]
    fn breakdown_sums_to_total() {
        let c = Container::pack(5000, 4, "bloom_p2", "qsgd", &[0; 100], &[0; 50], Some(&[3, 1, 0, 2]));
        let b = c.breakdown();
        assert_eq!(b.total(), c.wire_bytes());
        assert_eq!(b.index_bytes, 100);
        assert_eq!(b.value_bytes, 50);
        assert!(b.reorder_bytes >= 1);
    }

    #[test]
    fn perm_width_is_ceil_log2() {
        assert_eq!(perm_width(1), 1);
        assert_eq!(perm_width(2), 1);
        assert_eq!(perm_width(3), 2);
        assert_eq!(perm_width(369), 9);
        assert_eq!(perm_width(65536), 16);
    }
}
