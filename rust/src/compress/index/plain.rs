//! Lossless index codecs: raw keys, bitmap, bit-level RLE, Huffman over
//! byte planes, delta+varint, and Elias-gamma gap coding.
//!
//! All of these implement the buffer-reusing
//! [`encode_into`](IndexCodec::encode_into) primitive directly (they
//! append to the caller's buffer and return `None` — lossless codecs
//! never clone the support), with [`encode`](IndexCodec::encode)
//! provided by the trait default.

use crate::compress::IndexCodec;
use crate::tensor::Bitmap;
use crate::util::bitio::{BitReader, BitWriter};
use crate::util::elias::{gamma_decode, gamma_encode};
use crate::util::huffman::Huffman;
use crate::util::varint;

/// Raw u32 little-endian keys — the `(key, value)` baseline of Fig 1b.
pub struct RawIndex;

impl IndexCodec for RawIndex {
    fn name(&self) -> &str {
        "raw"
    }

    fn encode_into(&self, _d: usize, support: &[u32], out: &mut Vec<u8>) -> Option<Vec<u32>> {
        out.reserve(support.len() * 4);
        for &i in support {
            out.extend_from_slice(&i.to_le_bytes());
        }
        None
    }

    fn decode(&self, d: usize, bytes: &[u8]) -> anyhow::Result<Vec<u32>> {
        anyhow::ensure!(bytes.len() % 4 == 0, "raw index bytes not multiple of 4");
        let out: Vec<u32> = bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        anyhow::ensure!(out.iter().all(|&i| (i as usize) < d), "index out of range");
        Ok(out)
    }
}

/// Dense bitmap: d bits, `B[i]=1` iff i ∈ S (Fig 1c's index half).
pub struct BitmapIndex;

impl IndexCodec for BitmapIndex {
    fn name(&self) -> &str {
        "bitmap"
    }

    fn encode_into(&self, d: usize, support: &[u32], out: &mut Vec<u8>) -> Option<Vec<u32>> {
        let bm = Bitmap::from_indices(d, support);
        out.reserve(d / 8 + 9);
        varint::write_u64(out, d as u64);
        let start = out.len();
        for w in bm.words() {
            out.extend_from_slice(&w.to_le_bytes());
        }
        // trim to ceil(d/8) payload bytes
        out.truncate(start + d.div_ceil(8));
        None
    }

    fn decode(&self, d: usize, bytes: &[u8]) -> anyhow::Result<Vec<u32>> {
        let mut pos = 0usize;
        let stored_d = varint::read_u64(bytes, &mut pos)? as usize;
        anyhow::ensure!(stored_d == d, "bitmap d mismatch: {stored_d} vs {d}");
        let payload = &bytes[pos..];
        anyhow::ensure!(payload.len() == d.div_ceil(8), "bitmap payload size");
        let mut words = vec![0u64; d.div_ceil(64)];
        for (i, &b) in payload.iter().enumerate() {
            words[i / 8] |= (b as u64) << ((i % 8) * 8);
        }
        Ok(Bitmap::from_words(words, d).to_indices())
    }
}

/// Bit-level run-length encoding over the support bitmap (paper §2):
/// alternating run lengths, Elias-gamma coded; the first run's bit value
/// is stored explicitly. Wins when indices are clustered.
pub struct RleIndex;

impl IndexCodec for RleIndex {
    fn name(&self) -> &str {
        "rle"
    }

    fn encode_into(&self, d: usize, support: &[u32], out: &mut Vec<u8>) -> Option<Vec<u32>> {
        let bm = Bitmap::from_indices(d, support);
        let mut w = BitWriter::new();
        let mut first = true;
        for (bit, len) in bm.runs() {
            if first {
                w.write_bit(bit);
                first = false;
            }
            gamma_encode(&mut w, len as u64);
        }
        varint::write_u64(out, d as u64);
        out.extend_from_slice(&w.finish());
        None
    }

    fn decode(&self, d: usize, bytes: &[u8]) -> anyhow::Result<Vec<u32>> {
        let mut pos = 0usize;
        let stored_d = varint::read_u64(bytes, &mut pos)? as usize;
        anyhow::ensure!(stored_d == d, "rle d mismatch");
        let mut out = Vec::new();
        if d == 0 {
            return Ok(out);
        }
        let mut r = BitReader::new(&bytes[pos..]);
        let mut bit = r.read_bit()?;
        let mut covered = 0usize;
        while covered < d {
            let len = gamma_decode(&mut r)? as usize;
            anyhow::ensure!(covered + len <= d, "rle runs exceed d");
            if bit {
                out.extend((covered..covered + len).map(|i| i as u32));
            }
            covered += len;
            bit = !bit;
        }
        Ok(out)
    }
}

/// Huffman over index byte planes (paper §11, "Huffman Encoding"): each
/// 32-bit key is split into 4 little-endian bytes and coded with a
/// Huffman table built from the *model domain* `0..d-1` — a pre-defined
/// codec both sides derive from `d`, so no table travels on the wire.
pub struct HuffmanIndex;

impl HuffmanIndex {
    /// Byte frequencies of the little-endian representation of all
    /// integers in [0, d) — computed analytically per byte plane, then
    /// summed (the paper builds one codec over all unpacked bytes).
    fn domain_codec(d: usize) -> Huffman {
        let mut freqs = [0u64; 256];
        for plane in 0..4u32 {
            plane_freqs(d as u64, plane, &mut freqs);
        }
        Huffman::from_freqs(&freqs).expect("domain is nonempty")
    }
}

/// Accumulate frequency of each byte value in plane `p` (LE) over 0..d.
fn plane_freqs(d: u64, plane: u32, freqs: &mut [u64; 256]) {
    let shift = plane * 8;
    // value v at plane p appears for i in [0,d) with ((i >> shift) & 0xFF) == v
    // count = full_cycles * 2^shift + partial
    let block = 1u64 << shift; // consecutive run length per byte value
    let cycle = block * 256;
    let full_cycles = d / cycle;
    let rem = d % cycle;
    for (v, f) in freqs.iter_mut().enumerate() {
        let mut c = full_cycles * block;
        let v_start = v as u64 * block;
        if rem > v_start {
            c += (rem - v_start).min(block);
        }
        *f += c;
    }
}

impl IndexCodec for HuffmanIndex {
    fn name(&self) -> &str {
        "huffman"
    }

    fn encode_into(&self, d: usize, support: &[u32], out: &mut Vec<u8>) -> Option<Vec<u32>> {
        let codec = Self::domain_codec(d);
        let mut w = BitWriter::new();
        for &i in support {
            for b in i.to_le_bytes() {
                codec.encode_symbol(&mut w, b);
            }
        }
        varint::write_u64(out, support.len() as u64);
        out.extend_from_slice(&w.finish());
        None
    }

    fn decode(&self, d: usize, bytes: &[u8]) -> anyhow::Result<Vec<u32>> {
        let mut pos = 0usize;
        let n = varint::read_u64(bytes, &mut pos)? as usize;
        let codec = Self::domain_codec(d);
        let mut r = BitReader::new(&bytes[pos..]);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let mut le = [0u8; 4];
            for slot in le.iter_mut() {
                *slot = codec.decode_symbol(&mut r)?;
            }
            let v = u32::from_le_bytes(le);
            anyhow::ensure!((v as usize) < d, "huffman index out of range");
            out.push(v);
        }
        Ok(out)
    }
}

/// Delta encoding + LEB128 varints (the SketchML/SKCompress index style):
/// store `S[0], S[1]-S[0], ...`; ascending input makes deltas small.
pub struct DeltaVarint;

impl IndexCodec for DeltaVarint {
    fn name(&self) -> &str {
        "delta_varint"
    }

    fn encode_into(&self, _d: usize, support: &[u32], out: &mut Vec<u8>) -> Option<Vec<u32>> {
        out.reserve(support.len() * 2 + 9);
        varint::write_u64(out, support.len() as u64);
        let mut prev = 0u64;
        for (k, &i) in support.iter().enumerate() {
            let delta = if k == 0 { i as u64 } else { i as u64 - prev };
            varint::write_u64(out, delta);
            prev = i as u64;
        }
        None
    }

    fn decode(&self, d: usize, bytes: &[u8]) -> anyhow::Result<Vec<u32>> {
        let mut pos = 0usize;
        let n = varint::read_u64(bytes, &mut pos)? as usize;
        let mut out = Vec::with_capacity(n);
        let mut acc = 0u64;
        for k in 0..n {
            let delta = varint::read_u64(bytes, &mut pos)?;
            acc = if k == 0 { delta } else { acc + delta };
            anyhow::ensure!((acc as usize) < d, "delta index out of range");
            out.push(acc as u32);
        }
        Ok(out)
    }
}

/// Elias-gamma coded support gaps (the QSGD-style bit-level integer
/// code applied to the index set): store `S[0]+1` then the strictly
/// positive gaps `S[k] − S[k−1]`, each as a gamma code. Beats
/// delta+varint on very sparse supports where gaps are large but the
/// varint byte granularity wastes bits, and on clustered supports where
/// gaps of 1 cost a single bit.
pub struct EliasIndex;

impl IndexCodec for EliasIndex {
    fn name(&self) -> &str {
        "elias"
    }

    fn encode_into(&self, _d: usize, support: &[u32], out: &mut Vec<u8>) -> Option<Vec<u32>> {
        out.reserve(support.len() / 2 + 9);
        varint::write_u64(out, support.len() as u64);
        let mut w = BitWriter::with_capacity(support.len());
        let mut prev = 0u64;
        for (k, &i) in support.iter().enumerate() {
            let gap = if k == 0 { i as u64 + 1 } else { i as u64 - prev };
            gamma_encode(&mut w, gap);
            prev = i as u64;
        }
        out.extend_from_slice(&w.finish());
        None
    }

    fn decode(&self, d: usize, bytes: &[u8]) -> anyhow::Result<Vec<u32>> {
        let mut pos = 0usize;
        let n = varint::read_u64(bytes, &mut pos)? as usize;
        let mut r = BitReader::new(&bytes[pos..]);
        let mut out = Vec::with_capacity(n);
        let mut acc = 0u64;
        for k in 0..n {
            let gap = gamma_decode(&mut r)?;
            acc = if k == 0 { gap - 1 } else { acc + gap };
            anyhow::ensure!((acc as usize) < d, "elias index out of range");
            out.push(acc as u32);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::IndexCodec;

    #[test]
    fn elias_roundtrips_and_compresses_clusters() {
        let d = 100_000;
        for support in [
            vec![],
            vec![0u32],
            vec![d as u32 - 1],
            (40_000..41_000u32).collect::<Vec<_>>(),
            vec![0, 1, 2, 99_999],
        ] {
            let enc = EliasIndex.encode(d, &support);
            assert_eq!(enc.effective, support);
            assert_eq!(EliasIndex.decode(d, &enc.bytes).unwrap(), support, "{support:?}");
        }
        // clustered support: gaps of 1 cost one bit each
        let clustered: Vec<u32> = (40_000..41_000u32).collect();
        let e = EliasIndex.encode(d, &clustered);
        let raw = RawIndex.encode(d, &clustered);
        assert!(e.bytes.len() * 10 < raw.bytes.len(), "{} vs {}", e.bytes.len(), raw.bytes.len());
    }

    #[test]
    fn elias_decode_validates_domain() {
        let enc = EliasIndex.encode(100, &[99]);
        assert!(EliasIndex.decode(50, &enc.bytes).is_err());
    }

    #[test]
    fn plane_freqs_match_bruteforce() {
        for d in [1usize, 255, 256, 257, 1000, 65536, 70000] {
            for plane in 0..4u32 {
                let mut fast = [0u64; 256];
                plane_freqs(d as u64, plane, &mut fast);
                let mut slow = [0u64; 256];
                for i in 0..d as u64 {
                    slow[((i >> (plane * 8)) & 0xFF) as usize] += 1;
                }
                assert_eq!(fast, slow, "d={d} plane={plane}");
            }
        }
    }

    #[test]
    fn huffman_beats_raw_for_small_domains() {
        // d = 36864 -> top two byte planes are almost always zero
        let d = 36864;
        let support: Vec<u32> = (0..d as u32).step_by(100).collect();
        let h = HuffmanIndex.encode(d, &support);
        let raw = RawIndex.encode(d, &support);
        assert!((h.bytes.len() as f64) < 0.7 * raw.bytes.len() as f64, "{} vs {}", h.bytes.len(), raw.bytes.len());
        assert_eq!(HuffmanIndex.decode(d, &h.bytes).unwrap(), support);
    }

    #[test]
    fn rle_first_bit_one() {
        // support starting at 0 exercises the first-run=1 branch
        let support = vec![0u32, 1, 2, 50];
        let enc = RleIndex.encode(60, &support);
        assert_eq!(RleIndex.decode(60, &enc.bytes).unwrap(), support);
    }

    #[test]
    fn decode_validates_domain() {
        let enc = RawIndex.encode(100, &[99]);
        assert!(RawIndex.decode(50, &enc.bytes).is_err());
        let enc = DeltaVarint.encode(100, &[99]);
        assert!(DeltaVarint.decode(50, &enc.bytes).is_err());
    }

    #[test]
    fn encode_into_appends_after_existing_content() {
        let prefix = vec![0xEEu8, 0xEE];
        for codec in [
            &RawIndex as &dyn IndexCodec,
            &BitmapIndex,
            &RleIndex,
            &HuffmanIndex,
            &DeltaVarint,
            &EliasIndex,
        ] {
            let mut buf = prefix.clone();
            let eff = codec.encode_into(500, &[3, 4, 400], &mut buf);
            assert!(eff.is_none(), "{} is lossless", codec.name());
            assert_eq!(&buf[..2], &prefix[..], "{}", codec.name());
            assert_eq!(
                codec.decode(500, &buf[2..]).unwrap(),
                vec![3, 4, 400],
                "{}",
                codec.name()
            );
        }
    }
}
