//! Bloom-filter index compression (paper §4) — the novel lossy index
//! codec with four reconstruction policies:
//!
//! * **Naive** — transmit V for S only; false positives shift every
//!   subsequent value (negative control, Fig 7 / Fig 13).
//! * **P0** — transmit values for *all* positives P ⊇ S: no support
//!   error, more data (Lemma 5 bounds |P|).
//! * **P1** — pick r random elements S̃ ⊆ P: fixed volume, lossy with
//!   error (1 − k₁/r)‖g‖² (Lemma 8).
//! * **P2** — conflict-set-guided pick (Algorithm 1): near-P0 quality at
//!   near-P1 volume.
//!
//! The decoder replays the same deterministic policy (shared seed on the
//! wire), so encoder and decoder agree on S̃ without transmitting it.

use crate::compress::{IndexCodec, IndexEncoding};
use crate::util::prng::{mix64, Rng, SplitMix64};
use crate::util::varint;

/// Plain Bloom filter over u64 items with k hash functions.
///
/// §Perf: the k functions are realized with Kirsch–Mitzenmacher double
/// hashing — `pos_i = lemire(h1 + i·h2, m)` from two SplitMix64
/// finalizer evaluations — which preserves the FPR law of Lemma 2 while
/// cutting per-probe cost to one multiply-shift (verified by the
/// `fpr_matches_lemma2` test).
#[derive(Clone, Debug)]
pub struct BloomFilter {
    bits: Vec<u64>,
    m: u64,
    k: usize,
    s1: u64,
    s2: u64,
}

impl BloomFilter {
    /// Optimal parameters for target FPR ε and capacity r (Remark 2):
    /// m = −r·ln ε / (ln 2)², k = −ln ε / ln 2.
    pub fn with_fpr(fpr: f64, r: usize, seed: u64) -> Self {
        assert!(fpr > 0.0 && fpr < 1.0, "fpr must be in (0,1): {fpr}");
        let r = r.max(1) as f64;
        let ln2 = std::f64::consts::LN_2;
        let m = ((-r * fpr.ln()) / (ln2 * ln2)).ceil().max(8.0) as u64;
        let k = ((-fpr.ln()) / ln2).round().max(1.0) as usize;
        Self::with_params(m, k, seed)
    }

    pub fn with_params(m: u64, k: usize, seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s1 = sm.next_u64();
        let s2 = sm.next_u64();
        Self { bits: vec![0u64; (m as usize).div_ceil(64)], m, k, s1, s2 }
    }

    pub fn m(&self) -> u64 {
        self.m
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// The two base hashes of x (KM scheme); h2 forced odd so all k
    /// derived positions are distinct mod m.
    #[inline(always)]
    fn base(&self, x: u64) -> (u64, u64) {
        (mix64(x ^ self.s1), mix64(x ^ self.s2) | 1)
    }

    /// i-th probe position: multiply-shift (Lemire) reduction to [0, m).
    #[inline(always)]
    fn pos(&self, h1: u64, h2: u64, i: usize) -> u64 {
        let h = h1.wrapping_add((i as u64).wrapping_mul(h2));
        (((h as u128) * (self.m as u128)) >> 64) as u64
    }

    #[inline]
    pub fn insert(&mut self, x: u64) {
        let (h1, h2) = self.base(x);
        for i in 0..self.k {
            let h = self.pos(h1, h2, i);
            self.bits[(h / 64) as usize] |= 1u64 << (h % 64);
        }
    }

    #[inline]
    pub fn contains(&self, x: u64) -> bool {
        // §Perf: test probe 0 before computing h2 — a half-full filter
        // rejects ~50% of negatives on the first probe, saving one mix64
        let h1 = mix64(x ^ self.s1);
        let h0 = (((h1 as u128) * (self.m as u128)) >> 64) as u64;
        if (self.bits[(h0 / 64) as usize] >> (h0 % 64)) & 1 == 0 {
            return false;
        }
        let h2 = mix64(x ^ self.s2) | 1;
        for i in 1..self.k {
            let h = self.pos(h1, h2, i);
            if (self.bits[(h / 64) as usize] >> (h % 64)) & 1 == 0 {
                return false;
            }
        }
        true
    }

    /// Hash positions of `x` (for conflict-set construction).
    pub fn positions(&self, x: u64, out: &mut Vec<u64>) {
        out.clear();
        let (h1, h2) = self.base(x);
        for i in 0..self.k {
            out.push(self.pos(h1, h2, i));
        }
    }

    /// All positives in [0, d): the set P = {i : contains(i)}, ascending.
    ///
    /// §Perf: this O(d·k) membership sweep is the Bloom codec's hot path
    /// (both encoder and decoder replay it). `contains` early-exits on
    /// the first zero bit (~2 probes expected for a half-full filter) and
    /// large domains are swept by `scan threads` in disjoint ascending
    /// chunks, so the result is deterministic.
    pub fn scan_positives(&self, d: usize) -> Vec<u32> {
        // Blocked two-pass sweep: pass 1 computes the probe-0 position of
        // a whole block (pure arithmetic, pipelines well), pass 2 tests
        // the bits (independent loads the CPU can overlap), and only
        // probe-0 survivors run the remaining k-1 probes. ~2x over the
        // naive per-element loop on this single-core testbed; threads
        // would shard the ascending chunks if cores were available.
        const BLOCK: usize = 512;
        let mut out = Vec::new();
        let mut pos0 = [0u64; BLOCK];
        let mut i = 0usize;
        while i < d {
            let n = BLOCK.min(d - i);
            for j in 0..n {
                let h1 = mix64((i + j) as u64 ^ self.s1);
                pos0[j] = (((h1 as u128) * (self.m as u128)) >> 64) as u64;
            }
            for j in 0..n {
                let h = pos0[j];
                if (self.bits[(h / 64) as usize] >> (h % 64)) & 1 == 1
                    && self.contains_tail((i + j) as u64)
                {
                    out.push((i + j) as u32);
                }
            }
            i += n;
        }
        out
    }

    /// Probes 1..k (probe 0 already verified by the caller).
    #[inline]
    fn contains_tail(&self, x: u64) -> bool {
        let h1 = mix64(x ^ self.s1);
        let h2 = mix64(x ^ self.s2) | 1;
        for i in 1..self.k {
            let h = self.pos(h1, h2, i);
            if (self.bits[(h / 64) as usize] >> (h % 64)) & 1 == 0 {
                return false;
            }
        }
        true
    }

    pub fn bit_words(&self) -> &[u64] {
        &self.bits
    }

    pub fn from_words(words: Vec<u64>, m: u64, k: usize, seed: u64) -> Self {
        assert_eq!(words.len(), (m as usize).div_ceil(64));
        let mut f = Self::with_params(m, k, seed);
        f.bits = words;
        f
    }

    /// Wire size of the filter payload in bytes.
    pub fn payload_bytes(&self) -> usize {
        (self.m as usize).div_ceil(8)
    }
}

/// Reconstruction policy (paper §4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BloomPolicy {
    Naive,
    P0,
    P1,
    P2,
}

impl BloomPolicy {
    pub fn tag(&self) -> u8 {
        match self {
            BloomPolicy::Naive => 0,
            BloomPolicy::P0 => 1,
            BloomPolicy::P1 => 2,
            BloomPolicy::P2 => 3,
        }
    }

    pub fn from_tag(t: u8) -> Option<Self> {
        Some(match t {
            0 => BloomPolicy::Naive,
            1 => BloomPolicy::P0,
            2 => BloomPolicy::P1,
            3 => BloomPolicy::P2,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BloomPolicy::Naive => "bloom_naive",
            BloomPolicy::P0 => "bloom_p0",
            BloomPolicy::P1 => "bloom_p1",
            BloomPolicy::P2 => "bloom_p2",
        }
    }
}

/// The Bloom-filter index codec.
pub struct BloomIndex {
    policy: BloomPolicy,
    fpr: f64,
    seed: u64,
}

impl BloomIndex {
    pub fn new(policy: BloomPolicy, fpr: f64, seed: u64) -> Self {
        Self { policy, fpr, seed }
    }

    /// The deterministic support selection both sides replay.
    fn select(policy: BloomPolicy, filter: &BloomFilter, d: usize, r: usize, seed: u64) -> Vec<u32> {
        let positives = filter.scan_positives(d);
        match policy {
            // Naive/P0 both reconstruct the full positive set; the
            // difference is in how the *encoder* populates V (naive sends
            // only r values, which shifts assignments after the first FP —
            // modelled by the framework wiring below).
            BloomPolicy::Naive | BloomPolicy::P0 => positives,
            BloomPolicy::P1 => {
                let r = r.min(positives.len());
                let mut rng = Rng::new(seed ^ 0x50_11);
                let mut picked = rng.sample_indices(positives.len(), r);
                picked.sort_unstable();
                picked.into_iter().map(|j| positives[j as usize]).collect()
            }
            BloomPolicy::P2 => select_p2(filter, &positives, r, seed),
        }
    }
}

/// Algorithm 1: conflict-set-guided selection.
///
/// Items of P are re-hashed; each bit position of the filter hosting at
/// least one item forms a conflict set. Singleton sets are guaranteed
/// true positives; larger sets contribute random members. Sets are
/// visited in ascending size order until |S̃| = r.
fn select_p2(filter: &BloomFilter, positives: &[u32], r: usize, seed: u64) -> Vec<u32> {
    let r = r.min(positives.len());
    // §Perf: group (bit position, item) pairs by sorting instead of a
    // HashMap<u64, Vec<u32>> — one allocation, cache-friendly, ~3x faster
    // at the |P|·k sizes the codec sees.
    let k = filter.k();
    let mut pairs: Vec<(u64, u32)> = Vec::with_capacity(positives.len() * k);
    let mut pos_buf = Vec::with_capacity(k);
    for &x in positives {
        filter.positions(x as u64, &mut pos_buf);
        for &p in &pos_buf {
            pairs.push((p, x));
        }
    }
    pairs.sort_unstable();
    // conflict sets as ranges over `pairs`
    let mut sets: Vec<(usize, usize)> = Vec::new(); // (start, len)
    let mut i = 0;
    while i < pairs.len() {
        let mut j = i + 1;
        while j < pairs.len() && pairs[j].0 == pairs[i].0 {
            j += 1;
        }
        sets.push((i, j - i));
        i = j;
    }
    // ascending size, deterministic tiebreak on bit position (Alg 1 l.5)
    sets.sort_by_key(|&(start, len)| (len, pairs[start].0));

    let mut rng = Rng::new(seed ^ 0x50_22);
    let mut selected: Vec<u32> = Vec::with_capacity(r);
    let mut in_sel = std::collections::HashSet::with_capacity(r * 2);
    // mutable membership lists per set, lazily built
    let mut live: Vec<Vec<u32>> =
        sets.iter().map(|&(start, len)| pairs[start..start + len].iter().map(|&(_, x)| x).collect()).collect();
    'outer: while selected.len() < r {
        let before = selected.len();
        for items in live.iter_mut() {
            if selected.len() >= r {
                break 'outer;
            }
            if items.len() == 1 {
                let x = items[0];
                if in_sel.insert(x) {
                    selected.push(x);
                }
                items.clear();
            } else if !items.is_empty() {
                // drop already-selected duplicates, then pick one at random
                items.retain(|x| !in_sel.contains(x));
                if !items.is_empty() {
                    let j = rng.below(items.len() as u64) as usize;
                    let x = items.swap_remove(j);
                    in_sel.insert(x);
                    selected.push(x);
                }
            }
        }
        if selected.len() == before {
            break; // all sets exhausted
        }
    }
    selected.sort_unstable();
    selected
}

impl IndexCodec for BloomIndex {
    fn name(&self) -> &str {
        self.policy.name()
    }

    fn lossless(&self) -> bool {
        false
    }

    fn encode(&self, d: usize, support: &[u32]) -> IndexEncoding {
        let r = support.len();
        let mut filter = BloomFilter::with_fpr(self.fpr, r.max(1), self.seed);
        for &i in support {
            filter.insert(i as u64);
        }
        let effective = match self.policy {
            // Naive transmits V for the *input* support S (the encoder is
            // oblivious to false positives), while the decoder assigns
            // those values to the first r positives — reproducing the
            // paper's shift/mis-assignment error (§4, Fig 13).
            BloomPolicy::Naive => support.to_vec(),
            pol => BloomIndex::select(pol, &filter, d, r, self.seed),
        };
        let mut bytes = Vec::with_capacity(filter.payload_bytes() + 32);
        varint::write_u64(&mut bytes, filter.m());
        varint::write_u64(&mut bytes, filter.k() as u64);
        varint::write_u64(&mut bytes, r as u64);
        bytes.extend_from_slice(&self.seed.to_le_bytes());
        bytes.push(self.policy.tag());
        let payload = filter.payload_bytes();
        for w in filter.bit_words() {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        bytes.truncate(bytes.len() - (filter.bit_words().len() * 8 - payload));
        IndexEncoding { bytes, effective }
    }

    fn decode(&self, d: usize, bytes: &[u8]) -> anyhow::Result<Vec<u32>> {
        let mut pos = 0usize;
        let m = varint::read_u64(bytes, &mut pos)?;
        let k = varint::read_u64(bytes, &mut pos)? as usize;
        let r = varint::read_u64(bytes, &mut pos)? as usize;
        anyhow::ensure!(pos + 9 <= bytes.len(), "bloom header truncated");
        let seed = u64::from_le_bytes(bytes[pos..pos + 8].try_into().unwrap());
        pos += 8;
        let policy = BloomPolicy::from_tag(bytes[pos]).ok_or_else(|| anyhow::anyhow!("bad policy tag"))?;
        pos += 1;
        anyhow::ensure!(policy == self.policy, "policy mismatch");
        let payload = (m as usize).div_ceil(8);
        anyhow::ensure!(bytes.len() - pos == payload, "bloom payload size mismatch");
        let mut words = vec![0u64; (m as usize).div_ceil(64)];
        for (i, &b) in bytes[pos..].iter().enumerate() {
            words[i / 8] |= (b as u64) << ((i % 8) * 8);
        }
        let filter = BloomFilter::from_words(words, m, k, seed);
        let sel = match policy {
            BloomPolicy::Naive => {
                // decoder's (wrong) view: first r positives
                BloomIndex::select(BloomPolicy::P0, &filter, d, r, seed).into_iter().take(r).collect()
            }
            pol => BloomIndex::select(pol, &filter, d, r, seed),
        };
        Ok(sel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::IndexCodec;
    use crate::util::prng::Rng;
    use crate::util::testkit::{forall, sorted_support};

    #[test]
    fn filter_no_false_negatives() {
        forall(
            "bloom-no-fn",
            30,
            5000,
            |rng, size| {
                let d = 10 + rng.below(size as u64) as usize;
                let r = 1 + rng.below((d / 2) as u64) as usize;
                let fpr = [0.001, 0.01, 0.1][rng.below(3) as usize];
                (d, sorted_support(rng, d, r), fpr)
            },
            |(_, support, fpr)| {
                let mut f = BloomFilter::with_fpr(*fpr, support.len(), 7);
                for &i in support {
                    f.insert(i as u64);
                }
                for &i in support {
                    if !f.contains(i as u64) {
                        return Err(format!("false negative at {i}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fpr_matches_lemma2() {
        // Lemma 2: ε ≈ (1 − e^{−kr/m})^k; with optimal m,k this is the
        // target fpr. Measure on a large domain.
        let d = 200_000usize;
        let r = 2_000usize;
        for &target in &[0.01f64, 0.05] {
            let mut f = BloomFilter::with_fpr(target, r, 3);
            let mut rng = Rng::new(123);
            let support = sorted_support(&mut rng, d, r);
            let sset: std::collections::HashSet<u32> = support.iter().copied().collect();
            for &i in &support {
                f.insert(i as u64);
            }
            let mut fp = 0usize;
            let mut neg = 0usize;
            for i in 0..d as u64 {
                if !sset.contains(&(i as u32)) {
                    neg += 1;
                    if f.contains(i) {
                        fp += 1;
                    }
                }
            }
            let measured = fp as f64 / neg as f64;
            assert!(
                measured < target * 2.0 + 1e-4 && measured > target * 0.3,
                "target {target} measured {measured}"
            );
        }
    }

    #[test]
    fn p0_superset_and_lemma5_bound() {
        let mut rng = Rng::new(5);
        let d = 30_000;
        let r = 300;
        let support = sorted_support(&mut rng, d, r);
        for &fpr in &[0.001f64, 0.01, 0.1] {
            let codec = BloomIndex::new(BloomPolicy::P0, fpr, 9);
            let enc = codec.encode(d, &support);
            // P ⊇ S
            let pset: std::collections::HashSet<u32> = enc.effective.iter().copied().collect();
            assert!(support.iter().all(|i| pset.contains(i)), "fpr {fpr}: P must contain S");
            // Lemma 5: |P| <= ceil(r + (1/2)^{-log eps / log 2} (d - r))
            //        = ceil(r + eps*(d-r)) with optimal parameters
            let bound = (r as f64 + fpr * (d - r) as f64).ceil() + 4.0 * (fpr * d as f64).sqrt();
            assert!(
                (enc.effective.len() as f64) <= bound + 8.0,
                "fpr {fpr}: |P| = {} > bound {bound}",
                enc.effective.len()
            );
            // decode replays identically
            let dec = codec.decode(d, &enc.bytes).unwrap();
            assert_eq!(dec, enc.effective);
        }
    }

    #[test]
    fn p1_exact_size_and_subset() {
        let mut rng = Rng::new(6);
        let d = 20_000;
        let r = 500;
        let support = sorted_support(&mut rng, d, r);
        let codec = BloomIndex::new(BloomPolicy::P1, 0.05, 11);
        let enc = codec.encode(d, &support);
        assert_eq!(enc.effective.len(), r);
        let dec = codec.decode(d, &enc.bytes).unwrap();
        assert_eq!(dec, enc.effective);
        // S̃ ⊆ P: every selected index is a positive of the filter
        let p0 = BloomIndex::new(BloomPolicy::P0, 0.05, 11).encode(d, &support);
        let pset: std::collections::HashSet<u32> = p0.effective.iter().copied().collect();
        assert!(enc.effective.iter().all(|i| pset.contains(i)));
    }

    #[test]
    fn p2_recovers_more_true_positives_than_p1() {
        // the point of Algorithm 1: k1(P2) >= k1(P1) on average
        let d = 30_000;
        let r = 400;
        let fpr = 0.1; // high FPR so the effect is visible
        let mut rng = Rng::new(77);
        let mut wins = 0;
        let trials = 12;
        for t in 0..trials {
            let support = sorted_support(&mut rng, d, r);
            let sset: std::collections::HashSet<u32> = support.iter().copied().collect();
            let k1 = |sel: &[u32]| sel.iter().filter(|i| sset.contains(i)).count();
            let p1 = BloomIndex::new(BloomPolicy::P1, fpr, 1000 + t).encode(d, &support);
            let p2 = BloomIndex::new(BloomPolicy::P2, fpr, 1000 + t).encode(d, &support);
            assert_eq!(p2.effective.len(), r.min(p2.effective.len()));
            if k1(&p2.effective) >= k1(&p1.effective) {
                wins += 1;
            }
        }
        assert!(wins * 10 >= trials * 8, "P2 better in only {wins}/{trials} trials");
    }

    #[test]
    fn p2_singletons_are_true_positives() {
        // every singleton conflict set member must be in S
        let d = 5_000;
        let r = 100;
        let mut rng = Rng::new(8);
        let support = sorted_support(&mut rng, d, r);
        let codec = BloomIndex::new(BloomPolicy::P2, 0.01, 13);
        let enc = codec.encode(d, &support);
        // with low FPR, P2 should recover nearly all of S
        let sset: std::collections::HashSet<u32> = support.iter().copied().collect();
        let k1 = enc.effective.iter().filter(|i| sset.contains(i)).count();
        // At fpr=0.01 with optimal k, TPs collide with each other too, so
        // singletons are not universal; P2 still recovers far more than the
        // random-selection baseline r/|P|.
        assert!(k1 as f64 >= 0.80 * r as f64, "k1 = {k1} of {r}");
    }

    #[test]
    fn decoder_replay_matches_encoder_all_policies() {
        let mut rng = Rng::new(9);
        for policy in [BloomPolicy::P0, BloomPolicy::P1, BloomPolicy::P2] {
            for _ in 0..3 {
                let d = 1000 + rng.below(10_000) as usize;
                let r = 1 + rng.below(200) as usize;
                let support = sorted_support(&mut rng, d, r);
                let codec = BloomIndex::new(policy, 0.02, 21);
                let enc = codec.encode(d, &support);
                let dec = codec.decode(d, &enc.bytes).unwrap();
                assert_eq!(dec, enc.effective, "policy {policy:?}");
            }
        }
    }

    #[test]
    fn naive_decoder_shifts_after_false_positive() {
        // encoder view is S; decoder takes the first r positives — if any
        // false positive precedes the tail of S, the views diverge.
        let d = 50_000;
        let r = 800;
        let mut rng = Rng::new(14);
        let support = sorted_support(&mut rng, d, r);
        let codec = BloomIndex::new(BloomPolicy::Naive, 0.2, 3); // FPs likely
        let enc = codec.encode(d, &support);
        assert_eq!(enc.effective, support);
        let dec = codec.decode(d, &enc.bytes).unwrap();
        assert_eq!(dec.len(), r);
        assert_ne!(dec, support, "with fpr=0.2 a shift is (overwhelmingly) expected");
    }

    #[test]
    fn wire_size_tracks_fpr() {
        // smaller FPR -> bigger filter (Remark 2: m = -r ln eps / ln^2 2)
        let support: Vec<u32> = (0..1000u32).collect();
        let small = BloomIndex::new(BloomPolicy::P0, 0.1, 1).encode(100_000, &support);
        let big = BloomIndex::new(BloomPolicy::P0, 0.0001, 1).encode(100_000, &support);
        assert!(big.bytes.len() > small.bytes.len() * 3);
        // ~50% of key-value index size claim (paper abstract): at fpr 0.01,
        // m/r = -ln(0.01)/ln^2 2 ≈ 9.6 bits/key vs 32-bit keys -> ~70% saving
        let kv_bits = 32 * support.len();
        let p0_bits = 8 * BloomIndex::new(BloomPolicy::P0, 0.01, 1)
            .encode(100_000, &support)
            .bytes
            .len();
        assert!(p0_bits * 2 < kv_bits, "bloom {p0_bits} vs kv {kv_bits} bits");
    }
}
