//! Index-compression module (paper §3): encapsulates the two equivalent
//! support-set representations (integer array / bitmap) and the codecs
//! over them — raw keys, bitmap, bit-level RLE, Huffman over index byte
//! planes, delta+varint, and the Bloom-filter family (§4).
//!
//! Codecs are built by name through
//! [`index_by_name`](crate::compress::index_by_name) and implement
//! [`IndexCodec`](crate::compress::IndexCodec); lossless ones
//! roundtrip the support exactly:
//!
//! ```
//! use deepreduce::compress::index_by_name;
//!
//! let codec = index_by_name("delta_varint", f64::NAN, 0).unwrap();
//! let support = vec![3u32, 17, 18, 900];
//! let enc = codec.encode(1000, &support);
//! assert_eq!(enc.effective, support); // lossless: S̃ = S
//! assert_eq!(codec.decode(1000, &enc.bytes).unwrap(), support);
//! // clustered supports beat the 4 B/entry raw encoding
//! assert!(enc.bytes.len() < support.len() * 4);
//! ```
//!
//! The Bloom family is deliberately lossy in the support
//! (`lossless() == false`): decoding reconstructs a superset/subset S̃
//! chosen by the policy (P0/P1/P2), which is why the collective
//! segment codec refuses them (`collective::sparse::SegmentCodec`).

mod bloom;
mod plain;

pub use bloom::{BloomFilter, BloomIndex, BloomPolicy};
pub use plain::{BitmapIndex, DeltaVarint, EliasIndex, HuffmanIndex, RawIndex, RleIndex};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::IndexCodec;
    use crate::util::prng::Rng;
    use crate::util::testkit::{forall, sorted_support};

    fn all_lossless() -> Vec<Box<dyn IndexCodec>> {
        vec![
            Box::new(RawIndex),
            Box::new(BitmapIndex),
            Box::new(RleIndex),
            Box::new(HuffmanIndex),
            Box::new(DeltaVarint),
            Box::new(EliasIndex),
        ]
    }

    #[test]
    fn lossless_codecs_roundtrip_random_supports() {
        forall(
            "index-roundtrip",
            40,
            3000,
            |rng, size| {
                let d = 1 + rng.below(size as u64) as usize;
                let r = rng.below(d as u64 + 1) as usize;
                (d, sorted_support(rng, d, r))
            },
            |(d, support)| {
                for codec in all_lossless() {
                    let enc = codec.encode(*d, support);
                    if enc.effective != *support {
                        return Err(format!("{}: effective != input", codec.name()));
                    }
                    let dec = codec
                        .decode(*d, &enc.bytes)
                        .map_err(|e| format!("{}: {e}", codec.name()))?;
                    if dec != *support {
                        return Err(format!(
                            "{}: decode mismatch ({} vs {} items)",
                            codec.name(),
                            dec.len(),
                            support.len()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn edge_cases_empty_full_single() {
        for codec in all_lossless() {
            for (d, support) in [
                (1usize, vec![]),
                (1, vec![0u32]),
                (100, vec![]),
                (100, (0..100u32).collect::<Vec<_>>()),
                (64, vec![63]),
                (65, vec![0, 64]),
            ] {
                let enc = codec.encode(d, &support);
                let dec = codec.decode(d, &enc.bytes).unwrap();
                assert_eq!(dec, support, "codec {} d={d}", codec.name());
            }
        }
    }

    #[test]
    fn clustered_indices_compress_well_with_rle() {
        // contiguous support: RLE should beat raw 4-byte keys massively
        let d = 100_000;
        let support: Vec<u32> = (40_000..41_000u32).collect();
        let rle = RleIndex.encode(d, &support);
        let raw = RawIndex.encode(d, &support);
        assert!(rle.bytes.len() * 20 < raw.bytes.len(), "rle {} raw {}", rle.bytes.len(), raw.bytes.len());
    }

    #[test]
    fn uniform_random_sizes_sane() {
        let mut rng = Rng::new(90);
        let d = 36864; // the paper's Fig 10 conv gradient
        let r = 369; // top 1%
        let support = sorted_support(&mut rng, d, r);
        let bitmap = BitmapIndex.encode(d, &support).bytes.len();
        assert_eq!(bitmap, d.div_ceil(8) + crate::util::varint::encoded_len(d as u64));
        let raw = RawIndex.encode(d, &support).bytes.len();
        assert_eq!(raw, r * 4);
        let delta = DeltaVarint.encode(d, &support).bytes.len();
        assert!(delta < raw, "delta {delta} raw {raw}");
        let huff = HuffmanIndex.encode(d, &support).bytes.len();
        assert!(huff < raw, "huffman {huff} raw {raw}");
    }
}
