//! The DeepReduce framework (paper §3): a sparse tensor is decomposed
//! into an index set and a value array, each compressed independently by
//! pluggable codecs, then packed into a self-describing wire container.
//!
//! ```text
//!  SparseTensor ──► IndexCodec ──► index bytes ─┐
//!        │             │ effective support S̃    ├─► Container ─► transport
//!        └─► gather ─► ValueCodec ─► value bytes┘
//!                        │ optional reorder (sorted fits)
//! ```
//!
//! Index codecs may be lossy in the *support* (Bloom policies P1/P2
//! reconstruct S̃ ≠ S); value codecs may be lossy in the *values*
//! (QSGD, curve fits). The framework wires the two together, including
//! the paper's §5.1 reorder mapping for order-destroying value codecs.
//!
//! Codecs are named and constructed through the typed
//! [`CodecRegistry`]: each registers under a name with a declared
//! `key=value` parameter schema, and specs like `rle+deflate` or
//! `bloom_p2(fpr=0.01)+zstd` compose a head codec with lossless byte
//! stages ([`chain`]) behind the same trait objects. The preferred
//! construction route is the fluent [`DeepReduce::builder`]:
//!
//! ```
//! use deepreduce::compress::DeepReduce;
//!
//! let dr = DeepReduce::builder()
//!     .index("rle+deflate")
//!     .value("qsgd(bits=6)")
//!     .seed(7)
//!     .build()
//!     .unwrap();
//! // names are full spec labels — what the container header carries
//! assert_eq!(dr.name(), "DR[rle+deflate|qsgd(bits=6)]");
//! ```

pub mod chain;
pub mod container;
pub mod index;
pub mod registry;
pub mod spec;
pub mod value;

use crate::tensor::SparseTensor;
pub use chain::ByteStage;
pub use container::{Container, ContainerError};
pub use registry::{CodecEntry, CodecRegistry, CodecRow, CodecSet, ParamKind, ParamSpec, ParamValue, ResolvedParams};
pub use spec::{CodecSpec, CompressSpec, StageSpec};

/// Result of index encoding.
pub struct IndexEncoding {
    pub bytes: Vec<u8>,
    /// The support the decoder will reconstruct (ascending). For lossless
    /// codecs this equals the input support; Bloom policies return P (P0)
    /// or S̃ (P1/P2), and the framework gathers values for it.
    pub effective: Vec<u32>,
}

/// Compresses the support set S of a sparse gradient over domain [0, d).
///
/// Implement **at least one** of [`encode`](IndexCodec::encode) /
/// [`encode_into`](IndexCodec::encode_into) — each default is written
/// in terms of the other, so implementing neither compiles but loops
/// forever on first use. Hot-path codecs implement `encode_into`,
/// which appends to a caller-owned buffer and skips the
/// effective-support clone on the lossless path.
pub trait IndexCodec: Send + Sync {
    fn name(&self) -> &str;

    /// Whether the reconstructed support always equals the input support.
    fn lossless(&self) -> bool {
        true
    }

    fn encode(&self, d: usize, support: &[u32]) -> IndexEncoding {
        let mut bytes = Vec::new();
        let effective =
            self.encode_into(d, support, &mut bytes).unwrap_or_else(|| support.to_vec());
        IndexEncoding { bytes, effective }
    }

    /// Append the encoding of `support` to `out` (no clear, no
    /// truncate: callers may hold a prefix). Returns `None` when the
    /// decoder reconstructs exactly `support` — the lossless fast path,
    /// which allocates nothing beyond the bytes — or `Some(effective)`
    /// otherwise.
    fn encode_into(&self, d: usize, support: &[u32], out: &mut Vec<u8>) -> Option<Vec<u32>> {
        let enc = self.encode(d, support);
        out.extend_from_slice(&enc.bytes);
        if enc.effective == support {
            None
        } else {
            Some(enc.effective)
        }
    }

    /// Reconstruct the (effective) support, ascending.
    fn decode(&self, d: usize, bytes: &[u8]) -> anyhow::Result<Vec<u32>>;
}

/// Result of value encoding.
pub struct ValueEncoding {
    pub bytes: Vec<u8>,
    /// If the codec reordered values (e.g. sorted them), `perm[j]` is the
    /// original position of the j-th decoded value; the framework
    /// transmits it bit-packed at ⌈log₂ n⌉ bits/entry (paper §5.1).
    pub perm: Option<Vec<u32>>,
}

/// Compresses the value array V.
///
/// Implement **at least one** of [`encode`](ValueCodec::encode) /
/// [`encode_into`](ValueCodec::encode_into) — each default is written
/// in terms of the other, so implementing neither compiles but loops
/// forever on first use.
pub trait ValueCodec: Send + Sync {
    fn name(&self) -> &str;

    /// Whether decoded values are bit-exact.
    fn lossless(&self) -> bool {
        false
    }

    fn encode(&self, values: &[f32]) -> ValueEncoding {
        let mut bytes = Vec::new();
        let perm = self.encode_into(values, &mut bytes);
        ValueEncoding { bytes, perm }
    }

    /// Append the encoding of `values` to `out`; returns the reorder
    /// permutation, if the codec produced one.
    fn encode_into(&self, values: &[f32], out: &mut Vec<u8>) -> Option<Vec<u32>> {
        let enc = self.encode(values);
        out.extend_from_slice(&enc.bytes);
        enc.perm
    }

    /// Decode exactly `n` values in wire order (before un-permutation).
    fn decode(&self, bytes: &[u8], n: usize) -> anyhow::Result<Vec<f32>>;
}

/// A DeepReduce instantiation `DR_idx^val`.
pub struct DeepReduce {
    pub index: Box<dyn IndexCodec>,
    pub value: Box<dyn ValueCodec>,
}

/// Fluent constructor for [`DeepReduce`]: codec spec strings (chains
/// and `key=value` parameters included) resolved through the registry
/// at [`build`](DeepReduceBuilder::build) time.
pub struct DeepReduceBuilder {
    index: String,
    value: String,
    seed: u64,
}

impl DeepReduceBuilder {
    /// Index codec spec, e.g. `"rle"`, `"rle+deflate"`,
    /// `"bloom_p2(fpr=0.01)"`.
    pub fn index(mut self, spec: impl Into<String>) -> Self {
        self.index = spec.into();
        self
    }

    /// Value codec spec, e.g. `"raw"`, `"qsgd(bits=6)"`, `"fitpoly"`.
    pub fn value(mut self, spec: impl Into<String>) -> Self {
        self.value = spec.into();
        self
    }

    /// Seed for stochastic codecs (Bloom hashing, QSGD dithering).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Resolve both specs against the built-in registry.
    pub fn build(self) -> anyhow::Result<DeepReduce> {
        self.build_with(CodecRegistry::global())
    }

    /// Resolve both specs against a caller-extended registry.
    pub fn build_with(self, registry: &CodecRegistry) -> anyhow::Result<DeepReduce> {
        Ok(DeepReduce::new(
            registry.build_index(&CodecSpec::parse(&self.index)?, self.seed)?,
            registry.build_value(&CodecSpec::parse(&self.value)?, self.seed)?,
        ))
    }
}

/// Volume breakdown of one encoded tensor, for the Fig 10a accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct VolumeBreakdown {
    pub index_bytes: usize,
    pub value_bytes: usize,
    pub reorder_bytes: usize,
    pub header_bytes: usize,
}

impl VolumeBreakdown {
    pub fn total(&self) -> usize {
        self.index_bytes + self.value_bytes + self.reorder_bytes + self.header_bytes
    }
}

impl DeepReduce {
    pub fn new(index: Box<dyn IndexCodec>, value: Box<dyn ValueCodec>) -> Self {
        Self { index, value }
    }

    /// Start a fluent build from codec spec strings (default `raw|raw`).
    pub fn builder() -> DeepReduceBuilder {
        DeepReduceBuilder { index: "raw".into(), value: "raw".into(), seed: 0 }
    }

    /// Rebuild the codec pair a container was encoded with from its
    /// self-describing header: the stored specs (full chain labels
    /// included) are parsed and resolved through the built-in registry.
    pub fn for_container(c: &Container, seed: u64) -> anyhow::Result<Self> {
        let registry = CodecRegistry::global();
        Ok(Self::new(
            registry.build_index(&CodecSpec::parse(&c.index_codec)?, seed)?,
            registry.build_value(&CodecSpec::parse(&c.value_codec)?, seed)?,
        ))
    }

    pub fn name(&self) -> String {
        format!("DR[{}|{}]", self.index.name(), self.value.name())
    }

    /// Encode a sparse gradient. `dense` is the original gradient the
    /// sparse tensor was drawn from (GRACE exposes it; Bloom policies
    /// P0/P1/P2 read original values at false-positive positions). When
    /// `None`, positions outside the input support decode as 0.
    pub fn encode(&self, sparse: &SparseTensor, dense: Option<&[f32]>) -> Container {
        let d = sparse.dense_len();
        let mut idx_bytes = Vec::new();
        let effective = self.index.encode_into(d, sparse.indices(), &mut idx_bytes);

        // Gather the value array for the effective support (None = the
        // codec reconstructs the input support exactly: zero-copy path).
        let values: Vec<f32> = match &effective {
            None => sparse.values().to_vec(),
            Some(effective) => match dense {
                Some(g) => effective.iter().map(|&i| g[i as usize]).collect(),
                None => {
                    // merge-join sparse values onto the effective support
                    let mut out = vec![0.0f32; effective.len()];
                    let (mut a, mut b) = (0usize, 0usize);
                    let (si, sv) = (sparse.indices(), sparse.values());
                    while a < effective.len() && b < si.len() {
                        use std::cmp::Ordering::*;
                        match effective[a].cmp(&si[b]) {
                            Less => a += 1,
                            Greater => b += 1,
                            Equal => {
                                out[a] = sv[b];
                                a += 1;
                                b += 1;
                            }
                        }
                    }
                    out
                }
            },
        };

        let num_values = values.len();
        let mut val_bytes = Vec::new();
        let perm = self.value.encode_into(&values, &mut val_bytes);
        Container::pack_owned(
            d,
            num_values,
            self.index.name(),
            self.value.name(),
            idx_bytes,
            val_bytes,
            perm,
        )
    }

    /// Decode a container back to a sparse gradient.
    pub fn decode(&self, c: &Container) -> anyhow::Result<SparseTensor> {
        anyhow::ensure!(
            c.index_codec == self.index.name() && c.value_codec == self.value.name(),
            "container codec mismatch: {}/{} vs {}/{}",
            c.index_codec,
            c.value_codec,
            self.index.name(),
            self.value.name()
        );
        let support = self.index.decode(c.dense_len, &c.index_bytes)?;
        anyhow::ensure!(
            support.len() == c.num_values,
            "support length {} != value count {}",
            support.len(),
            c.num_values
        );
        let wire_values = self.value.decode(&c.value_bytes, c.num_values)?;
        let values = match &c.perm {
            Some(perm) => {
                anyhow::ensure!(perm.len() == wire_values.len(), "perm length mismatch");
                let mut out = vec![0.0f32; wire_values.len()];
                for (j, &p) in perm.iter().enumerate() {
                    anyhow::ensure!((p as usize) < out.len(), "perm out of range");
                    out[p as usize] = wire_values[j];
                }
                out
            }
            None => wire_values,
        };
        Ok(SparseTensor::new(c.dense_len, support, values))
    }

    /// Convenience: encode then report the wire volume split.
    pub fn volume(&self, sparse: &SparseTensor, dense: Option<&[f32]>) -> VolumeBreakdown {
        self.encode(sparse, dense).breakdown()
    }
}

/// Build an index codec from a full spec string (chains and parameters
/// included), applying the legacy single-`f64` parameter to the head
/// stage's declared legacy key (Bloom FPR). The typed route is
/// [`CodecRegistry::build_index`]; this shim exists for the old flag
/// surface.
pub fn build_index_spec(
    spec: &str,
    legacy_param: f64,
    seed: u64,
) -> anyhow::Result<Box<dyn IndexCodec>> {
    let registry = CodecRegistry::global();
    let mut cs = CodecSpec::parse(spec)?;
    registry.apply_legacy_param(CodecSet::Index, &mut cs, legacy_param);
    registry.build_index(&cs, seed)
}

/// Build a value codec from a full spec string; the legacy `f64` maps
/// onto qsgd bits / fitpoly degree / sketch quantiles, as the old
/// factories did. The typed route is [`CodecRegistry::build_value`].
pub fn build_value_spec(
    spec: &str,
    legacy_param: f64,
    seed: u64,
) -> anyhow::Result<Box<dyn ValueCodec>> {
    let registry = CodecRegistry::global();
    let mut cs = CodecSpec::parse(spec)?;
    registry.apply_legacy_param(CodecSet::Value, &mut cs, legacy_param);
    registry.build_value(&cs, seed)
}

/// Legacy factory, kept as a thin shim over the registry: every
/// pre-registry spelling (`raw`, `keys`, `delta`, `bloom_p2`, ...)
/// still parses, and chain specs now work here too. `param` is the old
/// overloaded codec parameter (FPR for bloom variants; defaults when
/// NaN or non-positive).
pub fn index_by_name(name: &str, param: f64, seed: u64) -> Option<Box<dyn IndexCodec>> {
    build_index_spec(name, param, seed).ok()
}

/// Legacy factory, kept as a thin shim over the registry. `param` is
/// the old overloaded codec parameter (quantization bits for qsgd,
/// polynomial degree for fitpoly, quantile count for sketch).
pub fn value_by_name(name: &str, param: f64, seed: u64) -> Option<Box<dyn ValueCodec>> {
    build_value_spec(name, param, seed).ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::testkit::gradient_like;

    /// Lossless-index + raw-value pipelines must roundtrip exactly.
    #[test]
    fn lossless_pipeline_roundtrips_exactly() {
        let mut rng = Rng::new(80);
        for idx_name in ["raw", "bitmap", "rle", "huffman", "delta_varint", "elias"] {
            for _ in 0..5 {
                let d = 200 + rng.below(2000) as usize;
                let g = gradient_like(&mut rng, d);
                let mut topk = crate::sparsify::TopK::new(0.05);
                use crate::sparsify::Sparsifier;
                let sp = topk.sparsify(&g);
                let dr = DeepReduce::new(
                    index_by_name(idx_name, f64::NAN, 1).unwrap(),
                    value_by_name("raw", f64::NAN, 1).unwrap(),
                );
                let c = dr.encode(&sp, Some(&g));
                let back = dr.decode(&c).unwrap();
                assert_eq!(back, sp, "codec {idx_name}");
            }
        }
    }

    #[test]
    fn factory_rejects_unknown() {
        assert!(index_by_name("nope", 0.0, 0).is_none());
        assert!(value_by_name("nope", 0.0, 0).is_none());
        // and malformed chain syntax
        assert!(index_by_name("rle+", 0.0, 0).is_none());
    }

    #[test]
    fn legacy_factories_accept_chain_specs() {
        let mut rng = Rng::new(81);
        let g = gradient_like(&mut rng, 3000);
        let mut topk = crate::sparsify::TopK::new(0.05);
        use crate::sparsify::Sparsifier;
        let sp = topk.sparsify(&g);
        let dr = DeepReduce::new(
            index_by_name("rle+deflate", f64::NAN, 1).unwrap(),
            value_by_name("raw+zstd", f64::NAN, 1).unwrap(),
        );
        assert_eq!(dr.name(), "DR[rle+deflate|raw+zstd]");
        let c = dr.encode(&sp, Some(&g));
        assert_eq!(c.index_codec, "rle+deflate");
        let back = dr.decode(&c).unwrap();
        assert_eq!(back, sp);
    }

    #[test]
    fn builder_builds_and_container_is_self_describing() {
        let mut rng = Rng::new(82);
        let g = gradient_like(&mut rng, 4000);
        let mut topk = crate::sparsify::TopK::new(0.02);
        use crate::sparsify::Sparsifier;
        let sp = topk.sparsify(&g);
        let dr = DeepReduce::builder()
            .index("elias+deflate")
            .value("raw")
            .seed(9)
            .build()
            .unwrap();
        let c = dr.encode(&sp, Some(&g));
        // rebuild the decoder purely from the wire header
        let bytes = c.to_bytes();
        let parsed = Container::from_bytes(&bytes).unwrap();
        let from_header = DeepReduce::for_container(&parsed, 9).unwrap();
        assert_eq!(from_header.decode(&parsed).unwrap(), sp);
    }

    #[test]
    fn parameterized_single_stages_stay_self_describing() {
        // a single-stage codec with explicit params must put the FULL
        // spec label on the wire (not the bare name), so a decoder
        // rebuilt from the header gets identical parameters — qsgd
        // hard-errors on a bits/bucket mismatch, which pins this
        let mut rng = Rng::new(83);
        let g = gradient_like(&mut rng, 3000);
        let mut topk = crate::sparsify::TopK::new(0.05);
        use crate::sparsify::Sparsifier;
        let sp = topk.sparsify(&g);
        let dr = DeepReduce::builder()
            .index("bloom_p2(fpr=0.01)")
            .value("qsgd(bits=6)")
            .seed(9)
            .build()
            .unwrap();
        assert_eq!(dr.name(), "DR[bloom_p2(fpr=0.01)|qsgd(bits=6)]");
        let c = dr.encode(&sp, Some(&g));
        assert_eq!(c.index_codec, "bloom_p2(fpr=0.01)");
        assert_eq!(c.value_codec, "qsgd(bits=6)");
        let parsed = Container::from_bytes(&c.to_bytes()).unwrap();
        let from_header = DeepReduce::for_container(&parsed, 9).unwrap();
        // both decoders agree (bloom replays the policy from the seed
        // on its own wire; qsgd params match, so decode succeeds)
        assert_eq!(
            from_header.decode(&parsed).unwrap(),
            dr.decode(&parsed).unwrap()
        );
    }

    #[test]
    fn builder_surfaces_registry_errors() {
        assert!(DeepReduce::builder().index("nope").build().is_err());
        assert!(DeepReduce::builder().value("qsgd(bits=99)").build().is_err());
        assert!(DeepReduce::builder().index("rle(fpr=1)").build().is_err());
    }

    #[test]
    fn encode_into_appends_without_clearing() {
        let codec = index_by_name("raw", f64::NAN, 0).unwrap();
        let mut buf = vec![0xAAu8; 3];
        let eff = codec.encode_into(100, &[1, 2, 3], &mut buf);
        assert!(eff.is_none(), "lossless codec must skip the effective clone");
        assert_eq!(&buf[..3], &[0xAA; 3]);
        assert_eq!(buf.len(), 3 + 12);
        // and the default-encode route agrees with the bytes
        let enc = codec.encode(100, &[1, 2, 3]);
        assert_eq!(enc.bytes, &buf[3..]);
        assert_eq!(enc.effective, vec![1, 2, 3]);
    }
}
