//! The DeepReduce framework (paper §3): a sparse tensor is decomposed
//! into an index set and a value array, each compressed independently by
//! pluggable codecs, then packed into a self-describing wire container.
//!
//! ```text
//!  SparseTensor ──► IndexCodec ──► index bytes ─┐
//!        │             │ effective support S̃    ├─► Container ─► transport
//!        └─► gather ─► ValueCodec ─► value bytes┘
//!                        │ optional reorder (sorted fits)
//! ```
//!
//! Index codecs may be lossy in the *support* (Bloom policies P1/P2
//! reconstruct S̃ ≠ S); value codecs may be lossy in the *values*
//! (QSGD, curve fits). The framework wires the two together, including
//! the paper's §5.1 reorder mapping for order-destroying value codecs.

pub mod container;
pub mod index;
pub mod value;

use crate::tensor::SparseTensor;
pub use container::Container;

/// Result of index encoding.
pub struct IndexEncoding {
    pub bytes: Vec<u8>,
    /// The support the decoder will reconstruct (ascending). For lossless
    /// codecs this equals the input support; Bloom policies return P (P0)
    /// or S̃ (P1/P2), and the framework gathers values for it.
    pub effective: Vec<u32>,
}

/// Compresses the support set S of a sparse gradient over domain [0, d).
pub trait IndexCodec: Send + Sync {
    fn name(&self) -> &'static str;

    /// Whether the reconstructed support always equals the input support.
    fn lossless(&self) -> bool {
        true
    }

    fn encode(&self, d: usize, support: &[u32]) -> IndexEncoding;

    /// Reconstruct the (effective) support, ascending.
    fn decode(&self, d: usize, bytes: &[u8]) -> anyhow::Result<Vec<u32>>;
}

/// Result of value encoding.
pub struct ValueEncoding {
    pub bytes: Vec<u8>,
    /// If the codec reordered values (e.g. sorted them), `perm[j]` is the
    /// original position of the j-th decoded value; the framework
    /// transmits it bit-packed at ⌈log₂ n⌉ bits/entry (paper §5.1).
    pub perm: Option<Vec<u32>>,
}

/// Compresses the value array V.
pub trait ValueCodec: Send + Sync {
    fn name(&self) -> &'static str;

    /// Whether decoded values are bit-exact.
    fn lossless(&self) -> bool {
        false
    }

    fn encode(&self, values: &[f32]) -> ValueEncoding;

    /// Decode exactly `n` values in wire order (before un-permutation).
    fn decode(&self, bytes: &[u8], n: usize) -> anyhow::Result<Vec<f32>>;
}

/// A DeepReduce instantiation `DR_idx^val`.
pub struct DeepReduce {
    pub index: Box<dyn IndexCodec>,
    pub value: Box<dyn ValueCodec>,
}

/// Volume breakdown of one encoded tensor, for the Fig 10a accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct VolumeBreakdown {
    pub index_bytes: usize,
    pub value_bytes: usize,
    pub reorder_bytes: usize,
    pub header_bytes: usize,
}

impl VolumeBreakdown {
    pub fn total(&self) -> usize {
        self.index_bytes + self.value_bytes + self.reorder_bytes + self.header_bytes
    }
}

impl DeepReduce {
    pub fn new(index: Box<dyn IndexCodec>, value: Box<dyn ValueCodec>) -> Self {
        Self { index, value }
    }

    pub fn name(&self) -> String {
        format!("DR[{}|{}]", self.index.name(), self.value.name())
    }

    /// Encode a sparse gradient. `dense` is the original gradient the
    /// sparse tensor was drawn from (GRACE exposes it; Bloom policies
    /// P0/P1/P2 read original values at false-positive positions). When
    /// `None`, positions outside the input support decode as 0.
    pub fn encode(&self, sparse: &SparseTensor, dense: Option<&[f32]>) -> Container {
        let d = sparse.dense_len();
        let idx_enc = self.index.encode(d, sparse.indices());

        // Gather the value array for the effective support.
        let values: Vec<f32> = if idx_enc.effective == sparse.indices() {
            sparse.values().to_vec()
        } else {
            match dense {
                Some(g) => idx_enc.effective.iter().map(|&i| g[i as usize]).collect(),
                None => {
                    // merge-join sparse values onto the effective support
                    let mut out = vec![0.0f32; idx_enc.effective.len()];
                    let (mut a, mut b) = (0usize, 0usize);
                    let (si, sv) = (sparse.indices(), sparse.values());
                    while a < idx_enc.effective.len() && b < si.len() {
                        use std::cmp::Ordering::*;
                        match idx_enc.effective[a].cmp(&si[b]) {
                            Less => a += 1,
                            Greater => b += 1,
                            Equal => {
                                out[a] = sv[b];
                                a += 1;
                                b += 1;
                            }
                        }
                    }
                    out
                }
            }
        };

        let val_enc = self.value.encode(&values);
        Container::pack(
            d,
            values.len(),
            self.index.name(),
            self.value.name(),
            &idx_enc.bytes,
            &val_enc.bytes,
            val_enc.perm.as_deref(),
        )
    }

    /// Decode a container back to a sparse gradient.
    pub fn decode(&self, c: &Container) -> anyhow::Result<SparseTensor> {
        anyhow::ensure!(
            c.index_codec == self.index.name() && c.value_codec == self.value.name(),
            "container codec mismatch: {}/{} vs {}/{}",
            c.index_codec,
            c.value_codec,
            self.index.name(),
            self.value.name()
        );
        let support = self.index.decode(c.dense_len, &c.index_bytes)?;
        anyhow::ensure!(
            support.len() == c.num_values,
            "support length {} != value count {}",
            support.len(),
            c.num_values
        );
        let wire_values = self.value.decode(&c.value_bytes, c.num_values)?;
        let values = match &c.perm {
            Some(perm) => {
                anyhow::ensure!(perm.len() == wire_values.len(), "perm length mismatch");
                let mut out = vec![0.0f32; wire_values.len()];
                for (j, &p) in perm.iter().enumerate() {
                    anyhow::ensure!((p as usize) < out.len(), "perm out of range");
                    out[p as usize] = wire_values[j];
                }
                out
            }
            None => wire_values,
        };
        Ok(SparseTensor::new(c.dense_len, support, values))
    }

    /// Convenience: encode then report the wire volume split.
    pub fn volume(&self, sparse: &SparseTensor, dense: Option<&[f32]>) -> VolumeBreakdown {
        self.encode(sparse, dense).breakdown()
    }
}

/// Build an index codec by name. `param` is codec-specific:
/// FPR for bloom variants (default 0.001 if NaN).
pub fn index_by_name(name: &str, param: f64, seed: u64) -> Option<Box<dyn IndexCodec>> {
    let fpr = if param.is_nan() || param <= 0.0 { 0.001 } else { param };
    match name {
        "raw" | "keys" => Some(Box::new(index::RawIndex)),
        "bitmap" => Some(Box::new(index::BitmapIndex)),
        "rle" => Some(Box::new(index::RleIndex)),
        "huffman" => Some(Box::new(index::HuffmanIndex)),
        "delta_varint" | "delta" => Some(Box::new(index::DeltaVarint)),
        "elias" | "elias_gamma" => Some(Box::new(index::EliasIndex)),
        "bloom_naive" => Some(Box::new(index::BloomIndex::new(index::BloomPolicy::Naive, fpr, seed))),
        "bloom_p0" => Some(Box::new(index::BloomIndex::new(index::BloomPolicy::P0, fpr, seed))),
        "bloom_p1" => Some(Box::new(index::BloomIndex::new(index::BloomPolicy::P1, fpr, seed))),
        "bloom_p2" => Some(Box::new(index::BloomIndex::new(index::BloomPolicy::P2, fpr, seed))),
        // SKCompress index stage (baselines module, same trait)
        "delta_huffman" => Some(Box::new(crate::baselines::DeltaHuffmanIndex)),
        _ => None,
    }
}

/// Build a value codec by name. `param` is codec-specific: quantization
/// bits for qsgd, polynomial degree for fitpoly.
pub fn value_by_name(name: &str, param: f64, seed: u64) -> Option<Box<dyn ValueCodec>> {
    match name {
        "raw" | "none" | "fp32" => Some(Box::new(value::RawValue)),
        "fp16" => Some(Box::new(value::Fp16Value)),
        "deflate" => Some(Box::new(value::DeflateValue::default())),
        "zstd" => Some(Box::new(value::ZstdValue::default())),
        "qsgd" => {
            let bits = if param.is_nan() || param <= 0.0 { 7 } else { param as u32 };
            Some(Box::new(value::QsgdValue::new(bits, 512, seed)))
        }
        "fitpoly" => {
            let deg = if param.is_nan() || param <= 0.0 { 5 } else { param as usize };
            Some(Box::new(value::FitPolyValue::new(deg)))
        }
        "fitdexp" => Some(Box::new(value::FitDExpValue::default())),
        // SketchML / SKCompress value stages (baselines module)
        "sketch" => {
            let q = if param.is_nan() || param <= 0.0 { 64 } else { param as usize };
            Some(Box::new(crate::baselines::QuantileBucketValue::new(q, false)))
        }
        "sketch_huff" => {
            let q = if param.is_nan() || param <= 0.0 { 64 } else { param as usize };
            Some(Box::new(crate::baselines::QuantileBucketValue::new(q, true)))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::testkit::gradient_like;

    /// Lossless-index + raw-value pipelines must roundtrip exactly.
    #[test]
    fn lossless_pipeline_roundtrips_exactly() {
        let mut rng = Rng::new(80);
        for idx_name in ["raw", "bitmap", "rle", "huffman", "delta_varint", "elias"] {
            for _ in 0..5 {
                let d = 200 + rng.below(2000) as usize;
                let g = gradient_like(&mut rng, d);
                let mut topk = crate::sparsify::TopK::new(0.05);
                use crate::sparsify::Sparsifier;
                let sp = topk.sparsify(&g);
                let dr = DeepReduce::new(
                    index_by_name(idx_name, f64::NAN, 1).unwrap(),
                    value_by_name("raw", f64::NAN, 1).unwrap(),
                );
                let c = dr.encode(&sp, Some(&g));
                let back = dr.decode(&c).unwrap();
                assert_eq!(back, sp, "codec {idx_name}");
            }
        }
    }

    #[test]
    fn factory_rejects_unknown() {
        assert!(index_by_name("nope", 0.0, 0).is_none());
        assert!(value_by_name("nope", 0.0, 0).is_none());
    }
}
