//! Composable codec chains (paper §3: index and value structures may be
//! compressed "independently or in combination" — e.g. RLE *then*
//! Deflate on the index bytes).
//!
//! A chain is a leading [`IndexCodec`]/[`ValueCodec`] followed by one
//! or more [`ByteStage`]s — lossless byte-to-byte transforms applied to
//! the head's output stream in order (and unwound in reverse on
//! decode). Only the head may be lossy; byte stages are lossless by
//! construction, which is what lets chains compose with the collective
//! segment codec exactly like single lossless codecs.
//!
//! Chains are built by the [`CodecRegistry`](super::CodecRegistry) from
//! specs like `rle+deflate`; their [`name`](IndexCodec::name) is the
//! full canonical chain label, which is what the container header
//! carries so the wire stays self-describing.

use super::{IndexCodec, ValueCodec};
use crate::util::varint;

/// A lossless byte-to-byte transform usable as stage 2+ of a chain.
pub trait ByteStage: Send + Sync {
    fn name(&self) -> &str;

    fn encode(&self, raw: &[u8]) -> Vec<u8>;

    fn decode(&self, enc: &[u8]) -> anyhow::Result<Vec<u8>>;
}

/// Deflate (LZSS in the offline shim) over the stage input bytes.
pub struct DeflateStage {
    pub level: u32,
}

impl ByteStage for DeflateStage {
    fn name(&self) -> &str {
        "deflate"
    }

    fn encode(&self, raw: &[u8]) -> Vec<u8> {
        use flate2::write::DeflateEncoder;
        use std::io::Write;
        let mut enc = DeflateEncoder::new(Vec::new(), flate2::Compression::new(self.level));
        enc.write_all(raw).expect("in-memory deflate cannot fail");
        enc.finish().expect("deflate finish")
    }

    fn decode(&self, enc: &[u8]) -> anyhow::Result<Vec<u8>> {
        use flate2::read::DeflateDecoder;
        use std::io::Read;
        let mut out = Vec::new();
        DeflateDecoder::new(enc).read_to_end(&mut out)?;
        Ok(out)
    }
}

/// Zstd over the stage input bytes. The stream is framed with the raw
/// length (LEB128) so the decoder can bound its output buffer.
pub struct ZstdStage {
    pub level: i32,
}

impl ByteStage for ZstdStage {
    fn name(&self) -> &str {
        "zstd"
    }

    fn encode(&self, raw: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(raw.len() / 2 + 8);
        varint::write_u64(&mut out, raw.len() as u64);
        out.extend_from_slice(&zstd::bulk::compress(raw, self.level).expect("in-memory zstd"));
        out
    }

    fn decode(&self, enc: &[u8]) -> anyhow::Result<Vec<u8>> {
        let mut pos = 0usize;
        let n = varint::read_u64(enc, &mut pos)? as usize;
        let out = zstd::bulk::decompress(&enc[pos..], n)?;
        anyhow::ensure!(out.len() == n, "zstd stage length mismatch: {} vs {n}", out.len());
        Ok(out)
    }
}

/// An index codec chain: head codec + byte stages. Lossless iff the
/// head is (byte stages always roundtrip exactly). A chain with zero
/// byte stages is a pure label override: the registry uses it so a
/// parameterized single stage (`bloom_p2(fpr=0.01)`) reports its full
/// spec — what the container header carries — instead of the bare name.
pub struct IndexChain {
    head: Box<dyn IndexCodec>,
    stages: Vec<Box<dyn ByteStage>>,
    label: String,
}

impl IndexChain {
    pub fn new(head: Box<dyn IndexCodec>, stages: Vec<Box<dyn ByteStage>>, label: String) -> Self {
        Self { head, stages, label }
    }
}

impl IndexCodec for IndexChain {
    fn name(&self) -> &str {
        &self.label
    }

    fn lossless(&self) -> bool {
        self.head.lossless()
    }

    fn encode_into(&self, d: usize, support: &[u32], out: &mut Vec<u8>) -> Option<Vec<u32>> {
        if self.stages.is_empty() {
            // label-only shell: no staging buffer
            return self.head.encode_into(d, support, out);
        }
        let mut buf = Vec::new();
        let effective = self.head.encode_into(d, support, &mut buf);
        for stage in &self.stages {
            buf = stage.encode(&buf);
        }
        out.extend_from_slice(&buf);
        effective
    }

    fn decode(&self, d: usize, bytes: &[u8]) -> anyhow::Result<Vec<u32>> {
        if self.stages.is_empty() {
            return self.head.decode(d, bytes);
        }
        // the outermost stage decodes straight from the input slice
        let mut stages = self.stages.iter().rev();
        let mut buf = stages.next().expect("stages checked non-empty").decode(bytes)?;
        for stage in stages {
            buf = stage.decode(&buf)?;
        }
        self.head.decode(d, &buf)
    }
}

/// A value codec chain: head codec + byte stages. The head's reorder
/// permutation (if any) passes through untouched — byte stages only see
/// the serialized value bytes. Zero byte stages = pure label override
/// (see [`IndexChain`]).
pub struct ValueChain {
    head: Box<dyn ValueCodec>,
    stages: Vec<Box<dyn ByteStage>>,
    label: String,
}

impl ValueChain {
    pub fn new(head: Box<dyn ValueCodec>, stages: Vec<Box<dyn ByteStage>>, label: String) -> Self {
        Self { head, stages, label }
    }
}

impl ValueCodec for ValueChain {
    fn name(&self) -> &str {
        &self.label
    }

    fn lossless(&self) -> bool {
        self.head.lossless()
    }

    fn encode_into(&self, values: &[f32], out: &mut Vec<u8>) -> Option<Vec<u32>> {
        if self.stages.is_empty() {
            return self.head.encode_into(values, out);
        }
        let mut buf = Vec::new();
        let perm = self.head.encode_into(values, &mut buf);
        for stage in &self.stages {
            buf = stage.encode(&buf);
        }
        out.extend_from_slice(&buf);
        perm
    }

    fn decode(&self, bytes: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        if self.stages.is_empty() {
            return self.head.decode(bytes, n);
        }
        let mut stages = self.stages.iter().rev();
        let mut buf = stages.next().expect("stages checked non-empty").decode(bytes)?;
        for stage in stages {
            buf = stage.decode(&buf)?;
        }
        self.head.decode(&buf, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::index::{RawIndex, RleIndex};
    use crate::compress::value::RawValue;

    #[test]
    fn byte_stages_roundtrip_and_reject_garbage() {
        let data: Vec<u8> = (0..2000u32).flat_map(|i| ((i % 7) as u8).to_le_bytes()).collect();
        for stage in [&DeflateStage { level: 6 } as &dyn ByteStage, &ZstdStage { level: 3 }] {
            let enc = stage.encode(&data);
            assert!(enc.len() < data.len(), "{} did not compress", stage.name());
            assert_eq!(stage.decode(&enc).unwrap(), data, "{}", stage.name());
            assert_eq!(stage.decode(&stage.encode(&[])).unwrap(), Vec::<u8>::new());
            assert!(stage.decode(&enc[..enc.len() / 2]).is_err(), "{}", stage.name());
        }
    }

    #[test]
    fn index_chain_roundtrips_and_compresses_clusters() {
        let d = 65_536usize;
        // periodic clustered support: RLE output is long and repetitive,
        // exactly what a byte stage crushes
        let mut support = Vec::new();
        let mut x = 0u32;
        while (x as usize) < d {
            for j in 0..32u32 {
                if ((x + j) as usize) < d {
                    support.push(x + j);
                }
            }
            x += 64;
        }
        let plain = RleIndex.encode(d, &support);
        let chain = IndexChain::new(
            Box::new(RleIndex),
            vec![Box::new(DeflateStage { level: 6 })],
            "rle+deflate".into(),
        );
        assert_eq!(chain.name(), "rle+deflate");
        assert!(chain.lossless());
        let enc = chain.encode(d, &support);
        assert_eq!(enc.effective, support);
        assert!(
            enc.bytes.len() < plain.bytes.len(),
            "rle+deflate {} vs rle {}",
            enc.bytes.len(),
            plain.bytes.len()
        );
        assert_eq!(chain.decode(d, &enc.bytes).unwrap(), support);
    }

    #[test]
    fn two_byte_stages_unwind_in_reverse() {
        let d = 4096usize;
        let support: Vec<u32> = (100..600).collect();
        let chain = IndexChain::new(
            Box::new(RawIndex),
            vec![Box::new(DeflateStage { level: 6 }), Box::new(ZstdStage { level: 3 })],
            "raw+deflate+zstd".into(),
        );
        let enc = chain.encode(d, &support);
        assert_eq!(chain.decode(d, &enc.bytes).unwrap(), support);
    }

    #[test]
    fn value_chain_passes_perm_through() {
        let values: Vec<f32> = (0..512).map(|i| (i % 13) as f32 * 0.25 - 1.0).collect();
        let chain = ValueChain::new(
            Box::new(RawValue),
            vec![Box::new(DeflateStage { level: 6 })],
            "raw+deflate".into(),
        );
        assert!(chain.lossless());
        let enc = chain.encode(&values);
        assert!(enc.perm.is_none());
        assert_eq!(chain.decode(&enc.bytes, values.len()).unwrap(), values);
    }
}
