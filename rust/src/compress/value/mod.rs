//! Value-compression module (paper §3/§5): raw/fp16 casts, general
//! entropy coders (Deflate, Zstd), QSGD quantization, and the novel
//! curve-fitting compressors (Fit-Poly, Fit-DExp).
//!
//! Codecs are built by name through
//! [`value_by_name`](crate::compress::value_by_name) and implement
//! [`ValueCodec`](crate::compress::ValueCodec). Lossless codecs
//! roundtrip bit-exactly; sorting codecs (the curve fits) additionally
//! return the reorder permutation the container transmits (paper §5.1):
//!
//! ```
//! use deepreduce::compress::value_by_name;
//!
//! let raw = value_by_name("raw", f64::NAN, 0).unwrap();
//! let values = vec![0.5f32, -2.0, 0.25];
//! let enc = raw.encode(&values);
//! assert!(enc.perm.is_none()); // raw keeps wire order
//! assert_eq!(raw.decode(&enc.bytes, values.len()).unwrap(), values);
//! assert_eq!(enc.bytes.len(), values.len() * 4);
//! ```

mod fit;
mod general;
mod qsgd;

pub use fit::{FitDExpValue, FitPolyValue};
pub use general::{DeflateValue, Fp16Value, RawValue, ZstdValue};
pub use qsgd::QsgdValue;

#[cfg(test)]
mod tests {
    use crate::compress::{value_by_name, ValueCodec};
    use crate::util::prng::Rng;
    use crate::util::stats::rel_l2_err;
    use crate::util::testkit::{forall, gradient_like};

    fn decode_aligned(codec: &dyn ValueCodec, values: &[f32]) -> Vec<f32> {
        let enc = codec.encode(values);
        let wire = codec.decode(&enc.bytes, values.len()).unwrap();
        match enc.perm {
            None => wire,
            Some(p) => {
                let mut out = vec![0.0f32; wire.len()];
                for (j, &orig) in p.iter().enumerate() {
                    out[orig as usize] = wire[j];
                }
                out
            }
        }
    }

    #[test]
    fn lossless_codecs_bit_exact() {
        forall(
            "value-lossless",
            30,
            4000,
            |rng, size| {
                let n = 1 + rng.below(size as u64) as usize;
                gradient_like(rng, n)
            },
            |values| {
                for name in ["raw", "deflate", "zstd"] {
                    let codec = value_by_name(name, f64::NAN, 1).unwrap();
                    let out = decode_aligned(codec.as_ref(), values);
                    if out != *values {
                        return Err(format!("{name} not bit-exact"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn lossy_codecs_bounded_error() {
        let mut rng = Rng::new(100);
        // sorted-magnitude gradient values (what reaches value codecs
        // after Top-r) — smooth enough for the fits
        let mut values = gradient_like(&mut rng, 2000);
        values.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for (name, tol) in
            // fitdexp: one 4-parameter model over a mixed-sign curve is the
        // coarsest compressor here (the paper applies it per-layer where
        // curves are smoother); EF absorbs the residual during training
        [("fp16", 1e-3), ("qsgd", 0.25), ("fitpoly", 0.35), ("fitdexp", 0.55)]
        {
            let codec = value_by_name(name, f64::NAN, 1).unwrap();
            let out = decode_aligned(codec.as_ref(), &values);
            let err = rel_l2_err(&values, &out);
            assert!(err < tol, "{name}: rel err {err} > {tol}");
        }
    }

    #[test]
    fn empty_and_singleton_inputs() {
        for name in ["raw", "fp16", "deflate", "zstd", "qsgd", "fitpoly", "fitdexp"] {
            let codec = value_by_name(name, f64::NAN, 1).unwrap();
            for vals in [vec![], vec![1.5f32], vec![0.0f32, -2.0]] {
                let out = decode_aligned(codec.as_ref(), &vals);
                assert_eq!(out.len(), vals.len(), "{name} len mismatch");
                if !vals.is_empty() {
                    let err = rel_l2_err(&vals, &out);
                    assert!(err < 0.5, "{name}: err {err} on {vals:?} -> {out:?}");
                }
            }
        }
    }
}
