//! QSGD value quantization (Alistarh et al., NeurIPS 2017), the paper's
//! existing-method value plug-in (§3, §6.3).
//!
//! Values are split into buckets of `bucket` elements; within a bucket,
//! each value v is stochastically quantized to one of `s = 2^bits − 1`
//! levels of |v|/‖bucket‖∞:
//!   `level = floor(|v|/max * s + u)`, u ~ U[0,1)
//! The wire carries the bucket max (f32), then per value a sign bit and
//! the level in Elias-gamma (level+1, since gamma needs v ≥ 1).
//! Unbiased: `E[decode] = value`.

use crate::compress::{ValueCodec, ValueEncoding};
use crate::util::bitio::{BitReader, BitWriter};
use crate::util::elias::{gamma_decode, gamma_encode};
use crate::util::prng::Rng;
use crate::util::varint;
use std::sync::Mutex;

pub struct QsgdValue {
    pub bits: u32,
    pub bucket: usize,
    rng: Mutex<Rng>,
}

impl QsgdValue {
    pub fn new(bits: u32, bucket: usize, seed: u64) -> Self {
        assert!((1..=16).contains(&bits), "qsgd bits in 1..=16");
        assert!(bucket > 0);
        Self { bits, bucket, rng: Mutex::new(Rng::new(seed)) }
    }

    fn levels(&self) -> u32 {
        (1u32 << self.bits) - 1
    }
}

impl ValueCodec for QsgdValue {
    fn name(&self) -> &str {
        "qsgd"
    }

    fn encode(&self, values: &[f32]) -> ValueEncoding {
        let s = self.levels() as f32;
        let mut rng = self.rng.lock().unwrap();
        let mut head = Vec::new();
        varint::write_u64(&mut head, self.bits as u64);
        varint::write_u64(&mut head, self.bucket as u64);
        let mut w = BitWriter::with_capacity(values.len() / 2);
        for chunk in values.chunks(self.bucket) {
            let max = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            head.extend_from_slice(&max.to_le_bytes());
            for &v in chunk {
                w.write_bit(v < 0.0);
                let level = if max > 0.0 {
                    let t = (v.abs() / max) * s + rng.next_f32();
                    (t as u32).min(self.levels())
                } else {
                    0
                };
                gamma_encode(&mut w, level as u64 + 1);
            }
        }
        let mut bytes = head;
        bytes.extend_from_slice(&w.finish());
        ValueEncoding { bytes, perm: None }
    }

    fn decode(&self, bytes: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        let mut pos = 0usize;
        let bits = varint::read_u64(bytes, &mut pos)? as u32;
        let bucket = varint::read_u64(bytes, &mut pos)? as usize;
        anyhow::ensure!(bits == self.bits && bucket == self.bucket, "qsgd param mismatch");
        let s = ((1u32 << bits) - 1) as f32;
        let nbuckets = n.div_ceil(bucket);
        anyhow::ensure!(pos + nbuckets * 4 <= bytes.len(), "qsgd maxima truncated");
        let mut maxima = Vec::with_capacity(nbuckets);
        for _ in 0..nbuckets {
            maxima.push(f32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()));
            pos += 4;
        }
        let mut r = BitReader::new(&bytes[pos..]);
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let neg = r.read_bit()?;
            let level = (gamma_decode(&mut r)? - 1) as f32;
            let max = maxima[i / bucket];
            let mag = max * level / s;
            out.push(if neg { -mag } else { mag });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::ValueCodec;
    use crate::util::prng::Rng;
    use crate::util::stats::rel_l2_err;

    #[test]
    fn roundtrip_shape_and_bounds() {
        let mut rng = Rng::new(200);
        let values: Vec<f32> = (0..5000).map(|_| rng.next_gaussian() as f32).collect();
        let q = QsgdValue::new(7, 512, 1);
        let enc = q.encode(&values);
        let out = q.decode(&enc.bytes, values.len()).unwrap();
        assert_eq!(out.len(), values.len());
        // 7-bit quantization: decoded magnitude within one level of source
        for (chunk_i, chunk) in values.chunks(512).enumerate() {
            let max = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let step = max / 127.0;
            for (j, &v) in chunk.iter().enumerate() {
                let o = out[chunk_i * 512 + j];
                assert!((v - o).abs() <= step + 1e-6, "v={v} o={o} step={step}");
                if o != 0.0 {
                    assert_eq!(v < 0.0, o < 0.0);
                }
            }
        }
    }

    #[test]
    fn unbiasedness() {
        // E[Q(v)] = v over the stochastic rounding
        let v = 0.3f32;
        let values = vec![v, 1.0]; // second value pins the bucket max to 1
        let mut acc = 0.0f64;
        let trials = 4000;
        for t in 0..trials {
            let q = QsgdValue::new(3, 2, t as u64);
            let out = q.decode(&q.encode(&values).bytes, 2).unwrap();
            acc += out[0] as f64;
        }
        let mean = acc / trials as f64;
        assert!((mean - v as f64).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(201);
        let values: Vec<f32> = (0..2000).map(|_| rng.next_gaussian() as f32).collect();
        let mut errs = Vec::new();
        for bits in [2u32, 4, 8] {
            let q = QsgdValue::new(bits, 256, 5);
            let out = q.decode(&q.encode(&values).bytes, values.len()).unwrap();
            errs.push(rel_l2_err(&values, &out));
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    #[test]
    fn compresses_vs_raw() {
        let mut rng = Rng::new(202);
        // gradient-like: most values far below the bucket max
        let values: Vec<f32> =
            (0..10_000).map(|_| (rng.next_gaussian() as f32) * 0.01).collect();
        let q = QsgdValue::new(7, 512, 1);
        let enc = q.encode(&values);
        assert!(
            enc.bytes.len() * 2 < values.len() * 4,
            "qsgd {} vs raw {}",
            enc.bytes.len(),
            values.len() * 4
        );
    }

    #[test]
    fn zero_bucket_handled() {
        let values = vec![0.0f32; 600];
        let q = QsgdValue::new(7, 512, 1);
        let out = q.decode(&q.encode(&values).bytes, 600).unwrap();
        assert!(out.iter().all(|&v| v == 0.0));
    }
}
