//! Curve-fitting value compressors (paper §5): sort the value array,
//! fit the resulting smooth curve, transmit only the fit parameters
//! (plus the reorder mapping, handled by the framework).
//!
//! * **Fit-Poly** — piecewise polynomial (default degree 5): segments are
//!   found by the paper's chord-residual rule (split at the point of
//!   maximum squared distance from the line joining the segment
//!   endpoints), then each segment gets a least-squares polynomial.
//! * **Fit-DExp** — one double-exponential `y = a·e^{bx} + c·e^{dx}`
//!   over the whole sorted curve: 4 coefficients, no segmentation.

use crate::compress::{ValueCodec, ValueEncoding};
use crate::linalg::{fit_double_exp, polyfit, polyval, PolyFit};
use crate::util::varint;

/// Sort values descending; return (sorted, perm) with `perm[j]` = original
/// position of sorted value j.
fn sort_desc(values: &[f32]) -> (Vec<f64>, Vec<u32>) {
    let mut order: Vec<u32> = (0..values.len() as u32).collect();
    order.sort_by(|&a, &b| {
        values[b as usize]
            .partial_cmp(&values[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let sorted = order.iter().map(|&i| values[i as usize] as f64).collect();
    (sorted, order)
}

/// Chord-residual segmentation (paper §5 "Piece-wise approximation"):
/// maintain segments; repeatedly split the segment whose max squared
/// distance to its endpoint chord is largest, at that point, until
/// `target` segments or segments get shorter than `min_len`.
fn segment(sorted: &[f64], target: usize, min_len: usize) -> Vec<(usize, usize)> {
    #[derive(Debug)]
    struct Seg {
        start: usize,
        len: usize,
        split_at: usize,
        score: f64,
    }
    fn score(sorted: &[f64], start: usize, len: usize) -> (usize, f64) {
        if len < 3 {
            return (start, 0.0);
        }
        let (x0, x1) = (start, start + len - 1);
        let (y0, y1) = (sorted[x0], sorted[x1]);
        let m = (y1 - y0) / (x1 - x0) as f64;
        let mut best = (start, 0.0f64);
        for i in (x0 + 1)..x1 {
            let yi = y0 + m * (i - x0) as f64;
            let di = (yi - sorted[i]).powi(2);
            if di > best.1 {
                best = (i, di);
            }
        }
        best
    }
    let n = sorted.len();
    let (sp, sc) = score(sorted, 0, n);
    let mut segs = vec![Seg { start: 0, len: n, split_at: sp, score: sc }];
    while segs.len() < target {
        // pick the worst segment that is still splittable
        let Some((wi, _)) = segs
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                s.score > 0.0
                    && s.split_at > s.start
                    && s.split_at + 1 - s.start >= min_len
                    && s.start + s.len - s.split_at >= min_len
            })
            .max_by(|a, b| a.1.score.partial_cmp(&b.1.score).unwrap())
        else {
            break;
        };
        let s = &segs[wi];
        let (a_start, a_len) = (s.start, s.split_at + 1 - s.start);
        let (b_start, b_len) = (s.split_at, s.start + s.len - s.split_at);
        let (asp, asc) = score(sorted, a_start, a_len);
        let (bsp, bsc) = score(sorted, b_start, b_len);
        segs[wi] = Seg { start: a_start, len: a_len, split_at: asp, score: asc };
        segs.push(Seg { start: b_start, len: b_len, split_at: bsp, score: bsc });
    }
    let mut out: Vec<(usize, usize)> = segs.iter().map(|s| (s.start, s.len)).collect();
    out.sort_unstable();
    out
}

/// Piecewise-polynomial value codec.
pub struct FitPolyValue {
    pub degree: usize,
    /// number of segments; `None` = the paper's p ≈ ⌈2√M⌉ heuristic
    /// (Lemma 1), clamped to [1, 64]
    pub segments: Option<usize>,
}

impl FitPolyValue {
    pub fn new(degree: usize) -> Self {
        assert!(degree <= 8);
        Self { degree, segments: Some(8) }
    }

    pub fn with_segments(degree: usize, segments: usize) -> Self {
        Self { degree, segments: Some(segments.max(1)) }
    }

    pub fn auto(degree: usize) -> Self {
        Self { degree, segments: None }
    }

    fn target_segments(&self, sorted: &[f64]) -> usize {
        match self.segments {
            Some(s) => s,
            None => {
                // Lemma 1 heuristic: M = |(C[1]-C[2]) - (C[d-1]-C[d])|,
                // p = ceil(2 sqrt(M))
                let n = sorted.len();
                if n < 4 {
                    return 1;
                }
                let m = ((sorted[0] - sorted[1]) - (sorted[n - 2] - sorted[n - 1])).abs();
                ((2.0 * m.sqrt()).ceil() as usize).clamp(1, 64)
            }
        }
    }
}

impl ValueCodec for FitPolyValue {
    fn name(&self) -> &str {
        "fitpoly"
    }

    fn encode(&self, values: &[f32]) -> ValueEncoding {
        let n = values.len();
        // tiny inputs: raw fallback (flag 1)
        if n <= (self.degree + 1) * 2 {
            let mut bytes = vec![1u8];
            for &v in values {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            return ValueEncoding { bytes, perm: None };
        }
        let (sorted, perm) = sort_desc(values);
        let target = self.target_segments(&sorted);
        let segs = segment(&sorted, target, self.degree + 1);
        let mut bytes = vec![0u8];
        varint::write_u64(&mut bytes, self.degree as u64);
        varint::write_u64(&mut bytes, segs.len() as u64);
        for &(start, len) in &segs {
            varint::write_u64(&mut bytes, start as u64);
            varint::write_u64(&mut bytes, len as u64);
            let fit = polyfit(start, &sorted[start..start + len], self.degree)
                .unwrap_or(PolyFit { coeffs: vec![0.0; 1], mid: 0.0, half: 1.0 });
            bytes.extend_from_slice(&fit.mid.to_le_bytes());
            bytes.extend_from_slice(&fit.half.to_le_bytes());
            varint::write_u64(&mut bytes, fit.coeffs.len() as u64);
            for &c in &fit.coeffs {
                bytes.extend_from_slice(&c.to_le_bytes());
            }
        }
        ValueEncoding { bytes, perm: Some(perm) }
    }

    fn decode(&self, bytes: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(!bytes.is_empty(), "empty fitpoly payload");
        if bytes[0] == 1 {
            let raw = &bytes[1..];
            anyhow::ensure!(raw.len() == n * 4, "fitpoly raw fallback size");
            return Ok(raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect());
        }
        let mut pos = 1usize;
        let _deg = varint::read_u64(bytes, &mut pos)?;
        let nsegs = varint::read_u64(bytes, &mut pos)? as usize;
        let mut out = vec![0.0f32; n];
        let mut covered = 0usize;
        for _ in 0..nsegs {
            let start = varint::read_u64(bytes, &mut pos)? as usize;
            let len = varint::read_u64(bytes, &mut pos)? as usize;
            anyhow::ensure!(start + len <= n, "fitpoly segment out of range");
            anyhow::ensure!(pos + 8 <= bytes.len(), "fitpoly segment truncated");
            let mid = f32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
            let half = f32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
            pos += 8;
            let ncoef = varint::read_u64(bytes, &mut pos)? as usize;
            anyhow::ensure!(ncoef <= 16 && pos + 4 * ncoef <= bytes.len(), "fitpoly coeffs");
            let mut coeffs = Vec::with_capacity(ncoef);
            for _ in 0..ncoef {
                coeffs.push(f32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()));
                pos += 4;
            }
            let fit = PolyFit { coeffs, mid, half };
            let vals = polyval(&fit, start, len);
            // overlapping knot points: later segment wins (same endpoint)
            out[start..start + len].copy_from_slice(&vals);
            covered = covered.max(start + len);
        }
        anyhow::ensure!(covered == n || nsegs == 0, "fitpoly segments do not cover values");
        Ok(out)
    }
}

/// Double-exponential value codec: 4 coefficients for the whole curve.
pub struct FitDExpValue {
    pub max_iters: usize,
}

impl Default for FitDExpValue {
    fn default() -> Self {
        Self { max_iters: 60 }
    }
}

impl ValueCodec for FitDExpValue {
    fn name(&self) -> &str {
        "fitdexp"
    }

    fn encode(&self, values: &[f32]) -> ValueEncoding {
        let n = values.len();
        if n < 8 {
            let mut bytes = vec![1u8];
            for &v in values {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            return ValueEncoding { bytes, perm: None };
        }
        let (sorted, perm) = sort_desc(values);
        // §Perf: the LM iterations are O(n·iters); for long value arrays
        // fit on a stratified subsample (the sorted curve is smooth, so
        // every 2nd/4th/... point carries the same information). Decode
        // evaluates the closed-form model at every position regardless.
        const FIT_CAP: usize = 1024;
        let fit_input: Vec<f64>;
        let fit_y: &[f64] = if sorted.len() > FIT_CAP {
            // evenly spaced indices over [0, n-1] INCLUSIVE — both curve
            // endpoints anchor the fit
            let n = sorted.len();
            fit_input = (0..FIT_CAP)
                .map(|j| sorted[j * (n - 1) / (FIT_CAP - 1)])
                .collect();
            &fit_input
        } else {
            &sorted
        };
        match fit_double_exp(fit_y, self.max_iters) {
            Some((model, _sse)) => {
                let mut bytes = vec![0u8];
                for c in [model.a, model.b, model.c, model.d] {
                    bytes.extend_from_slice(&c.to_le_bytes());
                }
                ValueEncoding { bytes, perm: Some(perm) }
            }
            None => {
                let mut bytes = vec![1u8];
                for &v in values {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
                ValueEncoding { bytes, perm: None }
            }
        }
    }

    fn decode(&self, bytes: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(!bytes.is_empty(), "empty fitdexp payload");
        if bytes[0] == 1 {
            let raw = &bytes[1..];
            anyhow::ensure!(raw.len() == n * 4, "fitdexp raw fallback size");
            return Ok(raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect());
        }
        anyhow::ensure!(bytes.len() == 17, "fitdexp payload must be 17 bytes");
        let f = |o: usize| f32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
        let model = crate::linalg::DoubleExp { a: f(1), b: f(5), c: f(9), d: f(13) };
        Ok((0..n).map(|i| model.eval(i, n)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::ValueCodec;
    use crate::util::prng::Rng;
    use crate::util::stats::rel_l2_err;

    fn decode_aligned(codec: &dyn ValueCodec, values: &[f32]) -> (Vec<f32>, usize) {
        let enc = codec.encode(values);
        let wire = codec.decode(&enc.bytes, values.len()).unwrap();
        let size = enc.bytes.len();
        match enc.perm {
            None => (wire, size),
            Some(p) => {
                let mut out = vec![0.0f32; wire.len()];
                for (j, &orig) in p.iter().enumerate() {
                    out[orig as usize] = wire[j];
                }
                (out, size)
            }
        }
    }

    /// Gradient-like sorted-curve generator: mixture of signed
    /// heavy-tailed values, like a Top-r output.
    fn topk_values(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| {
                let mag = 0.05 + (rng.next_f32().powi(3)) * 2.0;
                if rng.next_f64() < 0.5 {
                    mag
                } else {
                    -mag
                }
            })
            .collect()
    }

    #[test]
    fn segmentation_covers_and_is_contiguous() {
        let mut rng = Rng::new(300);
        for _ in 0..20 {
            let n = 20 + rng.below(3000) as usize;
            let mut sorted: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let segs = segment(&sorted, 8, 6);
            assert_eq!(segs[0].0, 0);
            let mut end = 0;
            for &(start, len) in &segs {
                // segments share knot endpoints: start == previous end - 1
                // for all but the first
                if start != 0 {
                    assert_eq!(start, end - 1, "segments must chain at knots");
                }
                end = start + len;
            }
            assert_eq!(end, n);
        }
    }

    #[test]
    fn fitpoly_compresses_smooth_curves_well() {
        let mut rng = Rng::new(301);
        let values = topk_values(&mut rng, 2000);
        let codec = FitPolyValue::new(5);
        let (out, size) = decode_aligned(&codec, &values);
        let err = rel_l2_err(&values, &out);
        assert!(err < 0.1, "rel err {err}");
        // payload (excluding the framework-carried perm) is tiny
        assert!(size < 600, "fitpoly payload {size}");
    }

    #[test]
    fn fitdexp_four_coefficients() {
        let mut rng = Rng::new(302);
        // single-sign curve: classic double-exp shape
        let mut values: Vec<f32> =
            (0..1500).map(|_| 0.01 + rng.next_f32().powi(4) * 3.0).collect();
        values.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let codec = FitDExpValue::default();
        let enc = codec.encode(&values);
        assert_eq!(enc.bytes.len(), 17, "4 coefficients + flag");
        let (out, _) = decode_aligned(&codec, &values);
        let err = rel_l2_err(&values, &out);
        assert!(err < 0.25, "rel err {err}");
    }

    #[test]
    fn paper_volume_shape_fig10a() {
        // Fit-Poly on Top-r(1%) of a 36864-dim gradient: value payload
        // (fit + mapping) should be well below raw 4 B/value (paper: ~40%
        // reduction incl. mapping; mapping is carried by the framework at
        // ⌈log₂ r⌉ = 9 bits/value here).
        let mut rng = Rng::new(303);
        let values = topk_values(&mut rng, 369);
        let codec = FitPolyValue::new(5);
        let enc = codec.encode(&values);
        let mapping_bits = 369 * 9;
        let total_bits = enc.bytes.len() * 8 + mapping_bits;
        let raw_bits = 369 * 32;
        let ratio = total_bits as f64 / raw_bits as f64;
        assert!(ratio < 0.75, "fit-poly total ratio {ratio}");
    }

    #[test]
    fn raw_fallback_for_tiny_inputs() {
        let codec = FitPolyValue::new(5);
        let values = vec![1.0f32, -2.0, 3.0];
        let (out, _) = decode_aligned(&codec, &values);
        assert_eq!(out, values);
        let codec = FitDExpValue::default();
        let (out, _) = decode_aligned(&codec, &values);
        assert_eq!(out, values);
    }

    #[test]
    fn monotonicity_of_decoded_sorted_curve() {
        // decoded wire-order values should be near-monotone (they model a
        // sorted curve); large inversions indicate a broken segment chain
        let mut rng = Rng::new(304);
        let values = topk_values(&mut rng, 1000);
        let codec = FitPolyValue::new(5);
        let enc = codec.encode(&values);
        let wire = codec.decode(&enc.bytes, values.len()).unwrap();
        let mut inversions = 0;
        let scale = wire[0] - wire[wire.len() - 1];
        for w in wire.windows(2) {
            if w[1] - w[0] > 0.05 * scale {
                inversions += 1;
            }
        }
        assert!(inversions < 20, "{inversions} large inversions");
    }

    #[test]
    fn auto_segment_heuristic_used() {
        let codec = FitPolyValue::auto(1);
        let mut rng = Rng::new(305);
        let values = topk_values(&mut rng, 500);
        let enc = codec.encode(&values);
        assert_eq!(enc.bytes[0], 0);
        // decodes fine
        let wire = codec.decode(&enc.bytes, values.len()).unwrap();
        assert_eq!(wire.len(), values.len());
    }
}
