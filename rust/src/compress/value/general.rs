//! General-purpose value codecs: raw f32, fp16 cast, Deflate (RFC 1951,
//! the paper's §3 example) and Zstd.

use crate::compress::{ValueCodec, ValueEncoding};
use crate::util::f16;

/// Uncompressed little-endian f32 — the bypass option.
pub struct RawValue;

impl ValueCodec for RawValue {
    fn name(&self) -> &str {
        "raw"
    }

    fn lossless(&self) -> bool {
        true
    }

    fn encode_into(&self, values: &[f32], out: &mut Vec<u8>) -> Option<Vec<u32>> {
        out.reserve(values.len() * 4);
        for &v in values {
            out.extend_from_slice(&v.to_le_bytes());
        }
        None
    }

    fn decode(&self, bytes: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(bytes.len() == n * 4, "raw value size mismatch");
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

/// IEEE binary16 cast — the fp16 rows of Fig 11.
pub struct Fp16Value;

impl ValueCodec for Fp16Value {
    fn name(&self) -> &str {
        "fp16"
    }

    fn encode_into(&self, values: &[f32], out: &mut Vec<u8>) -> Option<Vec<u32>> {
        out.reserve(values.len() * 2);
        for &v in values {
            out.extend_from_slice(&f16::f32_to_f16_bits(v).to_le_bytes());
        }
        None
    }

    fn decode(&self, bytes: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(bytes.len() == n * 2, "fp16 value size mismatch");
        Ok(bytes
            .chunks_exact(2)
            .map(|c| f16::f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }
}

/// Deflate over the f32 byte stream (flate2). Lossless; compression on
/// float gradients is modest (the paper uses it as the generic option).
pub struct DeflateValue {
    pub level: u32,
}

impl Default for DeflateValue {
    fn default() -> Self {
        Self { level: 6 }
    }
}

impl ValueCodec for DeflateValue {
    fn name(&self) -> &str {
        "deflate"
    }

    fn lossless(&self) -> bool {
        true
    }

    fn encode(&self, values: &[f32]) -> ValueEncoding {
        use flate2::write::DeflateEncoder;
        use std::io::Write;
        let mut raw = Vec::with_capacity(values.len() * 4);
        for &v in values {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        let mut enc = DeflateEncoder::new(Vec::new(), flate2::Compression::new(self.level));
        enc.write_all(&raw).expect("in-memory deflate cannot fail");
        ValueEncoding { bytes: enc.finish().expect("deflate finish"), perm: None }
    }

    fn decode(&self, bytes: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        use flate2::read::DeflateDecoder;
        use std::io::Read;
        let mut raw = Vec::with_capacity(n * 4);
        DeflateDecoder::new(bytes).read_to_end(&mut raw)?;
        anyhow::ensure!(raw.len() == n * 4, "deflate payload size mismatch");
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

/// Zstandard over the f32 byte stream — a stronger general coder than
/// Deflate at similar speed; included as a framework plug-in.
pub struct ZstdValue {
    pub level: i32,
}

impl Default for ZstdValue {
    fn default() -> Self {
        Self { level: 3 }
    }
}

impl ValueCodec for ZstdValue {
    fn name(&self) -> &str {
        "zstd"
    }

    fn lossless(&self) -> bool {
        true
    }

    fn encode(&self, values: &[f32]) -> ValueEncoding {
        let mut raw = Vec::with_capacity(values.len() * 4);
        for &v in values {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        let bytes = zstd::bulk::compress(&raw, self.level).expect("in-memory zstd");
        ValueEncoding { bytes, perm: None }
    }

    fn decode(&self, bytes: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        let raw = zstd::bulk::decompress(bytes, n * 4 + 16)?;
        anyhow::ensure!(raw.len() == n * 4, "zstd payload size mismatch");
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::ValueCodec;

    #[test]
    fn deflate_compresses_repetitive_values() {
        let values = vec![0.125f32; 10_000];
        let enc = DeflateValue::default().encode(&values);
        assert!(enc.bytes.len() < 1000, "deflate size {}", enc.bytes.len());
        let back = DeflateValue::default().decode(&enc.bytes, values.len()).unwrap();
        assert_eq!(back, values);
    }

    #[test]
    fn zstd_compresses_repetitive_values() {
        let values = vec![0.5f32; 10_000];
        let enc = ZstdValue::default().encode(&values);
        assert!(enc.bytes.len() < 1000, "zstd size {}", enc.bytes.len());
        let back = ZstdValue::default().decode(&enc.bytes, values.len()).unwrap();
        assert_eq!(back, values);
    }

    #[test]
    fn fp16_halves_volume() {
        let values = vec![1.0f32; 100];
        assert_eq!(Fp16Value.encode(&values).bytes.len(), 200);
        assert_eq!(RawValue.encode(&values).bytes.len(), 400);
    }

    #[test]
    fn decode_size_validation() {
        assert!(RawValue.decode(&[0u8; 7], 2).is_err());
        assert!(Fp16Value.decode(&[0u8; 3], 2).is_err());
    }

    #[test]
    fn encode_into_appends_after_existing_content() {
        let mut buf = vec![0x77u8];
        assert!(RawValue.encode_into(&[1.0, -2.0], &mut buf).is_none());
        assert_eq!(buf[0], 0x77);
        assert_eq!(RawValue.decode(&buf[1..], 2).unwrap(), vec![1.0, -2.0]);
    }
}
