//! The typed codec registry: every index codec, value codec, and chain
//! byte stage registers under a name with a **declared parameter
//! schema** (key, type, default, help). The registry replaces the old
//! `index_by_name(name, f64, seed)` factories whose single overloaded
//! `f64` meant multi-parameter codecs and combined compression were
//! unreachable without editing every call site.
//!
//! What hangs off it:
//!
//! - [`CodecRegistry::build_index`] / [`CodecRegistry::build_value`]
//!   turn a parsed [`CodecSpec`] (single stage or `a+b` chain) into a
//!   boxed codec, validating every parameter against the schema —
//!   an undeclared key is a **hard error naming the valid keys**, not a
//!   silent no-op.
//! - [`CodecRegistry::autotune_candidates`] enumerates the default
//!   autotuner candidate set — including two-stage chains — so the
//!   policy discovers new codecs without the trainer hardcoding names.
//! - [`CodecRegistry::rows`] renders the `list-codecs` CLI table.
//! - Library embedders extend the registry at runtime via
//!   [`CodecRegistry::register_index`] (and `_value`/`_stage`) with
//!   their own entries; chains and the autotuner pick them up.

use super::chain::{ByteStage, DeflateStage, IndexChain, ValueChain, ZstdStage};
use super::spec::{CodecSpec, StageSpec};
use super::{IndexCodec, ValueCodec};
use std::collections::BTreeMap;

/// Which table a codec lives in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecSet {
    Index,
    Value,
    /// chainable byte stage (stage 2+ of an `a+b` chain)
    Stage,
}

impl CodecSet {
    pub fn label(self) -> &'static str {
        match self {
            CodecSet::Index => "index",
            CodecSet::Value => "value",
            CodecSet::Stage => "stage",
        }
    }
}

/// Declared type of one codec parameter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamKind {
    Float,
    Int,
    Bool,
}

impl ParamKind {
    fn label(self) -> &'static str {
        match self {
            ParamKind::Float => "float",
            ParamKind::Int => "int",
            ParamKind::Bool => "bool",
        }
    }
}

/// A typed parameter value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ParamValue {
    Float(f64),
    Int(i64),
    Bool(bool),
}

impl ParamValue {
    pub fn render(&self) -> String {
        match self {
            ParamValue::Float(v) => format!("{v}"),
            ParamValue::Int(v) => format!("{v}"),
            ParamValue::Bool(v) => format!("{v}"),
        }
    }
}

/// One declared parameter of a codec: the schema the registry validates
/// spec-provided `key=value` pairs against.
#[derive(Clone, Copy, Debug)]
pub struct ParamSpec {
    pub key: &'static str,
    pub kind: ParamKind,
    pub default: ParamValue,
    pub help: &'static str,
}

/// The fully-resolved parameters handed to a codec builder: every
/// declared key is present (defaults filled in), every value is typed.
pub struct ResolvedParams {
    vals: BTreeMap<&'static str, ParamValue>,
    /// run seed, threaded to every stochastic codec
    pub seed: u64,
}

impl ResolvedParams {
    pub fn get_f64(&self, key: &str) -> f64 {
        match self.vals.get(key) {
            Some(ParamValue::Float(v)) => *v,
            Some(ParamValue::Int(v)) => *v as f64,
            _ => panic!("param {key} not declared as float"),
        }
    }

    pub fn get_i64(&self, key: &str) -> i64 {
        match self.vals.get(key) {
            Some(ParamValue::Int(v)) => *v,
            _ => panic!("param {key} not declared as int"),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        match self.vals.get(key) {
            Some(ParamValue::Bool(v)) => *v,
            _ => panic!("param {key} not declared as bool"),
        }
    }
}

type BuildFn<C> = Box<dyn Fn(&ResolvedParams) -> anyhow::Result<C> + Send + Sync>;

/// One registry entry: a named, schema'd codec constructor.
pub struct CodecEntry<C> {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    /// decode reconstructs the input exactly
    pub lossless: bool,
    /// member of the autotuner's default candidate set
    pub autotune: bool,
    /// schema key the legacy single-`f64` factories map their parameter
    /// onto (`--fpr`, `--value-param` back-compat shims)
    pub legacy_param: Option<&'static str>,
    pub params: &'static [ParamSpec],
    build: BuildFn<C>,
}

impl<C> CodecEntry<C> {
    pub fn new(
        name: &'static str,
        aliases: &'static [&'static str],
        lossless: bool,
        autotune: bool,
        legacy_param: Option<&'static str>,
        params: &'static [ParamSpec],
        build: BuildFn<C>,
    ) -> Self {
        Self { name, aliases, lossless, autotune, legacy_param, params, build }
    }
}

/// One row of the `list-codecs` table.
pub struct CodecRow {
    pub name: String,
    pub set: &'static str,
    /// `key:type=default` summary, `-` when parameter-free
    pub params: String,
    pub lossless: bool,
    /// may appear after a `+` (i.e. as a non-leading chain stage);
    /// every index/value codec may *lead* a chain
    pub chainable: bool,
}

// ---- parameter schemas (static: shared by entries and docs) --------

static P_FPR: &[ParamSpec] = &[ParamSpec {
    key: "fpr",
    kind: ParamKind::Float,
    default: ParamValue::Float(0.001),
    help: "bloom false-positive rate, in (0,1)",
}];

static P_DEFLATE: &[ParamSpec] = &[ParamSpec {
    key: "level",
    kind: ParamKind::Int,
    default: ParamValue::Int(6),
    help: "compression level 0..=9",
}];

static P_ZSTD: &[ParamSpec] = &[ParamSpec {
    key: "level",
    kind: ParamKind::Int,
    default: ParamValue::Int(3),
    help: "compression level 1..=22",
}];

static P_QSGD: &[ParamSpec] = &[
    ParamSpec {
        key: "bits",
        kind: ParamKind::Int,
        default: ParamValue::Int(7),
        help: "quantization bits 1..=16",
    },
    ParamSpec {
        key: "bucket",
        kind: ParamKind::Int,
        default: ParamValue::Int(512),
        help: "normalization bucket length",
    },
];

static P_DEGREE: &[ParamSpec] = &[ParamSpec {
    key: "degree",
    kind: ParamKind::Int,
    default: ParamValue::Int(5),
    help: "polynomial degree 1..=16",
}];

static P_QUANTILES: &[ParamSpec] = &[ParamSpec {
    key: "quantiles",
    kind: ParamKind::Int,
    default: ParamValue::Int(64),
    help: "quantile bucket count (>= 2)",
}];

/// The registry: three entry tables plus lookup/build/enumerate logic.
pub struct CodecRegistry {
    index: Vec<CodecEntry<Box<dyn IndexCodec>>>,
    value: Vec<CodecEntry<Box<dyn ValueCodec>>>,
    stage: Vec<CodecEntry<Box<dyn ByteStage>>>,
}

impl CodecRegistry {
    /// The process-wide built-in registry, constructed once. This is
    /// what the legacy factories, the container-header decoder and the
    /// trainer plumbing resolve against; build a fresh
    /// [`CodecRegistry::builtin`] (and thread it through
    /// `DeepReduceBuilder::build_with`) to extend the codec set.
    pub fn global() -> &'static CodecRegistry {
        static REG: std::sync::OnceLock<CodecRegistry> = std::sync::OnceLock::new();
        REG.get_or_init(CodecRegistry::builtin)
    }

    /// A fresh copy of the built-in codec set, for registries that will
    /// be extended with custom entries.
    pub fn builtin() -> Self {
        use crate::compress::{index, value};
        let mut r = Self { index: Vec::new(), value: Vec::new(), stage: Vec::new() };

        // ---- index codecs ----
        let bloom = |policy: index::BloomPolicy| {
            move |p: &ResolvedParams| -> anyhow::Result<Box<dyn IndexCodec>> {
                let fpr = p.get_f64("fpr");
                anyhow::ensure!(
                    fpr > 0.0 && fpr < 1.0,
                    "bloom fpr must be in (0,1), got {fpr}"
                );
                Ok(Box::new(index::BloomIndex::new(policy, fpr, p.seed)))
            }
        };
        r.register_index(CodecEntry::new(
            "raw",
            &["keys"],
            true,
            true,
            None,
            &[],
            Box::new(|_| Ok(Box::new(index::RawIndex))),
        ));
        r.register_index(CodecEntry::new(
            "bitmap",
            &[],
            true,
            true,
            None,
            &[],
            Box::new(|_| Ok(Box::new(index::BitmapIndex))),
        ));
        r.register_index(CodecEntry::new(
            "rle",
            &[],
            true,
            true,
            None,
            &[],
            Box::new(|_| Ok(Box::new(index::RleIndex))),
        ));
        r.register_index(CodecEntry::new(
            "huffman",
            &[],
            true,
            false,
            None,
            &[],
            Box::new(|_| Ok(Box::new(index::HuffmanIndex))),
        ));
        r.register_index(CodecEntry::new(
            "delta_varint",
            &["delta"],
            true,
            false,
            None,
            &[],
            Box::new(|_| Ok(Box::new(index::DeltaVarint))),
        ));
        r.register_index(CodecEntry::new(
            "elias",
            &["elias_gamma"],
            true,
            true,
            None,
            &[],
            Box::new(|_| Ok(Box::new(index::EliasIndex))),
        ));
        r.register_index(CodecEntry::new(
            "bloom_naive",
            &[],
            false,
            false,
            Some("fpr"),
            P_FPR,
            Box::new(bloom(index::BloomPolicy::Naive)),
        ));
        r.register_index(CodecEntry::new(
            "bloom_p0",
            &[],
            false,
            false,
            Some("fpr"),
            P_FPR,
            Box::new(bloom(index::BloomPolicy::P0)),
        ));
        r.register_index(CodecEntry::new(
            "bloom_p1",
            &[],
            false,
            false,
            Some("fpr"),
            P_FPR,
            Box::new(bloom(index::BloomPolicy::P1)),
        ));
        r.register_index(CodecEntry::new(
            "bloom_p2",
            &[],
            false,
            true,
            Some("fpr"),
            P_FPR,
            Box::new(bloom(index::BloomPolicy::P2)),
        ));
        r.register_index(CodecEntry::new(
            "delta_huffman",
            &[],
            true,
            false,
            None,
            &[],
            Box::new(|_| Ok(Box::new(crate::baselines::DeltaHuffmanIndex))),
        ));

        // ---- value codecs ----
        r.register_value(CodecEntry::new(
            "raw",
            &["none", "fp32"],
            true,
            true,
            None,
            &[],
            Box::new(|_| Ok(Box::new(value::RawValue))),
        ));
        r.register_value(CodecEntry::new(
            "fp16",
            &[],
            false,
            false,
            None,
            &[],
            Box::new(|_| Ok(Box::new(value::Fp16Value))),
        ));
        r.register_value(CodecEntry::new(
            "deflate",
            &[],
            true,
            true,
            None,
            P_DEFLATE,
            Box::new(|p: &ResolvedParams| -> anyhow::Result<Box<dyn ValueCodec>> {
                let level = p.get_i64("level");
                anyhow::ensure!((0..=9).contains(&level), "deflate level 0..=9, got {level}");
                Ok(Box::new(value::DeflateValue { level: level as u32 }))
            }),
        ));
        r.register_value(CodecEntry::new(
            "zstd",
            &[],
            true,
            false,
            None,
            P_ZSTD,
            Box::new(|p: &ResolvedParams| -> anyhow::Result<Box<dyn ValueCodec>> {
                let level = p.get_i64("level");
                anyhow::ensure!((1..=22).contains(&level), "zstd level 1..=22, got {level}");
                Ok(Box::new(value::ZstdValue { level: level as i32 }))
            }),
        ));
        r.register_value(CodecEntry::new(
            "qsgd",
            &[],
            false,
            true,
            Some("bits"),
            P_QSGD,
            Box::new(|p: &ResolvedParams| -> anyhow::Result<Box<dyn ValueCodec>> {
                let bits = p.get_i64("bits");
                let bucket = p.get_i64("bucket");
                anyhow::ensure!((1..=16).contains(&bits), "qsgd bits 1..=16, got {bits}");
                anyhow::ensure!(bucket > 0, "qsgd bucket must be positive, got {bucket}");
                Ok(Box::new(value::QsgdValue::new(bits as u32, bucket as usize, p.seed)))
            }),
        ));
        r.register_value(CodecEntry::new(
            "fitpoly",
            &[],
            false,
            true,
            Some("degree"),
            P_DEGREE,
            Box::new(|p: &ResolvedParams| -> anyhow::Result<Box<dyn ValueCodec>> {
                let degree = p.get_i64("degree");
                anyhow::ensure!((1..=16).contains(&degree), "fitpoly degree 1..=16, got {degree}");
                Ok(Box::new(value::FitPolyValue::new(degree as usize)))
            }),
        ));
        r.register_value(CodecEntry::new(
            "fitdexp",
            &[],
            false,
            false,
            None,
            &[],
            Box::new(|_| Ok(Box::new(value::FitDExpValue::default()))),
        ));
        let sketch = |huffman: bool| {
            move |p: &ResolvedParams| -> anyhow::Result<Box<dyn ValueCodec>> {
                let q = p.get_i64("quantiles");
                anyhow::ensure!(q >= 2, "sketch quantiles must be >= 2, got {q}");
                Ok(Box::new(crate::baselines::QuantileBucketValue::new(q as usize, huffman)))
            }
        };
        r.register_value(CodecEntry::new(
            "sketch",
            &[],
            false,
            false,
            Some("quantiles"),
            P_QUANTILES,
            Box::new(sketch(false)),
        ));
        r.register_value(CodecEntry::new(
            "sketch_huff",
            &[],
            false,
            false,
            Some("quantiles"),
            P_QUANTILES,
            Box::new(sketch(true)),
        ));

        // ---- chain byte stages ----
        r.register_stage(CodecEntry::new(
            "deflate",
            &[],
            true,
            true,
            None,
            P_DEFLATE,
            Box::new(|p: &ResolvedParams| -> anyhow::Result<Box<dyn ByteStage>> {
                let level = p.get_i64("level");
                anyhow::ensure!((0..=9).contains(&level), "deflate level 0..=9, got {level}");
                Ok(Box::new(DeflateStage { level: level as u32 }))
            }),
        ));
        // zstd and deflate share the offline LZSS shim, so enumerating
        // both as autotune chain tails would double the candidate set
        // with zero diversity — zstd stays opt-in
        r.register_stage(CodecEntry::new(
            "zstd",
            &[],
            true,
            false,
            None,
            P_ZSTD,
            Box::new(|p: &ResolvedParams| -> anyhow::Result<Box<dyn ByteStage>> {
                let level = p.get_i64("level");
                anyhow::ensure!((1..=22).contains(&level), "zstd level 1..=22, got {level}");
                Ok(Box::new(ZstdStage { level: level as i32 }))
            }),
        ));
        r
    }

    pub fn register_index(&mut self, entry: CodecEntry<Box<dyn IndexCodec>>) {
        self.index.push(entry);
    }

    pub fn register_value(&mut self, entry: CodecEntry<Box<dyn ValueCodec>>) {
        self.value.push(entry);
    }

    pub fn register_stage(&mut self, entry: CodecEntry<Box<dyn ByteStage>>) {
        self.stage.push(entry);
    }

    fn find<'a, C>(list: &'a [CodecEntry<C>], name: &str) -> Option<&'a CodecEntry<C>> {
        list.iter().find(|e| e.name == name || e.aliases.contains(&name))
    }

    /// The known names of one table (error messages, docs).
    pub fn names(&self, set: CodecSet) -> Vec<&'static str> {
        match set {
            CodecSet::Index => self.index.iter().map(|e| e.name).collect(),
            CodecSet::Value => self.value.iter().map(|e| e.name).collect(),
            CodecSet::Stage => self.stage.iter().map(|e| e.name).collect(),
        }
    }

    /// Validate `given` parameters against an entry's schema and fill
    /// defaults. An undeclared key is a hard error naming the valid
    /// keys (the old factories silently ignored extras).
    fn resolve(
        entry_name: &str,
        schema: &'static [ParamSpec],
        given: &[(String, String)],
        seed: u64,
    ) -> anyhow::Result<ResolvedParams> {
        let mut vals: BTreeMap<&'static str, ParamValue> = BTreeMap::new();
        for p in schema {
            vals.insert(p.key, p.default);
        }
        for (k, v) in given {
            let spec = schema.iter().find(|p| p.key == k).ok_or_else(|| {
                let valid = if schema.is_empty() {
                    "it takes no parameters".to_string()
                } else {
                    format!(
                        "valid keys: {}",
                        schema
                            .iter()
                            .map(|p| format!("{}:{}", p.key, p.kind.label()))
                            .collect::<Vec<_>>()
                            .join(", ")
                    )
                };
                anyhow::anyhow!("codec {entry_name} does not declare parameter {k:?} — {valid}")
            })?;
            let val = Self::parse_value(spec.kind, v).ok_or_else(|| {
                anyhow::anyhow!(
                    "codec {entry_name} parameter {k}: {v:?} is not a valid {}",
                    spec.kind.label()
                )
            })?;
            vals.insert(spec.key, val);
        }
        Ok(ResolvedParams { vals, seed })
    }

    fn parse_value(kind: ParamKind, raw: &str) -> Option<ParamValue> {
        match kind {
            ParamKind::Float => {
                raw.parse::<f64>().ok().filter(|v| v.is_finite()).map(ParamValue::Float)
            }
            ParamKind::Int => raw.parse::<i64>().ok().map(ParamValue::Int),
            ParamKind::Bool => match raw {
                "true" | "1" | "on" => Some(ParamValue::Bool(true)),
                "false" | "0" | "off" => Some(ParamValue::Bool(false)),
                _ => None,
            },
        }
    }

    /// Build the byte-stage tail of a chain (stages after the head).
    fn build_stages(
        &self,
        specs: &[StageSpec],
        head_set: CodecSet,
        seed: u64,
    ) -> anyhow::Result<Vec<Box<dyn ByteStage>>> {
        specs
            .iter()
            .map(|st| {
                let entry = Self::find(&self.stage, &st.name).ok_or_else(|| {
                    let is_head_codec = Self::find(&self.index, &st.name).is_some()
                        || Self::find(&self.value, &st.name).is_some();
                    if is_head_codec {
                        let set = if Self::find(&self.index, &st.name).is_some() {
                            "index"
                        } else {
                            "value"
                        };
                        anyhow::anyhow!(
                            "{} is a {set} codec and may only lead a chain — stages after \
                             the first must be lossless byte stages ({})",
                            st.name,
                            self.names(CodecSet::Stage).join(", ")
                        )
                    } else {
                        anyhow::anyhow!(
                            "unknown chain stage {:?} in a {} spec (known stages: {})",
                            st.name,
                            head_set.label(),
                            self.names(CodecSet::Stage).join(", ")
                        )
                    }
                })?;
                (entry.build)(&Self::resolve(entry.name, entry.params, &st.params, seed)?)
            })
            .collect()
    }

    /// Build an index codec (single stage or chain) from a spec.
    pub fn build_index(&self, spec: &CodecSpec, seed: u64) -> anyhow::Result<Box<dyn IndexCodec>> {
        let head_spec = spec.head();
        let entry = Self::find(&self.index, &head_spec.name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown index codec {} (known: {})",
                head_spec.name,
                self.names(CodecSet::Index).join(", ")
            )
        })?;
        let head = (entry.build)(&Self::resolve(entry.name, entry.params, &head_spec.params, seed)?)?;
        let stages = self.build_stages(&spec.stages[1..], CodecSet::Index, seed)?;
        // chains AND parameterized single stages wrap so that `name()`
        // reports the full spec label — what the self-describing
        // container header and `SegmentCodec::duplicate` rely on
        Ok(if stages.is_empty() && head_spec.params.is_empty() {
            head
        } else {
            Box::new(IndexChain::new(head, stages, spec.label()))
        })
    }

    /// Build a value codec (single stage or chain) from a spec.
    pub fn build_value(&self, spec: &CodecSpec, seed: u64) -> anyhow::Result<Box<dyn ValueCodec>> {
        let head_spec = spec.head();
        let entry = Self::find(&self.value, &head_spec.name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown value codec {} (known: {})",
                head_spec.name,
                self.names(CodecSet::Value).join(", ")
            )
        })?;
        let head = (entry.build)(&Self::resolve(entry.name, entry.params, &head_spec.params, seed)?)?;
        let stages = self.build_stages(&spec.stages[1..], CodecSet::Value, seed)?;
        Ok(if stages.is_empty() && head_spec.params.is_empty() {
            head
        } else {
            Box::new(ValueChain::new(head, stages, spec.label()))
        })
    }

    /// The autotuner's default candidate specs: lossless singles, every
    /// lossless *index* single × autotune byte stage as a two-stage
    /// chain (value chains are skipped — a byte stage over raw values
    /// duplicates the deflate/zstd value codecs), then (under error
    /// feedback, which compensates their loss) the lossy candidates.
    /// Enumerated from entry flags — adding a registered codec with
    /// `autotune: true` puts it in front of the policy without
    /// touching the trainer.
    pub fn autotune_candidates(&self, error_feedback: bool) -> (Vec<String>, Vec<String>) {
        let stages: Vec<&str> =
            self.stage.iter().filter(|e| e.autotune).map(|e| e.name).collect();
        let mut idx: Vec<String> = self
            .index
            .iter()
            .filter(|e| e.autotune && e.lossless)
            .map(|e| e.name.to_string())
            .collect();
        let singles = idx.clone();
        for s in &singles {
            for st in &stages {
                idx.push(format!("{s}+{st}"));
            }
        }
        if error_feedback {
            idx.extend(
                self.index
                    .iter()
                    .filter(|e| e.autotune && !e.lossless)
                    .map(|e| e.name.to_string()),
            );
        }
        let mut val: Vec<String> = self
            .value
            .iter()
            .filter(|e| e.autotune && e.lossless)
            .map(|e| e.name.to_string())
            .collect();
        if error_feedback {
            val.extend(
                self.value
                    .iter()
                    .filter(|e| e.autotune && !e.lossless)
                    .map(|e| e.name.to_string()),
            );
        }
        (idx, val)
    }

    /// Back-compat shim for the legacy single-`f64` parameter (`--fpr`,
    /// `--value-param`): writes it onto the head stage's declared
    /// legacy key, unless the spec already sets that key explicitly.
    /// NaN / non-positive values keep the old "use the default"
    /// behaviour; codecs without a legacy key ignore it, exactly like
    /// the old factories did.
    pub fn apply_legacy_param(&self, set: CodecSet, spec: &mut CodecSpec, param: f64) {
        if !param.is_finite() || param <= 0.0 {
            return;
        }
        let head_name = spec.head().name.clone();
        let (key, kind) = match set {
            CodecSet::Index => match Self::find(&self.index, &head_name) {
                Some(e) => match e.legacy_param {
                    Some(k) => (k, e.params.iter().find(|p| p.key == k).map(|p| p.kind)),
                    None => return,
                },
                None => return,
            },
            CodecSet::Value => match Self::find(&self.value, &head_name) {
                Some(e) => match e.legacy_param {
                    Some(k) => (k, e.params.iter().find(|p| p.key == k).map(|p| p.kind)),
                    None => return,
                },
                None => return,
            },
            CodecSet::Stage => return,
        };
        let head = &mut spec.stages[0];
        if head.params.iter().any(|(k, _)| k == key) {
            return;
        }
        match kind {
            Some(ParamKind::Float) => head.set_param(key, param),
            // the old factories truncated (`param as u32`)
            Some(ParamKind::Int) => head.set_param(key, param as i64),
            _ => {}
        }
    }

    /// All entries as display rows for the `list-codecs` table.
    pub fn rows(&self) -> Vec<CodecRow> {
        fn fmt_params(schema: &[ParamSpec]) -> String {
            if schema.is_empty() {
                "-".to_string()
            } else {
                schema
                    .iter()
                    .map(|p| format!("{}:{}={}", p.key, p.kind.label(), p.default.render()))
                    .collect::<Vec<_>>()
                    .join(" ")
            }
        }
        let mut rows = Vec::new();
        for e in &self.index {
            rows.push(CodecRow {
                name: e.name.to_string(),
                set: CodecSet::Index.label(),
                params: fmt_params(e.params),
                lossless: e.lossless,
                chainable: false,
            });
        }
        for e in &self.value {
            rows.push(CodecRow {
                name: e.name.to_string(),
                set: CodecSet::Value.label(),
                params: fmt_params(e.params),
                lossless: e.lossless,
                chainable: false,
            });
        }
        for e in &self.stage {
            rows.push(CodecRow {
                name: e.name.to_string(),
                set: CodecSet::Stage.label(),
                params: fmt_params(e.params),
                lossless: e.lossless,
                chainable: true,
            });
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CodecSpec;

    fn reg() -> CodecRegistry {
        CodecRegistry::builtin()
    }

    #[test]
    fn builds_singles_chains_and_aliases() {
        let r = reg();
        for name in ["raw", "keys", "bitmap", "rle", "huffman", "delta", "elias_gamma", "bloom_p2"] {
            let c = r.build_index(&CodecSpec::parse(name).unwrap(), 1).unwrap();
            assert!(!c.name().is_empty(), "{name}");
        }
        for name in ["raw", "none", "fp32", "fp16", "deflate", "zstd", "qsgd", "fitpoly"] {
            r.build_value(&CodecSpec::parse(name).unwrap(), 1).unwrap();
        }
        let chain = r.build_index(&CodecSpec::parse("rle+deflate").unwrap(), 1).unwrap();
        assert_eq!(chain.name(), "rle+deflate");
        assert!(chain.lossless());
        let lossy = r.build_index(&CodecSpec::parse("bloom_p2(fpr=0.01)+zstd").unwrap(), 1).unwrap();
        assert!(!lossy.lossless());
        // chain roundtrip through the built object
        let support: Vec<u32> = (10..200).collect();
        let enc = chain.encode(4096, &support);
        assert_eq!(chain.decode(4096, &enc.bytes).unwrap(), support);
    }

    #[test]
    fn unknown_codecs_name_the_known_set() {
        let r = reg();
        let e = r.build_index(&CodecSpec::parse("nope").unwrap(), 0).unwrap_err();
        assert!(e.to_string().contains("unknown index codec"), "{e}");
        assert!(e.to_string().contains("rle"), "{e}");
        let e = r.build_value(&CodecSpec::parse("nope").unwrap(), 0).unwrap_err();
        assert!(e.to_string().contains("unknown value codec"), "{e}");
    }

    #[test]
    fn undeclared_parameter_is_a_hard_error_naming_valid_keys() {
        let r = reg();
        // rle takes no parameters
        let e = r.build_index(&CodecSpec::parse("rle(fpr=0.1)").unwrap(), 0).unwrap_err();
        assert!(e.to_string().contains("does not declare parameter"), "{e}");
        assert!(e.to_string().contains("no parameters"), "{e}");
        // bloom_p2 declares fpr, not bits — the error names the valid keys
        let e = r.build_index(&CodecSpec::parse("bloom_p2(bits=3)").unwrap(), 0).unwrap_err();
        assert!(e.to_string().contains("valid keys: fpr:float"), "{e}");
        // same on the value side and inside chain tails
        let e = r.build_value(&CodecSpec::parse("qsgd(fpr=0.1)").unwrap(), 0).unwrap_err();
        assert!(e.to_string().contains("valid keys: bits:int, bucket:int"), "{e}");
        let e = r.build_index(&CodecSpec::parse("rle+deflate(window=9)").unwrap(), 0).unwrap_err();
        assert!(e.to_string().contains("valid keys: level:int"), "{e}");
    }

    #[test]
    fn parameters_are_typed_and_range_checked() {
        let r = reg();
        assert!(r.build_index(&CodecSpec::parse("bloom_p2(fpr=0.01)").unwrap(), 0).is_ok());
        assert!(r.build_index(&CodecSpec::parse("bloom_p2(fpr=2.0)").unwrap(), 0).is_err());
        assert!(r.build_index(&CodecSpec::parse("bloom_p2(fpr=abc)").unwrap(), 0).is_err());
        assert!(r.build_value(&CodecSpec::parse("qsgd(bits=6)").unwrap(), 0).is_ok());
        assert!(r.build_value(&CodecSpec::parse("qsgd(bits=99)").unwrap(), 0).is_err());
        assert!(r.build_value(&CodecSpec::parse("qsgd(bits=6.5)").unwrap(), 0).is_err());
        assert!(r.build_value(&CodecSpec::parse("deflate(level=12)").unwrap(), 0).is_err());
    }

    #[test]
    fn head_codecs_cannot_appear_mid_chain() {
        let r = reg();
        let e = r.build_index(&CodecSpec::parse("rle+bitmap").unwrap(), 0).unwrap_err();
        assert!(e.to_string().contains("may only lead a chain"), "{e}");
        let e = r.build_value(&CodecSpec::parse("raw+qsgd").unwrap(), 0).unwrap_err();
        assert!(e.to_string().contains("may only lead a chain"), "{e}");
        let e = r.build_index(&CodecSpec::parse("rle+nothing").unwrap(), 0).unwrap_err();
        assert!(e.to_string().contains("unknown chain stage"), "{e}");
    }

    #[test]
    fn autotune_candidates_enumerate_chains_from_the_registry() {
        let r = reg();
        let (idx, val) = r.autotune_candidates(false);
        for want in ["raw", "rle", "elias", "bitmap", "rle+deflate", "elias+deflate"] {
            assert!(idx.iter().any(|s| s == want), "missing index candidate {want}: {idx:?}");
        }
        assert!(!idx.iter().any(|s| s.contains("bloom")), "lossy candidate without EF");
        assert!(val.contains(&"raw".to_string()) && val.contains(&"deflate".to_string()));
        let (idx_ef, val_ef) = r.autotune_candidates(true);
        assert!(idx_ef.contains(&"bloom_p2".to_string()));
        assert!(val_ef.contains(&"qsgd".to_string()) && val_ef.contains(&"fitpoly".to_string()));
        // every candidate builds
        for spec in idx_ef.iter() {
            r.build_index(&CodecSpec::parse(spec).unwrap(), 3).unwrap();
        }
        for spec in val_ef.iter() {
            r.build_value(&CodecSpec::parse(spec).unwrap(), 3).unwrap();
        }
    }

    #[test]
    fn legacy_param_shim_matches_old_factories() {
        let r = reg();
        let mut s = CodecSpec::single("bloom_p2");
        r.apply_legacy_param(CodecSet::Index, &mut s, 0.01);
        assert_eq!(s.label(), "bloom_p2(fpr=0.01)");
        // NaN / non-positive -> default, like the old factories
        let mut s = CodecSpec::single("bloom_p2");
        r.apply_legacy_param(CodecSet::Index, &mut s, f64::NAN);
        r.apply_legacy_param(CodecSet::Index, &mut s, 0.0);
        assert_eq!(s.label(), "bloom_p2");
        // explicit spec param wins over the legacy flag
        let mut s = CodecSpec::parse("bloom_p2(fpr=0.5)").unwrap();
        r.apply_legacy_param(CodecSet::Index, &mut s, 0.01);
        assert_eq!(s.label(), "bloom_p2(fpr=0.5)");
        // int legacy params truncate like `param as u32` did
        let mut s = CodecSpec::single("qsgd");
        r.apply_legacy_param(CodecSet::Value, &mut s, 6.9);
        assert_eq!(s.label(), "qsgd(bits=6)");
        // codecs without a legacy key ignore it
        let mut s = CodecSpec::single("rle");
        r.apply_legacy_param(CodecSet::Index, &mut s, 0.5);
        assert_eq!(s.label(), "rle");
    }

    #[test]
    fn rows_cover_all_sets() {
        let rows = reg().rows();
        assert!(rows.iter().any(|r| r.name == "rle" && r.set == "index" && !r.chainable));
        assert!(rows.iter().any(|r| r.name == "qsgd" && r.set == "value" && r.params.contains("bits:int=7")));
        assert!(rows.iter().any(|r| r.name == "deflate" && r.set == "stage" && r.chainable));
        let bloom = rows.iter().find(|r| r.name == "bloom_p2").unwrap();
        assert!(!bloom.lossless && bloom.params.contains("fpr:float=0.001"));
    }

    #[test]
    fn registry_is_extensible() {
        let mut r = reg();
        r.register_index(CodecEntry::new(
            "mirror",
            &[],
            true,
            false,
            None,
            &[],
            Box::new(|_| Ok(Box::new(crate::compress::index::RawIndex))),
        ));
        let c = r.build_index(&CodecSpec::parse("mirror+deflate").unwrap(), 0).unwrap();
        assert_eq!(c.name(), "mirror+deflate");
        assert!(r.names(CodecSet::Index).contains(&"mirror"));
    }
}
