//! Bucketing: fuse the per-step tensor list into size-capped buckets.
//!
//! Production stacks (Horovod, DDP, SparCML's stream fusion) do not move
//! gradients one tensor at a time: small tensors are fused into buckets
//! so per-message latency (α) amortizes, and large messages pipeline.
//! A [`Bucket`] is a *fused index domain*: member tensors are laid
//! end-to-end, so the bucket's sparse payload is one [`SparseTensor`]
//! over `[0, total_elems)` and travels through the collective schedules
//! as a single segment stream.

use crate::tensor::SparseTensor;

/// One fused bucket: which tensors it carries and where each one starts
/// in the fused domain.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bucket {
    /// caller-side tensor ids (indices into the trainer's tensor list)
    pub tensors: Vec<usize>,
    /// element offset of each member within the fused domain (aligned
    /// with `tensors`)
    pub offsets: Vec<usize>,
    /// element count of each member (aligned with `tensors`)
    pub sizes: Vec<usize>,
    /// fused dense domain = Σ sizes
    pub total_elems: usize,
}

impl Bucket {
    /// Position of tensor id `ti` within this bucket, if present.
    pub fn slot_of(&self, ti: usize) -> Option<usize> {
        self.tensors.iter().position(|&t| t == ti)
    }
}

/// The step-invariant bucket assignment: tensor shapes do not change
/// across steps, so the plan is computed once at trainer construction.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BucketPlan {
    pub buckets: Vec<Bucket>,
}

impl BucketPlan {
    /// Greedy size-capped fusion in tensor order. `members` is the list
    /// of (tensor id, element count) to fuse; `bucket_bytes` caps each
    /// bucket at `bucket_bytes / 4` elements (fp32). `bucket_bytes == 0`
    /// means *no fusion*: one bucket per tensor (the legacy per-tensor
    /// path). A tensor larger than the cap gets a bucket of its own —
    /// tensors are never split.
    pub fn plan(members: &[(usize, usize)], bucket_bytes: usize) -> Self {
        let empty = || Bucket {
            tensors: Vec::new(),
            offsets: Vec::new(),
            sizes: Vec::new(),
            total_elems: 0,
        };
        let cap_elems = bucket_bytes / 4;
        let mut buckets = Vec::new();
        let mut cur = empty();
        for &(ti, sz) in members {
            let fits =
                cap_elems > 0 && !cur.tensors.is_empty() && cur.total_elems + sz <= cap_elems;
            if !cur.tensors.is_empty() && !fits {
                buckets.push(std::mem::replace(&mut cur, empty()));
            }
            cur.tensors.push(ti);
            cur.offsets.push(cur.total_elems);
            cur.sizes.push(sz);
            cur.total_elems += sz;
        }
        if !cur.tensors.is_empty() {
            buckets.push(cur);
        }
        Self { buckets }
    }

    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }
}

/// Fuse per-tensor sparse payloads into one sparse tensor over the
/// bucket's fused domain. `parts[j]` is the payload of `bucket.tensors[j]`
/// over its own dense domain (`dense_len == bucket.sizes[j]`); indices
/// are rebased by `bucket.offsets[j]` and concatenated — offsets are
/// ascending, so the fused support stays sorted.
pub fn fuse(bucket: &Bucket, parts: &[&SparseTensor]) -> SparseTensor {
    assert_eq!(parts.len(), bucket.tensors.len(), "fuse arity mismatch");
    let nnz: usize = parts.iter().map(|p| p.nnz()).sum();
    let mut idx = Vec::with_capacity(nnz);
    let mut val = Vec::with_capacity(nnz);
    for (j, part) in parts.iter().enumerate() {
        assert_eq!(
            part.dense_len(),
            bucket.sizes[j],
            "fuse: tensor {} domain mismatch",
            bucket.tensors[j]
        );
        let off = bucket.offsets[j] as u32;
        idx.extend(part.indices().iter().map(|&i| i + off));
        val.extend_from_slice(part.values());
    }
    SparseTensor::new(bucket.total_elems, idx, val)
}

/// Split a fused-domain sparse tensor back into one sparse tensor per
/// member, indices rebased to each member's own domain. Inverse of
/// [`fuse`] for payloads that respect the bucket layout.
pub fn unfuse(bucket: &Bucket, fused: &SparseTensor) -> Vec<SparseTensor> {
    assert_eq!(fused.dense_len(), bucket.total_elems, "unfuse domain mismatch");
    let idx = fused.indices();
    let mut out = Vec::with_capacity(bucket.tensors.len());
    for j in 0..bucket.tensors.len() {
        let (lo, hi) = (bucket.offsets[j], bucket.offsets[j] + bucket.sizes[j]);
        let a = idx.partition_point(|&i| (i as usize) < lo);
        let b = idx.partition_point(|&i| (i as usize) < hi);
        let local: Vec<u32> = idx[a..b].iter().map(|&i| i - lo as u32).collect();
        out.push(SparseTensor::new(bucket.sizes[j], local, fused.values()[a..b].to_vec()));
    }
    out
}

/// Concatenate per-member dense slices into the fused dense domain
/// (the reference gradient Bloom policies read at FP positions).
pub fn fuse_dense(bucket: &Bucket, parts: &[&[f32]]) -> Vec<f32> {
    assert_eq!(parts.len(), bucket.tensors.len(), "fuse_dense arity mismatch");
    let mut out = Vec::with_capacity(bucket.total_elems);
    for (j, part) in parts.iter().enumerate() {
        assert_eq!(part.len(), bucket.sizes[j], "fuse_dense: slice {j} size mismatch");
        out.extend_from_slice(part);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st(d: usize, iv: &[(u32, f32)]) -> SparseTensor {
        SparseTensor::new(d, iv.iter().map(|&(i, _)| i).collect(), iv.iter().map(|&(_, v)| v).collect())
    }

    #[test]
    fn zero_cap_means_one_bucket_per_tensor() {
        let plan = BucketPlan::plan(&[(0, 100), (2, 50), (5, 9000)], 0);
        assert_eq!(plan.len(), 3);
        let want = [(0usize, 100usize), (2, 50), (5, 9000)];
        for (b, &(ti, sz)) in plan.buckets.iter().zip(&want) {
            assert_eq!(b.tensors, vec![ti]);
            assert_eq!(b.offsets, vec![0]);
            assert_eq!(b.total_elems, sz);
        }
    }

    #[test]
    fn greedy_fusion_respects_cap() {
        // cap = 256 bytes = 64 elems
        let plan = BucketPlan::plan(&[(0, 30), (1, 30), (2, 30), (3, 200), (4, 10)], 256);
        assert_eq!(plan.len(), 4);
        assert_eq!(plan.buckets[0].tensors, vec![0, 1]);
        assert_eq!(plan.buckets[0].offsets, vec![0, 30]);
        assert_eq!(plan.buckets[0].total_elems, 60);
        assert_eq!(plan.buckets[1].tensors, vec![2]); // 60+30 > 64 would overflow with 3rd
        // oversized tensor gets its own bucket, never split
        assert_eq!(plan.buckets[2].tensors, vec![3]);
        assert_eq!(plan.buckets[2].total_elems, 200);
        assert_eq!(plan.buckets[3].tensors, vec![4]);
    }

    #[test]
    fn fuse_unfuse_roundtrip() {
        let plan = BucketPlan::plan(&[(7, 10), (9, 6)], 1 << 20);
        assert_eq!(plan.len(), 1);
        let b = &plan.buckets[0];
        let t0 = st(10, &[(1, 1.0), (9, -2.0)]);
        let t1 = st(6, &[(0, 3.0), (5, 4.0)]);
        let fused = fuse(b, &[&t0, &t1]);
        assert_eq!(fused.dense_len(), 16);
        assert_eq!(fused.indices(), &[1, 9, 10, 15]);
        assert_eq!(fused.values(), &[1.0, -2.0, 3.0, 4.0]);
        let parts = unfuse(b, &fused);
        assert_eq!(parts, vec![t0, t1]);
    }

    #[test]
    fn unfuse_handles_empty_members() {
        let plan = BucketPlan::plan(&[(0, 4), (1, 4), (2, 4)], 1 << 20);
        let b = &plan.buckets[0];
        let t0 = st(4, &[]);
        let t1 = st(4, &[(2, 5.0)]);
        let t2 = st(4, &[]);
        let fused = fuse(b, &[&t0, &t1, &t2]);
        assert_eq!(fused.indices(), &[6]);
        let parts = unfuse(b, &fused);
        assert_eq!(parts[0].nnz(), 0);
        assert_eq!(parts[1], t1);
        assert_eq!(parts[2].nnz(), 0);
    }

    #[test]
    fn fuse_dense_concatenates() {
        let plan = BucketPlan::plan(&[(0, 2), (1, 3)], 1 << 20);
        let b = &plan.buckets[0];
        let out = fuse_dense(b, &[&[1.0, 2.0], &[3.0, 4.0, 5.0]]);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn empty_plan() {
        let plan = BucketPlan::plan(&[], 1024);
        assert!(plan.is_empty());
    }
}
