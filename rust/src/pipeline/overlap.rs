//! Overlap: double-buffered encode/ship execution and the step-time
//! timeline that makes the overlap win visible in simnet accounting.
//!
//! On this single-machine testbed the fabric is in-process, so wall
//! clock cannot show a network-overlap win directly; instead the
//! trainer records, per bucket, the *measured* encode seconds and the
//! α–β *modelled* transfer seconds, and [`StepTimeline`] folds them
//! with `simnet::{serial_step_time, pipelined_step_time}`. Those
//! modelled numbers are what the trainer metrics and the
//! `pipeline_scaling` bench report. [`double_buffered`] is the
//! matching executor building block — encode of bucket *i+1* on a
//! second thread while bucket *i* ships through a one-slot hand-off —
//! exercised by the unit tests below and ready for the trainer once
//! its per-worker state moves onto worker threads; the modelled
//! pipeline time is the standard unbounded-lookahead lower bound, so
//! for strongly encode-skewed bucket mixes the one-slot executor can
//! lag it slightly.

use crate::simnet;

/// Per-step pipeline accounting: one `(encode_s, comm_s)` stage per
/// bucket, in ship order.
#[derive(Clone, Debug, Default)]
pub struct StepTimeline {
    stages: Vec<(f64, f64)>,
}

impl StepTimeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, encode_s: f64, comm_s: f64) {
        self.stages.push((encode_s, comm_s));
    }

    pub fn stages(&self) -> &[(f64, f64)] {
        &self.stages
    }

    /// Step time with no overlap: encode then ship, bucket by bucket.
    pub fn serial_s(&self) -> f64 {
        simnet::serial_step_time(&self.stages)
    }

    /// Step time with double-buffered overlap.
    pub fn pipelined_s(&self) -> f64 {
        simnet::pipelined_step_time(&self.stages)
    }

    /// The overlap win (≥ 0).
    pub fn overlap_saving_s(&self) -> f64 {
        (self.serial_s() - self.pipelined_s()).max(0.0)
    }
}

/// Run `count` items through a two-stage encode→ship pipeline: the
/// encoder thread stays at most `lookahead` items ahead of the shipper,
/// so item *i+1* encodes while item *i* is in flight. This is the
/// chunk-granular streaming state machine the chunked collective
/// schedule runs *inside* a ring step (encode of sub-chunk *i+1*
/// overlapping send/recv/merge of sub-chunk *i*); `double_buffered`
/// below is the one-slot bucket-level specialization.
///
/// The encoder thread re-installs the caller's tracer and redirects its
/// default span lane to [`crate::obs::Lane::Encoder`], so spans opened
/// *inside* the encode closure (the segment codec's `Pack`, merge
/// kernels, …) stay off the shipper's cpu lane and the per-(rank, lane)
/// nesting invariant holds. `ship` runs on the calling thread and is
/// not wrapped in any span — callers own the shipping spans.
///
/// Lockstep: `fleetsim::kernels::ChunkedTask` replays the ship-side
/// frame order of this pipeline cooperatively (encode inline, same
/// send/recv sequence) — change the frame order here, change it there
/// (DESIGN.md §13).
pub fn streamed<T, E, S>(count: usize, lookahead: usize, encode: E, mut ship: S)
where
    T: Send,
    E: FnMut(usize) -> T + Send,
    S: FnMut(usize, T),
{
    if count == 0 {
        return;
    }
    // hand the caller's tracer binding to the encoder thread so its
    // encode spans land on the same rank's lane
    let trace = crate::obs::scope();
    std::thread::scope(|scope| {
        let (tx, rx) = std::sync::mpsc::sync_channel::<(usize, T)>(lookahead.max(1));
        scope.spawn(move || {
            let _bind = trace.map(|(tracer, rank)| tracer.install(rank));
            // encoder lane: runs concurrently with the shipper's cpu
            // lane by design, so it gets its own nesting tree — and the
            // lane override extends it to spans opened by library code
            // the closure calls into
            let _lane = crate::obs::lane_scope(crate::obs::Lane::Encoder);
            let mut encode = encode;
            for i in 0..count {
                let item = {
                    let mut sp = crate::obs::span_on(
                        crate::obs::SpanKind::Encode,
                        crate::obs::Lane::Encoder,
                    );
                    sp.label_with(|| format!("overlap encode {i}"));
                    encode(i)
                };
                if tx.send((i, item)).is_err() {
                    return; // shipper bailed; nothing left to feed
                }
            }
        });
        for _ in 0..count {
            let (i, item) = rx.recv().expect("encoder thread hung up");
            ship(i, item);
        }
    });
}

/// Run `count` buckets through a two-stage encode→ship pipeline with a
/// one-slot hand-off: the encoder thread stays at most one bucket ahead
/// of the shipper (classic double buffering), so bucket *i+1* encodes
/// while bucket *i* is in flight.
pub fn double_buffered<T, E, S>(count: usize, encode: E, mut ship: S)
where
    T: Send,
    E: FnMut(usize) -> T + Send,
    S: FnMut(usize, T),
{
    streamed(count, 1, encode, |i, item| {
        let mut sp = crate::obs::span(crate::obs::SpanKind::Send);
        sp.label_with(|| format!("overlap ship {i}"));
        ship(i, item);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeline_accounting() {
        let mut t = StepTimeline::new();
        t.push(1.0, 10.0);
        t.push(1.0, 10.0);
        t.push(1.0, 10.0);
        assert_eq!(t.serial_s(), 33.0);
        assert_eq!(t.pipelined_s(), 31.0);
        assert_eq!(t.overlap_saving_s(), 2.0);
        assert_eq!(t.stages().len(), 3);
        assert_eq!(StepTimeline::new().serial_s(), 0.0);
    }

    #[test]
    fn double_buffered_preserves_order_and_runs_all() {
        let mut shipped = Vec::new();
        double_buffered(
            10,
            |i| i * i,
            |i, v| {
                assert_eq!(v, i * i);
                shipped.push(i);
            },
        );
        assert_eq!(shipped, (0..10).collect::<Vec<_>>());
        // empty pipeline is a no-op
        double_buffered(0, |_| 0u8, |_, _| panic!("nothing to ship"));
    }

    #[test]
    fn streamed_lookahead_preserves_order() {
        for lookahead in [1usize, 2, 4, 16] {
            let mut shipped = Vec::new();
            streamed(
                7,
                lookahead,
                |i| i + 100,
                |i, v| {
                    assert_eq!(v, i + 100);
                    shipped.push(i);
                },
            );
            assert_eq!(shipped, (0..7).collect::<Vec<_>>(), "lookahead {lookahead}");
        }
        streamed(0, 3, |_| 0u8, |_, _| panic!("nothing to ship"));
    }

    #[test]
    fn double_buffered_actually_overlaps() {
        // encoder sleeps 5ms per item, shipper 5ms per item; serial
        // would be 60ms for 6 items — overlapped must land well under
        use std::time::{Duration, Instant};
        let t0 = Instant::now();
        double_buffered(
            6,
            |i| {
                std::thread::sleep(Duration::from_millis(5));
                i
            },
            |_, _| std::thread::sleep(Duration::from_millis(5)),
        );
        let dt = t0.elapsed();
        assert!(dt < Duration::from_millis(55), "no overlap: {dt:?}");
    }
}
