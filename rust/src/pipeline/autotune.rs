//! Cost-model codec autotuning: per bucket, pick the index/value codec
//! pair that minimizes modelled step time.
//!
//! The paper frames DeepReduce as a *versatile* framework — any index
//! codec composes with any value codec — but leaves the choice static.
//! This module closes the loop: at startup every candidate codec is
//! **calibrated** (wire bytes and encode seconds per element, measured
//! on synthetic gradient-like data across a density ladder), and per
//! bucket the policy combines
//!
//!   1. the bucket's *measured density* (nnz / fused domain),
//!   2. interpolated per-codec byte and throughput estimates, and
//!   3. the simnet α–β link model (`allgather_time` on the estimated
//!      container volume — the paper's topology-oblivious exchange)
//!
//! into `cost = encode_s + comm_s` and picks the argmin pair. With
//! `--autotune off` the trainer keeps the static `CompressionSpec`
//! codecs unchanged.

use crate::compress::{build_index_spec, build_value_spec};
use crate::simnet::{allgather_time, Link};
use crate::tensor::SparseTensor;
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::util::testkit::{gradient_like, sorted_support};
use std::collections::BTreeMap;
use std::time::Instant;

/// Density ladder the calibrator samples; estimates interpolate
/// piecewise-linearly between rungs (clamped at the ends).
pub const CAL_DENSITIES: [f64; 6] = [0.001, 0.01, 0.05, 0.2, 0.5, 1.0];

/// Calibration domain size: large enough that per-call overhead
/// amortizes, small enough that startup stays in the low milliseconds.
const CAL_DOMAIN: usize = 8192;

/// One codec pair the policy may pick. Both sides are full codec
/// *spec* labels — a single name (`rle`), or a chain (`rle+deflate`) —
/// resolvable through the registry.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct CodecChoice {
    pub index: String,
    pub value: String,
}

impl CodecChoice {
    pub fn label(&self) -> String {
        format!("{}|{}", self.index, self.value)
    }
}

/// The per-hop picks of a hierarchical exchange
/// ([`CodecPolicy::choose_hierarchical`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HierChoices {
    /// member → node-leader hop (fast intra link, member density)
    pub leader: CodecChoice,
    /// leader → leader hop (slow inter link, node-sum density);
    /// `None` on single-node grids, where that hop never runs
    pub inter: Option<CodecChoice>,
}

/// Calibrated behaviour of one index codec: wire bytes and encode
/// seconds per *domain element* at each rung of [`CAL_DENSITIES`].
/// Per-domain (not per-entry) rates make entry-proportional codecs
/// (raw, elias) and domain-proportional ones (bitmap, rle) share one
/// model: at density p the raw codec's rate is 4p B/elem while the
/// bitmap's is a flat 1/8 B/elem.
#[derive(Clone, Debug)]
pub struct IndexProfile {
    pub name: String,
    pub bytes_per_elem: [f64; CAL_DENSITIES.len()],
    pub secs_per_elem: [f64; CAL_DENSITIES.len()],
}

/// Calibrated behaviour of one value codec (density-independent: value
/// codecs see only the gathered value array).
#[derive(Clone, Debug)]
pub struct ValueProfile {
    pub name: String,
    pub bytes_per_value: f64,
    pub secs_per_value: f64,
    /// codec reorders values — the container then carries a bit-packed
    /// permutation at ⌈log₂ n⌉ bits per value
    pub has_perm: bool,
}

/// Clamped piecewise-linear interpolation over the density ladder.
fn interp(ys: &[f64; CAL_DENSITIES.len()], p: f64) -> f64 {
    let xs = &CAL_DENSITIES;
    if p <= xs[0] {
        return ys[0];
    }
    for i in 1..xs.len() {
        if p <= xs[i] {
            let t = (p - xs[i - 1]) / (xs[i] - xs[i - 1]);
            return ys[i - 1] + t * (ys[i] - ys[i - 1]);
        }
    }
    ys[ys.len() - 1]
}

fn ceil_log2(n: usize) -> u32 {
    if n <= 1 {
        1
    } else {
        usize::BITS - (n - 1).leading_zeros()
    }
}

/// Where the comm term of the per-bucket cost comes from
/// (CLI `--autotune-cost`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CostSource {
    /// the α–β closed form (`simnet::allgather_time`) — available from
    /// step 0, blind to contention and stragglers
    #[default]
    Formula,
    /// measured virtual exchange time fed back by the trainer
    /// ([`CodecPolicy::observe_comm`]): an EMA of seconds per
    /// per-worker container byte on the virtual-time fabric. Falls
    /// back to the formula until the first observation arrives.
    Measured,
}

impl CostSource {
    pub fn parse(name: &str) -> Option<Self> {
        Some(match name {
            "formula" | "model" | "alpha_beta" => CostSource::Formula,
            "measured" | "vfabric" => CostSource::Measured,
            _ => return None,
        })
    }
}

/// The per-bucket codec selector.
pub struct CodecPolicy {
    pub index_profiles: Vec<IndexProfile>,
    pub value_profiles: Vec<ValueProfile>,
    /// modelled link the α–β comm cost uses
    pub link: Link,
    /// world size the α–β comm cost uses
    pub workers: usize,
    /// where the comm term comes from (formula vs measured feedback)
    pub cost_source: CostSource,
    /// EMA of measured exchange seconds per per-worker container byte
    /// (None until the first [`CodecPolicy::observe_comm`])
    measured_secs_per_byte: Option<f64>,
}

/// The candidate codec specs the trainer autotunes over, enumerated
/// from the [`CodecRegistry`](crate::compress::CodecRegistry): every
/// `autotune`-flagged lossless *index* codec, each of those chained
/// with every `autotune`-flagged byte stage (`rle+deflate`,
/// `elias+deflate`, ...), the `autotune`-flagged lossless value
/// singles (value chains are skipped: a byte stage over raw values is
/// exactly the deflate/zstd value codec), and — only when error
/// feedback is on to compensate their loss — the lossy candidates
/// (Bloom support, QSGD / curve-fit values).
pub fn default_candidates(error_feedback: bool) -> (Vec<String>, Vec<String>) {
    crate::compress::CodecRegistry::global().autotune_candidates(error_feedback)
}

impl CodecPolicy {
    /// Calibrate every candidate at startup: encode synthetic
    /// gradient-like tensors at each density rung, recording wire bytes
    /// and wall-clock encode throughput. Candidates are codec *specs* —
    /// chains like `rle+deflate` calibrate exactly like single codecs.
    pub fn calibrate<I: AsRef<str>, V: AsRef<str>>(
        index_specs: &[I],
        value_specs: &[V],
        seed: u64,
        link: Link,
        workers: usize,
    ) -> Self {
        Self::build(index_specs, value_specs, seed, link, workers, true)
    }

    /// Calibrate byte rates only, zeroing throughput terms — choices
    /// then depend solely on the (deterministic) byte estimates and the
    /// α–β model. For tests and benches that need reproducible picks.
    pub fn calibrate_bytes_only<I: AsRef<str>, V: AsRef<str>>(
        index_specs: &[I],
        value_specs: &[V],
        seed: u64,
        link: Link,
        workers: usize,
    ) -> Self {
        Self::build(index_specs, value_specs, seed, link, workers, false)
    }

    fn build<I: AsRef<str>, V: AsRef<str>>(
        index_specs: &[I],
        value_specs: &[V],
        seed: u64,
        link: Link,
        workers: usize,
        measure: bool,
    ) -> Self {
        let d = CAL_DOMAIN;
        let mut rng = Rng::new(seed ^ 0xCA11_B8A7E);
        let mut index_profiles = Vec::with_capacity(index_specs.len());
        for name in index_specs {
            let name = name.as_ref();
            let codec = build_index_spec(name, f64::NAN, seed)
                .unwrap_or_else(|e| panic!("bad index codec candidate {name}: {e}"));
            let mut bytes_per_elem = [0.0; CAL_DENSITIES.len()];
            let mut secs_per_elem = [0.0; CAL_DENSITIES.len()];
            for (i, &p) in CAL_DENSITIES.iter().enumerate() {
                let r = ((d as f64 * p).round() as usize).clamp(1, d);
                let support = sorted_support(&mut rng, d, r);
                let t0 = Instant::now();
                let enc = codec.encode(d, &support);
                let dt = t0.elapsed().as_secs_f64();
                bytes_per_elem[i] = enc.bytes.len() as f64 / d as f64;
                secs_per_elem[i] = if measure { dt / d as f64 } else { 0.0 };
            }
            index_profiles.push(IndexProfile {
                name: name.to_string(),
                bytes_per_elem,
                secs_per_elem,
            });
        }
        let n_cal = CAL_DOMAIN / 2;
        let values = gradient_like(&mut rng, n_cal);
        let mut value_profiles = Vec::with_capacity(value_specs.len());
        for name in value_specs {
            let name = name.as_ref();
            let codec = build_value_spec(name, f64::NAN, seed)
                .unwrap_or_else(|e| panic!("bad value codec candidate {name}: {e}"));
            let t0 = Instant::now();
            let enc = codec.encode(&values);
            let dt = t0.elapsed().as_secs_f64();
            value_profiles.push(ValueProfile {
                name: name.to_string(),
                bytes_per_value: enc.bytes.len() as f64 / n_cal as f64,
                secs_per_value: if measure { dt / n_cal as f64 } else { 0.0 },
                has_perm: enc.perm.is_some(),
            });
        }
        Self {
            index_profiles,
            value_profiles,
            link,
            workers,
            cost_source: CostSource::Formula,
            measured_secs_per_byte: None,
        }
    }

    /// Switch the comm term between the α–β formula and measured
    /// virtual-time feedback.
    pub fn set_cost_source(&mut self, source: CostSource) {
        self.cost_source = source;
    }

    /// Feed back one measured exchange: `bytes` is the per-worker
    /// container volume of a step and `secs` the measured virtual time
    /// its collective took. Maintains an EMA (weight 0.3 on the new
    /// sample) of seconds per byte; only consulted when the cost source
    /// is [`CostSource::Measured`].
    pub fn observe_comm(&mut self, bytes: f64, secs: f64) {
        if !bytes.is_finite() || bytes <= 0.0 || !secs.is_finite() || secs < 0.0 {
            return;
        }
        let rate = secs / bytes;
        self.measured_secs_per_byte = Some(match self.measured_secs_per_byte {
            None => rate,
            Some(old) => 0.7 * old + 0.3 * rate,
        });
    }

    /// Estimated container wire bytes for one (index, value) pair on a
    /// bucket of domain `d` with `nnz` surviving entries.
    pub fn estimate_bytes(
        &self,
        ip: &IndexProfile,
        vp: &ValueProfile,
        d: usize,
        nnz: usize,
    ) -> f64 {
        let p = if d == 0 { 0.0 } else { nnz as f64 / d as f64 };
        let idx = interp(&ip.bytes_per_elem, p) * d as f64;
        let val = vp.bytes_per_value * nnz as f64;
        let perm = if vp.has_perm {
            (nnz as f64 * ceil_log2(nnz.max(1)) as f64) / 8.0 + 2.0
        } else {
            0.0
        };
        32.0 + idx + val + perm // 32 ≈ container magic/names/lengths/crc
    }

    /// Estimated encode seconds for one pair on the same bucket.
    pub fn estimate_encode_s(
        &self,
        ip: &IndexProfile,
        vp: &ValueProfile,
        d: usize,
        nnz: usize,
    ) -> f64 {
        let p = if d == 0 { 0.0 } else { nnz as f64 / d as f64 };
        interp(&ip.secs_per_elem, p) * d as f64 + vp.secs_per_value * nnz as f64
    }

    /// Cost of shipping `bytes` through the exchange on the configured
    /// link/world: the α–β closed form, or — under
    /// [`CostSource::Measured`] with at least one observation — the
    /// measured rate times the bytes.
    pub fn comm_s(&self, bytes: f64) -> f64 {
        self.comm_s_for(bytes, self.workers, self.link)
    }

    fn comm_s_for(&self, bytes: f64, workers: usize, link: Link) -> f64 {
        match (self.cost_source, self.measured_secs_per_byte) {
            (CostSource::Measured, Some(rate)) => rate * bytes.max(0.0),
            _ => allgather_time(bytes.max(0.0) as u64, workers, link),
        }
    }

    /// Pick the pair minimizing `encode_s + comm_s` for a bucket with
    /// measured density `nnz / d`. Deterministic tie-break: candidate
    /// order.
    pub fn choose(&self, d: usize, nnz: usize) -> CodecChoice {
        self.choose_for(d, nnz, self.workers, self.link)
    }

    /// [`CodecPolicy::choose`] generalized to an explicit hop
    /// environment: `workers` ranks exchanging over `link`. This is how
    /// one calibration serves every hop of a hierarchical exchange —
    /// the hop's world size and link class change the comm term while
    /// the byte/throughput profiles are shared. (Under a measured cost
    /// source the rate already folds in the observed hop mix, so only
    /// the byte estimates differentiate candidates.)
    pub fn choose_for(&self, d: usize, nnz: usize, workers: usize, link: Link) -> CodecChoice {
        let mut best: Option<(f64, CodecChoice)> = None;
        for ip in &self.index_profiles {
            for vp in &self.value_profiles {
                let bytes = self.estimate_bytes(ip, vp, d, nnz);
                let cost = self.estimate_encode_s(ip, vp, d, nnz)
                    + self.comm_s_for(bytes, workers, link);
                if best.as_ref().is_none_or(|(b, _)| cost < *b) {
                    best = Some((cost, CodecChoice { index: ip.name.clone(), value: vp.name.clone() }));
                }
            }
        }
        best.expect("CodecPolicy has no candidates").1
    }

    /// Serialize the calibration state — the per-codec throughput
    /// curves plus the measured-comm EMA — to the JSON fragment
    /// embedded in `PROFILE_*.json` artifacts
    /// (`crate::service::profiles`). The link/world environment is
    /// *not* serialized: a profile is keyed by it externally and
    /// rebound on import, so one calibration can serve any job that
    /// matches the profile key.
    pub fn export_json(&self) -> Json {
        let arr6 =
            |ys: &[f64; CAL_DENSITIES.len()]| Json::Arr(ys.iter().map(|&y| Json::Num(y)).collect());
        let idx = self
            .index_profiles
            .iter()
            .map(|ip| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(ip.name.clone()));
                m.insert("bytes_per_elem".to_string(), arr6(&ip.bytes_per_elem));
                m.insert("secs_per_elem".to_string(), arr6(&ip.secs_per_elem));
                Json::Obj(m)
            })
            .collect();
        let val = self
            .value_profiles
            .iter()
            .map(|vp| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(vp.name.clone()));
                m.insert("bytes_per_value".to_string(), Json::Num(vp.bytes_per_value));
                m.insert("secs_per_value".to_string(), Json::Num(vp.secs_per_value));
                m.insert("has_perm".to_string(), Json::Bool(vp.has_perm));
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert(
            "densities".to_string(),
            Json::Arr(CAL_DENSITIES.iter().map(|&d| Json::Num(d)).collect()),
        );
        m.insert("index_profiles".to_string(), Json::Arr(idx));
        m.insert("value_profiles".to_string(), Json::Arr(val));
        m.insert(
            "measured_secs_per_byte".to_string(),
            match self.measured_secs_per_byte {
                Some(r) => Json::Num(r),
                None => Json::Null,
            },
        );
        Json::Obj(m)
    }

    /// Rebuild a policy from [`CodecPolicy::export_json`] output,
    /// rebound to the importing job's link/world environment. Every
    /// structural mismatch — missing key, wrong ladder arity, ladder
    /// drift against this build's [`CAL_DENSITIES`], non-finite rate,
    /// empty candidate set — is a `String` error, never a panic, so a
    /// corrupted profile artifact surfaces as a structured load failure
    /// and the caller falls back to cold calibration.
    pub fn import_json(v: &Json, link: Link, workers: usize) -> Result<Self, String> {
        fn nums6(v: &Json, what: &str) -> Result<[f64; CAL_DENSITIES.len()], String> {
            let arr = v.as_arr().ok_or_else(|| format!("{what}: expected array"))?;
            if arr.len() != CAL_DENSITIES.len() {
                return Err(format!(
                    "{what}: expected {} rungs, got {}",
                    CAL_DENSITIES.len(),
                    arr.len()
                ));
            }
            let mut out = [0.0; CAL_DENSITIES.len()];
            for (i, e) in arr.iter().enumerate() {
                let x = e.as_f64().ok_or_else(|| format!("{what}[{i}]: expected number"))?;
                if !x.is_finite() || x < 0.0 {
                    return Err(format!("{what}[{i}]: non-finite or negative rate {x}"));
                }
                out[i] = x;
            }
            Ok(out)
        }
        fn rate(v: &Json, what: &str) -> Result<f64, String> {
            let x = v.as_f64().ok_or_else(|| format!("{what}: expected number"))?;
            if !x.is_finite() || x < 0.0 {
                return Err(format!("{what}: non-finite or negative rate {x}"));
            }
            Ok(x)
        }
        let dens = nums6(v.get("densities").ok_or("missing densities")?, "densities")?;
        if dens != CAL_DENSITIES {
            return Err(format!(
                "density ladder {dens:?} does not match this build's {CAL_DENSITIES:?}"
            ));
        }
        let idx_arr = v
            .get("index_profiles")
            .and_then(Json::as_arr)
            .ok_or("missing index_profiles array")?;
        let mut index_profiles = Vec::with_capacity(idx_arr.len());
        for (i, e) in idx_arr.iter().enumerate() {
            let what = format!("index_profiles[{i}]");
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("{what}: missing name"))?;
            if name.is_empty() {
                return Err(format!("{what}: empty codec name"));
            }
            index_profiles.push(IndexProfile {
                name: name.to_string(),
                bytes_per_elem: nums6(
                    e.get("bytes_per_elem").ok_or_else(|| format!("{what}: missing bytes_per_elem"))?,
                    &format!("{what}.bytes_per_elem"),
                )?,
                secs_per_elem: nums6(
                    e.get("secs_per_elem").ok_or_else(|| format!("{what}: missing secs_per_elem"))?,
                    &format!("{what}.secs_per_elem"),
                )?,
            });
        }
        let val_arr = v
            .get("value_profiles")
            .and_then(Json::as_arr)
            .ok_or("missing value_profiles array")?;
        let mut value_profiles = Vec::with_capacity(val_arr.len());
        for (i, e) in val_arr.iter().enumerate() {
            let what = format!("value_profiles[{i}]");
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("{what}: missing name"))?;
            if name.is_empty() {
                return Err(format!("{what}: empty codec name"));
            }
            value_profiles.push(ValueProfile {
                name: name.to_string(),
                bytes_per_value: rate(
                    e.get("bytes_per_value").ok_or_else(|| format!("{what}: missing bytes_per_value"))?,
                    &format!("{what}.bytes_per_value"),
                )?,
                secs_per_value: rate(
                    e.get("secs_per_value").ok_or_else(|| format!("{what}: missing secs_per_value"))?,
                    &format!("{what}.secs_per_value"),
                )?,
                has_perm: e
                    .get("has_perm")
                    .and_then(Json::as_bool)
                    .ok_or_else(|| format!("{what}: missing has_perm"))?,
            });
        }
        if index_profiles.is_empty() || value_profiles.is_empty() {
            return Err("profile has an empty candidate set".to_string());
        }
        let measured_secs_per_byte = match v.get("measured_secs_per_byte") {
            None | Some(Json::Null) => None,
            Some(m) => Some(rate(m, "measured_secs_per_byte")?),
        };
        Ok(Self {
            index_profiles,
            value_profiles,
            link,
            workers,
            cost_source: CostSource::Formula,
            measured_secs_per_byte,
        })
    }

    /// Per-hop codec choices for a two-level exchange over `topo`: the
    /// *leader hop* ships each rank's payload (density `nnz/d`) to the
    /// node leader over the fast intra link, while the *inter hop*
    /// ships node sums — up to `ranks_per_node` times denser — across
    /// the slow boundary. The two hops often want different codecs:
    /// entry-proportional ones (raw, elias) at member density,
    /// domain-proportional ones (bitmap, rle) once the node sum
    /// approaches dense.
    pub fn choose_hierarchical(
        &self,
        d: usize,
        nnz: usize,
        topo: crate::collective::Topology,
        intra: Link,
        inter: Link,
    ) -> HierChoices {
        let node_nnz = (nnz * topo.ranks_per_node).min(d);
        HierChoices {
            leader: self.choose_for(d, nnz, topo.ranks_per_node.max(2), intra),
            // a 1×R grid has no inter-node links: advising a codec for a
            // hop that never runs would mislead the metrics
            inter: (topo.nodes > 1)
                .then(|| self.choose_for(d, node_nnz, topo.nodes, inter)),
        }
    }

    /// Convenience: density of a sparse payload.
    pub fn density_of(t: &SparseTensor) -> f64 {
        crate::collective::sparse::merge::density(t.nnz(), t.dense_len())
    }

    /// Pick the flat collective schedule minimizing the α–β modelled
    /// exchange time for a bucket of domain `d` with `nnz` entries
    /// across `workers` ranks on `link`. Every flat schedule is
    /// enumerated; [`Schedule::ChunkedRescatter`] is additionally swept
    /// over chunk counts `{n, 2n, 4n}` (the streaming-granularity knob:
    /// more chunks pay more α per frame). Returns the winner and its
    /// chunk count (`0` for the non-chunked schedules). Note the lossy
    /// `RingRescatter` competes on its reduced traffic — callers that
    /// need the exact sum should skip it when it wins.
    pub fn choose_schedule(
        &self,
        d: usize,
        nnz: usize,
        workers: usize,
        link: Link,
    ) -> (crate::collective::Schedule, usize) {
        use crate::collective::Schedule;
        use crate::simnet::{chunked_rescatter_time, flat_schedule_time, SegWire};
        let w = SegWire::raw(0.5);
        let mut best = (f64::INFINITY, Schedule::GatherAll, 0usize);
        for sched in Schedule::flat() {
            let chunk_counts: &[usize] = if sched == Schedule::ChunkedRescatter {
                &[workers, 2 * workers, 4 * workers]
            } else {
                &[0]
            };
            for &chunks in chunk_counts {
                let t = if sched == Schedule::ChunkedRescatter {
                    chunked_rescatter_time(nnz as u64, d as u64, workers, chunks, link, w)
                } else {
                    flat_schedule_time(sched, nnz as u64, d as u64, workers, link, w, true)
                };
                if t < best.0 {
                    best = (t, sched, chunks);
                }
            }
        }
        let (_, sched, chunks) = best;
        (sched, chunks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bytes_only_policy() -> CodecPolicy {
        CodecPolicy::calibrate_bytes_only(
            &["raw", "rle", "elias", "bitmap"],
            &["raw", "deflate"],
            7,
            Link::mbps(100.0),
            4,
        )
    }

    #[test]
    fn calibration_profiles_are_sane() {
        let p = bytes_only_policy();
        assert_eq!(p.index_profiles.len(), 4);
        assert_eq!(p.value_profiles.len(), 2);
        let raw = &p.index_profiles[0];
        // raw index: 4 bytes/entry -> rate ≈ 4·density
        for (i, &d) in CAL_DENSITIES.iter().enumerate() {
            let want = 4.0 * d;
            assert!(
                (raw.bytes_per_elem[i] - want).abs() < 0.02 + 0.05 * want,
                "raw rate at density {d}: {} vs {want}",
                raw.bytes_per_elem[i]
            );
        }
        // bitmap: flat ~1/8 byte per domain element regardless of density
        let bm = &p.index_profiles[3];
        for &r in &bm.bytes_per_elem {
            assert!((r - 0.125).abs() < 0.01, "bitmap rate {r}");
        }
        // raw value codec: exactly 4 bytes/value
        assert!((p.value_profiles[0].bytes_per_value - 4.0).abs() < 1e-9);
    }

    #[test]
    fn density_drives_distinct_choices() {
        let p = bytes_only_policy();
        let d = 1 << 16;
        // very sparse -> entry-proportional codec (raw/elias family);
        // near-dense -> domain-proportional (bitmap/rle) must win the
        // index slot since 4·p·d ≫ d/8 at p close to 1
        let sparse_pick = p.choose(d, d / 1000);
        let dense_pick = p.choose(d, d * 9 / 10);
        assert_ne!(sparse_pick.index, dense_pick.index, "{sparse_pick:?} vs {dense_pick:?}");
        assert!(
            dense_pick.index == "bitmap" || dense_pick.index == "rle",
            "dense pick {dense_pick:?}"
        );
    }

    #[test]
    fn measured_calibration_runs() {
        // smoke: the measuring constructor must work and produce
        // non-negative throughput estimates
        let p = CodecPolicy::calibrate(&["raw", "elias"], &["raw"], 3, Link::gbps(1.0), 2);
        for ip in &p.index_profiles {
            for &s in &ip.secs_per_elem {
                assert!(s >= 0.0);
            }
        }
        let c = p.choose(10_000, 100);
        assert!(!c.index.is_empty() && !c.value.is_empty());
    }

    #[test]
    fn hierarchical_hops_pick_distinct_codecs() {
        // the leader hop sees member density (very sparse), the inter
        // hop sees the node sum (~R× denser): with R·p ≈ 0.9 the node
        // sum is near-dense, so a domain-proportional index codec
        // (bitmap/rle) must win that hop while the member hop keeps an
        // entry-proportional one — same crossover the flat policy test
        // (`density_drives_distinct_choices`) pins
        let p = bytes_only_policy();
        let d = 1 << 16;
        let topo = crate::collective::Topology::new(2, 900);
        let hc = p.choose_hierarchical(d, d / 1000, topo, Link::gbps(10.0), Link::mbps(100.0));
        let inter = hc.inter.as_ref().expect("two nodes cross the boundary");
        assert_ne!(hc.leader.index, inter.index, "{hc:?}");
        assert!(
            inter.index == "bitmap" || inter.index == "rle",
            "node-sum hop should pick a domain-proportional index codec: {hc:?}"
        );
        // single-node grids: leader advice only — there is no inter hop
        let flat = crate::collective::Topology::flat(8);
        let hf = p.choose_hierarchical(d, d / 1000, flat, Link::gbps(10.0), Link::mbps(100.0));
        assert_eq!(hf.leader, p.choose_for(d, d / 1000, 8, Link::gbps(10.0)));
        assert!(hf.inter.is_none(), "1×n grid must not advise an inter codec");
    }

    #[test]
    fn measured_cost_source_feeds_back() {
        let mut p = bytes_only_policy();
        p.set_cost_source(CostSource::Measured);
        let d = 1 << 16;
        let nnz = d / 1000;
        // no observation yet: falls back to the formula — same pick as
        // an untouched formula policy
        assert_eq!(p.choose(d, nnz), bytes_only_policy().choose(d, nnz));
        // parse both spellings
        assert_eq!(CostSource::parse("measured"), Some(CostSource::Measured));
        assert_eq!(CostSource::parse("formula"), Some(CostSource::Formula));
        assert_eq!(CostSource::parse("nope"), None);
        // an expensive measured link: comm dominates, so the pick must
        // minimize estimated bytes among the candidates
        p.observe_comm(1000.0, 10.0); // 10 ms per byte
        let pick = p.choose(d, nnz);
        let (ip, vp) = (
            p.index_profiles.iter().find(|ip| ip.name == pick.index).unwrap(),
            p.value_profiles.iter().find(|vp| vp.name == pick.value).unwrap(),
        );
        let picked_bytes = p.estimate_bytes(ip, vp, d, nnz);
        for ip in &p.index_profiles {
            for vp in &p.value_profiles {
                assert!(
                    picked_bytes <= p.estimate_bytes(ip, vp, d, nnz) + 1e-9,
                    "measured-comm pick must be byte-minimal"
                );
            }
        }
        // the EMA moves with new observations, and garbage is ignored
        let before = p.comm_s(1.0);
        p.observe_comm(1000.0, 0.0);
        assert!(p.comm_s(1.0) < before);
        p.observe_comm(0.0, 5.0);
        p.observe_comm(f64::NAN, 5.0);
        p.observe_comm(1000.0, f64::NAN);
        assert!(p.comm_s(1.0) < before, "garbage observations must be ignored");
    }

    #[test]
    fn chain_candidates_calibrate_and_compete() {
        // registry-enumerated candidates (chains included) must all
        // calibrate; the policy then chooses among specs, and a pick is
        // always a buildable spec label
        let (idx, val) = default_candidates(false);
        assert!(idx.iter().any(|s| s == "rle+deflate"), "{idx:?}");
        let p = CodecPolicy::calibrate_bytes_only(&idx, &val, 7, Link::mbps(100.0), 4);
        assert_eq!(p.index_profiles.len(), idx.len());
        let d = 1 << 16;
        for nnz in [d / 1000, d / 10, d] {
            let c = p.choose(d, nnz);
            assert!(
                crate::compress::build_index_spec(&c.index, f64::NAN, 1).is_ok(),
                "{c:?}"
            );
            assert!(
                crate::compress::build_value_spec(&c.value, f64::NAN, 1).is_ok(),
                "{c:?}"
            );
        }
    }

    #[test]
    fn schedule_choice_is_model_minimal() {
        use crate::collective::Schedule;
        use crate::simnet::{chunked_rescatter_time, flat_schedule_time, SegWire};
        let p = bytes_only_policy();
        let w = SegWire::raw(0.5);
        let d = 1 << 16;
        for (nnz, workers) in [(d / 1000, 8usize), (d / 100, 4), (d / 10, 8)] {
            let link = Link::mbps(100.0);
            let (sched, chunks) = p.choose_schedule(d, nnz, workers, link);
            let picked = if sched == Schedule::ChunkedRescatter {
                assert!(chunks >= workers, "{sched:?} chunks={chunks}");
                chunked_rescatter_time(nnz as u64, d as u64, workers, chunks, link, w)
            } else {
                assert_eq!(chunks, 0, "{sched:?}");
                flat_schedule_time(sched, nnz as u64, d as u64, workers, link, w, true)
            };
            for other in Schedule::flat() {
                let t = if other == Schedule::ChunkedRescatter {
                    chunked_rescatter_time(nnz as u64, d as u64, workers, workers, link, w)
                } else {
                    flat_schedule_time(other, nnz as u64, d as u64, workers, link, w, true)
                };
                assert!(picked <= t + 1e-15, "{sched:?} beaten by {other:?}: {picked} vs {t}");
            }
        }
    }

    #[test]
    fn export_import_round_trips_choices() {
        let mut p = bytes_only_policy();
        p.observe_comm(1000.0, 0.5);
        let j = p.export_json();
        let back =
            CodecPolicy::import_json(&Json::parse(&j.to_string()).unwrap(), p.link, p.workers)
                .unwrap();
        assert_eq!(back.index_profiles.len(), p.index_profiles.len());
        assert_eq!(back.measured_secs_per_byte, p.measured_secs_per_byte);
        let d = 1 << 16;
        for nnz in [d / 1000, d / 10, d * 9 / 10] {
            assert_eq!(back.choose(d, nnz), p.choose(d, nnz));
        }
    }

    #[test]
    fn import_rejects_structural_damage() {
        let p = bytes_only_policy();
        let good = p.export_json().to_string();
        let (link, workers) = (p.link, p.workers);
        for bad in [
            "{}".to_string(),
            good.replace("\"densities\":[0.001,", "\"densities\":[0.002,"),
            good.replace("index_profiles", "index_profilez"),
            good.replace("\"has_perm\":false", "\"has_perm\":1"),
            good.replace("\"bytes_per_value\":4", "\"bytes_per_value\":-4"),
        ] {
            let v = match Json::parse(&bad) {
                Ok(v) => v,
                Err(_) => continue, // unparseable damage is rejected earlier
            };
            assert!(CodecPolicy::import_json(&v, link, workers).is_err(), "{bad}");
        }
    }

    #[test]
    fn interp_clamps_and_interpolates() {
        let ys = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        assert_eq!(interp(&ys, 0.0), 1.0);
        assert_eq!(interp(&ys, 2.0), 6.0);
        let mid = interp(&ys, (CAL_DENSITIES[0] + CAL_DENSITIES[1]) / 2.0);
        assert!(mid > 1.0 && mid < 2.0);
    }
}
