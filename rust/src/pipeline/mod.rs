//! Bucketed, overlapped gradient pipeline with cost-model codec
//! autotuning (DESIGN.md §6).
//!
//! Production stacks do not ship one tensor at a time with one static
//! codec: gradients are fused into size-capped buckets (SparCML's
//! stream fusion, Horovod/DDP bucketing), the codec is chosen per
//! payload, and encode overlaps with transfer. This subsystem brings
//! all three to the trainer:
//!
//! - [`bucket`] — the step-invariant [`BucketPlan`] plus fuse/unfuse
//!   kernels mapping per-tensor sparse payloads onto fused domains.
//! - [`autotune`] — [`CodecPolicy`]: startup-calibrated per-codec byte
//!   and throughput profiles combined with the simnet α–β link model
//!   into a per-bucket argmin codec choice.
//! - [`overlap`] — the double-buffered executor and the
//!   [`StepTimeline`] that folds measured encode seconds with modelled
//!   transfer seconds into serial vs. pipelined step time.
//!
//! [`GradientPipeline`] ties them together behind the API the trainer
//! drives: plan once, then per worker per bucket fuse → choose codec →
//! encode → decode, with the decoded fused tensor handed to the sparse
//! collective schedules (`collective::sparse`) as a single segment
//! stream.

pub mod autotune;
pub mod bucket;
pub mod overlap;

pub use autotune::{default_candidates, CodecChoice, CodecPolicy, CostSource, HierChoices};
pub use bucket::{fuse, fuse_dense, unfuse, Bucket, BucketPlan};
pub use overlap::{double_buffered, streamed, StepTimeline};

use crate::compress::{CodecRegistry, CodecSpec, CompressSpec, Container, DeepReduce};
use crate::simnet::Link;
use crate::tensor::SparseTensor;
use std::collections::BTreeMap;
use std::time::Instant;

/// One encoded bucket, ready for metering and the collective exchange.
pub struct EncodedBucket {
    /// what travels as the worker's upload (metered as
    /// `bytes_per_worker`)
    pub wire_bytes: u64,
    /// locally decoded payload over the fused domain — the collective's
    /// input (codec loss already applied, so error feedback sees it)
    pub decoded: SparseTensor,
    /// `index|value` label of the codec pair that ran
    pub choice_label: String,
    /// per-hop labels `(leader_hop, inter_hop)` the policy would pick
    /// on a two-level topology (`None` unless autotuning with a
    /// hierarchy configured; the inter label is `None` on single-node
    /// grids) — the leader hop ships member-density payloads on the
    /// fast link, the inter hop ships ~R× denser node sums on the slow
    /// one, so the picks often differ
    pub hier_choices: Option<(String, Option<String>)>,
    pub encode_s: f64,
    pub decode_s: f64,
    /// α–β modelled transfer time of `wire_bytes` on the pipeline link
    pub comm_model_s: f64,
}

/// The trainer-facing pipeline: a bucket plan plus the codec machinery
/// (static typed [`CompressSpec`] or autotuning policy with a cache of
/// built codec pairs — chains included).
pub struct GradientPipeline {
    plan: BucketPlan,
    static_codec: DeepReduce,
    static_label: String,
    /// the typed spec the static pair was built from; tuned candidates
    /// inherit matching stage parameters from it
    compress: CompressSpec,
    policy: Option<CodecPolicy>,
    tuned: BTreeMap<String, DeepReduce>,
    seed: u64,
    link: Link,
    workers: usize,
    /// two-level grid + per-class links for per-hop codec advice
    hier: Option<(crate::collective::Topology, Link, Link)>,
}

/// Candidate specs carry no explicit parameters; when the static spec
/// configures a stage the candidate also uses (e.g. a CLI
/// `bloom_p2(fpr=0.01)` static pair and the `bloom_p2` candidate),
/// the configured parameters carry over. Inheritance is applied to the
/// candidate list *before* [`CodecPolicy`] calibration (see
/// [`inherit_candidates`]), so the byte/throughput profiles describe
/// the codec that will actually run — a far-from-default inherited
/// parameter (say `bloom_p2(fpr=1e-9)`, whose filter outweighs raw
/// indices) can and should flip the pick. Earlier revisions calibrated
/// at default parameters and only inherited at build time, which skewed
/// the estimates the pick was based on.
fn inherit_params(spec: &mut CodecSpec, from: &CodecSpec) {
    for stage in &mut spec.stages {
        if stage.params.is_empty() {
            if let Some(src) =
                from.stages.iter().find(|s| s.name == stage.name && !s.params.is_empty())
            {
                stage.params = src.params.clone();
            }
        }
    }
}

/// Rewrite each candidate spec to its post-inheritance canonical label
/// against the static spec, so calibration profiles (and the labels the
/// policy reports) name the exact codec `build_candidate` will build.
/// Unparsable entries pass through untouched — calibration will surface
/// the error with the offending name.
fn inherit_candidates(specs: Vec<String>, from: &CodecSpec) -> Vec<String> {
    specs
        .into_iter()
        .map(|s| match CodecSpec::parse(&s) {
            Ok(mut spec) => {
                inherit_params(&mut spec, from);
                spec.label()
            }
            Err(_) => s,
        })
        .collect()
}

/// Build one autotune-candidate codec pair through the registry.
fn build_candidate(
    static_spec: &CompressSpec,
    choice: &CodecChoice,
    seed: u64,
) -> anyhow::Result<DeepReduce> {
    let registry = CodecRegistry::global();
    let mut idx = CodecSpec::parse(&choice.index)?;
    inherit_params(&mut idx, &static_spec.index);
    let mut val = CodecSpec::parse(&choice.value)?;
    inherit_params(&mut val, &static_spec.value);
    Ok(DeepReduce::new(registry.build_index(&idx, seed)?, registry.build_value(&val, seed)?))
}

impl GradientPipeline {
    /// Build the pipeline. `members` lists the compressible tensors as
    /// `(tensor id, element count)` in exchange order; `bucket_bytes`
    /// caps fused buckets (0 = one bucket per tensor, the legacy
    /// per-tensor path); `autotune` turns the per-bucket codec policy
    /// on (off = always the static `compress` pair).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        members: &[(usize, usize)],
        bucket_bytes: usize,
        autotune: bool,
        error_feedback: bool,
        compress: &CompressSpec,
        seed: u64,
        link: Link,
        workers: usize,
    ) -> anyhow::Result<Self> {
        let plan = BucketPlan::plan(members, bucket_bytes);
        let registry = CodecRegistry::global();
        let static_codec = DeepReduce::new(
            registry.build_index(&compress.index, seed)?,
            registry.build_value(&compress.value, seed)?,
        );
        let policy = if autotune {
            let (idx, val) = default_candidates(error_feedback);
            // calibrate at post-inheritance parameters: the static
            // spec's explicit params apply to any candidate sharing the
            // stage, and the profiles must describe that configuration
            let idx = inherit_candidates(idx, &compress.index);
            let val = inherit_candidates(val, &compress.value);
            Some(CodecPolicy::calibrate(&idx, &val, seed, link, workers))
        } else {
            None
        };
        Ok(Self {
            plan,
            static_codec,
            static_label: compress.label(),
            compress: compress.clone(),
            policy,
            tuned: BTreeMap::new(),
            seed,
            link,
            workers,
            hier: None,
        })
    }

    /// Warm-start construction: like [`GradientPipeline::new`] with
    /// `autotune` on, but the calibration sweep is replaced by an
    /// already-built [`CodecPolicy`] — typically rebound from a
    /// persisted `PROFILE_*.json` (`crate::service::profiles`), which
    /// is what makes a returning service job's first step cheap.
    #[allow(clippy::too_many_arguments)]
    pub fn with_policy(
        members: &[(usize, usize)],
        bucket_bytes: usize,
        compress: &CompressSpec,
        policy: CodecPolicy,
        seed: u64,
        link: Link,
        workers: usize,
    ) -> anyhow::Result<Self> {
        let plan = BucketPlan::plan(members, bucket_bytes);
        let registry = CodecRegistry::global();
        let static_codec = DeepReduce::new(
            registry.build_index(&compress.index, seed)?,
            registry.build_value(&compress.value, seed)?,
        );
        Ok(Self {
            plan,
            static_codec,
            static_label: compress.label(),
            compress: compress.clone(),
            policy: Some(policy),
            tuned: BTreeMap::new(),
            seed,
            link,
            workers,
            hier: None,
        })
    }

    /// Teach the autotuner the two-level grid: per bucket it will also
    /// report the codec pair each hop of a hierarchical exchange wants
    /// ([`EncodedBucket::hier_choices`]); the leader hop is costed on
    /// `intra`, the inter hop on `inter`. No-op unless autotuning.
    pub fn set_hierarchy(
        &mut self,
        topo: crate::collective::Topology,
        intra: Link,
        inter: Link,
    ) {
        self.hier = Some((topo, intra, inter));
    }

    pub fn plan(&self) -> &BucketPlan {
        &self.plan
    }

    pub fn autotuning(&self) -> bool {
        self.policy.is_some()
    }

    /// Switch the autotuner's comm term between the α–β formula and
    /// measured virtual-time feedback (CLI `--autotune-cost`). No-op
    /// unless autotuning.
    pub fn set_cost_source(&mut self, source: CostSource) {
        if let Some(policy) = self.policy.as_mut() {
            policy.set_cost_source(source);
        }
    }

    /// Feed one measured exchange back into the autotuner: the trainer
    /// calls this after each virtual-fabric step with the per-worker
    /// container bytes and the measured virtual collective seconds
    /// (see [`CodecPolicy::observe_comm`]). No-op unless autotuning.
    pub fn observe_comm(&mut self, bytes: f64, secs: f64) {
        if let Some(policy) = self.policy.as_mut() {
            policy.observe_comm(bytes, secs);
        }
    }

    /// The codec pair for a bucket of domain `d` with `nnz` entries.
    /// The returned label is the *built* codec's full spec label
    /// (inherited stage parameters included), so `autotune_choices`
    /// and the container header always name the same pipeline.
    fn codec_for(&mut self, d: usize, nnz: usize) -> (String, &DeepReduce) {
        let choice = match &self.policy {
            None => return (self.static_label.clone(), &self.static_codec),
            Some(policy) => policy.choose(d, nnz),
        };
        let label = choice.label();
        if label == self.static_label {
            return (label, &self.static_codec);
        }
        // steady state (cache hit) is allocation-free beyond the label
        if !self.tuned.contains_key(&label) {
            let built = build_candidate(&self.compress, &choice, self.seed)
                .expect("registry-enumerated candidate builds");
            self.tuned.insert(label.clone(), built);
        }
        let codec = self.tuned.get(&label).expect("present: just checked or inserted");
        (format!("{}|{}", codec.index.name(), codec.value.name()), codec)
    }

    /// Fuse, pick a codec, encode, and locally decode one bucket.
    /// `parts[j]` is the sparse payload of `bucket.tensors[j]` over its
    /// own domain; `dense_parts[j]` is the member's dense reference
    /// gradient. The fused dense copy is built only when the chosen
    /// index codec is lossy (Bloom reads original values at
    /// false-positive positions) — lossless codecs take the zero-copy
    /// path.
    pub fn encode_bucket(
        &mut self,
        bucket: &Bucket,
        parts: &[&SparseTensor],
        dense_parts: &[&[f32]],
    ) -> anyhow::Result<EncodedBucket> {
        let fused = fuse(bucket, parts);
        let hier_choices = match (&self.policy, &self.hier) {
            (Some(policy), Some(&(topo, intra, inter))) => {
                let hc =
                    policy.choose_hierarchical(fused.dense_len(), fused.nnz(), topo, intra, inter);
                Some((hc.leader.label(), hc.inter.map(|c| c.label())))
            }
            _ => None,
        };
        let (choice_label, codec) = self.codec_for(fused.dense_len(), fused.nnz());
        let fused_dense: Option<Vec<f32>> = if codec.index.lossless() {
            None
        } else {
            Some(fuse_dense(bucket, dense_parts))
        };
        let t0 = Instant::now();
        let container: Container = codec.encode(&fused, fused_dense.as_deref());
        let encode_s = t0.elapsed().as_secs_f64();
        let wire_bytes = container.wire_bytes() as u64;
        let t1 = Instant::now();
        let decoded = codec.decode(&container)?;
        let decode_s = t1.elapsed().as_secs_f64();
        let comm_model_s =
            crate::simnet::allgather_time(wire_bytes, self.workers, self.link);
        Ok(EncodedBucket {
            wire_bytes,
            decoded,
            choice_label,
            hier_choices,
            encode_s,
            decode_s,
            comm_model_s,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsify::Sparsifier;
    use crate::util::prng::Rng;
    use crate::util::testkit::gradient_like;

    fn parts_for(g: &[f32], ratio: f64) -> SparseTensor {
        let mut topk = crate::sparsify::TopK::new(ratio);
        topk.sparsify(g)
    }

    #[test]
    fn static_pipeline_roundtrips_fused_buckets() {
        let mut rng = Rng::new(0xF0F0);
        let sizes = [(0usize, 3000usize), (1, 1200), (2, 2500)];
        let mut pipe = GradientPipeline::new(
            &sizes,
            1 << 20, // everything fuses into one bucket
            false,
            true,
            &CompressSpec::raw(),
            1,
            Link::mbps(100.0),
            4,
        )
        .unwrap();
        assert_eq!(pipe.plan().len(), 1);
        assert!(!pipe.autotuning());
        let grads: Vec<Vec<f32>> = sizes.iter().map(|&(_, s)| gradient_like(&mut rng, s)).collect();
        let sparse: Vec<SparseTensor> = grads.iter().map(|g| parts_for(g, 0.05)).collect();
        let bucket = pipe.plan().buckets[0].clone();
        let parts: Vec<&SparseTensor> = sparse.iter().collect();
        let dense_parts: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let enc = pipe.encode_bucket(&bucket, &parts, &dense_parts).unwrap();
        assert_eq!(enc.choice_label, "raw|raw");
        assert!(enc.wire_bytes > 0);
        assert!(enc.comm_model_s > 0.0);
        // raw|raw is lossless: the decoded fused payload must unfuse
        // back to the exact inputs
        let back = unfuse(&bucket, &enc.decoded);
        assert_eq!(back, sparse);
    }

    #[test]
    fn autotuned_pipeline_caches_and_labels() {
        let sizes = [(0usize, 4000usize)];
        let mut pipe = GradientPipeline::new(
            &sizes,
            0,
            true,
            false, // no EF -> lossless candidates only
            &CompressSpec::raw(),
            1,
            Link::mbps(100.0),
            4,
        )
        .unwrap();
        assert!(pipe.autotuning());
        let mut rng = Rng::new(3);
        let g = gradient_like(&mut rng, 4000);
        let sp = parts_for(&g, 0.02);
        let bucket = pipe.plan().buckets[0].clone();
        let enc = pipe.encode_bucket(&bucket, &[&sp], &[g.as_slice()]).unwrap();
        assert!(enc.choice_label.contains('|'), "{}", enc.choice_label);
        // lossless candidates: decode must equal input exactly
        assert_eq!(unfuse(&bucket, &enc.decoded), vec![sp.clone()]);
        // second call with the same shape reuses the cached codec
        let enc2 = pipe.encode_bucket(&bucket, &[&sp], &[g.as_slice()]).unwrap();
        assert_eq!(enc2.choice_label, enc.choice_label);
        assert!(pipe.tuned.len() <= 1);
        // no hierarchy configured: no per-hop advice
        assert!(enc.hier_choices.is_none());
    }

    #[test]
    fn calibration_happens_at_inherited_params() {
        // the static spec pins a far-from-default fpr: ~43 bits/entry
        // of Bloom filter (power-of-2 rounded) vs 32 bits for raw
        // indices, so at the *inherited* parameters raw must win the
        // index slot
        let spec = CompressSpec::parse("bloom_p2(fpr=1e-9)", "raw").unwrap();
        let idx = inherit_candidates(vec!["raw".into(), "bloom_p2".into()], &spec.index);
        assert_eq!(idx, vec!["raw".to_string(), "bloom_p2(fpr=1e-9)".to_string()]);
        let d = 1 << 14;
        let nnz = d / 100;
        let tuned =
            CodecPolicy::calibrate_bytes_only(&idx, &["raw"], 7, Link::mbps(100.0), 4);
        assert_eq!(tuned.choose(d, nnz).index, "raw");
        // the pre-fix behaviour — calibrating the bare candidate at its
        // default fpr (0.001, ~14 bits/entry) — picks the Bloom filter
        // and would then build and ship a 3x larger one than estimated
        let stale = CodecPolicy::calibrate_bytes_only(
            &["raw", "bloom_p2"],
            &["raw"],
            7,
            Link::mbps(100.0),
            4,
        );
        assert_eq!(stale.choose(d, nnz).index, "bloom_p2");
        // candidates whose stages the static spec does not configure
        // pass through unchanged
        let plain = inherit_candidates(vec!["rle+deflate".into()], &spec.index);
        assert_eq!(plain, vec!["rle+deflate".to_string()]);
    }

    #[test]
    fn static_chain_spec_drives_the_pipeline() {
        let sizes = [(0usize, 3000usize)];
        let mut pipe = GradientPipeline::new(
            &sizes,
            0,
            false,
            true,
            &CompressSpec::parse("rle+deflate", "raw").unwrap(),
            1,
            Link::mbps(100.0),
            4,
        )
        .unwrap();
        let mut rng = Rng::new(5);
        let g = gradient_like(&mut rng, 3000);
        let sp = parts_for(&g, 0.05);
        let bucket = pipe.plan().buckets[0].clone();
        let enc = pipe.encode_bucket(&bucket, &[&sp], &[g.as_slice()]).unwrap();
        // the full chain label is what the metrics/bench artifacts see
        assert_eq!(enc.choice_label, "rle+deflate|raw");
        // chain is lossless end to end
        assert_eq!(unfuse(&bucket, &enc.decoded), vec![sp]);
    }

    #[test]
    fn warm_started_pipeline_autotunes_without_calibrating() {
        // build a policy once (the "cold" job), round-trip it through
        // the profile JSON fragment, and hand it to with_policy — the
        // warm pipeline must make the same picks with no sweep of its own
        let (idx, val) = default_candidates(false);
        let cold = CodecPolicy::calibrate_bytes_only(&idx, &val, 7, Link::mbps(100.0), 4);
        let rebound =
            CodecPolicy::import_json(&cold.export_json(), Link::mbps(100.0), 4).unwrap();
        let sizes = [(0usize, 4000usize)];
        let mut pipe = GradientPipeline::with_policy(
            &sizes,
            0,
            &CompressSpec::raw(),
            rebound,
            1,
            Link::mbps(100.0),
            4,
        )
        .unwrap();
        assert!(pipe.autotuning());
        let d = 4000;
        let nnz = 80;
        assert_eq!(
            pipe.policy.as_ref().unwrap().choose(d, nnz).label(),
            cold.choose(d, nnz).label(),
            "rebound policy makes the cold policy's picks"
        );
        let mut rng = Rng::new(3);
        let g = gradient_like(&mut rng, d);
        let sp = parts_for(&g, 0.02);
        let bucket = pipe.plan().buckets[0].clone();
        let enc = pipe.encode_bucket(&bucket, &[&sp], &[g.as_slice()]).unwrap();
        assert_eq!(unfuse(&bucket, &enc.decoded), vec![sp], "lossless end to end");
    }

    #[test]
    fn hierarchy_yields_per_hop_advice() {
        let sizes = [(0usize, 4000usize)];
        let mut pipe = GradientPipeline::new(
            &sizes,
            0,
            true,
            false,
            &CompressSpec::raw(),
            1,
            Link::mbps(100.0),
            4,
        )
        .unwrap();
        pipe.set_hierarchy(
            crate::collective::Topology::new(2, 2),
            Link::gbps(10.0),
            Link::mbps(100.0),
        );
        let mut rng = Rng::new(3);
        let g = gradient_like(&mut rng, 4000);
        let sp = parts_for(&g, 0.02);
        let bucket = pipe.plan().buckets[0].clone();
        let enc = pipe.encode_bucket(&bucket, &[&sp], &[g.as_slice()]).unwrap();
        let (leader, inter) = enc.hier_choices.expect("hierarchy configured");
        let inter = inter.expect("2-node grid has an inter hop");
        assert!(leader.contains('|') && inter.contains('|'), "{leader} / {inter}");
    }
}
